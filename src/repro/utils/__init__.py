from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_norm_sq,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_stack,
    tree_unstack,
    tree_where,
    tree_size,
    tree_ravel,
    tree_any_nan,
)
from repro.utils.registry import Registry
