"""Pytree vector-space helpers.

The AFTO core treats each level's variable block (x1, x2, x3, z_i, duals,
cut coefficients) as an element of a vector space represented by an
arbitrary pytree.  These helpers implement the handful of vector-space
operations the algorithm needs, preserving structure (and therefore
sharding) instead of flattening to a single dense vector.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """b + s * a, elementwise over matching pytrees."""
    return jax.tree.map(lambda x, y: y + s * x, a, b)


def tree_dot(a, b):
    """Full inner product <a, b> across every leaf (f32 accumulate)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def tree_norm_sq(a):
    return tree_dot(a, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_where(mask_scalar, a, b):
    """jnp.where with a scalar (or broadcastable) predicate over pytrees."""
    return jax.tree.map(lambda x, y: jnp.where(mask_scalar, x, y), a, b)


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of tree_stack: returns a list of n pytrees."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_size(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_ravel(a):
    """Concatenate all leaves into one 1-D f32 vector (host/test helper)."""
    leaves = [jnp.ravel(x).astype(jnp.float32) for x in jax.tree.leaves(a)]
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate(leaves)


def tree_any_nan(a):
    leaves = [jnp.any(~jnp.isfinite(x)) for x in jax.tree.leaves(a)
              if jnp.issubdtype(x.dtype, jnp.inexact)]
    if not leaves:
        return jnp.bool_(False)
    return jnp.any(jnp.stack(leaves))
