"""Unified decoder stack covering every assigned architecture.

One code path handles dense / GQA / sliding-window attention, MoE,
Mamba, mLSTM/sLSTM mixers, and the Whisper encoder-decoder — selected by
ModelConfig.stages.  The layer loop runs either as `lax.scan` over the
stacked (R, ...) parameters of each stage (compact HLO — training and
smoke tests) or Python-unrolled (`unroll=True` — the dry-run path, so
`compiled.cost_analysis()` counts every layer instead of one scan body).

Modes:
  train   : full-sequence forward, returns logits (+ MoE aux loss)
  prefill : full-sequence forward that also seeds the decode cache
  decode  : one token per call against the cache (serve_step)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.config import BlockSpec, ModelConfig, Stage
from repro.models.layers import (dense_init, dtype_of, embed_init,
                                 glu_mlp_apply, glu_mlp_init, rms_norm,
                                 softmax_cross_entropy)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _block_init(cfg: ModelConfig, spec: BlockSpec, key):
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.zeros((d,), dt),
                         "norm2": jnp.zeros((d,), dt)}
    if spec.mixer == "attn":
        p["attn"] = attn_lib.attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim, dt,
                                       cross=spec.cross_attn,
                                       qk_norm=spec.qk_norm)
        if spec.cross_attn:
            p["norm_x"] = jnp.zeros((d,), dt)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_lib.mamba_init(ks[0], d, cfg.ssm_expand,
                                          cfg.ssm_d_state, cfg.ssm_conv, dt)
    elif spec.mixer == "mlstm":
        p["mlstm"] = xlstm_lib.mlstm_init(ks[0], d, cfg.n_heads,
                                          cfg.head_dim, dt)
    elif spec.mixer == "slstm":
        p["slstm"] = xlstm_lib.slstm_init(ks[0], d, cfg.n_heads,
                                          cfg.head_dim, dt)
    if spec.mlp == "dense":
        p["mlp"] = glu_mlp_init(ks[1], d, cfg.d_ff, dt)
    elif spec.mlp == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], d, cfg.d_ff, cfg.n_experts, dt)
    return p


def _stage_init(cfg: ModelConfig, stage: Stage, key):
    """Stack per-pattern-position params over the stage's repeats."""
    out = {}
    for i, spec in enumerate(stage.pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), stage.repeats)
        out[f"pos{i}"] = jax.vmap(
            lambda k: _block_init(cfg, spec, k))(keys)
    return out


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    cfg.validate()
    dt = dtype_of(cfg.dtype)
    k_embed, k_stages, k_enc, k_head, k_pos = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                       dt)
    params["stages"] = [
        _stage_init(cfg, st, jax.random.fold_in(k_stages, i))
        for i, st in enumerate(cfg.stages)]
    if cfg.is_encoder_decoder:
        params["encoder"] = [
            _stage_init(cfg, st, jax.random.fold_in(k_enc, i))
            for i, st in enumerate(cfg.encoder_stages)]
        params["enc_pos"] = (jax.random.normal(
            k_pos, (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    return params


# ---------------------------------------------------------------------------
# single block forward (full sequence)
# ---------------------------------------------------------------------------

def _effective_window(cfg: ModelConfig, spec: BlockSpec, ctx_len: int) -> int:
    if spec.window:
        return spec.window
    if (cfg.long_context_window
            and ctx_len > cfg.long_context_threshold):
        return cfg.long_context_window
    return 0


def _block_fwd(cfg: ModelConfig, spec: BlockSpec, bp, x, positions,
               enc_out=None, collect_kv: bool = False):
    """Returns (x, aux_loss, kv_or_state_for_cache)."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        window = _effective_window(cfg, spec, x.shape[1])
        out, (k, v) = attn_lib.self_attention(
            bp["attn"], h, positions, n_kv_heads=cfg.n_kv_heads,
            rope_theta=cfg.rope_theta, causal=spec.causal, window=window,
            qk_norm=spec.qk_norm, norm_eps=cfg.norm_eps,
            impl=cfg.attn_impl, block_q=cfg.attn_block_q,
            block_k=cfg.attn_block_k)
        if collect_kv:
            kv = {"k": k, "v": v}
        x = x + out
        if spec.cross_attn:
            hx = rms_norm(x, bp["norm_x"], cfg.norm_eps)
            enc_kv = attn_lib.encode_kv(bp["attn"], enc_out)
            x = x + attn_lib.cross_attention(bp["attn"], hx, enc_kv)
            if collect_kv:
                kv["xk"], kv["xv"] = enc_kv
    elif spec.mixer == "mamba":
        out, state = mamba_lib.mamba_apply(bp["mamba"], h,
                                           chunk=cfg.ssm_chunk)
        if collect_kv:
            kv = state
        x = x + out
    elif spec.mixer == "mlstm":
        out, state = xlstm_lib.mlstm_apply(bp["mlstm"], h,
                                           chunk=cfg.mlstm_chunk)
        if collect_kv:
            kv = state
        x = x + out
    elif spec.mixer == "slstm":
        out, state = xlstm_lib.slstm_apply(bp["slstm"], h)
        if collect_kv:
            kv = state
        x = x + out

    if spec.mlp == "dense":
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        x = x + glu_mlp_apply(bp["mlp"], h, cfg.act)
    elif spec.mlp == "moe":
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        out, aux = moe_lib.moe_apply(bp["moe"], h, cfg.top_k,
                                     cfg.capacity_factor, cfg.act)
        x = x + out
    return x, aux, kv


# ---------------------------------------------------------------------------
# stage loops (scan or unrolled)
# ---------------------------------------------------------------------------

def _run_stages(cfg: ModelConfig, stages_params, stages_cfg, x, positions,
                enc_out=None, unroll: bool = False,
                collect_kv: bool = False, remat: bool = False):
    """Returns (x, total_aux, caches) — caches is a list parallel to
    stages, each {posN: stacked-over-repeats cache} (or None)."""
    total_aux = jnp.zeros((), jnp.float32)
    caches: List[Optional[dict]] = []

    def block_fwd(spec, bp, x, positions, enc_out, collect):
        if remat and not collect:
            return jax.checkpoint(
                lambda bp_, x_: _block_fwd(cfg, spec, bp_, x_, positions,
                                           enc_out, False))(bp, x)
        return _block_fwd(cfg, spec, bp, x, positions, enc_out, collect)

    for st_params, st in zip(stages_params, stages_cfg):
        st_cache: Dict[str, Any] = {}
        if unroll or collect_kv:
            # python loop (dry-run exactness / cache collection)
            per_pos_caches: Dict[str, List] = {f"pos{i}": []
                                               for i in range(len(st.pattern))}
            for r in range(st.repeats):
                for i, spec in enumerate(st.pattern):
                    bp = jax.tree.map(lambda a: a[r], st_params[f"pos{i}"])
                    x, aux, kv = block_fwd(spec, bp, x, positions,
                                           enc_out, collect_kv)
                    total_aux = total_aux + aux
                    if collect_kv:
                        per_pos_caches[f"pos{i}"].append(kv)
            if collect_kv:
                for k, lst in per_pos_caches.items():
                    st_cache[k] = jax.tree.map(
                        lambda *xs: jnp.stack(xs, 0), *lst)
        else:
            def body(carry, rp):
                xc, auxc = carry
                for i, spec in enumerate(st.pattern):
                    xc, aux, _ = block_fwd(spec, rp[f"pos{i}"], xc,
                                           positions, enc_out, False)
                    auxc = auxc + aux
                return (xc, auxc), None

            (x, total_aux), _ = jax.lax.scan(body, (x, total_aux),
                                             st_params)
        caches.append(st_cache if collect_kv else None)
    return x, total_aux, caches


# ---------------------------------------------------------------------------
# public forward passes
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, frames, unroll: bool = False):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend): frames (B, T_enc, d)."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1])[None], frames.shape[:2])
    x, _, _ = _run_stages(cfg, params["encoder"], cfg.encoder_stages, x,
                          positions, unroll=unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _logits(cfg: ModelConfig, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def forward(cfg: ModelConfig, params, tokens, frames=None,
            unroll: bool = False, collect_kv: bool = False,
            remat: bool = False, embed_perturbation=None):
    """tokens: (B,S) int32 -> logits (B,S,V).

    frames: (B, T_enc, d) for encoder-decoder / frame-frontend archs.
    embed_perturbation: optional (B,S,d) added to the token embeddings —
    the trilevel robust-HPO adversarial variable x2 enters here.
    Returns (logits, aux_loss, caches)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if embed_perturbation is not None:
        x = x + embed_perturbation.astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                                 tokens.shape)
    enc_out = None
    if cfg.is_encoder_decoder:
        assert frames is not None, f"{cfg.name} needs encoder frames"
        enc_out = encode(cfg, params, frames, unroll=unroll)
    x, aux, caches = _run_stages(cfg, params["stages"], cfg.stages, x,
                                 positions, enc_out, unroll, collect_kv,
                                 remat)
    return _logits(cfg, params, x), aux, caches


def train_loss(cfg: ModelConfig, params, tokens, frames=None,
               unroll: bool = False, remat: bool = False,
               embed_perturbation=None):
    """Next-token CE + MoE aux loss.

    embed_perturbation, if given, must match the model INPUT length
    (tokens.shape[1] - 1)."""
    logits, aux, _ = forward(cfg, params, tokens[:, :-1], frames, unroll,
                             remat=remat,
                             embed_perturbation=embed_perturbation)
    ce = softmax_cross_entropy(logits, tokens[:, 1:])
    return ce + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def _block_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                      max_seq: int, dtype):
    if spec.mixer == "attn":
        window = _effective_window(cfg, spec, max_seq)
        cap = min(max_seq, window) if window else max_seq
        c = attn_lib.init_kv_cache(batch, cfg.n_kv_heads, cfg.head_dim,
                                   cap, dtype)
        if spec.cross_attn:
            c["xk"] = jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dtype)
            c["xv"] = jnp.zeros_like(c["xk"])
        return c
    if spec.mixer == "mamba":
        return mamba_lib.init_mamba_state(batch, cfg.d_model,
                                          cfg.ssm_expand, cfg.ssm_d_state,
                                          cfg.ssm_conv, dtype)
    if spec.mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(batch, cfg.n_heads, cfg.head_dim)
    if spec.mixer == "slstm":
        return xlstm_lib.init_slstm_state(batch, cfg.n_heads, cfg.head_dim)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode cache for the whole stack, stacked (R, B, ...) per stage."""
    dt = dtype_of(cfg.dtype)
    caches = []
    for st in cfg.stages:
        st_cache = {}
        for i, spec in enumerate(st.pattern):
            one = _block_cache_init(cfg, spec, batch, max_seq, dt)
            st_cache[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (st.repeats,) + a.shape), one)
        caches.append(st_cache)
    return caches


def _block_decode(cfg: ModelConfig, spec: BlockSpec, bp, x, cache, cur_pos):
    """x: (B,1,d); cache: this block's cache. Returns (x, new_cache)."""
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        window = _effective_window(cfg, spec, int(cache["k"].shape[1]) + 1) \
            if not spec.window else spec.window
        # capacity already encodes the window; pass window for masking
        cap = cache["k"].shape[1]
        out, new_kv = attn_lib.decode_attention(
            bp["attn"], h, {k: cache[k] for k in ("k", "v", "pos")},
            cur_pos, rope_theta=cfg.rope_theta,
            window=window if window and window <= cap else 0,
            qk_norm=spec.qk_norm, norm_eps=cfg.norm_eps)
        x = x + out
        new_cache = dict(new_kv)
        if spec.cross_attn:
            hx = rms_norm(x, bp["norm_x"], cfg.norm_eps)
            x = x + attn_lib.cross_attention(bp["attn"], hx,
                                             (cache["xk"], cache["xv"]))
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    elif spec.mixer == "mamba":
        out, new_cache = mamba_lib.mamba_decode(bp["mamba"], h, cache)
        x = x + out
    elif spec.mixer == "mlstm":
        out, new_cache = xlstm_lib.mlstm_decode(bp["mlstm"], h, cache)
        x = x + out
    elif spec.mixer == "slstm":
        out, new_cache = xlstm_lib.slstm_decode(bp["slstm"], h, cache)
        x = x + out

    if spec.mlp == "dense":
        hh = rms_norm(x, bp["norm2"], cfg.norm_eps)
        x = x + glu_mlp_apply(bp["mlp"], hh, cfg.act)
    elif spec.mlp == "moe":
        hh = rms_norm(x, bp["norm2"], cfg.norm_eps)
        out, _ = moe_lib.moe_apply(bp["moe"], hh, cfg.top_k,
                                   cfg.capacity_factor, cfg.act)
        x = x + out
    return x, new_cache


def decode_step(cfg: ModelConfig, params, caches, tokens, cur_pos,
                unroll: bool = False):
    """One serve step: tokens (B,1) int32, cur_pos (B,) absolute position.

    Returns (logits (B,1,V), new_caches)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    new_caches = []
    for st_params, st_cache, st in zip(params["stages"], caches, cfg.stages):
        if unroll:
            new_st: Dict[str, List] = {f"pos{i}": []
                                       for i in range(len(st.pattern))}
            for r in range(st.repeats):
                for i, spec in enumerate(st.pattern):
                    bp = jax.tree.map(lambda a: a[r], st_params[f"pos{i}"])
                    cc = jax.tree.map(lambda a: a[r], st_cache[f"pos{i}"])
                    x, nc = _block_decode(cfg, spec, bp, x, cc, cur_pos)
                    new_st[f"pos{i}"].append(nc)
            new_caches.append({
                k: jax.tree.map(lambda *xs: jnp.stack(xs, 0), *v)
                for k, v in new_st.items()})
        else:
            def body(xc, rp_and_cache):
                rp, cc = rp_and_cache
                ncs = {}
                for i, spec in enumerate(st.pattern):
                    xc, nc = _block_decode(cfg, spec, rp[f"pos{i}"], xc,
                                           cc[f"pos{i}"], cur_pos)
                    ncs[f"pos{i}"] = nc
                return xc, ncs

            x, new_st = jax.lax.scan(body, x, (st_params, st_cache))
            new_caches.append(new_st)
    return _logits(cfg, params, x), new_caches


def prefill(cfg: ModelConfig, params, tokens, frames=None,
            unroll: bool = False, max_seq: Optional[int] = None):
    """Full-context forward that seeds the decode cache.

    max_seq: total capacity to allocate (prompt + planned generation);
    defaults to prompt_len + 1 (a single decode step).  Returns
    (logits, caches) positioned so the next decode_step uses
    cur_pos = tokens.shape[1]."""
    b, s = tokens.shape
    logits, _, kv = forward(cfg, params, tokens, frames, unroll=unroll,
                            collect_kv=True)
    caches = init_cache(cfg, b, max_seq or (s + 1))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = []
    for st_cache, st_kv, st in zip(caches, kv, cfg.stages):
        st_out = {}
        for i, spec in enumerate(st.pattern):
            blank = st_cache[f"pos{i}"]
            got = st_kv[f"pos{i}"]
            if spec.mixer == "attn":
                def seed(blank_r, got_r):
                    c = attn_lib.seed_kv_cache(
                        {k: blank_r[k] for k in ("k", "v", "pos")},
                        got_r["k"], got_r["v"], positions)
                    if spec.cross_attn:
                        c["xk"], c["xv"] = got_r["xk"], got_r["xv"]
                    return c
                st_out[f"pos{i}"] = jax.vmap(seed)(blank, got)
            else:
                st_out[f"pos{i}"] = got    # recurrent states are the cache
        out.append(st_out)
    return logits, out


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
