"""Mamba selective-SSM sequence mixer (Jamba's recurrent block).

Training/prefill uses a chunked parallel scan: sequential `lax.scan` over
chunks with an associative prefix-scan inside each chunk, so activation
memory is O(B * chunk * d_inner * d_state) instead of O(B * S * ...).
Decode carries (conv_state, ssm_state) and costs O(1) per token — this is
what makes jamba's long_500k shape natural.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_init(key, d, expand: int, d_state: int, d_conv: int, dtype):
    di = expand * d
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                              (di, 1)))   # S4D-real init
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype, fan_in=d),
        "conv_w": dense_init(ks[1], (d_conv, di), dtype, fan_in=d_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "xproj": dense_init(ks[2], (di, 2 * d_state + 1), dtype, fan_in=di),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": a_init.astype(jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], (di, d), dtype, fan_in=di),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,di), w: (K,di).

    state: (B,K-1,di) carried context (decode/chunk boundary) or None.
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, di)
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _ssm_params(params, xc):
    """xc: (B,L,di) post-conv activations -> (delta_a, delta_bx, c)."""
    proj = jnp.einsum("bld,dp->blp", xc, params["xproj"])
    d_state = (proj.shape[-1] - 1) // 2
    # rank-1 dt: shared scalar per position, per-channel bias (cf. mamba's
    # low-rank dt projection), softplus-positive
    dt = jax.nn.softplus(proj[..., 0][..., None] + params["dt_bias"])
    bmat = proj[..., 1:1 + d_state].astype(jnp.float32)       # (B,L,dS)
    cmat = proj[..., 1 + d_state:].astype(jnp.float32)        # (B,L,dS)
    a = -jnp.exp(params["a_log"])                             # (di,dS)
    dt = dt.astype(jnp.float32)                               # (B,L,di)
    delta_a = jnp.exp(dt[..., None] * a[None, None])          # (B,L,di,dS)
    delta_bx = (dt * xc.astype(jnp.float32))[..., None] \
        * bmat[..., None, :]                                  # (B,L,di,dS)
    return delta_a, delta_bx, cmat


def _chunk_scan(delta_a, delta_bx, h0):
    """Associative scan within one chunk with carry-in h0.

    Composition: (a2,b2) o (a1,b1) = (a1*a2, a2*b1 + b2)."""
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (delta_a, delta_bx),
                                            axis=1)
    h = a_cum * h0[:, None] + b_cum                  # (B,L,di,dS)
    return h, h[:, -1]


def mamba_apply(params, x, chunk: int = 256, state=None
                ) -> Tuple[jnp.ndarray, dict]:
    """x: (B,S,d) -> (y (B,S,d), state dict). S must be chunk-divisible
    (the model pads); decode calls with S=1 via `mamba_decode`."""
    b, s, d = x.shape
    xz = jnp.einsum("bsd,dp->bsp", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                # (B,S,di) each
    di = xi.shape[-1]
    d_state = params["a_log"].shape[1]

    if state is None:
        conv_state = jnp.zeros((b, params["conv_w"].shape[0] - 1, di),
                               x.dtype)
        ssm_state = jnp.zeros((b, di, d_state), jnp.float32)
    else:
        conv_state, ssm_state = state["conv"], state["ssm"]

    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks
    xi_c = xi.reshape(b, n_chunks, chunk, di).transpose(1, 0, 2, 3)

    def body(carry, xc_chunk):
        conv_st, h0 = carry
        xc, conv_st = _causal_conv(xc_chunk, params["conv_w"],
                                   params["conv_b"], conv_st)
        xc = jax.nn.silu(xc)
        da, dbx, cmat = _ssm_params(params, xc)
        h, h_last = _chunk_scan(da, dbx, h0)
        y = jnp.einsum("blds,bls->bld", h, cmat)      # (B,L,di)
        y = y + params["d_skip"] * xc.astype(jnp.float32)
        return (conv_st, h_last), y.astype(x.dtype)

    (conv_state, ssm_state), ys = jax.lax.scan(
        body, (conv_state, ssm_state), xi_c)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,dp->bsp", y, params["out_proj"])
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba_decode(params, x, state) -> Tuple[jnp.ndarray, dict]:
    """One-token decode; x: (B,1,d)."""
    b = x.shape[0]
    xz = jnp.einsum("bsd,dp->bsp", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                  state["conv"])
    xc = jax.nn.silu(xc)
    da, dbx, cmat = _ssm_params(params, xc)           # (B,1,di,dS)
    h = da[:, 0] * state["ssm"] + dbx[:, 0]           # (B,di,dS)
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None]
    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsd,dp->bsp", y, params["out_proj"])
    return out, {"conv": conv_state, "ssm": h}


def init_mamba_state(batch, d, expand, d_state, d_conv, dtype):
    di = expand * d
    return {"conv": jnp.zeros((batch, d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, d_state), jnp.float32)}
