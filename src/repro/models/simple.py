"""Small pure-JAX networks used by the paper's own experiments (§5).

MLP for the robust-HPO regression tasks (§5.1) and a LeNet-5 for the
domain-adaptation digits task (§5.2) — the paper uses LeNet-5 for all of
the pretraining/finetuning/reweighting networks.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    params = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"w{i}"] = (jax.random.normal(keys[i], (din, dout), dtype)
                           / jnp.sqrt(din))
        params[f"b{i}"] = jnp.zeros((dout,), dtype)
    return params


def mlp_apply(params, x):
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jnp.tanh(h)
    return h


def smoothed_l1(params, delta: float = 1e-3):
    """||w||_{1*}: smooth |w| via sqrt(w^2 + delta^2) - delta (paper §5.1)."""
    total = 0.0
    for p in jax.tree.leaves(params):
        total = total + jnp.sum(jnp.sqrt(p.astype(jnp.float32) ** 2
                                         + delta ** 2) - delta)
    return total


# ---------------------------------------------------------------------------
# LeNet-5 (32x32x1 inputs, 10 classes)
# ---------------------------------------------------------------------------

def lenet_init(key, n_classes: int = 10, dtype=jnp.float32):
    ks = jax.random.split(key, 5)

    def conv(key, kh, kw, cin, cout):
        fan = kh * kw * cin
        return jax.random.normal(key, (kh, kw, cin, cout), dtype) \
            / jnp.sqrt(fan)

    def dense(key, din, dout):
        return jax.random.normal(key, (din, dout), dtype) / jnp.sqrt(din)

    return {
        "c1": conv(ks[0], 5, 5, 1, 6), "c1b": jnp.zeros((6,), dtype),
        "c2": conv(ks[1], 5, 5, 6, 16), "c2b": jnp.zeros((16,), dtype),
        "f1": dense(ks[2], 16 * 5 * 5, 120), "f1b": jnp.zeros((120,), dtype),
        "f2": dense(ks[3], 120, 84), "f2b": jnp.zeros((84,), dtype),
        "f3": dense(ks[4], 84, n_classes),
        "f3b": jnp.zeros((n_classes,), dtype),
    }


def _conv2d(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0


def lenet_apply(params, x):
    """x: (B, 32, 32, 1) -> logits (B, n_classes)."""
    h = jnp.tanh(_conv2d(x, params["c1"]) + params["c1b"])   # (B,28,28,6)
    h = _avgpool2(h)                                          # (B,14,14,6)
    h = jnp.tanh(_conv2d(h, params["c2"]) + params["c2b"])   # (B,10,10,16)
    h = _avgpool2(h)                                          # (B,5,5,16)
    h = h.reshape(h.shape[0], -1)
    h = jnp.tanh(h @ params["f1"] + params["f1b"])
    h = jnp.tanh(h @ params["f2"] + params["f2b"])
    return h @ params["f3"] + params["f3b"]


def cross_entropy(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
