"""xLSTM sequence mixers: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM training/prefill uses the chunkwise-parallel form (matmul-heavy,
MXU-friendly — this is also what the `mlstm_chunk` Pallas kernel tiles):
within a chunk, intra-chunk terms are a decayed attention-like matmul;
across chunks the (hd x hd) matrix memory C and normalizer n are carried
with a per-chunk max-stabilizer m.  Decode is the O(1) recurrent update.

sLSTM keeps a per-head scalar-memory recurrence with exponential gating
and a stabilizer state; it is inherently sequential, so training scans
over time (cheap at xlstm-125m scale).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def mlstm_init(key, d, n_heads, head_dim, dtype):
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, n_heads, head_dim), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, n_heads, head_dim), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, n_heads, head_dim), dtype, fan_in=d),
        "wi": dense_init(ks[3], (d, n_heads), jnp.float32, fan_in=d),
        "wf": dense_init(ks[4], (d, n_heads), jnp.float32, fan_in=d),
        "fb": jnp.full((n_heads,), 3.0, jnp.float32),  # forget-bias ~ keep
        "wo": dense_init(ks[5], (n_heads, head_dim, d), dtype,
                         fan_in=n_heads * head_dim),
    }


def slstm_init(key, d, n_heads, head_dim, dtype):
    ks = jax.random.split(key, 7)
    return {
        "wz": dense_init(ks[0], (d, n_heads, head_dim), dtype, fan_in=d),
        "wo_gate": dense_init(ks[1], (d, n_heads, head_dim), dtype,
                              fan_in=d),
        "wi": dense_init(ks[2], (d, n_heads), jnp.float32, fan_in=d),
        "wf": dense_init(ks[3], (d, n_heads), jnp.float32, fan_in=d),
        "fb": jnp.full((n_heads,), 3.0, jnp.float32),
        "rz": dense_init(ks[4], (n_heads, head_dim, head_dim), dtype,
                         fan_in=head_dim),  # block-diag recurrent weights
        "wo": dense_init(ks[5], (n_heads, head_dim, d), dtype,
                         fan_in=n_heads * head_dim),
    }


def init_mlstm_state(batch, n_heads, head_dim):
    return {"c": jnp.zeros((batch, n_heads, head_dim, head_dim),
                           jnp.float32),
            "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e9, jnp.float32)}


def init_slstm_state(batch, n_heads, head_dim):
    return {"c": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            "h": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e9, jnp.float32)}


# ---------------------------------------------------------------------------
# mLSTM chunkwise (this math is the Pallas kernel's oracle)
# ---------------------------------------------------------------------------

def mlstm_chunk_body(q, k, v, li, lf, state):
    """One chunk. q/k/v: (B,L,H,hd); li/lf: (B,L,H) log gates;
    state: dict(c,n,m).  Returns (y (B,L,H,hd), new_state)."""
    b, l, h, hd = q.shape
    c_prev, n_prev, m_prev = state["c"], state["n"], state["m"]

    bcum = jnp.cumsum(lf, axis=1)                     # (B,L,H) inclusive
    btot = bcum[:, -1]                                # (B,H)
    # log-decay from chunk start to position t (exclusive of t's own f? we
    # use inclusive: f applies before the write at t, standard mLSTM)
    g_inter = bcum                                    # decay applied to C_prev
    # intra-chunk log weights: D_ts = bcum_t - bcum_s + li_s for s <= t
    dmat = bcum[:, :, None] - bcum[:, None] + li[:, None]   # (B,L,L,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)

    # stabilizer: per position max of (inter, intra)
    m_inter = g_inter + m_prev[:, None]               # (B,L,H)
    m_intra = jnp.max(dmat, axis=2)                   # (B,L,H)
    m_t = jnp.maximum(m_inter, m_intra)

    w_inter = jnp.exp(m_inter - m_t)                  # (B,L,H)
    w_intra = jnp.exp(dmat - m_t[:, :, None])         # (B,L,L,H)

    qf = q.astype(jnp.float32) / jnp.sqrt(hd)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    # intra: y_t += sum_s w_intra[t,s] (q_t . k_s) v_s
    scores = jnp.einsum("blhd,bshd->blsh", qf, kf) * w_intra
    y_intra = jnp.einsum("blsh,bshd->blhd", scores, vf)
    den_intra = jnp.sum(scores, axis=2)               # (B,L,H)

    # inter: y_t += w_inter[t] q_t C_prev ; den += w_inter q_t . n_prev
    y_inter = jnp.einsum("blhd,bhde->blhe", qf, c_prev) * w_inter[..., None]
    den_inter = jnp.einsum("blhd,bhd->blh", qf, n_prev) * w_inter

    den = jnp.abs(den_intra + den_inter)
    den = jnp.maximum(den, jnp.exp(-m_t))             # xLSTM normalizer
    y = (y_intra + y_inter) / den[..., None]

    # state update to end of chunk
    m_new = jnp.maximum(btot + m_prev, jnp.max(
        btot[:, None] - bcum + li, axis=1))           # (B,H)
    w_c = jnp.exp(btot + m_prev - m_new)              # decay on C_prev
    w_k = jnp.exp(btot[:, None] - bcum + li - m_new[:, None])  # (B,L,H)
    c_new = c_prev * w_c[:, :, None, None] \
        + jnp.einsum("blh,blhd,blhe->bhde", w_k, kf, vf)
    n_new = n_prev * w_c[..., None] + jnp.einsum("blh,blhd->bhd", w_k, kf)
    return y, {"c": c_new, "n": n_new, "m": m_new}


def _gates(params, x):
    li = jnp.einsum("bld,dh->blh", x.astype(jnp.float32), params["wi"])
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bld,dh->blh", x.astype(jnp.float32), params["wf"])
        + params["fb"])
    return li, lf


def mlstm_apply(params, x, chunk: int = 256, state=None
                ) -> Tuple[jnp.ndarray, dict]:
    """x: (B,S,d) -> (y (B,S,d), state)."""
    b, s, d = x.shape
    h, hd = params["wq"].shape[1], params["wq"].shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    li, lf = _gates(params, x)
    if state is None:
        state = init_mlstm_state(b, h, hd)

    n_chunks = max(1, s // chunk)
    cl = s // n_chunks

    def split(a):
        return a.reshape(b, n_chunks, cl, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))

    def body(st, inp):
        qc, kc, vc, lic, lfc = inp
        y, st = mlstm_chunk_body(qc, kc, vc, lic, lfc, st)
        return st, y

    state, ys = jax.lax.scan(body, state,
                             (split(q), split(k), split(v),
                              split(li), split(lf)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"]), state


def mlstm_decode(params, x, state) -> Tuple[jnp.ndarray, dict]:
    """O(1) recurrent step; x: (B,1,d)."""
    b = x.shape[0]
    h, hd = params["wq"].shape[1], params["wq"].shape[2]
    q = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wq"])
    k = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wk"])
    v = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wv"])
    li, lf = _gates(params, x)
    li, lf = li[:, 0], lf[:, 0]                        # (B,H)

    m_new = jnp.maximum(lf + state["m"], li)
    wf = jnp.exp(lf + state["m"] - m_new)[..., None]
    wi = jnp.exp(li - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = state["c"] * wf[..., None] \
        + (wi[..., None] * kf[..., None] * vf[:, :, None])
    n = state["n"] * wf + wi * kf
    qf = q.astype(jnp.float32) / jnp.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype)[:, None]  # (B,1,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_step(params, st, zt, ot_gate, lit, lft):
    """One recurrence step. zt/ot_gate: (B,H,hd); lit/lft: (B,H)."""
    rz = jnp.einsum("bhd,hde->bhe", st["h"].astype(params["rz"].dtype),
                    params["rz"]).astype(jnp.float32)
    z = jnp.tanh(zt.astype(jnp.float32) + rz)
    m_new = jnp.maximum(lft + st["m"], lit)
    wf = jnp.exp(lft + st["m"] - m_new)[..., None]
    wi = jnp.exp(lit - m_new)[..., None]
    c = wf * st["c"] + wi * z
    n = wf * st["n"] + wi
    h = jax.nn.sigmoid(ot_gate.astype(jnp.float32)) * c \
        / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(params, x, state=None) -> Tuple[jnp.ndarray, dict]:
    b, s, d = x.shape
    h_heads, hd = params["wz"].shape[1], params["wz"].shape[2]
    z = jnp.einsum("bsd,dhk->bshk", x, params["wz"])
    og = jnp.einsum("bsd,dhk->bshk", x, params["wo_gate"])
    li = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wi"])
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wf"])
        + params["fb"])
    if state is None:
        state = init_slstm_state(b, h_heads, hd)

    def body(st, inp):
        zt, ot, lit, lft = inp
        st = _slstm_step(params, st, zt, ot, lit, lft)
        return st, st["h"]

    state, hs = jax.lax.scan(
        body, state,
        (z.transpose(1, 0, 2, 3), og.transpose(1, 0, 2, 3),
         li.transpose(1, 0, 2), lf.transpose(1, 0, 2)))
    y = hs.transpose(1, 0, 2, 3).astype(x.dtype)       # (B,S,H,hd)
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"]), state


def slstm_decode(params, x, state) -> Tuple[jnp.ndarray, dict]:
    z = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wz"])
    og = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wo_gate"])
    li = jnp.einsum("bd,dh->bh", x[:, 0].astype(jnp.float32), params["wi"])
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bd,dh->bh", x[:, 0].astype(jnp.float32), params["wf"])
        + params["fb"])
    state = _slstm_step(params, state, z, og, li, lf)
    y = state["h"].astype(x.dtype)[:, None]
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"]), state
