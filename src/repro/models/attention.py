"""GQA attention: full/sliding-window causal, cross-attention, ring-buffer
KV cache for decode.

The dense-math path here doubles as the flash-attention kernel's oracle
(kernels/ref.py imports `attend`); the Pallas kernel replaces `attend` on
real TPUs via the `use_pallas` flag in the model.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -2.0 ** 20  # large-but-finite; avoids NaN from all-masked rows


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, d, n_heads, n_kv_heads, head_dim, dtype,
              cross: bool = False, qk_norm: bool = False):
    ks = jax.random.split(key, 8)
    p = {"wq": dense_init(ks[0], (d, n_heads, head_dim), dtype, fan_in=d),
         "wk": dense_init(ks[1], (d, n_kv_heads, head_dim), dtype, fan_in=d),
         "wv": dense_init(ks[2], (d, n_kv_heads, head_dim), dtype, fan_in=d),
         "wo": dense_init(ks[3], (n_heads, head_dim, d), dtype,
                          fan_in=n_heads * head_dim)}
    if cross:
        p["xwq"] = dense_init(ks[4], (d, n_heads, head_dim), dtype, fan_in=d)
        p["xwk"] = dense_init(ks[5], (d, n_kv_heads, head_dim), dtype,
                              fan_in=d)
        p["xwv"] = dense_init(ks[6], (d, n_kv_heads, head_dim), dtype,
                              fan_in=d)
        p["xwo"] = dense_init(ks[7], (n_heads, head_dim, d), dtype,
                              fan_in=n_heads * head_dim)
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# core attention math (the kernel oracle)
# ---------------------------------------------------------------------------

def attend(q, k, v, mask=None):
    """q: (B,S,H,hd), k/v: (B,T,Hkv,hd); GQA via head grouping.

    mask: broadcastable to (B, H_or_1, S, T), True = attend.
    """
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    if mask is not None:
        m = mask if mask.ndim == 4 else mask[:, None]
        m = m.reshape(b, -1, 1, s, t) if m.shape[1] not in (1, hkv) \
            else m[:, :, None]
        scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def causal_window_mask(q_pos, k_pos, window: int = 0):
    """True where q may attend k: k<=q and (optionally) q-k < window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   block_q: int = 1024, block_k: int = 1024):
    """Flash-style streaming attention in jnp (mirrors the Pallas
    kernel's online softmax): never materializes the (S,T) score matrix.

    Used by the §Perf prefill optimization; the Pallas flash kernel is
    the TPU-native version of exactly this loop.
    """
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq, nk = -(-s // bq), -(-t // bk)
    s_pad, t_pad = nq * bq, nk * bk
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    qg = qp.reshape(b, nq, bq, hkv, g, hd).astype(jnp.float32) \
        / jnp.sqrt(hd)
    kc = kp.reshape(b, nk, bk, hkv, hd).astype(jnp.float32)
    vc = vp.reshape(b, nk, bk, hkv, hd).astype(jnp.float32)

    def q_block_impl(qi, q_blk, kc_b, vc_b):
        q_pos = qi * bq + jnp.arange(bq)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = inp
            k_pos = ki * bk + jnp.arange(bk)
            sc = jnp.einsum("qkgd,tkd->kgqt", q_blk, k_blk)
            valid = (k_pos[None, :] < t) & (q_pos[:, None] < s)
            if causal:
                valid &= k_pos[None, :] <= q_pos[:, None]
            if window:
                valid &= k_pos[None, :] > q_pos[:, None] - window
            sc = jnp.where(valid[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] \
                + jnp.einsum("kgqt,tkd->kgqd", p, v_blk)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((hkv, g, bq), NEG_INF, jnp.float32),
                jnp.zeros((hkv, g, bq), jnp.float32),
                jnp.zeros((hkv, g, bq, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kc_b, vc_b))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.transpose(2, 0, 1, 3)                 # (bq,hkv,g,hd)

    out = jax.vmap(
        lambda q_b, k_b, v_b: jax.lax.map(
            lambda qi: q_block_impl(qi, q_b[qi], k_b, v_b),
            jnp.arange(nq)))(qg, kc, vc)                 # (B,nq,bq,hkv,g,hd)
    out = out.reshape(b, s_pad, h, hd)[:, :s]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full-sequence (train / prefill) self-attention
# ---------------------------------------------------------------------------

def self_attention(params, x, positions, *, n_kv_heads, rope_theta,
                   causal: bool = True, window: int = 0,
                   qk_norm: bool = False, norm_eps: float = 1e-6,
                   impl: str = "naive", block_q: int = 1024,
                   block_k: int = 1024):
    """x: (B,S,d) -> (B,S,d); also returns (k,v) for cache seeding."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if impl == "chunked" and causal:
        o = attend_chunked(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k)
    else:
        if causal:
            mask = causal_window_mask(positions, positions,
                                      window)[:, None]
        else:
            mask = None
        o = attend(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]), (k, v)


def cross_attention(params, x, enc_kv, *, qk_norm: bool = False,
                    norm_eps: float = 1e-6):
    """Decoder cross-attn; enc_kv = (k, v) precomputed from the encoder."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["xwq"])
    k, v = enc_kv
    o = attend(q, k, v, None)
    return jnp.einsum("bshk,hkd->bsd", o, params["xwo"])


def encode_kv(params, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, params["xwk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, params["xwv"])
    return k, v


# ---------------------------------------------------------------------------
# ring-buffer KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(batch, n_kv_heads, head_dim, capacity, dtype):
    """capacity = window for SWA archs, max_seq for full attention."""
    return {
        "k": jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def decode_attention(params, x, cache, cur_pos, *, rope_theta,
                     window: int = 0, qk_norm: bool = False,
                     norm_eps: float = 1e-6):
    """One-token decode: x (B,1,d), cur_pos (B,) absolute position.

    Writes (k,v) at slot cur_pos % capacity (ring), attends over all valid
    slots.  Returns (out (B,1,d), new_cache).
    """
    b = x.shape[0]
    cap = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    pos = cur_pos[:, None]                     # (B,1)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    slot = jnp.mod(cur_pos, cap)               # (B,)
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(cur_pos)

    valid = (new_pos >= 0) & (new_pos <= cur_pos[:, None])
    if window:
        valid = valid & (new_pos > cur_pos[:, None] - window)
    o = attend(q, new_k, new_v, valid[:, None, None, :])
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def seed_kv_cache(cache, k, v, positions):
    """Write a prefill's (k,v) into the ring cache (last `cap` tokens)."""
    cap = cache["k"].shape[1]
    s = k.shape[1]
    take = min(cap, s)
    k_t, v_t = k[:, -take:], v[:, -take:]
    p_t = positions[:, -take:]
    slots = jnp.mod(p_t, cap)                  # (B,take)
    bidx = jnp.arange(k.shape[0])[:, None]
    return {
        "k": cache["k"].at[bidx, slots].set(k_t),
        "v": cache["v"].at[bidx, slots].set(v_t),
        "pos": cache["pos"].at[bidx, slots].set(p_t),
    }
