"""Shared neural-net building blocks (pure functions + param factories)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(fan)).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02) \
        .astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)) \
        .astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (hd/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) absolute token positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]                         # (...,S,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / mlp
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "tanh": jnp.tanh}[name]


def glu_mlp_init(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, (d, d_ff), dtype),
            "wg": dense_init(k2, (d, d_ff), dtype),
            "wo": dense_init(k3, (d_ff, d), dtype, fan_in=d_ff)}


def glu_mlp_apply(params, x, act="silu"):
    a = activation(act)
    h = a(jnp.einsum("...d,df->...f", x, params["wg"])) \
        * jnp.einsum("...d,df->...f", x, params["wi"])
    return jnp.einsum("...f,fd->...d", h, params["wo"])


def softmax_cross_entropy(logits, labels, mask=None):
    """Token-level CE; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
