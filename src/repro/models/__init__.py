from repro.models.config import (BlockSpec, ModelConfig, Stage,
                                 active_param_count, param_count,
                                 step_flops, uniform_stages)
from repro.models.transformer import (decode_step, encode, forward,
                                      greedy_sample, init_cache,
                                      init_params, prefill, train_loss)
from repro.models import simple
