"""Model configuration: one schema covering every assigned architecture.

A model is a stack of *stages*; each stage repeats a short *pattern* of
blocks R times.  Patterns express the heterogeneous interleaves in the
pool (gemma3's 5 local : 1 global attention, jamba's 1:7 attn:mamba with
MoE every other layer, xLSTM's mLSTM/sLSTM mix) while keeping parameters
stacked (R, ...) per pattern position so the layer loop can be a
`lax.scan` (compact HLO) or Python-unrolled (exact cost analysis for the
dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer's shape: the sequence mixer + the channel mixer."""
    mixer: str = "attn"          # attn | mamba | mlstm | slstm
    window: int = 0              # 0 = full attention, >0 = sliding window
    cross_attn: bool = False     # decoder block with encoder cross-attn
    causal: bool = True
    mlp: str = "dense"           # dense | moe | none
    qk_norm: bool = False


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: Tuple[BlockSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # 0 -> d_model // n_heads
    stages: Tuple[Stage, ...] = ()
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba)
    ssm_d_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # xLSTM
    mlstm_chunk: int = 256
    # encoder-decoder (whisper): decoder uses the fields above
    is_encoder_decoder: bool = False
    encoder_stages: Tuple[Stage, ...] = ()
    encoder_seq: int = 1500      # whisper: 30 s of audio -> 1500 frames
    # frontend stubs (audio / vlm): inputs arrive as precomputed embeddings
    frontend: str = "tokens"     # tokens | frames
    # attention implementation: "naive" materializes (S,T) scores (the
    # XLA default / dry-run baseline); "chunked" streams KV blocks with
    # an online softmax (the §Perf optimization; mirrors the Pallas
    # flash kernel)
    attn_impl: str = "naive"
    attn_block_q: int = 1024
    attn_block_k: int = 1024
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "silu"
    dtype: str = "bfloat16"
    # long-context decode variant for dense archs (beyond-paper flag):
    # when a decode shape exceeds `long_context_threshold` and the arch
    # has no native sub-quadratic mode, attention falls back to this
    # sliding window (0 disables the variant -> the pair is skipped).
    long_context_window: int = 0
    long_context_threshold: int = 131_072

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def all_layers(self) -> List[BlockSpec]:
        out: List[BlockSpec] = []
        for st in self.stages:
            out.extend(list(st.pattern) * st.repeats)
        return out

    def validate(self):
        n = sum(st.n_layers for st in self.stages)
        assert n == self.n_layers, \
            f"{self.name}: stages cover {n} layers != n_layers={self.n_layers}"
        if self.is_encoder_decoder:
            assert self.encoder_stages, f"{self.name}: missing encoder stages"
        for st in self.stages:
            for b in st.pattern:
                assert b.mixer in ("attn", "mamba", "mlstm", "slstm"), b.mixer
                assert b.mlp in ("dense", "moe", "none"), b.mlp
                if b.mlp == "moe":
                    assert self.n_experts > 0 and self.top_k > 0
        return self


def uniform_stages(n_layers: int, block: BlockSpec) -> Tuple[Stage, ...]:
    return (Stage(pattern=(block,), repeats=n_layers),)


# ---------------------------------------------------------------------------
# analytic cost model (roofline §Roofline; corrects HLO scan undercounting)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> dict:
    """Per-component parameter counts (embedding counted once if tied)."""
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    counts = {"embed": cfg.vocab_size * d, "norms": 0, "mixer": 0, "mlp": 0}
    if not cfg.tie_embeddings:
        counts["embed"] *= 2

    def mixer_params(b: BlockSpec) -> int:
        if b.mixer == "attn":
            p = d * h * hd + 2 * d * hkv * hd + h * hd * d
            if b.cross_attn:
                p *= 2
            return p
        if b.mixer == "mamba":
            di = cfg.ssm_expand * d
            return (d * 2 * di            # in_proj (x and gate)
                    + di * cfg.ssm_conv   # depthwise conv
                    + di * (2 * cfg.ssm_d_state + 1) + di  # dt/B/C proj + A
                    + di * d)             # out_proj
        if b.mixer in ("mlstm", "slstm"):
            # qkv + i/f gates + out
            return d * 3 * h * hd + 2 * d * h + h * hd * d
        raise ValueError(b.mixer)

    def mlp_params(b: BlockSpec) -> int:
        if b.mlp == "dense":
            return 3 * d * cfg.d_ff
        if b.mlp == "moe":
            return d * cfg.n_experts + cfg.n_experts * 3 * d * cfg.d_ff
        return 0

    layers = cfg.all_layers()
    if cfg.is_encoder_decoder:
        for st in cfg.encoder_stages:
            layers = layers + list(st.pattern) * st.repeats
    for b in layers:
        counts["mixer"] += mixer_params(b)
        counts["mlp"] += mlp_params(b)
        counts["norms"] += 2 * d + (d if b.cross_attn else 0)
    counts["total"] = sum(v for k, v in counts.items() if k != "total")
    return counts


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k experts instead of all)."""
    if cfg.n_experts == 0:
        return param_count(cfg)["total"]
    layers = cfg.all_layers()
    moe_layers = sum(1 for b in layers if b.mlp == "moe")
    full = param_count(cfg)["total"]
    inactive = moe_layers * (cfg.n_experts - cfg.top_k) * 3 \
        * cfg.d_model * cfg.d_ff
    return full - inactive


def step_flops(cfg: ModelConfig, batch: int, seq: int, training: bool,
               kv_len: int = 0) -> dict:
    """Analytic FLOPs for one forward (and backward if training).

    kv_len > 0 means decode: `seq` new tokens attending to kv_len cached
    positions.  Matmul flops only (2*MACs); backward = 2x forward.
    """
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    tokens = batch * seq
    out = {"proj": 0.0, "attn": 0.0, "mixer_state": 0.0, "mlp": 0.0,
           "logits": 2.0 * tokens * d * cfg.vocab_size}

    def attn_ctx(b: BlockSpec) -> float:
        if kv_len:
            ctx = min(kv_len, b.window or cfg.long_context_window or kv_len)
            return 2.0 * 2.0 * tokens * h * hd * ctx
        w = b.window or seq
        # causal: sum over i of min(i, w) approx seq*min(seq,w)/2 for full
        eff = seq * min(seq, w) / 2 if w >= seq else seq * w
        return 2.0 * 2.0 * batch * h * hd * eff

    layers = cfg.all_layers()
    if cfg.is_encoder_decoder:
        enc_tokens = batch * cfg.encoder_seq
        for st in cfg.encoder_stages:
            for b in st.pattern:
                out["proj"] += st.repeats * 2.0 * enc_tokens * (
                    d * h * hd + 2 * d * hkv * hd + h * hd * d)
                out["attn"] += st.repeats * 2.0 * 2.0 * batch * h * hd \
                    * cfg.encoder_seq ** 2
                out["mlp"] += st.repeats * 2.0 * enc_tokens * 3 * d * cfg.d_ff

    for b in layers:
        if b.mixer == "attn":
            out["proj"] += 2.0 * tokens * (d * h * hd + 2 * d * hkv * hd
                                           + h * hd * d)
            out["attn"] += attn_ctx(b)
            if b.cross_attn:
                out["proj"] += 2.0 * tokens * (d * h * hd + h * hd * d)
                out["attn"] += 2.0 * 2.0 * tokens * h * hd * cfg.encoder_seq
        elif b.mixer == "mamba":
            di = cfg.ssm_expand * d
            out["proj"] += 2.0 * tokens * (2 * d * di + di * d
                                           + di * (2 * cfg.ssm_d_state + 1))
            out["mixer_state"] += 2.0 * tokens * di * cfg.ssm_d_state * 2
        else:  # mlstm / slstm
            out["proj"] += 2.0 * tokens * (3 * d * h * hd + h * hd * d)
            if b.mixer == "mlstm":
                # chunkwise matrix-memory update ~ 2 * dh^2 per token-head
                out["mixer_state"] += 2.0 * tokens * h * hd * hd * 2
            else:
                out["mixer_state"] += 2.0 * tokens * h * hd * 4
        if b.mlp == "dense":
            out["mlp"] += 2.0 * tokens * 3 * d * cfg.d_ff
        elif b.mlp == "moe":
            out["mlp"] += 2.0 * tokens * (d * cfg.n_experts
                                          + cfg.top_k * 3 * d * cfg.d_ff)

    out["fwd_total"] = sum(v for k, v in out.items())
    out["total"] = out["fwd_total"] * (3.0 if training else 1.0)
    return out
