"""Mixture-of-Experts channel mixer: top-k router + capacity-based
grouped-GEMM dispatch (sort/scatter, NOT the dense one-hot dispatch
einsum — at 384 experts the GShard-style dispatch einsum costs
G*E*C*d MACs and would dwarf the experts themselves).

Experts are sharded over the `model` mesh axis (expert parallelism); the
scatter/gather over the expert axis lowers to collectives recorded by the
dry-run.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation, dense_init


def moe_init(key, d, d_ff, n_experts, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, n_experts), jnp.float32),
        "wi": dense_init(k2, (n_experts, d, d_ff), dtype, fan_in=d),
        "wg": dense_init(k3, (n_experts, d, d_ff), dtype, fan_in=d),
        "wo": dense_init(k4, (n_experts, d_ff, d), dtype, fan_in=d_ff),
    }


def _route(router_w, x_flat, top_k: int):
    """Returns (expert_idx (T,K), weight (T,K), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    weight, expert_idx = jax.lax.top_k(probs, top_k)
    weight = weight / jnp.maximum(jnp.sum(weight, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * <f_e, p_e>
    e = router_w.shape[1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(fe * me)
    return expert_idx, weight.astype(x_flat.dtype), aux


def moe_apply(params, x, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss).

    Tokens are routed to (expert, slot) buffers of shape (E, C, d) via a
    capacity-bounded scatter; each expert runs a dense GLU MLP on its
    buffer; results gather back with routing weights.  Overflowing tokens
    are dropped (standard capacity behaviour).
    """
    b, s, d = x.shape
    e = params["wi"].shape[0]
    xf = x.reshape(b * s, d)
    t = b * s
    expert_idx, weight, aux = _route(params["router"], xf, top_k)

    # flatten (token, k) assignments
    flat_e = expert_idx.reshape(-1)                      # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)            # (T*K,)
    flat_w = weight.reshape(-1)                          # (T*K,)

    capacity = max(1, int(capacity_factor * t * top_k / e))
    # slot of each assignment within its expert = rank among same-expert
    # assignments (stable by token order):
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within the sorted run of each expert
    idx_in_sorted = jnp.arange(flat_e.shape[0])
    start_of_expert = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    slot_sorted = idx_in_sorted - start_of_expert[sorted_e]
    slot = jnp.zeros_like(flat_e).at[order].set(slot_sorted)
    keep = slot < capacity

    # scatter tokens into (E, C, d)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    se = jnp.where(keep, flat_e, 0)
    ss = jnp.where(keep, slot, 0)
    contrib = jnp.where(keep[:, None], xf[flat_t], 0)
    buf = buf.at[se, ss].add(contrib)

    # expert GLU MLPs as grouped dense matmuls
    a = activation(act)
    h = a(jnp.einsum("ecd,edf->ecf", buf, params["wg"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])

    # gather back with routing weights
    out_flat = jnp.zeros((t, d), jnp.float32)
    picked = y[se, ss].astype(jnp.float32) * (flat_w * keep)[:, None]
    out_flat = out_flat.at[flat_t].add(picked)
    return out_flat.reshape(b, s, d).astype(x.dtype), aux
