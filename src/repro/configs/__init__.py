from repro.configs.archs import ARCHS, get_config, reduced
from repro.configs.shapes import INPUT_SHAPES, InputShape, get_shape

ASSIGNED_ARCHS = (
    "kimi-k2-1t-a32b", "llama3-405b", "gemma3-12b", "jamba-v0.1-52b",
    "llama3-8b", "xlstm-125m", "mixtral-8x22b", "chameleon-34b",
    "whisper-large-v3", "yi-34b",
)

# (arch, shape) pairs excluded from the dry-run matrix, with reasons
# (see DESIGN.md §Arch-applicability / decode-shape applicability)
DRYRUN_SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec with <=448-token decoder spec and no sub-quadratic mode; "
        "524k-token self-attention decode is not meaningful for this "
        "family",
}
