"""The 10 assigned architectures (exact specs from the public pool) plus
the paper-scale configs.  Every entry cites its source in brackets.

`reduced(cfg)` produces the same-family smoke variant (<=2 pattern
periods, d_model<=512, <=4 experts) used by per-arch CPU smoke tests;
full configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.models.config import BlockSpec, ModelConfig, Stage, uniform_stages

ARCHS: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        ARCHS[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]().validate()


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@register("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    """Kimi K2 — trillion-param MoE, 384 experts top-8, first layer dense
    [arXiv:2501.kimi2]."""
    return ModelConfig(
        name="kimi-k2-1t-a32b", arch_type="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=2048, vocab_size=163_840, n_experts=384, top_k=8,
        stages=(Stage((BlockSpec(mlp="dense"),), 1),
                Stage((BlockSpec(mlp="moe"),), 60)),
        long_context_window=8_192)


@register("mixtral-8x22b")
def mixtral() -> ModelConfig:
    """Mixtral 8x22B — 8 experts top-2, sliding-window attention
    [arXiv:2401.04088]."""
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=16384, vocab_size=32_768, n_experts=8, top_k=2,
        stages=uniform_stages(56, BlockSpec(window=4096, mlp="moe")))


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@register("llama3-405b")
def llama3_405b() -> ModelConfig:
    """Llama-3 405B — GQA, 128k vocab [arXiv:2407.21783]."""
    return ModelConfig(
        name="llama3-405b", arch_type="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
        d_ff=53248, vocab_size=128_256,
        stages=uniform_stages(126, BlockSpec()),
        tie_embeddings=False, long_context_window=8_192)


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    """Llama-3 8B — GQA, 128k vocab [arXiv:2407.21783]."""
    return ModelConfig(
        name="llama3-8b", arch_type="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab_size=128_256,
        stages=uniform_stages(32, BlockSpec()),
        tie_embeddings=False, long_context_window=8_192)


@register("gemma3-12b")
def gemma3_12b() -> ModelConfig:
    """Gemma-3 12B — 5 local(1024) : 1 global attention interleave, 256k
    vocab [hf:google/gemma-3-1b-pt family]."""
    local = BlockSpec(window=1024)
    glob = BlockSpec()
    return ModelConfig(
        name="gemma3-12b", arch_type="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_head=256,
        d_ff=15360, vocab_size=262_144, act="gelu",
        stages=(Stage((local, local, local, local, local, glob), 8),))


@register("yi-34b")
def yi_34b() -> ModelConfig:
    """Yi-34B — llama-architecture GQA [arXiv:2403.04652]."""
    return ModelConfig(
        name="yi-34b", arch_type="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=20480, vocab_size=64_000,
        stages=uniform_stages(60, BlockSpec()),
        tie_embeddings=False, long_context_window=8_192)


# ---------------------------------------------------------------------------
# hybrid / ssm
# ---------------------------------------------------------------------------

@register("jamba-v0.1-52b")
def jamba() -> ModelConfig:
    """Jamba v0.1 — Mamba+attention 1:7 interleave, MoE(16e top-2) every
    other layer [arXiv:2403.19887]."""
    pattern = tuple(
        BlockSpec(mixer=("attn" if i == 3 else "mamba"),
                  mlp=("moe" if i % 2 == 1 else "dense"))
        for i in range(8))
    return ModelConfig(
        name="jamba-v0.1-52b", arch_type="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab_size=65_536, n_experts=16, top_k=2,
        ssm_d_state=16, ssm_conv=4, ssm_expand=2,
        stages=(Stage(pattern, 4),))


@register("xlstm-125m")
def xlstm_125m() -> ModelConfig:
    """xLSTM-125M — mLSTM blocks with interleaved sLSTM
    [arXiv:2405.04517]."""
    m = BlockSpec(mixer="mlstm", mlp="none")
    s = BlockSpec(mixer="slstm", mlp="none")
    return ModelConfig(
        name="xlstm-125m", arch_type="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_head=192,
        d_ff=0, vocab_size=50_304,
        stages=(Stage((m, m, m, m, m, s), 2),))


# ---------------------------------------------------------------------------
# vlm / audio
# ---------------------------------------------------------------------------

@register("chameleon-34b")
def chameleon() -> ModelConfig:
    """Chameleon-34B — early-fusion VQ image tokens (ids in the shared
    65536 vocab; the VQ tokenizer is the stubbed frontend), qk-norm
    [arXiv:2405.09818]."""
    return ModelConfig(
        name="chameleon-34b", arch_type="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=22016, vocab_size=65_536,
        stages=uniform_stages(48, BlockSpec(qk_norm=True)),
        tie_embeddings=False, long_context_window=8_192)


@register("whisper-large-v3")
def whisper() -> ModelConfig:
    """Whisper large-v3 — encoder-decoder; the mel+conv frontend is
    stubbed (input_specs feeds (B, 1500, d) frame embeddings)
    [arXiv:2212.04356]."""
    return ModelConfig(
        name="whisper-large-v3", arch_type="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
        d_ff=5120, vocab_size=51_866, act="gelu",
        is_encoder_decoder=True, encoder_seq=1500, frontend="frames",
        stages=uniform_stages(32, BlockSpec(cross_attn=True)),
        encoder_stages=uniform_stages(32, BlockSpec(causal=False)))


# ---------------------------------------------------------------------------
# reduced smoke variants
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same family, toy size: one pattern period per stage (<=2 for
    uniform stacks), d_model<=256, <=4 experts, small vocab."""
    def shrink_stage(st: Stage) -> Stage:
        reps = 1 if len(st.pattern) > 1 else min(2, st.repeats)
        return Stage(st.pattern, reps)

    stages = tuple(shrink_stage(st) for st in cfg.stages)
    enc_stages = tuple(shrink_stage(st) for st in cfg.encoder_stages) \
        if cfg.is_encoder_decoder else ()
    n_layers = sum(st.n_layers for st in stages)
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    shrunk = dataclasses.replace(
        cfg, name=cfg.name + "-reduced",
        n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv, d_head=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_chunk=16, mlstm_chunk=16,
        encoder_seq=16 if cfg.is_encoder_decoder else cfg.encoder_seq,
        stages=stages, encoder_stages=enc_stages,
        dtype="float32")
    return shrunk.validate()
