from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_debug_mesh, make_production_mesh)
