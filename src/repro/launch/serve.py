"""Serving front end: batched decode + the async federation runtime.

Two subcommands:

  decode   the batched LLM serving driver (prefill + N decode steps):
      PYTHONPATH=src python -m repro.launch.serve decode \
          --arch llama3-8b --reduced --batch 4 --prompt-len 64 --gen 32
      (a bare flag invocation without a subcommand still routes here —
      the historical CLI surface.)

  fed      launch an async federation run — one master plus N workers
           over the in-process transport (threads) or TCP (real worker
           subprocesses) — streaming per-record status lines and an
           optional HTTP status endpoint:
      PYTHONPATH=src python -m repro.launch.serve fed \
          --problem quadratic --workers 2 --iters 60 --transport tcp
      GET /status on --status-port (0 picks an ephemeral port) returns
      the master's live counters as JSON (including the recent arrival
      rows).  Exits nonzero unless the stationarity gap decreased over
      the run — the end-to-end convergence gate the CI smoke step
      drives.  `--stream` runs on streamed data (workers synthesize
      their own batches) and additionally gates the recorded schedule's
      replay through the compiled engine; `--adapt-arrivals` turns on
      the closed-loop arrival policy.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# decode: the batched serving driver
# ---------------------------------------------------------------------------

def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0,
          greedy: bool = True):
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = jnp.asarray(make_token_stream(cfg.vocab_size, batch,
                                            prompt_len, seed=seed))
    frames = None
    if cfg.frontend == "frames":
        frames = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (batch, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)

    prefill = jax.jit(lambda p, tk: tfm.prefill(
        cfg, p, tk, frames, max_seq=prompt_len + gen + 1))
    decode = jax.jit(lambda p, c, tk, pos: tfm.decode_step(cfg, p, c, tk,
                                                           pos))
    t0 = time.time()
    logits, caches = prefill(params, prompts)
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [nxt]
    t0 = time.time()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, caches = decode(params, caches, nxt, pos)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out_tokens.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0
    gen_ids = jnp.concatenate(out_tokens, axis=1)
    return {"prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
            "generated": np.asarray(gen_ids)}


def main_decode(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="serve decode")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    res = serve(cfg, args.batch, args.prompt_len, args.gen, args.seed)
    print(f"prefill {res['prefill_s']:.2f}s, decode {res['decode_s']:.2f}s"
          f" ({res['tok_per_s']:.1f} tok/s)")
    print("first generations:", res["generated"][:2, :16].tolist())
    return 0


# ---------------------------------------------------------------------------
# fed: master + N workers over a live transport
# ---------------------------------------------------------------------------

def start_status_server(master, port: int):
    """Serve `master.status` as JSON on GET /status (daemon thread);
    returns the HTTPServer (read the bound port off `.server_address`)."""
    import http.server
    import threading

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/status"):
                self.send_error(404)
                return
            body = json.dumps(master.status).encode()
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):   # stay quiet on the run's stdout
            pass

    srv = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def spawn_tcp_workers(args, port: int):
    """One `repro.fed.runtime.worker` subprocess per worker id, pointed
    at the master's bound port (each rebuilds the problem by name)."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    base = [sys.executable, "-m", "repro.fed.runtime.worker",
            "--problem", args.problem,
            "--port", str(port), "--n-workers", str(args.workers),
            "--dim", str(args.dim), "--seed", str(args.seed)]
    # getattr: callers like the chaos smoke hand-build a minimal args
    # namespace that predates the streaming flags
    if getattr(args, "stream", False):
        base.append("--stream")   # each worker rebuilds the same Stream
    return [subprocess.Popen(base + ["--worker", str(j)], env=env)
            for j in range(args.workers)]


def run_fed(args):
    """Launch the run described by parsed `fed` args; returns
    (RunResult, status_server | None)."""
    from repro.core.scheduler import ArrivalPolicy
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    from repro.fed.runtime.membership import FaultConfig
    from repro.fed.runtime.transport import TcpTransport

    problem, hyper = problems_lib.build(
        args.problem, n_workers=args.workers, dim=args.dim,
        seed=args.seed)
    stream = None
    if args.stream:
        # TCP subprocess workers rebuild this identical Stream by name
        stream = problems_lib.build_stream(
            args.problem, n_workers=args.workers, dim=args.dim,
            seed=args.seed)
    policy = None
    if args.adapt_arrivals:
        policy = ArrivalPolicy(s_active=hyper.s_active, tau=hyper.tau)
    elastic = None
    max_workers = getattr(args, "max_workers", 0)
    if max_workers > args.workers:
        # accept ADMITs from ids [workers, max_workers): a late worker
        # (`--worker J` with J >= --workers) joins mid-run at the next
        # iteration boundary
        elastic = problems_lib.elastic_config(
            args.problem, max_workers, dim=args.dim, seed=args.seed,
            stream=bool(args.stream))

    transport, procs = None, []
    if args.transport == "tcp":
        transport = TcpTransport(args.workers, port=args.port,
                                 max_workers=max(max_workers,
                                                 args.workers))
        transport.master_endpoint()          # bind before spawning
        print(f"master listening on 127.0.0.1:{transport.port}")
        procs = spawn_tcp_workers(args, transport.port)

    fault = FaultConfig(
        death_timeout=args.death_timeout,
        min_iter_time=args.min_iter_time)
    status_server = None

    def hook(master):
        nonlocal status_server
        if args.status_port >= 0:
            status_server = start_status_server(master, args.status_port)
            print(f"status endpoint: http://127.0.0.1:"
                  f"{status_server.server_address[1]}/status")

    try:
        result = run_async(
            problem, hyper, n_iterations=args.iters,
            metrics_every=args.metrics_every, transport=transport,
            data=stream, policy=policy,
            master_hook=hook, fault=fault,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            resume=args.resume, elastic=elastic,
            accept_timeout=(args.accept_timeout
                            if args.accept_timeout > 0 else None))
    finally:
        for p in procs:
            p.wait(timeout=60)
    return result, status_server


def main_fed(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="serve fed")
    ap.add_argument("--problem", default="quadratic")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--metrics-every", type=int, default=10)
    ap.add_argument("--transport", choices=("inproc", "tcp"),
                    default="inproc")
    ap.add_argument("--max-workers", type=int, default=0,
                    help="accept elastic ADMITs for worker ids up to "
                         "this population cap (0 = fixed membership); "
                         "late workers connect with --worker >= "
                         "--workers and join at the next iteration "
                         "boundary")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP master port (0 = ephemeral)")
    ap.add_argument("--status-port", type=int, default=-1,
                    help="HTTP status port (0 = ephemeral, -1 = off)")
    ap.add_argument("--accept-timeout", type=float, default=0.0,
                    help="seconds to wait for the full worker population "
                         "at launch (0 = wait forever)")
    ap.add_argument("--death-timeout", type=float, default=10.0,
                    help="seconds of silence before a worker is "
                         "declared dead")
    ap.add_argument("--min-iter-time", type=float, default=0.0,
                    help="master pacing floor per iteration (seconds); "
                         "the chaos smoke uses it to keep a run alive "
                         "long enough to kill and respawn a worker")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for durable master checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the master carry every K "
                         "iterations (0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from --ckpt-dir "
                         "before running")
    ap.add_argument("--stream", action="store_true",
                    help="streamed data: workers synthesize their own "
                         "batch at the refresh's master iteration; the "
                         "run exits nonzero unless the recorded "
                         "schedule replays through run_scanned")
    ap.add_argument("--adapt-arrivals", action="store_true",
                    help="close the arrival loop: an ArrivalPolicy "
                         "adapts the effective (s, tau) per iteration "
                         "inside the paper's tau bound")
    args = ap.parse_args(argv)

    result, status_server = run_fed(args)
    for i, t in enumerate(result.history["t"]):
        print(json.dumps({
            "t": int(t),
            "gap_sq": result.history["gap_sq"][i],
            "n_cuts_ii": result.history["n_cuts_ii"][i],
            "max_staleness": result.history["max_staleness"][i]}))
    if status_server is not None:
        status_server.shutdown()

    gaps = result.history["gap_sq"]
    # Streamed runs measure the gap on a FRESH batch at each record
    # point, so a first-vs-last decrease is batch noise, not a
    # convergence signal — their gate is the exact-replay echo below.
    decreasing = bool(args.stream) or gaps[-1] < gaps[0]
    max_stale = int(result.arrivals.max_staleness.max())
    stale_ok = max_stale <= _problem_tau(args)
    trend = ("streamed (per-batch)" if args.stream
             else "decreasing" if decreasing else "NOT decreasing")
    print(f"gap {gaps[0]:.4f} -> {gaps[-1]:.4f} ({trend}); "
          f"max recorded staleness {max_stale} "
          f"(tau bound {'ok' if stale_ok else 'VIOLATED'})")
    replay_ok = True
    if args.stream:
        replay_ok = _streamed_replay_gate(args, result)
    return 0 if (decreasing and stale_ok and replay_ok) else 1


def _streamed_replay_gate(args, result) -> bool:
    """Echo a streamed run's recorded Schedule through `run_scanned`
    with the rebuilt Stream and gate the gap history at rel err 1e-5.
    The echo is a different XLA compilation context (batch synthesis
    fuses into the scan body), so the floor is ~1e-7 ulp noise, not 0.0
    — the bitwise contract is runtime replay (`Master(replay=...)`),
    pinned in tests/test_runtime.py."""
    from repro.core.engine import run_scanned
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime.membership import run_scanned_elastic

    if result.arrivals.width is not None:
        # a widened (elastic) run echoes through the segmented replay:
        # the engine runs each constant-width segment at its own width
        ref = run_scanned_elastic(
            lambda n: problems_lib.build(
                args.problem, n_workers=n, dim=args.dim, seed=args.seed),
            result.arrivals, metrics_every=args.metrics_every,
            build_stream=lambda n: problems_lib.build_stream(
                args.problem, n_workers=n, dim=args.dim, seed=args.seed))
    else:
        problem, hyper = problems_lib.build(
            args.problem, n_workers=args.workers, dim=args.dim,
            seed=args.seed)
        stream = problems_lib.build_stream(
            args.problem, n_workers=args.workers, dim=args.dim,
            seed=args.seed)
        ref = run_scanned(problem, hyper, result.arrivals,
                          metrics_every=args.metrics_every, data=stream)
    live = np.asarray(result.history["gap_sq"], np.float64)
    echo = np.asarray(ref.history["gap_sq"], np.float64)
    if live.shape != echo.shape:
        print(f"streamed replay gate: history shape mismatch "
              f"{live.shape} vs {echo.shape}")
        return False
    rel = float(np.max(np.abs(live - echo) /
                       np.maximum(np.abs(echo), 1e-30)))
    ok = rel <= 1e-5
    print(f"streamed replay gate: max gap rel err {rel:.3e} "
          f"({'ok' if ok else 'EXCEEDS 1e-5'})")
    return ok


def _problem_tau(args) -> int:
    from repro.fed.runtime import problems as problems_lib
    _, hyper = problems_lib.build(args.problem, n_workers=args.workers,
                                  dim=args.dim, seed=args.seed)
    return hyper.tau


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # historical CLI surface: a bare flag invocation is `decode`
    if not argv or argv[0] not in ("decode", "fed"):
        argv = ["decode"] + argv
    if argv[0] == "decode":
        return main_decode(argv[1:])
    return main_fed(argv[1:])


if __name__ == "__main__":
    raise SystemExit(main())
