"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tfm


def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0,
          greedy: bool = True):
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = jnp.asarray(make_token_stream(cfg.vocab_size, batch,
                                            prompt_len, seed=seed))
    frames = None
    if cfg.frontend == "frames":
        frames = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (batch, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)

    prefill = jax.jit(lambda p, tk: tfm.prefill(
        cfg, p, tk, frames, max_seq=prompt_len + gen + 1))
    decode = jax.jit(lambda p, c, tk, pos: tfm.decode_step(cfg, p, c, tk,
                                                           pos))
    t0 = time.time()
    logits, caches = prefill(params, prompts)
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [nxt]
    t0 = time.time()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, caches = decode(params, caches, nxt, pos)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out_tokens.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0
    gen_ids = jnp.concatenate(out_tokens, axis=1)
    return {"prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
            "generated": np.asarray(gen_ids)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    res = serve(cfg, args.batch, args.prompt_len, args.gen, args.seed)
    print(f"prefill {res['prefill_s']:.2f}s, decode {res['decode_s']:.2f}s"
          f" ({res['tok_per_s']:.1f} tok/s)")
    print("first generations:", res["generated"][:2, :16].tolist())


if __name__ == "__main__":
    main()
