import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first — jax locks the device count
# at first init (see module docstring below).  `from __future__` is
# therefore deliberately omitted in this file.

_DOC = """Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) combination with ShapeDtypeStruct
stand-ins (no allocation) and extract roofline terms (deliverable g).

The two lines above MUST precede any jax import: jax locks the device
count at first init.  Only this entry point forces 512 host devices;
tests and benches see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, DRYRUN_SKIPS, get_config,
                           get_shape)
from repro.configs.shapes import InputShape
from repro.fed import sharding as shd
from repro.fed.trilevel_llm import (FedHyper, afto_llm_step, cut_refresh_llm,
                                    init_fed_state, plain_train_step)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models.config import (ModelConfig, active_param_count,
                                 step_flops)


# ---------------------------------------------------------------------------
# shape stand-ins
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _n_workers(mesh) -> int:
    shape = dict(mesh.shape)
    return shape.get("pod", 1) * shape["data"]


def input_specs(cfg: ModelConfig, shape: InputShape, mesh,
                fed: bool) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type
    correct, shardable, no device allocation)."""
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        if fed:
            n = _n_workers(mesh)
            b = max(1, shape.global_batch // n)
            out["tokens"] = _sds((n, b, shape.seq_len), jnp.int32)
            out["val_tokens"] = _sds((n, b, shape.seq_len), jnp.int32)
            if cfg.frontend == "frames":
                fr = _sds((n, b, cfg.encoder_seq, cfg.d_model),
                          jnp.bfloat16)
                out["frames"] = fr
                out["val_frames"] = fr
        else:
            out["tokens"] = _sds((shape.global_batch, shape.seq_len),
                                 jnp.int32)
            if cfg.frontend == "frames":
                out["frames"] = _sds(
                    (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                    jnp.bfloat16)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((shape.global_batch, shape.seq_len), jnp.int32)
        if cfg.frontend == "frames":
            out["frames"] = _sds(
                (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                jnp.bfloat16)
    else:  # decode: ONE new token against a seq_len KV cache
        out["tokens"] = _sds((shape.global_batch, 1), jnp.int32)
        out["cur_pos"] = _sds((shape.global_batch,), jnp.int32)
    return out


def _safe(spec: P, shape, mesh) -> P:
    """Drop axis names from dims they don't divide."""
    sizes = dict(mesh.shape)
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axes]))
        fixed.append(ax if shape[i] % total == 0 else None)
    return P(*fixed)


def fed_state_specs(state_shapes, mesh, hyper: FedHyper):
    """PartitionSpec tree for a FedLLMState shape tree."""
    ax = shd.data_axis(mesh)

    def pspec(*spec):
        return spec

    def cutset_specs(cs):
        if hyper.cut_mode == "sketch":
            a2 = _safe(P(None, None), cs.a2.shape, mesh)
            a3 = _safe(P(None, None), cs.a3.shape, mesh)
            b2 = _safe(P(None, ax, None), cs.b2.shape, mesh)
            b3 = _safe(P(None, ax, None), cs.b3.shape, mesh)
        else:
            a2 = jax.tree.map(
                lambda x: _safe(P(None, ax, None, None, "model"),
                                x.shape, mesh), cs.a2)
            a3 = _pspecs(cs.a3, mesh, stack_axes=(None,))
            b2 = jax.tree.map(
                lambda x: _safe(P(None, ax, None, None, "model"),
                                x.shape, mesh), cs.b2)
            b3 = _pspecs(cs.b3, mesh, stack_axes=(None, ax))
        return dataclasses.replace(
            cs, a1=P(None, None), a2=a2, a3=a3, b2=b2, b3=b3,
            c=P(None), active=P(None), age=P(None))

    x2_spec = jax.tree.map(
        lambda x: _safe(P(ax, None, None, "model"), x.shape, mesh),
        state_shapes.X2)
    return dataclasses.replace(
        state_shapes,
        X1=P(ax, None),
        X2=x2_spec,
        X3=_pspecs(state_shapes.X3, mesh, stack_axes=(ax,)),
        z1=P(None),
        z2=x2_spec,
        z3=_pspecs(state_shapes.z3, mesh),
        theta=P(ax, None), lam=P(None),
        cuts=cutset_specs(state_shapes.cuts),
        cuts_i=cutset_specs(state_shapes.cuts_i),
        gamma_k=P(None),
        stale_lam=_safe(P(ax, None), state_shapes.stale_lam.shape, mesh),
        stale_theta=P(ax, None),
        t=P())


# ---------------------------------------------------------------------------
# step builders: (fn, args, in_shardings)
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: InputShape, mesh,
                hyper: FedHyper, step: str):
    n = _n_workers(mesh)
    b_local = max(1, shape.global_batch // n)
    batch = input_specs(cfg, shape, mesh, fed=True)
    state_shapes = jax.eval_shape(
        lambda k: init_fed_state(cfg, hyper, k, b_local, shape.seq_len - 1),
        jax.random.PRNGKey(0))
    state_specs = fed_state_specs(state_shapes, mesh, hyper)
    ax = shd.data_axis(mesh)
    batch_specs = {k: _safe(P(ax, *(None,) * (v.ndim - 1)), v.shape, mesh)
                   for k, v in batch.items()}
    active = _sds((n,), jnp.float32)

    if step == "cut_refresh":
        fn = lambda s, bt: cut_refresh_llm(cfg, hyper, s, bt)
        args = (state_shapes, batch)
        shardings = (state_specs, batch_specs)
    else:
        fn = lambda s, bt, a: afto_llm_step(cfg, hyper, s, bt, a)
        args = (state_shapes, batch, active)
        shardings = (state_specs, batch_specs, P(None))
    return fn, args, shardings


def build_train_scan(cfg: ModelConfig, shape: InputShape, mesh,
                     hyper: FedHyper, chunk: int, t_pre: int = 2):
    """A `chunk`-iteration slice of the compiled trajectory engine: scan
    of afto_llm_step with the t_pre-periodic cut_refresh folded in via
    lax.cond — proves the scan-driven runner lowers and compiles at
    production shapes (cf. repro.core.engine for the core runner)."""
    _, (state_shapes, batch, _), (state_specs, batch_specs, _) = \
        build_train(cfg, shape, mesh, hyper, "train")
    n = _n_workers(mesh)
    batch_c = {k: _sds((chunk,) + v.shape, v.dtype)
               for k, v in batch.items()}
    batch_c_specs = {k: P(None, *spec) for k, spec in batch_specs.items()}
    masks = _sds((chunk, n), jnp.float32)
    its = _sds((chunk,), jnp.int32)

    def fn(st, bt, ms, it0):
        def body(s, xs):
            b, m, it = xs
            s = afto_llm_step(cfg, hyper, s, b, m)
            s = jax.lax.cond(
                (it + 1) % t_pre == 0,
                lambda s2: cut_refresh_llm(cfg, hyper, s2, b),
                lambda s2: s2, s)
            return s, None
        st, _ = jax.lax.scan(body, st, (bt, ms, it0))
        return st

    return fn, (state_shapes, batch_c, masks, its), \
        (state_specs, batch_c_specs, P(None, None), P(None))


HEAD_DIM_FALLBACK = False  # set by --shard-head-dim (perf lever)


def _pspecs(params, mesh, **kw):
    return shd.param_specs(params, mesh,
                           shard_head_dim_fallback=HEAD_DIM_FALLBACK,
                           **kw)


def build_plain_train(cfg: ModelConfig, shape: InputShape, mesh,
                      unroll: bool, remat: bool):
    from repro.optim import adamw
    params = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
    opt = adamw(3e-4)
    opt_state = jax.eval_shape(opt.init, params)
    batch = input_specs(cfg, shape, mesh, fed=False)
    ax = shd.data_axis(mesh)
    p_specs = _pspecs(params, mesh)
    o_specs = {"step": P(),
               "m": _pspecs(opt_state["m"], mesh),
               "v": _pspecs(opt_state["v"], mesh)}
    b_specs = {k: _safe(P(ax, *(None,) * (v.ndim - 1)), v.shape, mesh)
               for k, v in batch.items()}

    def fn(p, o, bt):
        return plain_train_step(cfg, p, o, bt["tokens"],
                                bt.get("frames"), optimizer=opt,
                                remat=remat, unroll=unroll)

    return fn, (params, opt_state, batch), (p_specs, o_specs, b_specs)


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh, unroll: bool):
    params = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
    batch = input_specs(cfg, shape, mesh, fed=False)
    ax = shd.data_axis(mesh)
    p_specs = _pspecs(params, mesh)
    b_specs = {k: _safe(P(ax, *(None,) * (v.ndim - 1)), v.shape, mesh)
               for k, v in batch.items()}

    def fn(p, bt):
        return tfm.prefill(cfg, p, bt["tokens"], bt.get("frames"),
                           unroll=unroll)

    return fn, (params, batch), (p_specs, b_specs)


def build_decode(cfg: ModelConfig, shape: InputShape, mesh, unroll: bool,
                 kv_seq_sharded: bool = False):
    params = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
    caches = jax.eval_shape(
        lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len))
    batch = input_specs(cfg, shape, mesh, fed=False)
    p_specs = _pspecs(params, mesh)
    c_specs = shd.cache_specs(caches, mesh,
                              kv_seq_sharded=kv_seq_sharded)
    ax = shd.data_axis(mesh)
    t_spec = _safe(P(ax, None), batch["tokens"].shape, mesh)
    pos_spec = _safe(P(ax), batch["cur_pos"].shape, mesh)

    def fn(p, c, tok, pos):
        return tfm.decode_step(cfg, p, c, tok, pos, unroll=unroll)

    return fn, (params, caches, batch["tokens"], batch["cur_pos"]), \
        (p_specs, c_specs, t_spec, pos_spec)


# ---------------------------------------------------------------------------
# run one combination
# ---------------------------------------------------------------------------

def default_step_kind(shape: InputShape) -> str:
    return {"train": "afto_train", "prefill": "prefill",
            "decode": "decode"}[shape.kind]


def analytic_flops(cfg: ModelConfig, shape: InputShape,
                   step_kind: str) -> Tuple[float, float]:
    """(analytic_total, model_flops_6nd)."""
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        sf = step_flops(cfg, shape.global_batch, shape.seq_len - 1,
                        training=True)
        tokens = shape.global_batch * (shape.seq_len - 1)
        return sf["total"], 6.0 * n_act * tokens
    if shape.kind == "prefill":
        sf = step_flops(cfg, shape.global_batch, shape.seq_len,
                        training=False)
        tokens = shape.global_batch * shape.seq_len
        return sf["total"], 2.0 * n_act * tokens
    sf = step_flops(cfg, shape.global_batch, 1, training=False,
                    kv_len=shape.seq_len)
    return sf["total"], 2.0 * n_act * shape.global_batch


def run_one(arch: str, shape_name: str, mesh_kind: str,
            step: Optional[str] = None, cut_mode: str = "exact",
            p_max: int = 2, verbose: bool = True,
            layer_mode: str = "unroll",
            attn_impl: str = "naive", sketch_r: int = 4096,
            kv_seq_shard: bool = False,
            first_order: bool = False,
            scan_chunk: int = 4) -> dict:
    cfg = get_config(arch)
    if attn_impl != "naive":
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    shape = get_shape(shape_name)
    if (arch, shape_name) in DRYRUN_SKIPS:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": DRYRUN_SKIPS[(arch, shape_name)]}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = int(np.prod(list(dict(mesh.shape).values())))
    step_kind = step or default_step_kind(shape)

    unroll = layer_mode == "unroll"
    hyper = FedHyper(n_workers=_n_workers(mesh), cut_mode=cut_mode,
                     sketch_r=sketch_r, first_order_cuts=first_order,
                     p_max=p_max, k_inner=1, remat=True, unroll=unroll)
    t0 = time.time()
    if step_kind == "afto_scan":
        fn, args, shardings = build_train_scan(cfg, shape, mesh, hyper,
                                               chunk=scan_chunk)
    elif step_kind in ("afto_train", "cut_refresh"):
        fn, args, shardings = build_train(
            cfg, shape, mesh, hyper,
            "cut_refresh" if step_kind == "cut_refresh" else "train")
    elif step_kind == "plain_train":
        fn, args, shardings = build_plain_train(cfg, shape, mesh,
                                                unroll=unroll, remat=True)
    elif step_kind == "prefill":
        fn, args, shardings = build_prefill(cfg, shape, mesh,
                                            unroll=unroll)
    elif step_kind == "decode":
        fn, args, shardings = build_decode(cfg, shape, mesh,
                                           unroll=unroll,
                                           kv_seq_sharded=kv_seq_shard)
    else:
        raise ValueError(step_kind)

    named = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        shardings, is_leaf=lambda x: isinstance(x, P))

    with mesh:
        lowered = jax.jit(fn, in_shardings=named).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    an_total, model_flops = analytic_flops(cfg, shape, step_kind)
    report = rl.build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_kind, chips=chips,
        step_kind=step_kind, compiled=compiled,
        analytic_flops_total=an_total, model_flops_total=model_flops)
    out = report.to_json()
    out.update({"status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "layer_mode": layer_mode, "cut_mode": cut_mode,
                "attn_impl": attn_impl, "kv_seq_shard": kv_seq_shard,
                "tag": os.environ.get("HILLCLIMB_TAG", "")})
    if verbose:
        ma = compiled.memory_analysis()
        print(f"== {arch} x {shape_name} x {mesh_kind} [{step_kind}] ==")
        print(f"  memory_analysis: arg={ma.argument_size_in_bytes/1e9:.2f}GB"
              f" temp={ma.temp_size_in_bytes/1e9:.2f}GB"
              f" out={ma.output_size_in_bytes/1e9:.2f}GB per device")
        ca = compiled.cost_analysis()
        print(f"  cost_analysis: flops/dev={ca.get('flops', 0):.3e}"
              f" bytes/dev={ca.get('bytes accessed', 0):.3e}")
        t = report.terms()
        print(f"  roofline: compute={t['compute_corrected_s']*1e3:.2f}ms"
              f" memory={t['memory_s']*1e3:.2f}ms"
              f" collective={t['collective_s']*1e3:.2f}ms"
              f" dominant={report.dominant()}"
              f" useful_ratio={t['useful_ratio']:.2f}")
        print(f"  collectives: {report.coll_bytes}")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--step", default=None,
                    choices=[None, "afto_train", "afto_scan", "plain_train",
                             "prefill", "decode", "cut_refresh"])
    ap.add_argument("--scan-chunk", type=int, default=4,
                    help="iterations per compiled-trajectory slice for "
                         "--step afto_scan")
    ap.add_argument("--cut-mode", default="exact",
                    choices=["exact", "sketch"])
    ap.add_argument("--p-max", type=int, default=2)
    ap.add_argument("--first-order", action="store_true",
                    help="first-order cuts: stop-grad through the inner "
                         "rollout at cut generation (perf lever)")
    ap.add_argument("--shard-head-dim", action="store_true",
                    help="shard head_dim over the model axis when the "
                         "head count doesn't divide it (perf lever)")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="context-parallel decode: shard the KV cache "
                         "sequence dim over the data axis")
    ap.add_argument("--attn-impl", default="naive",
                    choices=["naive", "chunked"])
    ap.add_argument("--sketch-r", type=int, default=4096)
    ap.add_argument("--layer-mode", default="unroll",
                    choices=["unroll", "scan"],
                    help="unroll = exact cost analysis (roofline table); "
                         "scan = compact HLO, fast compile (multipod "
                         "lowering proof)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for --mesh")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    combos = []
    if args.all:
        from repro.configs.shapes import INPUT_SHAPES
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    global HEAD_DIM_FALLBACK
    HEAD_DIM_FALLBACK = args.shard_head_dim

    failures = 0
    for arch, shape in combos:
        try:
            res = run_one(arch, shape, args.mesh, step=args.step,
                          cut_mode=args.cut_mode, p_max=args.p_max,
                          layer_mode=args.layer_mode,
                          attn_impl=args.attn_impl,
                          sketch_r=args.sketch_r,
                          kv_seq_shard=args.kv_seq_shard,
                          first_order=args.first_order,
                          scan_chunk=args.scan_chunk)
        except Exception as e:  # a dry-run failure is a bug in the system
            traceback.print_exc()
            res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "status": "error", "error": repr(e)}
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
