"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; everything else sees the real single-device CPU).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU tests (requires >= n_data*n_model fake devices)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
