"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; everything else sees the real single-device CPU).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU tests (requires >= n_data*n_model fake devices)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_worker_mesh(n_shards: int, axis_name: str = "worker"):
    """1-D federation mesh for the sharded trajectory engine
    (`repro.core.engine.run_scanned(mesh=...)`): `n_shards` devices, one
    axis.  Uses the classic Mesh API so fake-device CPU runs (set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes) work on every jax the repo supports.  `axis_name`
    defaults to the engine's "worker"; `launch.train --mesh-workers`
    passes "data" to reuse the LLM zoo's worker-axis partitioning rules.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    if len(devices) < n_shards:
        raise ValueError(
            f"worker mesh needs {n_shards} devices but only "
            f"{len(devices)} are visible; launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} (before "
            "jax initializes) for a fake-device CPU mesh")
    return Mesh(np.asarray(devices[:n_shards]), (axis_name,))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
