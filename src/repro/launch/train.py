"""End-to-end training driver (deliverable b).

Trains a reduced (or xlstm-125m-class) model with the federated trilevel
AFTO step — or plain AdamW for comparison — on synthetic token streams,
with checkpointing and loss logging.  Runs on CPU.

The default `--engine scan` drives `--scan-chunk`-sized chunks of the
trajectory (default: `--log-every`, keeping the old behavior) inside
one donated-buffer `lax.scan` over a precomputed straggler schedule
(one XLA dispatch per chunk instead of one per master iteration);
`--engine eager` keeps the per-step host loop.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --reduced --steps 200 --mode afto
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config, reduced as reduce_cfg
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.data.synthetic import make_token_stream
from repro.fed.trilevel_llm import (FedHyper, afto_llm_step, cut_refresh_llm,
                                    init_fed_state, plain_train_step)
from repro.models import transformer as tfm
from repro.optim import adamw


def _chunk_tokens(cfg, args, start: int, stop: int) -> np.ndarray:
    n, b, s = args.workers, args.batch, args.seq
    return np.stack([
        np.asarray(make_token_stream(cfg.vocab_size, n * b, s,
                                     seed=args.seed * 7919 + it))
        .reshape(n, b, s)
        for it in range(start, stop)])


def _worker_mesh_put(state, n_shards):
    """Place the fed state on an `n_shards`-device worker mesh: stacked
    per-worker leaves (X-stacks, duals, stale views) shard their leading
    N axis over the mesh's "data" axis and the cut b-blocks shard their
    worker axis; master leaves replicate.  Returns (mesh, state,
    batch_sharding_fn) — GSPMD then partitions the chunked scan over
    workers, riding the same fake-device XLA_FLAGS machinery as the
    dry-run (launch with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_worker_mesh

    mesh = make_worker_mesh(n_shards, axis_name="data")
    stacked = {"X1", "X2", "X3", "theta", "stale_lam", "stale_theta",
               "z2"}
    cut_fields = {"cuts", "cuts_i"}

    def rule(path, leaf):
        names = [str(e.name) for e in path
                 if isinstance(e, jax.tree_util.GetAttrKey)]
        head = names[0] if names else ""
        if head in stacked and leaf.ndim >= 1 \
                and leaf.shape[0] % n_shards == 0:
            return P("data")
        if head in cut_fields and ("b2" in names or "b3" in names) \
                and leaf.ndim >= 2 and leaf.shape[1] % n_shards == 0:
            return P(None, "data")
        return P()

    specs = jax.tree_util.tree_map_with_path(rule, state)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, named)

    def put_batch(toks, masks):
        """tokens (chunk, N, b, s) / masks (chunk, N): worker axis 1."""
        tok_s = NamedSharding(mesh, P(None, "data"))
        return (jax.device_put(toks, tok_s), jax.device_put(masks, tok_s))

    return mesh, state, put_batch


def run_afto_scan(cfg, args, hyper, state, sched, val_loss) -> dict:
    """Chunked compiled trajectory: `--scan-chunk` master iterations per
    donated-buffer lax.scan dispatch (defaulting to `--log-every`, the
    pre-flag behavior), schedule precomputed up front.

    Decoupling the dispatch granularity from the logging stride lets the
    chunk grow to amortize dispatch overhead at real model scale while
    keeping the log cadence; losses are still evaluated at chunk
    boundaries, so a chunk larger than `log_every` logs once per chunk
    (at the first crossed `log_every` boundary).  `--mesh-workers N`
    additionally distributes the federation over an N-device worker
    mesh (`_worker_mesh_put`)."""
    schedule = sched.precompute(args.steps)
    chunk = max(1, args.scan_chunk or args.log_every)
    # init_fed_state may alias buffers across fields; donation needs
    # each buffer to appear once.
    state = jax.tree.map(jnp.array, state)
    put_batch = None
    if args.mesh_workers:
        mesh, state, put_batch = _worker_mesh_put(state, args.mesh_workers)
        print(f"worker mesh: {dict(mesh.shape)} over "
              f"{args.workers} federated workers")

    def body(st, xs):
        toks, mask, it = xs
        batch = {"tokens": toks, "val_tokens": toks}
        st = afto_llm_step(cfg, hyper, st, batch, mask)
        st = jax.lax.cond(
            ((it + 1) % args.t_pre == 0) & (it < args.t1),
            lambda s2: cut_refresh_llm(cfg, hyper, s2, batch),
            lambda s2: s2, st)
        return st, None

    @partial(jax.jit, donate_argnums=(0,))
    def run_chunk(st, toks, masks, its):
        st, _ = jax.lax.scan(body, st, (toks, masks, its))
        return st

    history = []
    t0 = time.time()
    for start in range(0, args.steps, chunk):
        stop = min(start + chunk, args.steps)
        toks = _chunk_tokens(cfg, args, start, stop)
        toks = jnp.asarray(toks)
        masks = jnp.asarray(schedule.active[start:stop])
        if put_batch is not None:
            toks, masks = put_batch(toks, masks)
        state = run_chunk(state, toks, masks,
                          jnp.arange(start, stop, dtype=jnp.int32))
        # log whenever a log_every boundary was crossed inside the chunk
        # (every chunk when chunk == log_every, the default) or at the end
        if (stop // args.log_every > start // args.log_every
                or stop == args.steps):
            w = jax.tree.map(lambda x: x[0], state.X3)
            loss = float(val_loss(w, jnp.asarray(toks[-1][0])))
            history.append({"step": stop, "loss": loss,
                            "sim_time": float(schedule.sim_time[stop - 1]),
                            "host_s": round(time.time() - t0, 1),
                            "cuts": float(jnp.sum(state.cuts.active))})
            print(json.dumps(history[-1]))
        # save whenever a ckpt_every boundary was crossed inside the chunk
        if args.ckpt_dir and stop // args.ckpt_every > start // args.ckpt_every:
            save_checkpoint(args.ckpt_dir, state.z3, stop)
    return {"history": history}


def run_afto(cfg, args) -> dict:
    n, b, s = args.workers, args.batch, args.seq
    hyper = FedHyper(n_workers=n, cut_mode=args.cut_mode,
                     sketch_r=args.sketch_r, p_max=2, k_inner=1,
                     remat=False, eta_x=args.lr, eta_z=args.lr)
    state = init_fed_state(cfg, hyper, jax.random.PRNGKey(args.seed),
                           b, s - 1)
    val_loss = jax.jit(lambda w, tk: tfm.train_loss(cfg, w, tk))
    sched = StragglerScheduler(StragglerConfig(
        n_workers=n, s_active=max(1, n - 1), tau=args.tau,
        n_stragglers=1, seed=args.seed))

    if args.engine == "scan":
        return run_afto_scan(cfg, args, hyper, state, sched, val_loss)
    if args.mesh_workers:
        raise ValueError("--mesh-workers requires --engine scan")

    step = jax.jit(lambda st, bt, m: afto_llm_step(cfg, hyper, st, bt, m))
    refresh = jax.jit(lambda st, bt: cut_refresh_llm(cfg, hyper, st, bt))
    history = []
    t0 = time.time()
    for it in range(args.steps):
        toks = make_token_stream(cfg.vocab_size, n * b, s,
                                 seed=args.seed * 7919 + it)
        toks = jnp.asarray(toks).reshape(n, b, s)
        batch = {"tokens": toks, "val_tokens": toks}
        mask, sim_t = sched.next_active()
        state = step(state, batch, jnp.asarray(mask))
        if (it + 1) % args.t_pre == 0 and it < args.t1:
            state = refresh(state, batch)
        if (it + 1) % args.log_every == 0 or it == args.steps - 1:
            w = jax.tree.map(lambda x: x[0], state.X3)
            loss = float(val_loss(w, toks[0]))
            history.append({"step": it + 1, "loss": loss,
                            "sim_time": sim_t,
                            "host_s": round(time.time() - t0, 1),
                            "cuts": float(jnp.sum(state.cuts.active))})
            print(json.dumps(history[-1]))
        if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, state.z3, it + 1)
    return {"history": history}


def run_plain(cfg, args) -> dict:
    params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    step = jax.jit(lambda p, o, tk: plain_train_step(
        cfg, p, o, tk, optimizer=opt, remat=False))
    history = []
    t0 = time.time()
    b = args.workers * args.batch
    for it in range(args.steps):
        toks = jnp.asarray(make_token_stream(
            cfg.vocab_size, b, args.seq, seed=args.seed * 7919 + it))
        params, opt_state, loss = step(params, opt_state, toks)
        if (it + 1) % args.log_every == 0 or it == args.steps - 1:
            history.append({"step": it + 1, "loss": float(loss),
                            "host_s": round(time.time() - t0, 1)})
            print(json.dumps(history[-1]))
        if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, params, it + 1)
    return {"history": history}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--mode", default="afto", choices=["afto", "plain"])
    ap.add_argument("--engine", default="scan", choices=["scan", "eager"],
                    help="scan = chunked compiled trajectory (default); "
                         "eager = one dispatch per master iteration")
    ap.add_argument("--cut-mode", default="sketch",
                    choices=["sketch", "exact"])
    ap.add_argument("--sketch-r", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2,
                    help="per-worker batch")
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--t-pre", type=int, default=20)
    ap.add_argument("--t1", type=int, default=10_000)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--scan-chunk", type=int, default=None,
                    help="master iterations per compiled scan dispatch "
                         "(--engine scan); defaults to --log-every. "
                         "Larger chunks amortize dispatch overhead at "
                         "real model scale independently of the log "
                         "cadence")
    ap.add_argument("--mesh-workers", type=int, default=None,
                    help="distribute the federation over this many "
                         "devices (--engine scan): worker-stacked state "
                         "and cut b-blocks shard over a 1-axis mesh. "
                         "Needs >= N visible devices — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for "
                         "a fake-device CPU mesh (the dry-run machinery)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"training {cfg.name} mode={args.mode} steps={args.steps}")
    if args.mode == "afto":
        run_afto(cfg, args)
    else:
        run_plain(cfg, args)


if __name__ == "__main__":
    main()
