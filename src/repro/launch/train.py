"""End-to-end training driver (deliverable b).

Trains a reduced (or xlstm-125m-class) model with the federated trilevel
AFTO step — or plain AdamW for comparison — on synthetic token streams,
with checkpointing and loss logging.  Runs on CPU.

The default `--engine scan` drives `--scan-chunk`-sized chunks of the
trajectory (default: `--log-every`, keeping the old behavior) inside
one donated-buffer `lax.scan` over a precomputed straggler schedule
(one XLA dispatch per chunk instead of one per master iteration);
`--engine eager` keeps the per-step host loop.

`--stream` makes the scan DEVICE-RESIDENT end to end: worker token
batches are synthesized inside the scan body from fold-in PRNG keys
(`repro.fed.trilevel_llm.batch_stream`), the base key and the chunk
cursor ride the donated carry across chunk dispatches, and the whole
schedule's masks live on the device — chunk boundaries transfer NO
token data to the device (only losses/checkpoints come back out).

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --reduced --steps 200 --mode afto --stream
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config, reduced as reduce_cfg
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.data import stream as stream_lib
from repro.data.synthetic import make_token_stream
from repro.fed.trilevel_llm import (FedHyper, afto_llm_step, batch_stream,
                                    cut_refresh_llm, init_fed_state,
                                    plain_train_step)
from repro.models import transformer as tfm
from repro.optim import adamw

# How many times each chunked-scan runner actually traced (python
# side-effect at trace time): warm equal-size chunks must reuse the jit
# cache — a retrace would silently break donation and recompile per
# chunk.  tests/test_launchers.py asserts these stay flat.
SCAN_TRACES = {"host": 0, "stream": 0}


def _chunk_tokens(cfg, args, start: int, stop: int) -> np.ndarray:
    n, b, s = args.workers, args.batch, args.seq
    return np.stack([
        np.asarray(make_token_stream(cfg.vocab_size, n * b, s,
                                     seed=args.seed * 7919 + it))
        .reshape(n, b, s)
        for it in range(start, stop)])


def _worker_mesh_put(state, n_shards):
    """Place the fed state on an `n_shards`-device worker mesh: stacked
    per-worker leaves (X-stacks, duals, stale views) shard their leading
    N axis over the mesh's "data" axis and the cut b-blocks shard their
    worker axis; master leaves replicate.  Returns (mesh, state,
    batch_sharding_fn, state_shardings) — GSPMD then partitions the
    chunked scan over workers, riding the same fake-device XLA_FLAGS
    machinery as the dry-run (launch with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    `state_shardings` pins the chunk runners' state out_shardings to
    these input shardings: without it GSPMD is free to hand the state
    back in a different layout, and every warm chunk then misses the
    executable cache and recompiles (same trace, new shardings)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_worker_mesh

    mesh = make_worker_mesh(n_shards, axis_name="data")
    stacked = {"X1", "X2", "X3", "theta", "stale_lam", "stale_theta",
               "z2"}
    cut_fields = {"cuts", "cuts_i"}

    def rule(path, leaf):
        names = [str(e.name) for e in path
                 if isinstance(e, jax.tree_util.GetAttrKey)]
        head = names[0] if names else ""
        if head in stacked and leaf.ndim >= 1 \
                and leaf.shape[0] % n_shards == 0:
            return P("data")
        if head in cut_fields and ("b2" in names or "b3" in names) \
                and leaf.ndim >= 2 and leaf.shape[1] % n_shards == 0:
            return P(None, "data")
        return P()

    specs = jax.tree_util.tree_map_with_path(rule, state)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, named)

    def put_batch(*arrays):
        """Arrays with the worker axis second — tokens (chunk, N, b, s),
        masks (chunk, N) or (T, N) — shard axis 1 over the mesh."""
        tok_s = NamedSharding(mesh, P(None, "data"))
        return tuple(jax.device_put(a, tok_s) for a in arrays)

    return mesh, state, put_batch, named


def run_afto_scan(cfg, args, hyper, state, sched, val_loss) -> dict:
    """Chunked compiled trajectory: `--scan-chunk` master iterations per
    donated-buffer lax.scan dispatch (defaulting to `--log-every`, the
    pre-flag behavior), schedule precomputed up front.

    Decoupling the dispatch granularity from the logging stride lets the
    chunk grow to amortize dispatch overhead at real model scale while
    keeping the log cadence; losses are still evaluated at chunk
    boundaries, so a chunk larger than `log_every` logs once per chunk
    (at the first crossed `log_every` boundary).  `--mesh-workers N`
    additionally distributes the federation over an N-device worker
    mesh (`_worker_mesh_put`).

    With `--stream` the per-chunk host token synthesis + transfer
    (`_chunk_tokens` / `jnp.asarray`) disappears entirely: the scan body
    draws each iteration's worker batches from fold-in keys on the
    absolute iteration, and the chunk loop's whole device input is the
    donated (state, key, cursor) carry — the schedule masks are put on
    the device once and sliced in-dispatch, so warm equal-size chunks
    do zero host→device transfers."""
    schedule = sched.precompute(args.steps)
    chunk = max(1, args.scan_chunk or args.log_every)
    # init_fed_state may alias buffers across fields; donation needs
    # each buffer to appear once.
    state = jax.tree.map(jnp.array, state)
    if getattr(args, "resume", False) and args.mesh_workers:
        raise ValueError("--resume with --mesh-workers is not supported "
                         "yet (restore precedes mesh placement)")
    put_batch = state_shardings = None
    if args.mesh_workers:
        mesh, state, put_batch, state_shardings = _worker_mesh_put(
            state, args.mesh_workers)
        print(f"worker mesh: {dict(mesh.shape)} over "
              f"{args.workers} federated workers")

    def step(st, batch, mask, it):
        st = afto_llm_step(cfg, hyper, st, batch, mask)
        return jax.lax.cond(
            ((it + 1) % args.t_pre == 0) & (it < args.t1),
            lambda s2: cut_refresh_llm(cfg, hyper, s2, batch),
            lambda s2: s2, st)

    if getattr(args, "stream", False):
        return _afto_scan_streamed(cfg, args, state, schedule, chunk,
                                   step, put_batch, val_loss,
                                   state_shardings)

    def body(st, xs):
        toks, mask, it = xs
        return step(st, {"tokens": toks, "val_tokens": toks}, mask, it), \
            None

    @partial(jax.jit, donate_argnums=(0,), out_shardings=state_shardings)
    def run_chunk(st, toks, masks, its):
        SCAN_TRACES["host"] += 1
        st, _ = jax.lax.scan(body, st, (toks, masks, its))
        return st

    last_toks = None     # the live chunk's tokens, for the loss slice

    def one_chunk(st, start, stop):
        nonlocal last_toks
        toks = jnp.asarray(_chunk_tokens(cfg, args, start, stop))
        masks = jnp.asarray(schedule.active[start:stop])
        if put_batch is not None:
            toks, masks = put_batch(toks, masks)
        last_toks = toks
        return run_chunk(st, toks, masks,
                         jnp.arange(start, stop, dtype=jnp.int32))

    def loss_at(st, stop):
        w = jax.tree.map(lambda x: x[0], st.X3)
        return val_loss(w, jnp.asarray(last_toks[-1][0]))

    carry, start0 = _maybe_resume(args, {"state": state})
    return _chunk_loop(args, schedule, chunk, carry["state"], one_chunk,
                       loss_at, carry_to_save=lambda st: {"state": st},
                       start=start0)


def _maybe_resume(args, template):
    """(carry, start_step): restore the latest full-carry checkpoint from
    `--ckpt-dir` when `--resume` is set, else the template untouched.

    The restored carry is exactly what `_chunk_loop` saved at a chunk
    boundary — for the streamed path (state, key, cursor), i.e. the
    whole donated scan carry — so continuing from it is bit-identical to
    the uninterrupted run by the chunking-invariance contract (schedule
    masks and stream batches key on the absolute iteration)."""
    if not (getattr(args, "resume", False) and args.ckpt_dir):
        return template, 0
    step = latest_step(args.ckpt_dir)
    if step is None:
        return template, 0
    carry = load_checkpoint(args.ckpt_dir, template, step)
    carry = jax.tree.map(
        lambda t, v: jnp.asarray(v, getattr(t, "dtype", None)),
        template, carry)
    print(json.dumps({"resumed_from": step, "ckpt_dir": args.ckpt_dir}))
    return carry, step


def _chunk_loop(args, schedule, chunk, state, one_chunk, loss_at,
                carry_to_save=None, start: int = 0) -> dict:
    """The chunk-dispatch loop shared by the host-fed and streamed scan
    drivers: log whenever a `log_every` boundary was crossed inside the
    chunk (every chunk when chunk == log_every, the default) or at the
    final — possibly partial — chunk, and save whenever a `ckpt_every`
    boundary was crossed.  `one_chunk(state, start, stop)` advances the
    donated carry; `loss_at(state, stop)` evaluates worker 0's
    validation loss at iteration stop - 1; `carry_to_save(state)` is the
    checkpoint payload — the FULL restart carry for the scan drivers
    (legacy z3-only when unset).  `start` > 0 continues a resumed run
    from that absolute step."""
    history = []
    t0 = time.time()
    for begin in range(start, args.steps, chunk):
        stop = min(begin + chunk, args.steps)
        state = one_chunk(state, begin, stop)
        if (stop // args.log_every > begin // args.log_every
                or stop == args.steps):
            history.append({"step": stop, "loss": float(loss_at(state, stop)),
                            "sim_time": float(schedule.sim_time[stop - 1]),
                            "host_s": round(time.time() - t0, 1),
                            "cuts": float(jnp.sum(state.cuts.active))})
            print(json.dumps(history[-1]))
        if args.ckpt_dir and stop // args.ckpt_every > begin // args.ckpt_every:
            save_checkpoint(
                args.ckpt_dir,
                carry_to_save(state) if carry_to_save else state.z3,
                stop)
    return {"history": history}


def _afto_scan_streamed(cfg, args, state, schedule, chunk, step,
                        put_batch, val_loss, state_shardings) -> dict:
    """The `--stream` chunk driver: tokens synthesized in-scan, (state,
    key, cursor) donated across chunk dispatches, masks device-resident
    and sliced in-dispatch (`_chunk_loop` holds the boundary logic)."""
    stream = batch_stream(cfg, args.workers, args.batch, args.seq,
                          seed=args.seed)
    spec = stream.spec

    key = jnp.asarray(stream.key)
    cursor = jnp.zeros((), jnp.int32)
    carry, start0 = _maybe_resume(
        args, {"state": state, "key": key, "cursor": cursor})
    state, key, cursor = carry["state"], carry["key"], carry["cursor"]
    out_shardings = None
    if state_shardings is not None:
        # commit the scalar carry replicated and pin the outputs to the
        # input layout, so warm chunks hit the executable cache
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(
            jax.tree.leaves(state_shardings)[0].mesh, P())
        key, cursor = jax.device_put((key, cursor), rep)
        out_shardings = (state_shardings, rep, rep)

    def body(carry, xs):
        st, key = carry
        mask, it = xs
        batch = stream_lib.batch_at(spec, key, it)
        return (step(st, batch, mask, it), key), None

    @partial(jax.jit, static_argnames=("n",), donate_argnums=(0, 1, 2),
             out_shardings=out_shardings)
    def run_chunk(st, key, start, masks, n):
        SCAN_TRACES["stream"] += 1
        its = start + jnp.arange(n, dtype=jnp.int32)
        mk = jax.lax.dynamic_slice_in_dim(masks, start, n)
        (st, key), _ = jax.lax.scan(body, (st, key), (mk, its))
        return st, key, start + n

    @jax.jit
    def val_at(w, key, it):
        # worker 0's tokens at iteration `it` — the streamed stand-in
        # for the host path's `toks[-1][0]` validation slice
        toks = stream_lib.batch_at(spec, key, it, n_local=1)["tokens"][0]
        return val_loss(w, toks)

    masks = jnp.asarray(schedule.active, jnp.float32)
    if put_batch is not None:
        masks, = put_batch(masks)

    def one_chunk(st, start, stop):
        nonlocal key, cursor
        st, key, cursor = run_chunk(st, key, cursor, masks,
                                    n=stop - start)
        return st

    def loss_at(st, stop):
        w = jax.tree.map(lambda x: x[0], st.X3)
        return val_at(w, key, jnp.asarray(stop - 1, jnp.int32))

    def carry_to_save(st):
        # the WHOLE donated carry: restoring (state, key, cursor) and
        # continuing is bit-identical to the uninterrupted run
        return {"state": st, "key": key, "cursor": cursor}

    return _chunk_loop(args, schedule, chunk, state, one_chunk, loss_at,
                       carry_to_save=carry_to_save, start=start0)


def _afto_setup(cfg, args):
    """(hyper, state, sched, val_loss) for the AFTO drivers — split out
    so tests exercise `run_afto_scan` in-process."""
    n, b, s = args.workers, args.batch, args.seq
    hyper = FedHyper(n_workers=n, cut_mode=args.cut_mode,
                     sketch_r=args.sketch_r, p_max=2, k_inner=1,
                     remat=False, eta_x=args.lr, eta_z=args.lr)
    state = init_fed_state(cfg, hyper, jax.random.PRNGKey(args.seed),
                           b, s - 1)
    val_loss = jax.jit(lambda w, tk: tfm.train_loss(cfg, w, tk))
    sched = StragglerScheduler(StragglerConfig(
        n_workers=n, s_active=max(1, n - 1), tau=args.tau,
        n_stragglers=1, seed=args.seed))
    return hyper, state, sched, val_loss


def run_afto(cfg, args) -> dict:
    hyper, state, sched, val_loss = _afto_setup(cfg, args)

    if args.engine == "scan":
        return run_afto_scan(cfg, args, hyper, state, sched, val_loss)
    if args.mesh_workers:
        raise ValueError("--mesh-workers requires --engine scan")
    if getattr(args, "stream", False):
        raise ValueError("--stream requires --engine scan")
    n, b, s = args.workers, args.batch, args.seq

    step = jax.jit(lambda st, bt, m: afto_llm_step(cfg, hyper, st, bt, m))
    refresh = jax.jit(lambda st, bt: cut_refresh_llm(cfg, hyper, st, bt))
    history = []
    t0 = time.time()
    for it in range(args.steps):
        toks = make_token_stream(cfg.vocab_size, n * b, s,
                                 seed=args.seed * 7919 + it)
        toks = jnp.asarray(toks).reshape(n, b, s)
        batch = {"tokens": toks, "val_tokens": toks}
        mask, sim_t = sched.next_active()
        state = step(state, batch, jnp.asarray(mask))
        if (it + 1) % args.t_pre == 0 and it < args.t1:
            state = refresh(state, batch)
        if (it + 1) % args.log_every == 0 or it == args.steps - 1:
            w = jax.tree.map(lambda x: x[0], state.X3)
            loss = float(val_loss(w, toks[0]))
            history.append({"step": it + 1, "loss": loss,
                            "sim_time": sim_t,
                            "host_s": round(time.time() - t0, 1),
                            "cuts": float(jnp.sum(state.cuts.active))})
            print(json.dumps(history[-1]))
        if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, state.z3, it + 1)
    return {"history": history}


def run_plain(cfg, args) -> dict:
    params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    step = jax.jit(lambda p, o, tk: plain_train_step(
        cfg, p, o, tk, optimizer=opt, remat=False))
    history = []
    t0 = time.time()
    b = args.workers * args.batch
    for it in range(args.steps):
        toks = jnp.asarray(make_token_stream(
            cfg.vocab_size, b, args.seq, seed=args.seed * 7919 + it))
        params, opt_state, loss = step(params, opt_state, toks)
        if (it + 1) % args.log_every == 0 or it == args.steps - 1:
            history.append({"step": it + 1, "loss": float(loss),
                            "host_s": round(time.time() - t0, 1)})
            print(json.dumps(history[-1]))
        if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, params, it + 1)
    return {"history": history}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--mode", default="afto", choices=["afto", "plain"])
    ap.add_argument("--engine", default="scan", choices=["scan", "eager"],
                    help="scan = chunked compiled trajectory (default); "
                         "eager = one dispatch per master iteration")
    ap.add_argument("--cut-mode", default="sketch",
                    choices=["sketch", "exact"])
    ap.add_argument("--sketch-r", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2,
                    help="per-worker batch")
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--t-pre", type=int, default=20)
    ap.add_argument("--t1", type=int, default=10_000)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--stream", action="store_true",
                    help="device-resident token stream (--engine scan): "
                         "worker batches are synthesized inside the "
                         "scan body from fold-in PRNG keys instead of "
                         "host numpy chunks, and the key/cursor carry "
                         "is donated across chunk dispatches — chunk "
                         "boundaries transfer no token data")
    ap.add_argument("--scan-chunk", type=int, default=None,
                    help="master iterations per compiled scan dispatch "
                         "(--engine scan); defaults to --log-every. "
                         "Larger chunks amortize dispatch overhead at "
                         "real model scale independently of the log "
                         "cadence")
    ap.add_argument("--mesh-workers", type=int, default=None,
                    help="distribute the federation over this many "
                         "devices (--engine scan): worker-stacked state "
                         "and cut b-blocks shard over a 1-axis mesh. "
                         "Needs >= N visible devices — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for "
                         "a fake-device CPU mesh (the dry-run machinery)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest full-carry checkpoint from "
                         "--ckpt-dir and continue from its step "
                         "(--engine scan; bit-identical to the "
                         "uninterrupted run)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"training {cfg.name} mode={args.mode} steps={args.steps}")
    if args.mode == "afto":
        run_afto(cfg, args)
    else:
        run_plain(cfg, args)


if __name__ == "__main__":
    main()
