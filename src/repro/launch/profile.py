import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (device-count override must precede jax import, as in dryrun.py)

_DOC = """HLO profile inspector: the dry-run's "profiler" (no real TPU).

Prints, for one (arch x shape x mesh):
  * op-kind histogram of the optimized HLO (what the program is made of),
  * every collective instruction with its shape/bytes (the collective
    schedule the roofline term summarizes),
  * the top-k largest tensors materialized (where the memory term
    comes from).

  PYTHONPATH=src python -m repro.launch.profile --arch llama3-8b \
      --shape train_4k --mesh pod --top 15
"""
__doc__ = _DOC

import argparse
import re
from collections import Counter

from repro.launch import roofline as rl


def op_histogram(hlo: str) -> Counter:
    ops = Counter()
    for line in hlo.splitlines():
        m = re.match(r"\s*(%?[\w.\-]+) = (.*?) ([\w\-]+)\(", line)
        if m:
            ops[m.group(3)] += 1
    return ops


def largest_tensors(hlo: str, top: int = 15):
    out = []
    for line in hlo.splitlines():
        m = re.match(r"\s*%?[\w.\-]+ = (.*?) ([\w\-]+)\(", line)
        if not m:
            continue
        b = rl._shape_bytes(m.group(1))
        if b:
            out.append((b, m.group(2), m.group(1)[:70]))
    out.sort(key=lambda x: -x[0])
    return out[:top]


def collectives(hlo: str):
    rows = []
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (%?[\w\-]+)\(", line)
        if m and m.group(2).lstrip("%").replace("-start", "") \
                in rl._COLLECTIVES:
            rows.append((m.group(2), rl._shape_bytes(m.group(1)),
                         m.group(1)[:60]))
    return rows


def main():
    from repro.launch import dryrun as dr

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--step", default=None)
    ap.add_argument("--layer-mode", default="scan",
                    choices=["scan", "unroll"])
    ap.add_argument("--cut-mode", default="exact",
                    choices=["exact", "sketch"])
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, get_shape
    from repro.fed.trilevel_llm import FedHyper
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    hyper = FedHyper(n_workers=dr._n_workers(mesh),
                     cut_mode=args.cut_mode, p_max=2, k_inner=1,
                     remat=True, unroll=(args.layer_mode == "unroll"))
    step_kind = args.step or dr.default_step_kind(shape)
    if step_kind in ("afto_train", "cut_refresh"):
        fn, a, sh = dr.build_train(cfg, shape, mesh, hyper,
                                   "cut_refresh" if step_kind ==
                                   "cut_refresh" else "train")
    elif step_kind == "prefill":
        fn, a, sh = dr.build_prefill(cfg, shape, mesh,
                                     hyper.unroll)
    else:
        fn, a, sh = dr.build_decode(cfg, shape, mesh, hyper.unroll)
    named = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        sh, is_leaf=lambda x: isinstance(x, P))
    with mesh:
        compiled = jax.jit(fn, in_shardings=named).lower(*a).compile()
    hlo = compiled.as_text()

    print(f"== op histogram ({args.arch} x {args.shape} x {args.mesh}, "
          f"{step_kind}) ==")
    for op, n in op_histogram(hlo).most_common(20):
        print(f"  {op:>24s} {n}")
    print("\n== collectives (schedule order) ==")
    for op, b, shp in collectives(hlo):
        print(f"  {op:>24s} {b/1e6:12.1f} MB  {shp}")
    print(f"\n== top-{args.top} largest tensors ==")
    for b, op, shp in largest_tensors(hlo, args.top):
        print(f"  {b/1e9:8.2f} GB  {op:>18s}  {shp}")


if __name__ == "__main__":
    main()
