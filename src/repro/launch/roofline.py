"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (deliverable g):

  compute    = HLO_FLOPs_total   / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes_total   / (chips * 819 GB/s HBM)
  collective = collective_bytes  / (chips * 50 GB/s ICI link)

Sourcing notes (measured behaviour of jax 0.8.2 / XLA CPU AOT):
  * `compiled.cost_analysis()` reports PER-DEVICE numbers after SPMD
    partitioning -> multiply by chips for the totals above.
  * a `lax.scan` body is counted ONCE regardless of trip count.  The
    dry-run therefore python-unrolls the layer loop; the remaining
    sequence-chunk scans (mamba / mLSTM chunks) are corrected with the
    analytic `step_flops` model, and we report both raw and corrected.
  * collective bytes are parsed from `compiled.as_text()`: the sum of
    output-shape bytes of every all-reduce / all-gather / reduce-scatter
    / all-to-all / collective-permute instruction (output size ~ operand
    size for all-reduce; for all-gather this upper-bounds the wire
    bytes).  Instructions inside while-loop bodies appear once; with the
    layer loop unrolled the only looped collectives are the small chunk
    scans, noted per-arch.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "f32[16,128]{1,0}" or "bf16[4096]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (%?[\w\-]+)\(", line)
        if not m:
            continue
        op = m.group(2).lstrip("%")
        # start ops appear as "all-reduce-start" etc.
        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(m.group(1))
            out["count"] += 1
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    step_kind: str
    # raw per-device numbers from cost_analysis
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    # memory_analysis (per device)
    arg_bytes: float
    temp_bytes: float
    out_bytes: float
    # HLO-text collectives (whole-program, already per-device SPMD module)
    coll_bytes: Dict[str, int]
    # analytic
    analytic_flops_total: float
    model_flops_total: float      # 6 * N_active * tokens

    def terms(self) -> Dict[str, float]:
        flops_total = self.hlo_flops_per_dev * self.chips
        # scan-mode undercount correction: the analytic model is the
        # floor (see module docstring); useful_ratio uses the corrected
        # figure so scan rows don't report >1 "useful" compute.
        flops_corr = max(flops_total, self.analytic_flops_total)
        coll = sum(v for k, v in self.coll_bytes.items() if k != "count")
        return {
            "compute_s": flops_total / (self.chips * PEAK_FLOPS_BF16),
            "compute_corrected_s":
                flops_corr / (self.chips * PEAK_FLOPS_BF16),
            "memory_s": (self.hlo_bytes_per_dev * self.chips)
                / (self.chips * HBM_BW),
            "collective_s": coll / (self.chips * ICI_BW),
            "useful_ratio": (self.model_flops_total
                             / max(flops_corr, 1.0)),
            "hbm_gb_per_dev": (self.arg_bytes + self.temp_bytes
                               + self.out_bytes) / 1e9,
        }

    def dominant(self) -> str:
        t = self.terms()
        kinds = {"compute": t["compute_corrected_s"],
                 "memory": t["memory_s"],
                 "collective": t["collective_s"]}
        return max(kinds, key=kinds.get)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(self.terms())
        d["dominant"] = self.dominant()
        return d


def build_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                 step_kind: str, compiled, analytic_flops_total: float,
                 model_flops_total: float) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        step_kind=step_kind,
        hlo_flops_per_dev=float(ca.get("flops", 0.0)),
        hlo_bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        arg_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        out_bytes=float(ma.output_size_in_bytes),
        coll_bytes=collective_bytes(txt),
        analytic_flops_total=analytic_flops_total,
        model_flops_total=model_flops_total)
