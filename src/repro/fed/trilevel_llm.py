"""AFTO instantiated for LLM-scale architectures (the paper's robust-HPO
trilevel, Eq. 31, with the model zoo as level 3).

Variables (DESIGN.md §5):
  x1 = phi   : per-category regularization log-strengths (d1 = 4: embed /
               mixer / mlp / other) — exact everywhere (tiny).
  x2 = p     : adversarial embedding perturbation; worker j owns block j
               (Eq. 31's p' = [p'_1..p'_N]), so local copies store only
               their own (b_local, seq, d_model) block — exact by the
               block structure of Eq. 31, not an approximation.
  x3 = w     : model weights; worker copies are a leading-(N,) stacked
               param tree sharded (worker -> data axis, tensor dims ->
               model axis).

Cut storage: phi-blocks exact; x2/x3/z2/z3 blocks either EXACT (stacked
model-sized coefficient trees — the paper-faithful baseline whose memory
blow-up the dry-run quantifies) or SKETCHED into an r-dim count-sketch
subspace (beyond-paper; see fed/sketch.py).

Worker gradients in Eq. 16 never reference the master's z directly (f1
depends only on local variables; z enters L_p through theta/lambda terms
whose x-gradients are the stale duals and cut coefficients), so the only
per-worker stale state is (theta_j, lambda) — small — and asynchrony at
LLM scale is exact, not approximated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data import stream as stream_lib
from repro.fed.sketch import sketch as _sketch, unsketch as _unsketch
from repro.models import config as mcfg
from repro.models import transformer as tfm
from repro.utils.tree import (tree_axpy, tree_dot, tree_norm_sq, tree_sub,
                              tree_zeros_like)


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields))
    return cls


# ---------------------------------------------------------------------------
# hyper / state
# ---------------------------------------------------------------------------

N_PHI = 4  # regularization categories: embed / mixer / mlp / other


@dataclasses.dataclass(frozen=True)
class FedHyper:
    n_workers: int = 16
    k_inner: int = 1
    p_max: int = 2
    cut_mode: str = "exact"        # exact | sketch
    sketch_r: int = 4096
    adv_penalty: float = 1.0       # c in Eq. 31
    eta_x: float = 1e-2
    eta_z: float = 1e-2
    eta_lambda: float = 1e-2
    eta_theta: float = 1e-2
    eta_dual_inner: float = 1e-2
    kappa3: float = 1.0
    eps_i: float = 1e-3
    eps_ii: float = 1e-3
    mu_i: float = 0.5
    mu_ii: float = 0.5
    alpha: float = 1e4             # shared variable-norm bound
    alpha4: float = 100.0
    alpha5: float = 100.0
    c1_floor: float = 1e-3
    c2_floor: float = 1e-3
    remat: bool = True
    unroll: bool = False            # python-unroll layer loops (dry-run)
    first_order_cuts: bool = False  # stop-grad through the inner rollout
    seed_i: int = 1                # sketch seeds per cut layer
    seed_ii: int = 2

    def c1(self, t):
        return jnp.maximum(self.c1_floor,
                           1.0 / (self.eta_lambda * (t + 1.0) ** 0.25))

    def c2(self, t):
        return jnp.maximum(self.c2_floor,
                           1.0 / (self.eta_theta * (t + 1.0) ** 0.25))


@dataclasses.dataclass
class LLMCutSet:
    """Cuts over (z1, z2, z3, {x2_j}, {x3_j}).

    exact mode: a2/a3 are (P,)-stacked trees, b2/b3 are (P,N,)-stacked.
    sketch mode: a2/a3 are (P, r) arrays, b2/b3 are (P, N, r)."""
    a1: jnp.ndarray               # (P, N_PHI) — always exact
    a2: Any
    a3: Any
    b2: Any
    b3: Any
    c: jnp.ndarray                # (P,)
    active: jnp.ndarray           # (P,)
    age: jnp.ndarray              # (P,)


_register(LLMCutSet, ["a1", "a2", "a3", "b2", "b3", "c", "active", "age"])


@dataclasses.dataclass
class FedLLMState:
    X1: jnp.ndarray               # (N, N_PHI)
    X2: jnp.ndarray               # (N, b_local, seq, d_model) own blocks
    X3: Any                       # (N,)-stacked model params
    z1: jnp.ndarray               # (N_PHI,)
    z2: jnp.ndarray               # (N, b_local, seq, d_model)
    z3: Any                       # model params
    theta: jnp.ndarray            # (N, N_PHI) consensus duals
    lam: jnp.ndarray              # (P,)
    cuts: LLMCutSet               # II-layer polytope (enters L_p)
    cuts_i: LLMCutSet             # I-layer polytope (enters level-2 inner)
    gamma_k: jnp.ndarray          # (P,) last inner multipliers (drop rule)
    stale_lam: jnp.ndarray        # (N, P)
    stale_theta: jnp.ndarray      # (N, N_PHI)
    t: jnp.ndarray                # iteration


_register(FedLLMState, ["X1", "X2", "X3", "z1", "z2", "z3", "theta", "lam",
                        "cuts", "cuts_i", "gamma_k", "stale_lam",
                        "stale_theta", "t"])


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_n(tree, n):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def _empty_cuts(hyper: FedHyper, x2_block, params) -> LLMCutSet:
    p, n = hyper.p_max, hyper.n_workers
    if hyper.cut_mode == "sketch":
        r = hyper.sketch_r
        a2 = jnp.zeros((p, r), jnp.float32)
        a3 = jnp.zeros((p, r), jnp.float32)
        b2 = jnp.zeros((p, n, r), jnp.float32)
        b3 = jnp.zeros((p, n, r), jnp.float32)
    else:
        def stack_p(tree):
            return jax.tree.map(
                lambda x: jnp.zeros((p,) + x.shape, x.dtype), tree)

        def stack_pn(tree):
            return jax.tree.map(
                lambda x: jnp.zeros((p, n) + x.shape, x.dtype), tree)

        a2 = stack_p(_stack_n(x2_block, n))   # z2 is the (N,...) stack
        a3 = stack_p(params)
        b2 = stack_pn(x2_block)
        b3 = stack_pn(params)
    return LLMCutSet(
        a1=jnp.zeros((p, N_PHI), jnp.float32), a2=a2, a3=a3, b2=b2, b3=b3,
        c=jnp.zeros((p,), jnp.float32),
        active=jnp.zeros((p,), jnp.float32),
        age=jnp.full((p,), -1, jnp.int32))


def init_fed_state(cfg: mcfg.ModelConfig, hyper: FedHyper, key,
                   b_local: int, seq: int) -> FedLLMState:
    n = hyper.n_workers
    params = tfm.init_params(cfg, key)
    x2_block = jnp.zeros((b_local, seq, cfg.d_model), jnp.bfloat16)
    p = hyper.p_max
    return FedLLMState(
        X1=jnp.full((n, N_PHI), -3.0, jnp.float32),
        X2=jnp.zeros((n,) + x2_block.shape, x2_block.dtype),
        X3=_stack_n(params, n),
        z1=jnp.full((N_PHI,), -3.0, jnp.float32),
        z2=jnp.zeros((n,) + x2_block.shape, x2_block.dtype),
        z3=params,
        theta=jnp.zeros((n, N_PHI), jnp.float32),
        lam=jnp.zeros((p,), jnp.float32),
        cuts=_empty_cuts(hyper, x2_block, params),
        cuts_i=_empty_cuts(hyper, x2_block, params),
        gamma_k=jnp.zeros((p,), jnp.float32),
        stale_lam=jnp.zeros((n, p), jnp.float32),
        stale_theta=jnp.zeros((n, N_PHI), jnp.float32),
        t=jnp.zeros((), jnp.int32))


def batch_stream(cfg: mcfg.ModelConfig, n_workers: int, b_local: int,
                 seq: int, seed=0, zipf_a: float = 1.2) -> stream_lib.Stream:
    """Device-resident token stream for the LLM AFTO step: each worker's
    per-iteration {tokens, val_tokens} chunk is synthesized inside the
    scan from fold-in keys (`repro.data.stream`), replacing the
    host-side `data.synthetic.make_token_stream` round-trip.  Batches
    stack to the `afto_llm_step` layout ((N, b_local, seq) int32);
    tokens double as val_tokens exactly like the host driver's chunks.
    """
    def sample(key):
        toks = stream_lib.zipf_tokens(key, (b_local, seq),
                                      cfg.vocab_size, zipf_a)
        return {"tokens": toks, "val_tokens": toks}

    return stream_lib.make_stream(sample, n_workers, seed)


# ---------------------------------------------------------------------------
# objectives (per worker)
# ---------------------------------------------------------------------------

def _phi_category(path) -> int:
    name = ""
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = str(entry.key)
            break
    if name in ("embed", "lm_head", "enc_pos"):
        return 0
    if name in ("wq", "wk", "wv", "wo", "xwq", "xwk", "xwv", "xwo",
                "in_proj", "out_proj", "conv_w", "xproj", "wz", "wo_gate",
                "rz", "a_log"):
        return 1
    if name in ("wi", "wg", "router"):
        return 2
    return 3


def reg_term(phi, params):
    """sum_cat exp(phi_cat) * ||params_cat||^2 / size_cat."""
    sq = [jnp.zeros((), jnp.float32)] * N_PHI
    cnt = [0] * N_PHI

    leaves = jax.tree_util.tree_leaves_with_path(params)
    for path, leaf in leaves:
        c = _phi_category(path)
        sq[c] = sq[c] + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
        cnt[c] += int(leaf.size)
    total = jnp.zeros((), jnp.float32)
    for c in range(N_PHI):
        if cnt[c]:
            total = total + jnp.exp(phi[c]) * sq[c] / cnt[c]
    return total


def f1_loss(cfg, w_j, batch_j, hyper: FedHyper):
    """Clean validation CE for one worker."""
    return tfm.train_loss(cfg, w_j, batch_j["val_tokens"],
                          batch_j.get("val_frames"), remat=hyper.remat,
                          unroll=hyper.unroll)


def f3_loss(cfg, phi, p_j, w_j, batch_j, hyper: FedHyper):
    """Perturbed train CE + e^phi regularization (level 3, minimized)."""
    ce = tfm.train_loss(cfg, w_j, batch_j["tokens"],
                        batch_j.get("frames"), remat=hyper.remat,
                        unroll=hyper.unroll, embed_perturbation=p_j)
    return ce + reg_term(phi, w_j)


def f2_loss(cfg, phi, p_j, w_j, batch_j, hyper: FedHyper):
    """Negated adversarial objective (level 2 maximizes)."""
    ce = tfm.train_loss(cfg, w_j, batch_j["tokens"],
                        batch_j.get("frames"), remat=hyper.remat,
                        unroll=hyper.unroll, embed_perturbation=p_j)
    return -(ce - hyper.adv_penalty
             * jnp.mean(jnp.square(p_j.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# cut algebra (mode-dispatched)
# ---------------------------------------------------------------------------

def _dot_stacked_p(stacked, v):
    """<a_l, v> per cut slot; stacked leaves have leading (P,)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda a, x: jnp.einsum(
            "pd,d->p", a.reshape(a.shape[0], -1).astype(jnp.float32),
            x.reshape(-1).astype(jnp.float32)), stacked, v))
    return sum(leaves)


def _dot_stacked_pn(stacked, V):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda b, x: jnp.einsum(
            "pnd,nd->p",
            b.reshape(b.shape[0], b.shape[1], -1).astype(jnp.float32),
            x.reshape(x.shape[0], -1).astype(jnp.float32)), stacked, V))
    return sum(leaves)


def eval_llm_cuts(hyper: FedHyper, cuts: LLMCutSet, z1, z2, z3, X2, X3,
                  seed: int):
    val = jnp.einsum("pd,d->p", cuts.a1, z1)
    if hyper.cut_mode == "sketch":
        r = hyper.sketch_r
        s_z2 = _sketch(z2, seed, r)
        s_z3 = _sketch(z3, seed, r)
        s_x2 = jax.vmap(lambda x: _sketch(x, seed, r))(X2)   # (N,r)
        s_x3 = jax.vmap(lambda x: _sketch(x, seed, r))(X3)
        val = val + cuts.a2 @ s_z2 + cuts.a3 @ s_z3 \
            + jnp.einsum("pnr,nr->p", cuts.b2, s_x2) \
            + jnp.einsum("pnr,nr->p", cuts.b3, s_x3)
    else:
        val = val + _dot_stacked_p(cuts.a2, z2) \
            + _dot_stacked_p(cuts.a3, z3) \
            + _dot_stacked_pn(cuts.b2, X2) \
            + _dot_stacked_pn(cuts.b3, X3)
    return (val - cuts.c) * cuts.active


def _contract_b(hyper: FedHyper, cuts: LLMCutSet, weights_np, block: str,
                template, seed: int):
    """sum_l w[j,l] * b_{l,j} as a per-worker tree (the worker-update cut
    gradient)."""
    w = weights_np * cuts.active[None, :]
    b = getattr(cuts, block)
    if hyper.cut_mode == "sketch":
        coeff = jnp.einsum("np,pnr->nr", w, b)                  # (N,r)
        return jax.vmap(lambda c: _unsketch(template, c, seed))(coeff)
    return jax.tree.map(
        lambda bb: jnp.einsum("np,pn...->n...", w,
                              bb.astype(jnp.float32)).astype(bb.dtype), b)


def _contract_a(hyper: FedHyper, cuts: LLMCutSet, weights_p, block: str,
                template, seed: int):
    w = weights_p * cuts.active
    a = getattr(cuts, block)
    if hyper.cut_mode == "sketch":
        coeff = jnp.einsum("p,pr->r", w, a)
        return _unsketch(template, coeff, seed)
    return jax.tree.map(
        lambda aa: jnp.tensordot(w, aa.astype(jnp.float32),
                                 axes=(0, 0)).astype(aa.dtype), a)


def _store_block(hyper: FedHyper, cur, grad_tree, slot, seed: int,
                 per_worker: bool):
    """Write one cut's coefficient block into slot (sketch or exact)."""
    if hyper.cut_mode == "sketch":
        r = hyper.sketch_r
        if per_worker:
            s = jax.vmap(lambda g: _sketch(g, seed, r))(grad_tree)
        else:
            s = _sketch(grad_tree, seed, r)
        return cur.at[slot].set(s)
    return jax.tree.map(lambda buf, g: buf.at[slot].set(g.astype(buf.dtype)),
                        cur, grad_tree)


# ---------------------------------------------------------------------------
# the per-iteration AFTO step (Eqs. 16-21, LLM instantiation)
# ---------------------------------------------------------------------------

def afto_llm_step(cfg: mcfg.ModelConfig, hyper: FedHyper,
                  state: FedLLMState, batch: Dict[str, Any],
                  active: jnp.ndarray) -> FedLLMState:
    """batch: worker-stacked {"val_tokens": (N,b,S), "tokens": (N,b,S),
    optional frames}.  active: (N,) mask."""
    t = state.t
    seed = hyper.seed_ii

    # ---- workers (Eq. 16)
    g3_f1 = jax.vmap(lambda w, bj: jax.grad(
        lambda ww: f1_loss(cfg, ww, bj, hyper))(w))(
        state.X3, batch)
    g3_cut = _contract_b(hyper, state.cuts, state.stale_lam, "b3",
                         state.z3, seed)
    g2_cut = _contract_b(hyper, state.cuts, state.stale_lam, "b2",
                         state.X2[0], seed)

    def bmask(x):
        return active.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)

    X3 = jax.tree.map(
        lambda x, gf, gc: x - hyper.eta_x * bmask(x)
        * (gf + gc).astype(x.dtype),
        state.X3, g3_f1, g3_cut)
    X2 = jax.tree.map(
        lambda x, gc: x - hyper.eta_x * bmask(x) * gc.astype(x.dtype),
        state.X2, g2_cut)
    # x1: f1 has no phi-gradient; theta (stale) + no cut block -> dual pull
    X1 = state.X1 - hyper.eta_x * active[:, None] * state.stale_theta

    # ---- master (Eqs. 17-19)
    gz1 = -jnp.sum(state.theta, axis=0) \
        + jnp.einsum("p,pd->d", state.lam * state.cuts.active, state.cuts.a1)
    z1 = state.z1 - hyper.eta_z * gz1
    gz2 = _contract_a(hyper, state.cuts, state.lam, "a2", state.z2, seed)
    z2 = jax.tree.map(lambda z, g: z - hyper.eta_z * g.astype(z.dtype),
                      state.z2, gz2)
    gz3 = _contract_a(hyper, state.cuts, state.lam, "a3", state.z3, seed)
    z3 = jax.tree.map(lambda z, g: z - hyper.eta_z * g.astype(z.dtype),
                      state.z3, gz3)

    # ---- duals (Eqs. 20/21)
    cutval = eval_llm_cuts(hyper, state.cuts, z1, z2, z3, X2, X3, seed)
    lam = jnp.clip(
        state.lam + hyper.eta_lambda * (cutval - hyper.c1(t) * state.lam),
        0.0, jnp.sqrt(hyper.alpha4)) * state.cuts.active
    r_theta = jnp.sqrt(hyper.alpha5) / N_PHI
    theta = jnp.clip(
        state.theta + hyper.eta_theta
        * ((X1 - z1[None]) - hyper.c2(t) * state.theta),
        -r_theta, r_theta)

    # ---- stale views of newly-active workers
    stale_lam = jnp.where(active[:, None] > 0, lam[None], state.stale_lam)
    stale_theta = jnp.where(active[:, None] > 0, theta, state.stale_theta)

    return dataclasses.replace(
        state, X1=X1, X2=X2, X3=X3, z1=z1, z2=z2, z3=z3, theta=theta,
        lam=lam, stale_lam=stale_lam, stale_theta=stale_theta, t=t + 1)


# ---------------------------------------------------------------------------
# cut refresh (Eqs. 23-25, LLM instantiation)
# ---------------------------------------------------------------------------

def _rollout3(cfg, hyper: FedHyper, z1, Z2, X3_0, z3_0, batch):
    """K rounds of the level-3 federated ADMM (Eqs. 5-7); differentiable
    w.r.t. (z1, Z2).  Duals start at zero each refresh (re-initialized —
    the paper leaves inner warm-starting unspecified).  Duals are f32
    (the ascent update promotes to f32, so the scan carry must start
    f32)."""
    phi0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                        X3_0)

    def round_fn(carry, _):
        X3, z3, duals = carry

        def worker_grad(w, p_j, d_j, dual_j):
            def local(w_):
                cons = tree_dot(dual_j, tree_sub(w_, z3)) \
                    + 0.5 * hyper.kappa3 * tree_norm_sq(tree_sub(w_, z3))
                return f3_loss(cfg, z1, p_j, w_, d_j, hyper) + cons
            return jax.grad(local)(w)

        g = jax.vmap(worker_grad)(X3, Z2, batch, duals)
        X3_new = jax.tree.map(
            lambda x, gg: (x - hyper.eta_x * gg.astype(x.dtype)), X3, g)
        # master step at old X3 (Eq. 6): grad_z3 = -sum_j(dual + k(x-z))
        gz = jax.tree.map(
            lambda d, x, z: -jnp.sum(
                d + hyper.kappa3 * (x - z[None]), axis=0),
            duals, jax.tree.map(lambda a: a.astype(jnp.float32), X3),
            jax.tree.map(lambda a: a.astype(jnp.float32), z3))
        z3_new = jax.tree.map(
            lambda z, gg: z - hyper.eta_z * gg.astype(z.dtype), z3, gz)
        duals_new = jax.tree.map(
            lambda d, x, z: d + hyper.eta_dual_inner
            * (x.astype(jnp.float32) - z.astype(jnp.float32)[None]),
            duals, X3_new, z3_new)
        return (X3_new, z3_new, duals_new), None

    (X3_k, z3_k, _), _ = jax.lax.scan(
        round_fn, (X3_0, z3_0, phi0), None, length=hyper.k_inner)
    return X3_k, z3_k


def _rollout2(cfg, hyper: FedHyper, z1, z3, X2_0, Z2_0, X3, batch,
              cuts_i: LLMCutSet):
    """K rounds of the level-2 inner ADMM: workers ascend the adversarial
    objective; the I-layer polytope enters via multipliers gamma."""
    gamma0 = jnp.zeros_like(cuts_i.c)
    s0 = jnp.zeros_like(cuts_i.c)
    seed = hyper.seed_i

    def round_fn(carry, _):
        X2, Z2, gamma, s = carry

        def worker_grad(p_j, w_j, d_j, z2_j):
            def local(p_):
                cons = 0.5 * hyper.kappa3 * jnp.sum(
                    jnp.square((p_ - z2_j).astype(jnp.float32)))
                return f2_loss(cfg, z1, p_, w_j, d_j, hyper) + cons
            return jax.grad(local)(p_j)

        g = jax.vmap(worker_grad)(X2, X3, batch, Z2)
        # cut-gradient contribution on x2 blocks (gamma-weighted)
        g_cut = _contract_b(hyper, cuts_i, jnp.broadcast_to(
            gamma[None], (hyper.n_workers,) + gamma.shape), "b2", X2[0],
            seed)
        X2_new = jax.tree.map(
            lambda x, ga, gc: x - hyper.eta_x * (ga + gc).astype(x.dtype),
            X2, g, g_cut)
        Z2_new = Z2 - hyper.eta_z * hyper.kappa3 * (Z2 - X2)
        # I-layer cut value at (z1, z2'=Z2_new, z3, {x3_j}=X3); x2 blocks
        # do not participate in I-layer cuts (their b2 slots are zero)
        cutval = eval_llm_cuts(hyper, cuts_i, z1, Z2_new, z3,
                               X2_new, X3, seed)
        s_new = jnp.maximum(0.0, s - hyper.eta_x * (gamma + cutval + s)) \
            * cuts_i.active
        gamma_new = jnp.maximum(
            0.0, gamma + hyper.eta_dual_inner * (cutval + s_new)) \
            * cuts_i.active
        return (X2_new, Z2_new, gamma_new, s_new), None

    (X2_k, Z2_k, gamma_k, _), _ = jax.lax.scan(
        round_fn, (X2_0, Z2_0, gamma0, s0), None, length=hyper.k_inner)
    return X2_k, Z2_k, gamma_k


def _add_llm_cut(hyper: FedHyper, cuts: LLMCutSet, grads: Dict[str, Any],
                 point: Dict[str, Any], h0, eps, mu, bound, t, seed
                 ) -> LLMCutSet:
    # integer eviction scores (f32 1e9+age loses age bits; see
    # core/cuts.add_cut)
    score = jnp.where(cuts.active > 0, cuts.age, jnp.int32(-(2 ** 30)))
    slot = jnp.argmin(score)
    gv0 = jnp.float32(0.0)
    v0_sq = jnp.float32(0.0)
    for k in grads:
        gv0 = gv0 + tree_dot(grads[k], point[k])
        v0_sq = v0_sq + tree_norm_sq(point[k])
    c = eps + mu * (bound + v0_sq) - h0 + gv0
    return LLMCutSet(
        a1=cuts.a1.at[slot].set(grads.get(
            "a1", jnp.zeros((N_PHI,), jnp.float32))),
        a2=_store_block(hyper, cuts.a2, grads["a2"], slot, seed, False)
        if "a2" in grads else cuts.a2,
        a3=_store_block(hyper, cuts.a3, grads["a3"], slot, seed, False)
        if "a3" in grads else cuts.a3,
        b2=_store_block(hyper, cuts.b2, grads["b2"], slot, seed, True)
        if "b2" in grads else cuts.b2,
        b3=_store_block(hyper, cuts.b3, grads["b3"], slot, seed, True)
        if "b3" in grads else cuts.b3,
        c=cuts.c.at[slot].set(c),
        active=cuts.active.at[slot].set(1.0),
        age=cuts.age.at[slot].set(jnp.asarray(t, jnp.int32)))


def cut_refresh_llm(cfg: mcfg.ModelConfig, hyper: FedHyper,
                    state: FedLLMState, batch) -> FedLLMState:
    t = state.t
    n = hyper.n_workers

    # ---- I-layer cut (Eq. 23): h_I = ||[X3; z3] - rollout3(z1, Z2)||^2
    def h_i(X3, z3, z1, Z2):
        ro = _rollout3(cfg, hyper, z1, Z2,
                       jax.lax.stop_gradient(X3),
                       jax.lax.stop_gradient(z3), batch)
        if hyper.first_order_cuts:
            ro = jax.lax.stop_gradient(ro)
        X3_k, z3_k = ro
        return tree_norm_sq(tree_sub(X3, X3_k)) \
            + tree_norm_sq(tree_sub(z3, z3_k))

    h0_i, g_i = jax.value_and_grad(h_i, argnums=(0, 1, 2, 3))(
        state.X3, state.z3, state.z1, state.z2)
    gX3, gz3, gz1, gz2 = g_i
    bound_i = (n + 3) * hyper.alpha
    cuts_i = _add_llm_cut(
        hyper, state.cuts_i,
        {"a1": gz1, "a2": gz2, "a3": gz3, "b3": gX3},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3, "b3": state.X3},
        h0_i, hyper.eps_i, hyper.mu_i, bound_i, t, hyper.seed_i)

    # ---- II-layer cut (Eq. 24): h_II = ||[X2; Z2] - rollout2(...)||^2
    def h_ii(X2, Z2, z1, z3, X3):
        ro = _rollout2(cfg, hyper, z1, z3,
                       jax.lax.stop_gradient(X2),
                       jax.lax.stop_gradient(Z2), X3, batch, cuts_i)
        X2_k, Z2_k, gamma_k = ro
        if hyper.first_order_cuts:
            X2_k, Z2_k = (jax.lax.stop_gradient(X2_k),
                          jax.lax.stop_gradient(Z2_k))
        h = jnp.sum(jnp.square((X2 - X2_k).astype(jnp.float32))) \
            + jnp.sum(jnp.square((Z2 - Z2_k).astype(jnp.float32)))
        return h, gamma_k

    (h0_ii, gamma_k), g_ii = jax.value_and_grad(
        h_ii, argnums=(0, 1, 2, 3, 4), has_aux=True)(
        state.X2, state.z2, state.z1, state.z3, state.X3)
    gX2, gZ2, gz1b, gz3b, gX3b = g_ii
    bound_ii = (2 * n + 2) * hyper.alpha
    cuts_ii = _add_llm_cut(
        hyper, state.cuts,
        {"a1": gz1b, "a2": gZ2, "a3": gz3b, "b2": gX2, "b3": gX3b},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3,
         "b2": state.X2, "b3": state.X3},
        h0_ii, hyper.eps_ii, hyper.mu_ii, bound_ii, t, hyper.seed_ii)

    # ---- drop rule (Eq. 25), newly-added cuts exempt
    fresh_i = (cuts_i.age == t).astype(jnp.float32)
    keep_i = ((jnp.abs(gamma_k) > 1e-8).astype(jnp.float32) + fresh_i) > 0
    cuts_i = dataclasses.replace(
        cuts_i, active=cuts_i.active * keep_i.astype(jnp.float32))
    fresh_ii = (cuts_ii.age == t).astype(jnp.float32)
    keep_ii = ((jnp.abs(state.lam) > 1e-8).astype(jnp.float32)
               + fresh_ii) > 0
    cuts_ii = dataclasses.replace(
        cuts_ii, active=cuts_ii.active * keep_ii.astype(jnp.float32))

    return dataclasses.replace(
        state, cuts_i=cuts_i, cuts=cuts_ii,
        lam=state.lam * cuts_ii.active, gamma_k=gamma_k)


# ---------------------------------------------------------------------------
# plain (non-trilevel) reference training step
# ---------------------------------------------------------------------------

def plain_train_step(cfg: mcfg.ModelConfig, params, opt_state, tokens,
                     frames=None, optimizer=None, remat: bool = True,
                     unroll: bool = False):
    from repro.optim import adamw
    from repro.optim.optimizers import apply_updates
    opt = optimizer or adamw(3e-4, weight_decay=0.1)
    loss, grads = jax.value_and_grad(
        lambda p: tfm.train_loss(cfg, p, tokens, frames, unroll=unroll,
                                 remat=remat))(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss
