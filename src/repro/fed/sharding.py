"""Mesh partitioning rules for every parameter/activation in the zoo.

Axes:
  data  : federated workers / data parallel (batch, worker-stacked vars)
  model : tensor parallel (heads, d_ff, experts, vocab, d_inner)
  pod   : optional outer axis; worker stacks shard over ('pod','data')

Rules are name-based on the *last* path segment of each leaf.  Every
parameter that lives inside a stage carries a leading repeat axis (R,...)
— so its base rank is `leaf.ndim - n_worker_axes - 1` — while top-level
parameters (embed, lm_head, norms, enc_pos) have no repeat axis.  That
convention makes name+rank dispatch unambiguous (e.g. dense-MLP `wo`
(R,f,d) vs attention `wo` (R,H,hd,d) vs MoE `wo` (R,E,f,d)).

Any dim not divisible by its mesh axis falls back to replication (tiny
models like xlstm-125m have 4 heads against a 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parameters that live OUTSIDE stages (no repeat axis), with full specs
_TOP_LEVEL = {
    "embed": ("model", None),
    "lm_head": (None, "model"),
    "enc_pos": (None, None),
    "enc_norm": (None,),
    "final_norm": (None,),
}

# stage parameters: spec per (name, base_rank)
_STAGE_RULES = {
    # norms
    ("norm1", 1): (None,), ("norm2", 1): (None,), ("norm_x", 1): (None,),
    ("q_norm", 1): (None,), ("k_norm", 1): (None,),
    # attention (+ cross)
    ("wq", 3): (None, "model", None), ("wk", 3): (None, "model", None),
    ("wv", 3): (None, "model", None), ("wo", 3): ("model", None, None),
    ("xwq", 3): (None, "model", None), ("xwk", 3): (None, "model", None),
    ("xwv", 3): (None, "model", None), ("xwo", 3): ("model", None, None),
    # dense GLU mlp
    ("wi", 2): (None, "model"), ("wg", 2): (None, "model"),
    ("wo", 2): ("model", None),
    # MoE (expert-parallel over the leading E dim; MoE `wo` (E,f,d) is
    # rank-3 like attention's and shares its ("model",None,None) spec)
    ("router", 2): (None, "model"),
    ("wi", 3): ("model", None, None), ("wg", 3): ("model", None, None),
    # mamba
    ("in_proj", 2): (None, "model"), ("conv_w", 2): (None, "model"),
    ("conv_b", 1): ("model",), ("xproj", 2): ("model", None),
    ("dt_bias", 1): ("model",), ("a_log", 2): ("model", None),
    ("d_skip", 1): ("model",), ("out_proj", 2): ("model", None),
    # xlstm (mlstm's input gate `wi` (d,H) hits the rank-2 rule above)
    ("wf", 2): (None, "model"), ("fb", 1): (None,),
    ("wz", 3): (None, "model", None), ("wo_gate", 3): (None, "model", None),
    ("rz", 3): ("model", None, None),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _divisible(dim: int, axis, mesh_shape: dict) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = int(np.prod([mesh_shape[a] for a in axes]))
    return dim % size == 0


_HEAD_TENSORS = {"wq", "wk", "wv", "xwq", "xwk", "xwv", "wz", "wo_gate"}
_HEAD_OUT_TENSORS = {"wo", "xwo"}


def param_specs(params, mesh: Mesh, *, stack_axes: Tuple = (),
                shard_head_dim_fallback: bool = False) -> Any:
    """PartitionSpec tree for a model param pytree.

    stack_axes: shardings for extra leading axes prepended OUTSIDE the
    per-stage repeat axis — e.g. ('data',) or (('pod','data'),) for the
    federated worker axis.

    shard_head_dim_fallback: when the head count doesn't divide the model
    axis (whisper: 20 heads on a 16-way axis) shard head_dim instead of
    replicating — the attention contraction then psums over the model
    axis (a §Perf lever; off by default = the faithful baseline).
    """
    mesh_shape = dict(mesh.shape)
    n_stack = len(stack_axes)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if name in _TOP_LEVEL:
            base = list(_TOP_LEVEL[name])
            n_lead = leaf.ndim - len(base) - n_stack
            lead = list(stack_axes) + [None] * n_lead
        else:
            base_rank = leaf.ndim - n_stack - 1     # strip worker + repeat
            base = list(_STAGE_RULES.get((name, base_rank),
                                         (None,) * max(base_rank, 0)))
            lead = list(stack_axes) + [None]        # repeat axis unsharded
        full = lead + base
        for i, ax in enumerate(full):
            if ax is not None and not _divisible(leaf.shape[i], ax,
                                                 mesh_shape):
                full[i] = None
        if shard_head_dim_fallback and base_rank_is_attn(name, leaf,
                                                         n_stack):
            full = _head_dim_fallback(name, full, leaf, mesh_shape)
        return P(*full)

    def base_rank_is_attn(name, leaf, n_stack):
        return (name in _HEAD_TENSORS or name in _HEAD_OUT_TENSORS) \
            and leaf.ndim - n_stack - 1 == 3

    def _head_dim_fallback(name, full, leaf, mesh_shape):
        # (..., d, H, hd) or (..., H, hd, d): if H failed divisibility,
        # try hd instead
        if name in _HEAD_TENSORS:
            h_i, hd_i = leaf.ndim - 2, leaf.ndim - 1
        else:
            h_i, hd_i = leaf.ndim - 3, leaf.ndim - 2
        if full[h_i] is None and _divisible(leaf.shape[hd_i], "model",
                                            mesh_shape):
            full = list(full)
            full[hd_i] = "model"
        return full

    return jax.tree_util.tree_map_with_path(spec_for, params)


def to_named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def data_axis(mesh: Mesh):
    """The axis (or axes) that batch/worker dims shard over."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_spec(mesh: Mesh, stacked: bool = False) -> P:
    """Tokens (B, S) or worker-stacked (N, b, S)."""
    ax = data_axis(mesh)
    return P(ax, None, None) if stacked else P(ax, None)


# ---------------------------------------------------------------------------
# AFTO core worker mesh: the trajectory engine's shard_map partitioning
# ---------------------------------------------------------------------------

# AFTOState fields whose leaves lead with the worker axis (N, ...)
_WORKER_STACKED = {"X1", "X2", "X3", "theta"}
# nested containers: which of their fields are worker-stacked
_WORKER_STACKED_NESTED = {
    "stale": {"z1", "z2", "z3", "lam", "theta", "t_hat"},
    "inner3": {"x3", "phi"},
    "inner2": {"x2", "phi"},
}
# FlatCuts: only the stacked-local coefficient matrix is per-shard
_CUT_FIELDS = {"cuts_i", "cuts_ii"}


def _attr_names(path):
    return [str(e.name) for e in path
            if isinstance(e, jax.tree_util.GetAttrKey)]


def afto_state_specs(state, axis: str = "worker", lead: Tuple = ()) -> Any:
    """PartitionSpec tree for an `AFTOState` on a worker mesh.

    Worker-stacked leaves (X1/X2/X3, theta, stale views, inner duals)
    shard their leading N axis over `axis`; master leaves (z1/z2/z3,
    lam, gamma_k, t, cut c/active/age) replicate; the cut coefficient
    matrices must already be in the `cuts.shard_cuts` stacked-local
    layout (n_shards, P, D_loc), whose leading axis shards over `axis`.

    lead: extra leading spec entries OUTSIDE the worker axis — (None,)
    for the sweep engine's run axis.
    """
    def spec_for(path, leaf):
        names = _attr_names(path)
        head = names[0] if names else ""
        if head in _WORKER_STACKED:
            return P(*lead, axis)
        if head in _CUT_FIELDS:
            return P(*lead, axis) if names[-1] == "a" else P(*lead)
        if head in _WORKER_STACKED_NESTED:
            if names[-1] in _WORKER_STACKED_NESTED[head]:
                return P(*lead, axis)
            return P(*lead)
        return P(*lead)            # z1, z2, z3, lam, gamma_k, t
    return jax.tree_util.tree_map_with_path(spec_for, state)


def worker_data_specs(data, axis: str = "worker", lead: Tuple = ()) -> Any:
    """Every `problem.data` leaf leads with the worker axis."""
    return jax.tree.map(lambda _: P(*lead, axis), data)


def cache_specs(cache, mesh: Mesh, batch_sharded: bool = True,
                kv_seq_sharded: bool = False) -> Any:
    """Decode caches: (R, B, ...) leaves — shard batch over data (when it
    divides) and heads/d_inner dims over model by name.

    kv_seq_sharded: context-parallel decode — shard the KV *sequence*
    dim over the data axis instead of (or in addition to) batch; the
    one-token attention reduction over the sharded sequence lowers to a
    psum.  The §Perf lever for long_500k's batch=1 (data axis otherwise
    idle)."""
    ax = data_axis(mesh)
    mesh_shape = dict(mesh.shape)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        spec = [None] * leaf.ndim
        if batch_sharded and leaf.ndim >= 2:
            spec[1] = ax                          # (R, B, ...)
        if name in ("k", "v", "pos"):             # (R,B,W,Hkv,hd)/(R,B,W)
            if kv_seq_sharded:
                spec[1] = None
                spec[2] = ax
            if name in ("k", "v"):
                spec[3] = "model"
        elif name in ("xk", "xv"):                # (R,B,T,Hkv,hd)
            spec[3] = "model"
        elif name == "conv":                      # (R,B,K-1,di)
            spec[3] = "model"
        elif name == "ssm":                       # (R,B,di,dS)
            spec[2] = "model"
        elif name in ("c", "n", "h", "m"):        # xlstm states (R,B,H,..)
            if leaf.ndim >= 3:
                spec[2] = "model"
        for i, a in enumerate(spec):
            if a is not None and not _divisible(leaf.shape[i], a,
                                                mesh_shape):
                spec[i] = None
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)
