"""Count-sketch compression of mu-cut coefficients (beyond-paper).

An exact mu-cut's coefficient vector lives in the full variable space —
at LLM scale that is P_max model-sized pytrees per polytope, which is
memory-prohibitive (see DESIGN.md §7).  We therefore restrict the x3/z3
(and x2/z2) blocks of the cut space to a fixed r-dimensional count-sketch
subspace:

    S(v)[k] = sum_{i : h(i)=k} sigma_i * v_i,

with h / sigma derived from a seeded integer hash of each element's flat
index — O(n) elementwise compute, no projection matrix is ever
materialized, and the ops are trivially shardable (the final segment-sum
reduces over the sharded axis with one small psum).

<S(a), S(b)> is an unbiased JL-style estimator of <a, b>; cuts generated
and evaluated inside the same sketch are exact *within the subspace*.
The paper-scale experiments validate sketched-vs-exact trajectories
empirically (benchmarks/sketch_fidelity.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_MIX1 = jnp.uint32(2654435761)
_MIX2 = jnp.uint32(2246822519)
_MIX3 = jnp.uint32(3266489917)


def _mix(x: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Cheap integer hash (xxhash-style avalanche), uint32 -> uint32."""
    h = x * _MIX1 + seed
    h = h ^ (h >> 15)
    h = h * _MIX2
    h = h ^ (h >> 13)
    h = h * _MIX3
    return h ^ (h >> 16)


def _leaf_hashes(shape, leaf_seed: jnp.ndarray, r: int):
    n = 1
    for s in shape:
        n *= int(s)
    iota = jax.lax.iota(jnp.uint32, n)
    h = _mix(iota, leaf_seed)
    idx = (h % jnp.uint32(r)).astype(jnp.int32)
    sign = jnp.where((h >> 31) > 0, 1.0, -1.0).astype(jnp.float32)
    return idx.reshape(shape), sign.reshape(shape)


def _leaf_seeds(tree, seed: int):
    leaves, treedef = jax.tree.flatten(tree)
    seeds = [jnp.uint32((seed * 1_000_003 + 7919 * i + 1) % (2 ** 32))
             for i in range(len(leaves))]
    return leaves, treedef, seeds


def sketch(tree: Any, seed: int, r: int) -> jnp.ndarray:
    """Count-sketch a pytree into an (r,) f32 vector."""
    leaves, _, seeds = _leaf_seeds(tree, seed)
    out = jnp.zeros((r,), jnp.float32)
    for leaf, s in zip(leaves, seeds):
        idx, sign = _leaf_hashes(leaf.shape, s, r)
        vals = leaf.astype(jnp.float32) * sign
        out = out + jax.ops.segment_sum(vals.reshape(-1),
                                        idx.reshape(-1), num_segments=r)
    return out


def unsketch(template: Any, s_vec: jnp.ndarray, seed: int) -> Any:
    """Adjoint of `sketch`: lift an (r,) vector back to the tree space.

    unsketch(t, sketch(v)) has <unsketch, w> == <sketch(v), sketch(w)>,
    so using it as a gradient is exactly 'the cut acts in sketch space'.
    """
    r = s_vec.shape[0]
    leaves, treedef, seeds = _leaf_seeds(template, seed)
    out = []
    for leaf, sd in zip(leaves, seeds):
        idx, sign = _leaf_hashes(leaf.shape, sd, r)
        out.append((s_vec[idx] * sign).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def sketch_dot(s_a: jnp.ndarray, s_b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(s_a * s_b)
