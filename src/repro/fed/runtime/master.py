"""The master endpoint: bounded-staleness arrival rule + step loop.

The master owns the canonical state — the `FlatCuts` polytopes, the z
variables, the duals, and every worker's last-consumed local point.
Workers own nothing but their data shard and the gradient they are
currently computing.  One master iteration:

  1. ARRIVE.  Block until the paper's arrival rule is satisfied: at
     least `hyper.s_active` worker pushes pending AND every tau-forced
     worker (staleness about to exceed `hyper.tau`) has arrived; then
     drain anything else already in flight (the scheduler's "extra
     workers finished by t_done" rule) and consume ALL pending pushes.
     In replay mode the master instead waits for — and consumes exactly
     — the workers of `replay.active[t]`, which makes the run
     deterministic on a deterministic transport.
  2. STEP.  Zero-fill the inactive gradient rows (exact: the Eq. 16
     update masks them out bitwise) and apply
     `afto_step_from_grads` — the stale-dual cut corrections, the
     masked worker updates, the master Gauss-Seidel z updates, and the
     dual ascent, all at the master's consumption-time polytope.
  3. REFRESH.  Every `t_pre` iterations (t < t1) generate the mu-cuts
     (`cut_refresh`) — master-side, exactly as in the scanned engine.
  4. REPLY.  Send each consumed worker its refreshed local point
     (x1_j, x2_j, x3_j).  Worker rows change only at the worker's own
     consumption, so each worker's local copy stays exactly in sync
     with the master's row between its activations — the property that
     makes the push-gradients / pull-rows decomposition reproduce the
     single-process trajectory.

The live arrival process is recorded per iteration
(`ArrivalRecorder`) and returned as `RunResult.arrivals` — a
`Schedule` replayable through `run_scanned` or through this master.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import afto as afto_lib
from repro.core import stationarity as stat_lib
from repro.core.engine import RunResult
from repro.core.scheduler import ArrivalRecorder, Schedule
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.data.stream import Stream
from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import transport as transport_lib


def _row(tree, j: int):
    return jax.tree.map(lambda x: x[j], tree)


def _zero_stack(template_stack):
    return jax.tree.map(lambda x: np.zeros(x.shape, x.dtype),
                        template_stack)


def _set_row(stack, j: int, row_tree) -> None:
    for dst, src in zip(jax.tree.leaves(stack), jax.tree.leaves(row_tree)):
        dst[j] = np.asarray(src)


class Master:
    """Runs the async master loop over any `MasterEndpoint`."""

    def __init__(self, problem: TrilevelProblem, hyper: Hyper,
                 endpoint: transport_lib.MasterEndpoint,
                 n_iterations: int,
                 metrics_fn: Optional[Callable] = None,
                 metrics_every: int = 10,
                 state: Optional[AFTOState] = None,
                 replay: Optional[Schedule] = None):
        if replay is not None and replay.n_workers != hyper.n_workers:
            raise ValueError(
                f"replay schedule has {replay.n_workers} workers; hyper "
                f"has {hyper.n_workers}")
        self.problem, self.hyper = problem, hyper
        self.endpoint = endpoint
        self.n_iterations = (replay.n_iterations if replay is not None
                             else n_iterations)
        self.metrics_fn, self.metrics_every = metrics_fn, metrics_every
        self.state = state if state is not None else afto_lib.init_state(
            problem, hyper)
        self.replay = replay
        self.recorder = ArrivalRecorder(hyper.n_workers)
        self.pending: Dict[int, tuple] = {}   # worker -> grads triple
        self.status: Dict = {"t": 0, "n_iterations": self.n_iterations,
                             "gap_sq": None, "max_staleness": 0,
                             "pending": 0, "done": False}
        self._step = jax.jit(
            lambda s, m, g: afto_lib.afto_step_from_grads(
                problem, hyper, s, m, g)[0])
        self._cut_refresh = jax.jit(
            lambda s: afto_lib.cut_refresh(problem, hyper, s))
        self._gap = jax.jit(
            lambda s: stat_lib.stationarity_gap_sq(problem, hyper, s))
        self._row_templates = (problem.x1_init, problem.x2_init,
                               problem.x3_init)

    # -- message plumbing ---------------------------------------------------

    def _consume_frame(self, frame: Optional[bytes]) -> None:
        if frame is None:
            return
        m = msg_lib.decode(frame)
        if m.kind == msg_lib.HELLO:
            return   # handshakes are transport-level; ignore here
        if m.kind != msg_lib.PUSH:
            raise ValueError(f"master got unexpected {m.kind!r} message")
        j = int(m.meta["worker"])
        self.pending[j] = msg_lib.push_grads(m, self._row_templates)

    def _send_rows(self, j: int, t_master: int) -> None:
        rows = (_row(self.state.X1, j), _row(self.state.X2, j),
                _row(self.state.X3, j))
        self.endpoint.send(j, msg_lib.encode(
            msg_lib.refresh(j, t_master, rows)))

    # -- the arrival rule ---------------------------------------------------

    def _wait_arrivals(self, it: int) -> np.ndarray:
        """Block until this iteration's arrival set is pending; return
        the sorted worker ids to consume."""
        if self.replay is not None:
            target = np.nonzero(self.replay.active[it] > 0)[0]
            while not all(j in self.pending for j in target):
                self._consume_frame(self.endpoint.recv())
            return target
        forced_rule, s_active = self.hyper.tau, self.hyper.s_active
        while True:
            forced = np.nonzero(
                self.recorder.staleness() >= forced_rule)[0]
            if (len(self.pending) >= s_active
                    and all(j in self.pending for j in forced)):
                break
            self._consume_frame(self.endpoint.recv())
        # the scheduler's "extra" rule: anything already in flight when
        # the master proceeds counts as arrived this iteration
        while True:
            frame = self.endpoint.recv(timeout=0.0)
            if frame is None:
                break
            self._consume_frame(frame)
        return np.array(sorted(self.pending), dtype=np.int64)

    # -- the loop -----------------------------------------------------------

    def run(self) -> RunResult:
        problem, hyper = self.problem, self.hyper
        n = hyper.n_workers
        hist: Dict[str, List[float]] = {
            "t": [], "sim_time": [], "host_time": [], "gap_sq": [],
            "n_cuts_i": [], "n_cuts_ii": [], "max_staleness": []}
        t0_abs = int(self.state.t)
        t_start = time.perf_counter()

        # every worker starts from the master's initial rows
        for j in range(n):
            self._send_rows(j, t0_abs)

        for it in range(self.n_iterations):
            active_ids = self._wait_arrivals(it)
            mask = np.zeros((n,), np.float32)
            mask[active_ids] = 1.0

            # zero-filled inactive rows are exact: Eq. 16 multiplies
            # every gradient row by the arrival mask before applying it
            grads = tuple(_zero_stack(s) for s in
                          (self.state.X1, self.state.X2, self.state.X3))
            for j in active_ids:
                g1, g2, g3 = self.pending.pop(int(j))
                _set_row(grads[0], int(j), g1)
                _set_row(grads[1], int(j), g2)
                _set_row(grads[2], int(j), g3)

            self.state = self._step(self.state, jnp.asarray(mask), grads)
            elapsed = time.perf_counter() - t_start
            sim_t = (float(self.replay.sim_time[it])
                     if self.replay is not None else elapsed)
            stale = self.recorder.record(mask, sim_t)

            t_post = t0_abs + it + 1
            if t_post % hyper.t_pre == 0 and t_post - 1 < hyper.t1:
                self.state = self._cut_refresh(self.state)

            for j in active_ids:
                self._send_rows(int(j), t_post)

            self.status.update(t=it + 1, max_staleness=stale,
                               pending=len(self.pending))
            if (it + 1) % self.metrics_every == 0 \
                    or it == self.n_iterations - 1:
                gap = float(self._gap(self.state))
                hist["t"].append(it + 1)
                hist["sim_time"].append(sim_t)
                hist["host_time"].append(time.perf_counter() - t_start)
                hist["gap_sq"].append(gap)
                hist["n_cuts_i"].append(
                    float(jnp.sum(self.state.cuts_i.active)))
                hist["n_cuts_ii"].append(
                    float(jnp.sum(self.state.cuts_ii.active)))
                hist["max_staleness"].append(float(stale))
                if self.metrics_fn is not None:
                    for k, v in self.metrics_fn(self.state).items():
                        hist.setdefault(k, []).append(float(v))
                self.status.update(gap_sq=gap)

        for j in range(n):
            self.endpoint.send(j, msg_lib.encode(msg_lib.stop()))
        self.status.update(done=True)
        return RunResult(state=self.state, history=hist,
                         arrivals=self.recorder.to_schedule())


def run_async(problem: TrilevelProblem, hyper: Hyper,
              n_iterations: int = 200,
              metrics_fn: Optional[Callable] = None,
              metrics_every: int = 10,
              state: Optional[AFTOState] = None,
              replay: Optional[Schedule] = None,
              transport=None, data=None,
              master_hook: Optional[Callable] = None) -> RunResult:
    """Run the async runtime end to end and return a `RunResult` (with
    `.arrivals` carrying the recorded live Schedule).

    transport=None (default) builds an `InProcTransport` and spawns one
    thread per worker — the deterministic single-process configuration.
    Passing a `TcpTransport` runs the master over sockets; the worker
    processes must be launched separately (`launch/serve.py fed` does
    both ends).  `master_hook(master)` runs after construction, before
    the loop — the status-server attach point.
    """
    import threading

    from repro.fed.runtime import worker as worker_lib

    if isinstance(data, Stream):
        raise NotImplementedError(
            "the async runtime consumes static problem.data; streamed "
            "batch synthesis folds on consumption-time state.t, which a "
            "self-paced worker cannot know ahead of its push")
    if data is not None:
        problem = dataclasses.replace(
            problem, data=jax.tree.map(jnp.asarray, data))

    threads: List = []
    if transport is None:
        transport = transport_lib.InProcTransport(hyper.n_workers)
    if isinstance(transport, transport_lib.InProcTransport):
        for j in range(hyper.n_workers):
            t = threading.Thread(
                target=worker_lib.worker_loop,
                args=(problem, j, transport.worker_endpoint(j)),
                daemon=True)
            t.start()
            threads.append(t)
        endpoint = transport.master_endpoint()
    else:
        endpoint = transport.master_endpoint()
        endpoint.wait_for_workers()

    master = Master(problem, hyper, endpoint, n_iterations,
                    metrics_fn=metrics_fn, metrics_every=metrics_every,
                    state=state, replay=replay)
    if master_hook is not None:
        master_hook(master)
    try:
        result = master.run()
    finally:
        endpoint.close()
    for t in threads:
        t.join(timeout=30.0)
    return result
