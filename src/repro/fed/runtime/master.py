"""The master endpoint: bounded-staleness arrival rule + step loop.

The master owns the canonical state — the `FlatCuts` polytopes, the z
variables, the duals, and every worker's last-consumed local point.
Workers own nothing but their data shard and the gradient they are
currently computing.  One master iteration:

  1. ARRIVE.  Block until the paper's arrival rule is satisfied: at
     least `hyper.s_active` worker pushes pending AND every tau-forced
     worker (staleness about to exceed `hyper.tau`) has arrived; then
     drain anything else already in flight (the scheduler's "extra
     workers finished by t_done" rule) and consume ALL pending pushes.
     In replay mode the master instead waits for — and consumes exactly
     — the workers of `replay.active[t]`, which makes the run
     deterministic on a deterministic transport.
  2. STEP.  Zero-fill the inactive gradient rows (exact: the Eq. 16
     update masks them out bitwise) and apply
     `afto_step_from_grads` — the stale-dual cut corrections, the
     masked worker updates, the master Gauss-Seidel z updates, and the
     dual ascent, all at the master's consumption-time polytope.
  3. REFRESH.  Every `t_pre` iterations (t < t1) generate the mu-cuts
     (`cut_refresh`) — master-side, exactly as in the scanned engine.
  4. REPLY.  Send each consumed worker its refreshed local point
     (x1_j, x2_j, x3_j).  Worker rows change only at the worker's own
     consumption, so each worker's local copy stays exactly in sync
     with the master's row between its activations — the property that
     makes the push-gradients / pull-rows decomposition reproduce the
     single-process trajectory.

Fault tolerance (ISSUE 7) wraps the loop without touching the math:

  - FAILURE DETECTION.  Every frame from worker j refreshes its
    liveness clock (`membership.Membership`); a transport DISCONNECT or
    silence past `FaultConfig.death_timeout` declares it dead — removed
    from the tau-forced set, pending rows dropped (zero-filled rows are
    exact), effective S shrinks to the live population, and the
    degradation is recorded in the Schedule's `dead` mask, so the
    degraded trajectory still replays exactly through `run_scanned`.
  - RETRY/RECONNECT.  Pushes carry (epoch, seq); duplicates and
    dead-session frames are exact no-ops, a current-session duplicate
    seq retransmits the lost refresh, and a re-HELLO with a bumped
    resume epoch replays the worker's last consumed local point — a
    rejoined worker is bit-identical to one that never left.
  - DURABLE STATE.  `ckpt_every` arrivals, the WHOLE canonical carry
    (state + recorder + pending map + per-worker epochs + history) is
    written through `checkpoint/io.py` array dicts; `restore()` resumes
    it bitwise (`serve fed --resume`).

The live arrival process is recorded per iteration
(`ArrivalRecorder`) and returned as `RunResult.arrivals` — a
`Schedule` replayable through `run_scanned` or through this master.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import afto as afto_lib
from repro.core import stationarity as stat_lib
from repro.core.engine import RunResult, _check_stream
from repro.core.scheduler import (ArrivalPolicy, ArrivalRecorder, Schedule,
                                  validate_arrival_params)
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream
from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import transport as transport_lib
from repro.fed.runtime.membership import (ElasticConfig, FaultConfig,
                                          Membership, grow_state)


def _row(tree, j: int):
    return jax.tree.map(lambda x: x[j], tree)


def _zero_stack(template_stack):
    return jax.tree.map(lambda x: np.zeros(x.shape, x.dtype),
                        template_stack)


def _set_row(stack, j: int, row_tree) -> None:
    for dst, src in zip(jax.tree.leaves(stack), jax.tree.leaves(row_tree)):
        dst[j] = np.asarray(src)


_HIST_KEYS = ("t", "sim_time", "host_time", "gap_sq", "n_cuts_i",
              "n_cuts_ii", "max_staleness")


class Master:
    """Runs the async master loop over any `MasterEndpoint`."""

    def __init__(self, problem: TrilevelProblem, hyper: Hyper,
                 endpoint: transport_lib.MasterEndpoint,
                 n_iterations: int,
                 metrics_fn: Optional[Callable] = None,
                 metrics_every: int = 10,
                 state: Optional[AFTOState] = None,
                 replay: Optional[Schedule] = None,
                 fault: Optional[FaultConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 0,
                 stream: Optional[Stream] = None,
                 policy: Optional[ArrivalPolicy] = None,
                 elastic: Optional[ElasticConfig] = None):
        if replay is not None and replay.n_workers != hyper.n_workers:
            # a WIDENING schedule replays from its initial width with
            # the elastic machinery growing the run at the recorded
            # boundaries; anything else is a plain mismatch
            widening = (replay.width is not None and elastic is not None
                        and int(replay.width[0]) == hyper.n_workers
                        and replay.n_workers <= elastic.max_workers)
            if not widening:
                raise ValueError(
                    f"replay schedule has {replay.n_workers} workers; "
                    f"hyper has {hyper.n_workers}")
        # Hyper validates at construction too, but the master is the
        # component that actually deadlocks on a bad quorum — re-check
        # here so hand-built/legacy hypers fail before the first wait.
        validate_arrival_params(hyper.s_active, hyper.tau,
                                hyper.n_workers, what="Master")
        if stream is not None:
            _check_stream(stream, hyper)
        self.problem, self.hyper = problem, hyper
        self.stream, self.policy = stream, policy
        self.elastic = elastic
        self._admit: Dict[int, int] = {}   # pending ADMITs: worker -> epoch
        self.endpoint = endpoint
        self.n_iterations = (replay.n_iterations if replay is not None
                             else n_iterations)
        self.metrics_fn, self.metrics_every = metrics_fn, metrics_every
        self.state = state if state is not None else afto_lib.init_state(
            problem, hyper)
        self.replay = replay
        self.fault = fault or FaultConfig()
        self.ckpt_dir, self.ckpt_every = ckpt_dir, int(ckpt_every)
        n = hyper.n_workers
        self.recorder = ArrivalRecorder(n)
        self.members = Membership(n, self.fault)
        self._eff = (None, None)   # this iteration's effective (s, tau)
        self.pending: Dict[int, tuple] = {}   # worker -> (seq, grads)
        self.last_refresh_t = np.zeros(n, dtype=np.int64)
        self._last_tx = np.zeros(n, dtype=np.float64)  # refresh send times
        self.start_it = 0
        self.hist: Dict[str, List[float]] = {k: [] for k in _HIST_KEYS}
        self.status: Dict = {"t": 0, "n_iterations": self.n_iterations,
                             "gap_sq": None, "max_staleness": 0,
                             "pending": 0, "done": False, "deaths": 0,
                             "rejoins": 0, "corrupt_frames": 0,
                             "resumed_from": None,
                             "workers": self.members.status()}
        self._build_jits()
        self._row_templates = (problem.x1_init, problem.x2_init,
                               problem.x3_init)
        self._update_worker_status()

    def _build_jits(self) -> None:
        """(Re)build the jitted step/refresh/gap closures over the
        CURRENT (problem, hyper, stream) — called at construction and
        again after every elastic growth (the closures are width-static:
        a grown run is a different XLA program)."""
        problem, hyper, stream = self.problem, self.hyper, self.stream

        # `afto_step_from_grads` never touches problem.data (the workers
        # already differentiated at their shards); cut_refresh and the
        # gap DO — in stream mode they take the batch synthesized at the
        # consumption-time fold (`_batch` mirrors the streamed scan
        # body's `batch_at(spec, key, state.stale.t_hat)` bitwise).
        def _with(d):
            return problem if d is None else dataclasses.replace(
                problem, data=d)
        self._step = jax.jit(
            lambda s, m, g: afto_lib.afto_step_from_grads(
                problem, hyper, s, m, g)[0])
        self._cut_refresh = jax.jit(
            lambda s, d: afto_lib.cut_refresh(_with(d), hyper, s))
        self._gap = jax.jit(
            lambda s, d: stat_lib.stationarity_gap_sq(_with(d), hyper, s))
        if stream is not None:
            spec = stream.spec
            self._batch = jax.jit(
                lambda key, t_hat: stream_lib.batch_at(spec, key, t_hat))
            self._stream_key = jnp.asarray(stream.key)

    # -- message plumbing ---------------------------------------------------

    def _consume_frame(self, frame: Optional[bytes]) -> None:
        if frame is None:
            return
        try:
            m = msg_lib.decode(frame)
        except Exception:
            # a chaos-cut / mid-frame-truncated frame: count it and let
            # the retransmit protocol recover the payload
            self.status["corrupt_frames"] += 1
            return
        n = self.hyper.n_workers
        j = int(m.meta.get("worker", -1))
        if m.kind == msg_lib.ADMIT:
            epoch = int(m.meta.get("epoch", 0))
            if 0 <= j < n:
                # an already-admitted worker reconnecting: the ADMIT is
                # its rejoin HELLO — replay its rows immediately
                if self.members.hello(j, epoch):
                    self.recorder.mark_alive(j)
                    self._resend_last(j)
            elif (self.elastic is not None
                    and n <= j < self.elastic.max_workers):
                # queue for the next iteration boundary (latest epoch
                # wins if the newcomer retries its ADMIT)
                self._admit[j] = max(epoch, self._admit.get(j, epoch))
            else:
                self.status["corrupt_frames"] += 1
            return
        if not 0 <= j < n:
            if (self.elastic is not None
                    and 0 <= j < self.elastic.max_workers):
                # pending-admission chatter (heartbeats) is not corrupt;
                # a newcomer dying before its boundary just dequeues
                if m.kind == msg_lib.DISCONNECT:
                    self._admit.pop(j, None)
                return
            self.status["corrupt_frames"] += 1
            return
        if m.kind == msg_lib.DISCONNECT:
            if self.members.disconnect(j):
                self._degrade(j)
            return
        if m.kind == msg_lib.HELLO:
            rejoin = self.members.hello(j, int(m.meta.get("epoch", 0)))
            if rejoin:
                self.recorder.mark_alive(j)
                self._resend_last(j)
            return
        if m.kind == msg_lib.HEARTBEAT:
            if self.members.saw(j):
                self.recorder.mark_alive(j)   # slow, not gone: resurrect
            self.members.observe_epoch(j, int(m.meta.get("epoch", 0)))
            return
        if m.kind != msg_lib.PUSH:
            raise ValueError(f"master got unexpected {m.kind!r} message")
        if self.members.saw(j):
            self.recorder.mark_alive(j)
        epoch = int(m.meta.get("epoch", 0))
        seq = int(m.meta.get("n_pushes", 0))
        self.members.observe_epoch(j, epoch)
        if self.members.fresh_push(j, epoch, seq):
            self.pending[j] = (seq,
                               msg_lib.push_grads(m, self._row_templates))
        elif epoch == int(self.members.epoch[j]):
            # current-session duplicate: the worker's refresh was lost —
            # retransmit its last consumed local point (rows unchanged
            # since, so this is an exact retransmission)
            self._resend_last(j)

    def _degrade(self, j: int) -> None:
        """Declare worker j dead: drop it from the tau-forced set and
        zero its pending rows (exact — Eq. 16 masks inactive rows)."""
        self.recorder.mark_dead(j)
        self.pending.pop(j, None)
        self.status["deaths"] = self.members.deaths

    def _send(self, j: int, frame: bytes) -> None:
        try:
            self.endpoint.send(j, frame)
        except (ConnectionError, OSError):
            # a dead socket surfaces through the reader's DISCONNECT (or
            # the deadline); sends to the gone worker are best-effort
            pass

    def _send_rows(self, j: int, t_master: int) -> None:
        rows = (_row(self.state.X1, j), _row(self.state.X2, j),
                _row(self.state.X3, j))
        self._send(j, msg_lib.encode(msg_lib.refresh(j, t_master, rows)))
        self.last_refresh_t[j] = int(t_master)
        self._last_tx[j] = time.monotonic()

    def _resend_last(self, j: int) -> None:
        """Replay worker j's last consumed local point (its rows changed
        only at its own consumption, so resending last_refresh_t's rows
        is bit-identical to the original refresh)."""
        self._send_rows(int(j), int(self.last_refresh_t[int(j)]))

    # -- failure detection --------------------------------------------------

    def _check_deadlines(self) -> None:
        for j in self.members.overdue():
            self.members.mark_dead(j)
            self._degrade(j)

    def _heal_stalled(self) -> None:
        """Retransmit the last refresh to live workers that owe a push
        but have been silent on the compute side too long — recovers a
        refresh (or initial-rows) frame lost in flight."""
        now = time.monotonic()
        for j in range(self.hyper.n_workers):
            if (self.members.alive[j] and j not in self.pending
                    and now - self._last_tx[j]
                    > self.fault.refresh_resend_every):
                self._resend_last(j)

    # -- elastic admission (the boundary barrier) ---------------------------

    def _grow_to(self, n_new: int) -> None:
        """Grow the run to `n_new` workers at an iteration boundary:
        widen the canonical state (`grow_state` — zero rows, exact),
        rebuild (problem, hyper, stream) at the new width via the
        elastic builders, recompile the width-static jits, and widen
        every per-worker bookkeeping array.  The arrival rule is stated
        over the CURRENT live set, so the grown hyper's (s_active, tau)
        govern from the next iteration on (a configured `ArrivalPolicy`
        adopts them as its new baseline)."""
        assert self.elastic is not None
        n_new = int(n_new)
        problem, hyper = self.elastic.build(n_new)
        validate_arrival_params(hyper.s_active, hyper.tau,
                                hyper.n_workers, what="Master (grown)")
        self.state = grow_state(self.state, n_new)
        add = n_new - self.hyper.n_workers
        self.problem, self.hyper = problem, hyper
        if self.stream is not None:
            if self.elastic.build_stream is None:
                raise ValueError(
                    "a streamed elastic run needs "
                    "ElasticConfig.build_stream to widen the Stream")
            self.stream = self.elastic.build_stream(n_new)
            _check_stream(self.stream, hyper)
        self._build_jits()
        self.members.grow(n_new)
        self.recorder.widen(n_new)
        self.last_refresh_t = np.concatenate(
            [self.last_refresh_t, np.zeros(add, np.int64)])
        self._last_tx = np.concatenate(
            [self._last_tx, np.zeros(add, np.float64)])
        if self.policy is not None:
            self.policy.s_active = hyper.s_active
            self.policy.tau = hyper.tau
        self.status["n_workers"] = n_new

    def _welcome(self, j: int, epoch: int, t_bnd: int) -> None:
        """Open an admitted worker's session at boundary `t_bnd`: grant
        (WELCOME), then its initial rows stamped with the boundary —
        the newcomer's first consumption clock, so its locally folded
        stream batch agrees with the master's bitwise."""
        self.members.admit(j, epoch)
        self.recorder.mark_alive(j)
        self._send(j, msg_lib.encode(msg_lib.welcome(
            j, t_bnd, self.hyper.n_workers)))
        self._send_rows(j, t_bnd)

    def _process_admissions(self) -> None:
        """LIVE boundary: grow to cover every queued ADMIT and open the
        newcomers' sessions.  Ids between the old width and the highest
        admitted id that never said ADMIT stay dead (excluded from the
        tau-forced set like any crashed worker)."""
        if not self._admit:
            return
        n_new = max(self._admit) + 1
        if n_new > self.hyper.n_workers:
            self._grow_to(n_new)
        t_bnd = int(self.state.t)
        for j in sorted(self._admit):
            self._welcome(j, self._admit[j], t_bnd)
        self._admit.clear()
        self._update_worker_status()

    def _admit_for_replay(self, it: int) -> None:
        """REPLAY boundary: at the recorded widening iteration, block
        until every recorded newcomer's ADMIT is queued, then grow to
        exactly the recorded width — the widened trajectory replays
        bit-exactly because the growth happens at the same boundary
        with the same zero rows."""
        rp = self.replay
        if rp.width is None:
            return
        w = int(rp.width[it])
        n = self.hyper.n_workers
        if w <= n:
            return
        newcomers = list(range(n, w))
        poll = self.fault.poll_interval
        while not all(j in self._admit for j in newcomers):
            self._consume_frame(self.endpoint.recv(timeout=poll))
        self._grow_to(w)
        t_bnd = int(self.state.t)
        for j in newcomers:
            self._welcome(j, self._admit.pop(j), t_bnd)
        self._update_worker_status()

    # -- the arrival rule ---------------------------------------------------

    def _wait_arrivals(self, it: int) -> np.ndarray:
        """Block until this iteration's arrival set is pending; return
        the sorted worker ids to consume."""
        poll = self.fault.poll_interval
        if self.replay is not None:
            # echo the source schedule's effective-(s, tau) audit
            # columns (if any) so a replayed recorder reproduces them
            rp = self.replay
            self._eff = (
                None if rp.s_eff is None else int(rp.s_eff[it]),
                None if rp.tau_eff is None else int(rp.tau_eff[it]))
            target = np.nonzero(self.replay.active[it] > 0)[0]
            while not all(j in self.pending for j in target):
                self._consume_frame(self.endpoint.recv(timeout=poll))
                self._heal_stalled()
            return target
        forced_rule, s_active = self.hyper.tau, self.hyper.s_active
        if self.policy is not None:
            # one feedback step per master iteration: the policy sees
            # the recorded staleness and proposes this iteration's
            # effective (quorum, forcing horizon) within the tau bound
            s_active, forced_rule = self.policy.propose(
                self.recorder.staleness(), self.members.alive)
        self._eff = (s_active, forced_rule)
        dead_deadline = None
        while True:
            # drain everything already in flight BEFORE judging
            # liveness: the master may have been away compiling/stepping
            # for seconds, and queued heartbeats prove the silence was
            # ours, not the workers'
            while True:
                frame = self.endpoint.recv(timeout=0.0)
                if frame is None:
                    break
                self._consume_frame(frame)
            self._check_deadlines()
            alive = self.members.alive
            n_live = self.members.n_live
            if n_live == 0:
                # nobody left: hold the line for a rejoin, then fail
                if dead_deadline is None:
                    dead_deadline = (time.monotonic()
                                     + self.fault.all_dead_timeout)
                elif time.monotonic() > dead_deadline:
                    raise RuntimeError(
                        "all workers declared dead and none rejoined "
                        f"within {self.fault.all_dead_timeout}s")
            else:
                dead_deadline = None
                stale = self.recorder.staleness()
                forced = np.nonzero((stale >= forced_rule) & alive)[0]
                s_eff = max(1, min(s_active, n_live))
                pend_live = sum(1 for j in self.pending if alive[j])
                if (pend_live >= s_eff
                        and all(j in self.pending for j in forced)):
                    self._eff = (s_eff, forced_rule)
                    break
            self._consume_frame(self.endpoint.recv(timeout=poll))
            self._heal_stalled()
        # the scheduler's "extra" rule: anything already in flight when
        # the master proceeds counts as arrived this iteration
        while True:
            frame = self.endpoint.recv(timeout=0.0)
            if frame is None:
                break
            self._consume_frame(frame)
        return np.array(sorted(self.pending), dtype=np.int64)

    # -- durable master state (checkpoint/io.py array dicts) ----------------

    def snapshot(self) -> Dict[str, np.ndarray]:
        """The master's WHOLE runtime carry as a flat name -> ndarray
        dict: canonical state leaves, the recorder's live arrival
        process, the pending push map (stacked rows + per-worker seq),
        membership epochs, refresh bookkeeping and the metrics history.
        Restoring it reproduces the loop bitwise from the same point."""
        out: Dict[str, np.ndarray] = {
            "it": np.asarray(self.start_it, np.int64),
            "n_workers": np.asarray(self.hyper.n_workers, np.int64),
            "last_refresh_t": self.last_refresh_t.copy(),
        }
        for i, leaf in enumerate(jax.tree.leaves(self.state)):
            out[f"state/{i}"] = np.asarray(leaf)
        for k, v in self.recorder.state_dict().items():
            out[f"rec/{k}"] = v
        for k, v in self.members.state_dict().items():
            out[f"mem/{k}"] = v
        n = self.hyper.n_workers
        pend_seq = np.zeros(n, np.int64)
        stacks = tuple(_zero_stack(s) for s in
                       (self.state.X1, self.state.X2, self.state.X3))
        for j, (seq, grads) in self.pending.items():
            pend_seq[j] = seq
            for stack, g in zip(stacks, grads):
                _set_row(stack, int(j), g)
        out["pending_seq"] = pend_seq
        for gi, stack in enumerate(stacks):
            for i, leaf in enumerate(jax.tree.leaves(stack)):
                out[f"pend/g{gi + 1}/{i}"] = np.asarray(leaf)
        for k, v in self.hist.items():
            out[f"hist/{k}"] = np.asarray(v, np.float64)
        return out

    def save(self, step: int) -> str:
        """Checkpoint the runtime carry (called every `ckpt_every`
        arrivals from the loop; safe to call manually)."""
        assert self.ckpt_dir, "Master has no ckpt_dir configured"
        snap = self.snapshot()
        snap["it"] = np.asarray(step, np.int64)
        return ckpt_io.save_array_dict(self.ckpt_dir, snap, step=step)

    def restore(self, step: Optional[int] = None) -> int:
        """Restore the runtime carry saved by `save`; returns the
        iteration to resume from.  Connection-scoped session state
        (epochs, consumed seqs) is reset — a resumed master faces a
        fresh worker population and replays each worker's last consumed
        local point instead of the initial rows."""
        assert self.ckpt_dir, "Master has no ckpt_dir configured"
        d = ckpt_io.load_array_dict(self.ckpt_dir, step=step)
        # a checkpoint written after an elastic growth is WIDER than the
        # launch width: grow this master to the recorded population
        # first, then restore the leaves against the grown templates
        n_ckpt = int(d.get("n_workers", self.hyper.n_workers))
        if n_ckpt > self.hyper.n_workers:
            if self.elastic is None or n_ckpt > self.elastic.max_workers:
                raise ckpt_io.CheckpointError(
                    f"checkpoint was written at {n_ckpt} workers; this "
                    f"master launched at {self.hyper.n_workers} with no "
                    "elastic config able to grow that far")
            self._grow_to(n_ckpt)
        elif n_ckpt < self.hyper.n_workers:
            raise ckpt_io.CheckpointError(
                f"checkpoint was written at {n_ckpt} workers; this "
                f"master has {self.hyper.n_workers} (membership only "
                "grows)")
        leaves, treedef = jax.tree_util.tree_flatten(self.state)
        restored = []
        for i, tpl in enumerate(leaves):
            arr = d[f"state/{i}"]
            if tuple(arr.shape) != tuple(np.shape(tpl)):
                raise ckpt_io.CheckpointError(
                    f"state leaf {i}: checkpoint shape {arr.shape} != "
                    f"runtime template {np.shape(tpl)}")
            restored.append(jnp.asarray(arr, dtype=tpl.dtype))
        self.state = jax.tree_util.tree_unflatten(treedef, restored)
        self.recorder.load_state_dict(
            {k[len("rec/"):]: v for k, v in d.items()
             if k.startswith("rec/")})
        self.members.load_state_dict(
            {k[len("mem/"):]: v for k, v in d.items()
             if k.startswith("mem/")})
        self.members.reset_sessions()
        self.last_refresh_t = np.asarray(d["last_refresh_t"],
                                         np.int64).copy()
        pend_seq = np.asarray(d["pending_seq"], np.int64)
        self.pending = {}
        for j in np.nonzero(pend_seq > 0)[0]:
            grads = []
            for gi, tpl_stack in enumerate((self.state.X1, self.state.X2,
                                            self.state.X3)):
                t_leaves, t_def = jax.tree_util.tree_flatten(tpl_stack)
                g_leaves = [np.asarray(d[f"pend/g{gi + 1}/{i}"][j])
                            for i in range(len(t_leaves))]
                grads.append(jax.tree_util.tree_unflatten(
                    t_def, g_leaves))
            self.pending[int(j)] = (int(pend_seq[j]), tuple(grads))
        # a resumed master's consumed counters restart with the fresh
        # sessions; restored pending seqs must stay ahead of them
        self.members.consumed_seq[:] = 0
        self.hist = {k[len("hist/"):]: list(np.asarray(v))
                     for k, v in d.items() if k.startswith("hist/")}
        self.start_it = int(d["it"])
        self.status.update(t=self.start_it, resumed_from=self.start_it,
                           pending=len(self.pending))
        return self.start_it

    # -- the loop -----------------------------------------------------------

    def _update_worker_status(self) -> None:
        stale = self.recorder.staleness()
        rows = self.members.status()
        for j, row in enumerate(rows):
            row["staleness"] = int(stale[j])
            row["dead"] = bool(self.recorder.dead[j])
        self.status.update(workers=rows, deaths=self.members.deaths,
                           rejoins=self.members.rejoins,
                           arrivals=self.recorder.recent())

    def run(self) -> RunResult:
        hist = self.hist
        # absolute-iteration origin: state.t advances one per consumed
        # iteration, so subtracting the resume point recovers t0
        t0_abs = int(self.state.t) - self.start_it
        t_start = time.perf_counter()

        if self.start_it == 0:
            # every worker starts from the master's initial rows
            for j in range(self.hyper.n_workers):
                self._send_rows(j, t0_abs)
        else:
            # resumed master, fresh workers: replay each live worker's
            # last consumed local point (rows unchanged since — a
            # rejoined population is bit-identical to one that never
            # saw the crash)
            for j in range(self.hyper.n_workers):
                if self.members.alive[j]:
                    self._resend_last(j)
        self._update_worker_status()

        for it in range(self.start_it, self.n_iterations):
            iter_t0 = time.monotonic()
            # elastic admissions happen ONLY here, at the iteration
            # boundary — the width is constant within an iteration
            if self.replay is not None:
                self._admit_for_replay(it)
            else:
                self._process_admissions()
            active_ids = self._wait_arrivals(it)
            hyper = self.hyper   # fixed for this iteration
            mask = np.zeros((hyper.n_workers,), np.float32)
            mask[active_ids] = 1.0

            # zero-filled inactive rows are exact: Eq. 16 multiplies
            # every gradient row by the arrival mask before applying it
            grads = tuple(_zero_stack(s) for s in
                          (self.state.X1, self.state.X2, self.state.X3))
            for j in active_ids:
                seq, (g1, g2, g3) = self.pending.pop(int(j))
                self.members.consumed(int(j), seq)
                _set_row(grads[0], int(j), g1)
                _set_row(grads[1], int(j), g2)
                _set_row(grads[2], int(j), g3)

            # streamed data: cut_refresh and the gap consume the same
            # batch the workers differentiated against — each row folded
            # at its PRE-step consumption time, captured before _step
            # advances t_hat (exactly the streamed scan body's fold)
            t_hat_pre = (self.state.stale.t_hat
                         if self.stream is not None else None)
            self.state = self._step(self.state, jnp.asarray(mask), grads)
            elapsed = time.perf_counter() - t_start
            sim_t = (float(self.replay.sim_time[it])
                     if self.replay is not None else elapsed)
            stale = self.recorder.record(mask, sim_t,
                                         s_eff=self._eff[0],
                                         tau_eff=self._eff[1])

            t_post = t0_abs + it + 1
            record_now = ((it + 1) % self.metrics_every == 0
                          or it == self.n_iterations - 1)
            do_refresh = (t_post % hyper.t_pre == 0
                          and t_post - 1 < hyper.t1)
            batch = (self._batch(self._stream_key, t_hat_pre)
                     if self.stream is not None
                     and (do_refresh or record_now) else None)
            if do_refresh:
                self.state = self._cut_refresh(self.state, batch)

            for j in active_ids:
                self._send_rows(int(j), t_post)

            self.status.update(t=it + 1, max_staleness=stale,
                               pending=len(self.pending))
            self._update_worker_status()
            if record_now:
                gap = float(self._gap(self.state, batch))
                hist["t"].append(it + 1)
                hist["sim_time"].append(sim_t)
                hist["host_time"].append(time.perf_counter() - t_start)
                hist["gap_sq"].append(gap)
                hist["n_cuts_i"].append(
                    float(jnp.sum(self.state.cuts_i.active)))
                hist["n_cuts_ii"].append(
                    float(jnp.sum(self.state.cuts_ii.active)))
                hist["max_staleness"].append(float(stale))
                if self.metrics_fn is not None:
                    for k, v in self.metrics_fn(self.state).items():
                        hist.setdefault(k, []).append(float(v))
                self.status.update(gap_sq=gap)
            if self.ckpt_dir and self.ckpt_every \
                    and (it + 1) % self.ckpt_every == 0:
                self.save(step=it + 1)
            if self.replay is None and self.fault.min_iter_time > 0:
                left = self.fault.min_iter_time \
                    - (time.monotonic() - iter_t0)
                if left > 0:
                    time.sleep(left)

        self._shutdown()
        self.status.update(done=True)
        return RunResult(state=self.state, history=hist,
                         arrivals=self.recorder.to_schedule())

    def _shutdown(self) -> None:
        """Reliable dismissal: resend STOP until every session closes.

        STOP is the one frame with no worker-side retransmit to heal it
        (a stopped worker is gone — there is nobody left to notice the
        loss), so the MASTER owns shutdown reliability: send STOP to
        every live worker, then keep draining frames — any frame from a
        still-talking worker proves its STOP was lost (chaos cut, dead
        socket write) and triggers a resend — until each session closes
        (its DISCONNECT arrives; both transports surface one: TCP via
        the reader thread, in-proc via `WorkerEndpoint.close`) or
        `FaultConfig.stop_timeout` expires.  Workers declared dead
        count as already closed; a newcomer still queued for admission
        (its boundary never came) is dismissed too — it is parked in
        its WELCOME wait and must not outlive the run."""
        n = self.hyper.n_workers
        stop = msg_lib.encode(msg_lib.stop())
        open_set = {j for j in range(n) if self.members.alive[j]}
        open_set.update(self._admit)
        for j in sorted(open_set):
            self._send(j, stop)
        deadline = time.monotonic() + self.fault.stop_timeout
        while open_set and time.monotonic() < deadline:
            frame = self.endpoint.recv(timeout=self.fault.poll_interval)
            if frame is None:
                continue
            meta = msg_lib.peek_meta(frame)
            j = -1 if meta is None else int(meta.get("worker", -1))
            if j < 0:
                # corrupt frame after shutdown began: the sender is
                # unknowable, so re-dismiss everyone still open
                for k in sorted(open_set):
                    self._send(k, stop)
                continue
            if msg_lib.peek_kind(frame) == msg_lib.DISCONNECT:
                open_set.discard(j)
            else:
                self._send(j, stop)


def run_async(problem: TrilevelProblem, hyper: Hyper,
              n_iterations: int = 200,
              metrics_fn: Optional[Callable] = None,
              metrics_every: int = 10,
              state: Optional[AFTOState] = None,
              replay: Optional[Schedule] = None,
              transport=None, data=None,
              master_hook: Optional[Callable] = None,
              fault: Optional[FaultConfig] = None,
              ckpt_dir: Optional[str] = None,
              ckpt_every: int = 0,
              resume: bool = False,
              accept_timeout: Optional[float] = None,
              policy: Optional[ArrivalPolicy] = None,
              elastic: Optional[ElasticConfig] = None) -> RunResult:
    """Run the async runtime end to end and return a `RunResult` (with
    `.arrivals` carrying the recorded live Schedule).

    transport=None (default) builds an `InProcTransport` and spawns one
    thread per worker — the deterministic single-process configuration.
    Passing a `TcpTransport` runs the master over sockets; the worker
    processes must be launched separately (`launch/serve.py fed` does
    both ends).  `master_hook(master)` runs after construction, before
    the loop — the status-server attach point.

    fault / ckpt_dir / ckpt_every configure the fault-tolerant layer
    (liveness deadlines, durable state); `resume=True` restores the
    latest checkpoint from `ckpt_dir` before the loop and continues the
    interrupted trajectory.

    data may be a `Stream`: each worker then synthesizes its own batch
    at the master iteration its REFRESH frame carries (the fold is on
    the worker's consumption time t_hat_j, which IS that `t`), and the
    master folds the same keys for cut refresh and the gap — so the
    recorded Schedule replays bit-exactly through `run_scanned` with
    the same Stream.  `policy` (live runs only) adapts the effective
    quorum / forcing horizon from observed staleness each iteration.

    `elastic` enables mid-run admission of workers beyond the launch
    width (see `membership.ElasticConfig`).  Replaying a WIDENING
    Schedule over the in-process transport additionally spawns the
    recorded newcomers up front in admit mode — each is held at the
    recorded boundary by the master, so the widened trajectory replays
    bit-exactly.
    """
    import threading

    from repro.fed.runtime import worker as worker_lib

    stream = data if isinstance(data, Stream) else None
    if data is not None and stream is None:
        problem = dataclasses.replace(
            problem, data=jax.tree.map(jnp.asarray, data))

    threads: List = []
    if transport is None:
        transport = transport_lib.InProcTransport(hyper.n_workers)
    if isinstance(transport, transport_lib.InProcTransport):
        for j in range(hyper.n_workers):
            t = threading.Thread(
                target=worker_lib.worker_loop,
                args=(problem, j, transport.worker_endpoint(j)),
                kwargs={"fault": fault, "stream": stream},
                daemon=True)
            t.start()
            threads.append(t)
        if (replay is not None and replay.width is not None
                and elastic is not None
                and replay.n_workers > hyper.n_workers):
            # the recorded newcomers: spawn each in admit mode against a
            # problem built at (its id + 1) — the elastic builders are
            # per-worker-row stable, so row j is identical at any build
            # width >= j + 1
            for j in range(hyper.n_workers, replay.n_workers):
                wp, _ = elastic.build(j + 1)
                ws = (None if stream is None
                      else elastic.build_stream(j + 1))
                t = threading.Thread(
                    target=worker_lib.worker_loop,
                    args=(wp, j, transport.worker_endpoint(j)),
                    kwargs={"fault": fault, "stream": ws,
                            "admit": True},
                    daemon=True)
                t.start()
                threads.append(t)
        endpoint = transport.master_endpoint()
    else:
        endpoint = transport.master_endpoint()
        endpoint.wait_for_workers(timeout=accept_timeout)

    master = Master(problem, hyper, endpoint, n_iterations,
                    metrics_fn=metrics_fn, metrics_every=metrics_every,
                    state=state, replay=replay, fault=fault,
                    ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                    stream=stream, policy=policy, elastic=elastic)
    try:
        if resume:
            master.restore()
        if master_hook is not None:
            master_hook(master)
        result = master.run()
    except BaseException:
        # don't leak worker threads: a failed master still dismisses
        # its population (including any spawned newcomers) before
        # propagating
        n_spawned = max(hyper.n_workers, len(threads))
        for j in range(n_spawned):
            try:
                endpoint.send(j, msg_lib.encode(msg_lib.stop()))
            except Exception:
                pass
        raise
    finally:
        endpoint.close()
    for t in threads:
        t.join(timeout=30.0)
    return result
