"""Serializable message layer: json header + npz array payload.

Every runtime message crosses the wire as one byte string:

    [4-byte big-endian header length][json header][npz of the arrays]

The header carries the message kind and json-safe scalars (worker id,
iteration counts, flags); pytree payloads travel as their flattened
leaves under positional keys ("g1/0", "g1/1", ...).  Treedefs are NEVER
transmitted — both endpoints rebuild the same problem (in-process by
sharing it, across processes via `problems.py`'s registry) and unflatten
against their local templates.  No pickle anywhere, so a worker process
can't smuggle arbitrary objects into the master.

The same bytes flow over every transport — the in-process queue
transport carries encoded frames too, so unit tests exercise the real
wire format, not a shortcut.
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
from typing import Dict, Sequence, Tuple

import jax
import numpy as np

HELLO, PUSH, REFRESH, STOP = "hello", "push", "refresh", "stop"


@dataclasses.dataclass
class Message:
    """One wire message: a kind tag, json-safe `meta` scalars, and named
    array leaves."""
    kind: str
    meta: Dict
    arrays: Dict[str, np.ndarray]


def encode(msg: Message) -> bytes:
    """`Message` -> one self-delimiting byte frame."""
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in msg.arrays.items()})
    header = json.dumps({"kind": msg.kind, "meta": msg.meta}).encode()
    return struct.pack(">I", len(header)) + header + buf.getvalue()


def decode(data: bytes) -> Message:
    """Byte frame -> `Message` (arrays rejected if they'd need pickle)."""
    (hlen,) = struct.unpack(">I", data[:4])
    header = json.loads(data[4:4 + hlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    payload = data[4 + hlen:]
    if payload:
        with np.load(io.BytesIO(payload), allow_pickle=False) as npz:
            arrays = {k: npz[k] for k in npz.files}
    return Message(kind=header["kind"], meta=header["meta"], arrays=arrays)


# ---------------------------------------------------------------------------
# pytree <-> named-leaf helpers
# ---------------------------------------------------------------------------

def pack_trees(groups: Dict[str, object]) -> Dict[str, np.ndarray]:
    """Flatten each named pytree into positional leaf keys."""
    out: Dict[str, np.ndarray] = {}
    for name, tree in groups.items():
        for i, leaf in enumerate(jax.tree.leaves(tree)):
            out[f"{name}/{i}"] = np.asarray(leaf)
    return out


def unpack_tree(msg: Message, name: str, template):
    """Rebuild pytree `name` from a message against a local template
    (leaf count must match — a wire/format mismatch fails loudly)."""
    treedef = jax.tree.structure(template)
    leaves = []
    i = 0
    while f"{name}/{i}" in msg.arrays:
        leaves.append(msg.arrays[f"{name}/{i}"])
        i += 1
    if i != treedef.num_leaves:
        raise ValueError(
            f"message group {name!r} has {i} leaves; local template "
            f"expects {treedef.num_leaves}")
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# message constructors (the whole protocol surface)
# ---------------------------------------------------------------------------

def hello(worker: int) -> Message:
    """Worker -> master handshake (TCP connection registration)."""
    return Message(HELLO, {"worker": int(worker)}, {})


def push(worker: int, n_pushes: int, grads: Sequence) -> Message:
    """Worker -> master: the Eq. 16 gradient triple (g1_j, g2_j, g3_j)
    at the worker's current local point.  `n_pushes` counts this
    worker's pushes (master-side sanity / debugging)."""
    g1, g2, g3 = grads
    return Message(PUSH, {"worker": int(worker), "n_pushes": int(n_pushes)},
                   pack_trees({"g1": g1, "g2": g2, "g3": g3}))


def push_grads(msg: Message, templates: Tuple) -> Tuple:
    """Decode a PUSH payload against (x1, x2, x3) worker-row templates."""
    t1, t2, t3 = templates
    return (unpack_tree(msg, "g1", t1), unpack_tree(msg, "g2", t2),
            unpack_tree(msg, "g3", t3))


def refresh(worker: int, t_master: int, rows: Sequence) -> Message:
    """Master -> worker: the worker's refreshed local point
    (x1_j, x2_j, x3_j) after its push was consumed at master iteration
    `t_master` (and the new local rows it must differentiate at next)."""
    x1, x2, x3 = rows
    return Message(REFRESH, {"worker": int(worker), "t": int(t_master)},
                   pack_trees({"x1": x1, "x2": x2, "x3": x3}))


def refresh_rows(msg: Message, templates: Tuple) -> Tuple:
    """Decode a REFRESH payload against (x1, x2, x3) row templates."""
    t1, t2, t3 = templates
    return (unpack_tree(msg, "x1", t1), unpack_tree(msg, "x2", t2),
            unpack_tree(msg, "x3", t3))


def stop() -> Message:
    """Master -> worker: run complete, exit the compute loop."""
    return Message(STOP, {}, {})
