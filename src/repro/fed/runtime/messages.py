"""Serializable message layer: json header + npz array payload.

Every runtime message crosses the wire as one byte string:

    [4-byte big-endian header length][json header][npz of the arrays]

The header carries the message kind and json-safe scalars (worker id,
iteration counts, flags); pytree payloads travel as their flattened
leaves under positional keys ("g1/0", "g1/1", ...).  Treedefs are NEVER
transmitted — both endpoints rebuild the same problem (in-process by
sharing it, across processes via `problems.py`'s registry) and unflatten
against their local templates.  No pickle anywhere, so a worker process
can't smuggle arbitrary objects into the master.

The same bytes flow over every transport — the in-process queue
transport carries encoded frames too, so unit tests exercise the real
wire format, not a shortcut.
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

HELLO, PUSH, REFRESH, STOP = "hello", "push", "refresh", "stop"
# fault-tolerance protocol surface: HEARTBEAT keeps a silent-but-alive
# worker out of the master's dead set; DISCONNECT never crosses the wire
# — it is synthesized LOCALLY (by a transport reader thread or a chaos
# supervisor) so the master loop can distinguish "slow" from "gone".
HEARTBEAT, DISCONNECT = "heartbeat", "disconnect"
# elastic-admission surface: a worker with an id BEYOND the launch
# population opens with ADMIT instead of HELLO; the master queues it,
# grows the canonical state at the next iteration boundary, and replies
# WELCOME (carrying the grown population width and the boundary
# iteration) followed by the newcomer's initial rows.
ADMIT, WELCOME = "admit", "welcome"


@dataclasses.dataclass
class Message:
    """One wire message: a kind tag, json-safe `meta` scalars, and named
    array leaves."""
    kind: str
    meta: Dict
    arrays: Dict[str, np.ndarray]


def encode(msg: Message) -> bytes:
    """`Message` -> one self-delimiting byte frame."""
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in msg.arrays.items()})
    header = json.dumps({"kind": msg.kind, "meta": msg.meta}).encode()
    return struct.pack(">I", len(header)) + header + buf.getvalue()


def peek_kind(data: bytes) -> Optional[str]:
    """The frame's kind without decoding the array payload (None if the
    frame is truncated/corrupt) — what chaos scripts key faults on."""
    try:
        (hlen,) = struct.unpack(">I", data[:4])
        return json.loads(data[4:4 + hlen].decode())["kind"]
    except Exception:
        return None


def peek_meta(data: bytes) -> Optional[Dict]:
    """The frame's meta dict without decoding the array payload (None if
    truncated/corrupt) — lets chaos scripts key on push sequence
    numbers without paying for the npz."""
    try:
        (hlen,) = struct.unpack(">I", data[:4])
        return json.loads(data[4:4 + hlen].decode())["meta"]
    except Exception:
        return None


def decode(data: bytes) -> Message:
    """Byte frame -> `Message` (arrays rejected if they'd need pickle)."""
    (hlen,) = struct.unpack(">I", data[:4])
    header = json.loads(data[4:4 + hlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    payload = data[4 + hlen:]
    if payload:
        with np.load(io.BytesIO(payload), allow_pickle=False) as npz:
            arrays = {k: npz[k] for k in npz.files}
    return Message(kind=header["kind"], meta=header["meta"], arrays=arrays)


# ---------------------------------------------------------------------------
# pytree <-> named-leaf helpers
# ---------------------------------------------------------------------------

def pack_trees(groups: Dict[str, object]) -> Dict[str, np.ndarray]:
    """Flatten each named pytree into positional leaf keys."""
    out: Dict[str, np.ndarray] = {}
    for name, tree in groups.items():
        for i, leaf in enumerate(jax.tree.leaves(tree)):
            out[f"{name}/{i}"] = np.asarray(leaf)
    return out


def unpack_tree(msg: Message, name: str, template):
    """Rebuild pytree `name` from a message against a local template
    (leaf count must match — a wire/format mismatch fails loudly)."""
    treedef = jax.tree.structure(template)
    leaves = []
    i = 0
    while f"{name}/{i}" in msg.arrays:
        leaves.append(msg.arrays[f"{name}/{i}"])
        i += 1
    if i != treedef.num_leaves:
        raise ValueError(
            f"message group {name!r} has {i} leaves; local template "
            f"expects {treedef.num_leaves}")
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# message constructors (the whole protocol surface)
# ---------------------------------------------------------------------------

def hello(worker: int, epoch: int = 0) -> Message:
    """Worker -> master handshake / rejoin announcement.  `epoch` is the
    worker's session counter: 0 for a first connection, incremented on
    every reconnect, so the master can replay the worker's last consumed
    local point and discard frames from dead sessions."""
    return Message(HELLO, {"worker": int(worker), "epoch": int(epoch)}, {})


def admit(worker: int, epoch: int = 0) -> Message:
    """Worker -> master: request admission into the population for an
    id at-or-beyond the launch width.  `epoch` follows the HELLO
    session-counter contract — an admitted worker that reconnects sends
    ADMIT again with a bumped epoch and is treated like any rejoin."""
    return Message(ADMIT, {"worker": int(worker), "epoch": int(epoch)}, {})


def welcome(worker: int, t_master: int, n_workers: int) -> Message:
    """Master -> worker: the admission grant, sent at the iteration
    boundary where the population grew to `n_workers`; the newcomer's
    initial rows (a REFRESH stamped with the same boundary `t_master`)
    follow immediately."""
    return Message(WELCOME, {"worker": int(worker), "t": int(t_master),
                             "n_workers": int(n_workers)}, {})


def heartbeat(worker: int, epoch: int = 0) -> Message:
    """Worker -> master liveness beacon (sent while idle-waiting for a
    refresh, so a slow worker is never declared dead)."""
    return Message(HEARTBEAT, {"worker": int(worker),
                               "epoch": int(epoch)}, {})


def disconnect(worker: int) -> Message:
    """LOCAL frame a transport reader (or chaos supervisor) enqueues when
    worker `worker`'s connection breaks — never sent over a wire."""
    return Message(DISCONNECT, {"worker": int(worker)}, {})


def push(worker: int, n_pushes: int, grads: Sequence,
         epoch: int = 0) -> Message:
    """Worker -> master: the Eq. 16 gradient triple (g1_j, g2_j, g3_j)
    at the worker's current local point.  `n_pushes` is the within-epoch
    push sequence number — the master consumes each (epoch, seq) at most
    once, so duplicated / retransmitted frames are exact no-ops."""
    g1, g2, g3 = grads
    return Message(PUSH, {"worker": int(worker), "n_pushes": int(n_pushes),
                          "epoch": int(epoch)},
                   pack_trees({"g1": g1, "g2": g2, "g3": g3}))


def push_grads(msg: Message, templates: Tuple) -> Tuple:
    """Decode a PUSH payload against (x1, x2, x3) worker-row templates."""
    t1, t2, t3 = templates
    return (unpack_tree(msg, "g1", t1), unpack_tree(msg, "g2", t2),
            unpack_tree(msg, "g3", t3))


def refresh(worker: int, t_master: int, rows: Sequence) -> Message:
    """Master -> worker: the worker's refreshed local point
    (x1_j, x2_j, x3_j) after its push was consumed at master iteration
    `t_master` (and the new local rows it must differentiate at next)."""
    x1, x2, x3 = rows
    return Message(REFRESH, {"worker": int(worker), "t": int(t_master)},
                   pack_trees({"x1": x1, "x2": x2, "x3": x3}))


def refresh_rows(msg: Message, templates: Tuple) -> Tuple:
    """Decode a REFRESH payload against (x1, x2, x3) row templates."""
    t1, t2, t3 = templates
    return (unpack_tree(msg, "x1", t1), unpack_tree(msg, "x2", t2),
            unpack_tree(msg, "x3", t3))


def stop() -> Message:
    """Master -> worker: run complete, exit the compute loop."""
    return Message(STOP, {}, {})
