"""The worker endpoint: Eq. 16 gradient compute loop + subprocess CLI.

A worker owns exactly its data shard.  Its loop is the dual of the
master's reply protocol: receive the refreshed local point
(x1_j, x2_j, x3_j), differentiate the local objective f1 there, push the
gradient triple, repeat until STOP.  The worker's point only changes
when the master consumes one of its pushes, so between activations the
local copy is bitwise the master's row — the worker never recomputes a
gradient the master won't use, and every gradient it pushes is evaluated
exactly where the scanned reference would evaluate it.

Fault tolerance (the worker half of the ISSUE 7 protocol):

  - The session opens with `hello(worker, epoch)` — epoch 0 for a first
    connection, bumped on every reconnect, so the master can replay the
    worker's last consumed local point and discard dead-session frames.
  - While idle the worker emits HEARTBEATs (period
    `FaultConfig.heartbeat_every`), so slow is never mistaken for gone.
  - An unacknowledged push is retransmitted every
    `FaultConfig.resend_every` — pushes carry (epoch, seq), so the
    master consumes each at most once and duplicates are exact no-ops.
  - Refreshes are deduplicated by master iteration `t`: a retransmitted
    refresh for an already-computed point triggers an immediate push
    retransmit instead of recomputation (the rows are bitwise the same,
    so recomputing would be exact too — just wasted).  A REFRESH whose
    meta lacks `t` is a PROTOCOL ERROR and raises immediately: the dedup
    rule would otherwise read it as t=0 <= last_t — a silent duplicate —
    and wedge the worker into an infinite push-retransmit loop.
  - Corrupt frames (a connection cut mid-write, a chaos `cut` fault)
    are skipped; the retransmit protocol recovers the payload.

Streamed data (`stream=`): the worker synthesizes its own batch at the
master iteration its REFRESH carries.  That `t` IS the worker's
consumption time t_hat_j at the moment the master will consume the
resulting push (the master stamps refreshes with post-step t+1, exactly
what `afto_step_from_grads` writes into t_hat for active workers), so
`batch_at(spec, key, t, worker_offset=j, n_local=1)` reproduces the
streamed scan body's row j bitwise — no batch bytes cross the wire.

`main()` is the multi-process entry (`python -m repro.fed.runtime.worker
--problem quadratic --worker 0 --port P`): problem closures aren't
picklable, so subprocess workers rebuild the problem by name from
`problems.py` and connect over TCP — with capped-exponential-backoff
reconnects (seeded jitter) and an epoch bump whenever an established
session breaks.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream
from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import transport as transport_lib
from repro.fed.runtime.membership import FaultConfig


def worker_loop(problem: TrilevelProblem, worker: int,
                endpoint: transport_lib.WorkerEndpoint,
                max_pushes: Optional[int] = None,
                epoch: int = 0,
                fault: Optional[FaultConfig] = None,
                stream: Optional[Stream] = None,
                admit: bool = False) -> int:
    """Run worker `worker`'s compute loop until STOP (or `max_pushes`);
    returns the number of gradients pushed.  `epoch` is the session
    counter announced in the opening HELLO (bumped by reconnect loops).
    With `stream`, each refresh's batch row is synthesized locally at
    the frame's master iteration `t` (see module docstring).

    `admit=True` opens with ADMIT instead of HELLO — the elastic
    protocol for an id beyond the launch population.  The worker then
    idles (heartbeating) until the master's boundary WELCOME + initial
    rows arrive; an admitted worker keeps using ADMIT on reconnect.

    Raises `ConnectionError` if the transport breaks mid-session — the
    caller (supervisor thread / CLI reconnect loop) owns the retry."""
    fault = fault or FaultConfig()
    templates = (problem.x1_init, problem.x2_init, problem.x3_init)

    if stream is None:
        data_j = jax.tree.map(lambda d: jnp.asarray(d)[worker],
                              problem.data)

        def batch_row(t):
            return data_j
    else:
        spec, base_key = stream.spec, jnp.asarray(stream.key)

        # the vmapped n_local=1 path, row 0 — bitwise the sharded
        # engines' layout (test_worker_blocks_are_layout_independent);
        # `t` traces, so every iteration reuses one compiled fold
        @jax.jit
        def _row(t):
            return jax.tree.map(
                lambda x: x[0],
                stream_lib.batch_at(spec, base_key, t,
                                    worker_offset=worker, n_local=1))

        def batch_row(t):
            return _row(jnp.asarray(t, jnp.int32))

    @jax.jit
    def grad_fn(data, x1, x2, x3):
        return jax.grad(
            lambda a, b, c: problem.f1(data, a, b, c),
            argnums=(0, 1, 2))(x1, x2, x3)

    opening = (msg_lib.admit(worker, epoch) if admit
               else msg_lib.hello(worker, epoch))
    endpoint.send(msg_lib.encode(opening))
    n_pushes = 0
    last_t = -1                 # newest master iteration acted on
    last_push_frame: Optional[bytes] = None   # unacked push, for resends
    last_push_tx = 0.0

    def push_current() -> None:
        nonlocal last_push_tx
        if last_push_frame is not None:
            endpoint.send(last_push_frame)
            last_push_tx = time.monotonic()

    while max_pushes is None or n_pushes < max_pushes:
        frame = endpoint.recv(timeout=fault.heartbeat_every)
        if frame is None:
            # idle: retransmit an unacked push (the master may have lost
            # it), otherwise beacon liveness so slow != dead
            if last_push_frame is not None and \
                    time.monotonic() - last_push_tx > fault.resend_every:
                push_current()
            else:
                endpoint.send(msg_lib.encode(
                    msg_lib.heartbeat(worker, epoch)))
            continue
        try:
            m = msg_lib.decode(frame)
        except Exception:
            continue            # corrupt frame; retransmits recover it
        if m.kind == msg_lib.STOP:
            break
        if m.kind == msg_lib.WELCOME:
            # the admission grant; the initial rows (a REFRESH stamped
            # with the same boundary t) follow on the same connection
            continue
        if m.kind != msg_lib.REFRESH:
            raise ValueError(f"worker got unexpected {m.kind!r} message")
        if "t" not in m.meta:
            # protocol error, NOT a duplicate: defaulting a missing `t`
            # to 0 would read as t <= last_t and wedge this worker into
            # retransmitting a stale push forever — surface it instead
            raise ValueError(
                f"worker {worker} got a REFRESH without a master "
                f"iteration 't' in its meta {m.meta!r}; refusing to "
                "treat an unstamped frame as a duplicate")
        t = int(m.meta["t"])
        if t <= last_t:
            # duplicate refresh: our push for this point was lost in
            # flight — the rows are unchanged, so retransmit instead of
            # recomputing the identical gradients
            push_current()
            continue
        last_t = t
        x1, x2, x3 = (jax.tree.map(jnp.asarray, r) for r in
                      msg_lib.refresh_rows(m, templates))
        grads = grad_fn(batch_row(t), x1, x2, x3)
        n_pushes += 1
        last_push_frame = msg_lib.encode(
            msg_lib.push(worker, n_pushes, grads, epoch=epoch))
        push_current()
    endpoint.close()
    return n_pushes


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Subprocess worker entry (TCP transport only) with a reconnect
    loop: capped exponential backoff + seeded jitter on connection
    refusal, and an epoch bump whenever an ESTABLISHED session breaks
    (so the master replays the last consumed local point)."""
    from repro.fed.runtime import problems as problems_lib

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--problem", default="quadratic",
                   help="problem registry name (problems.py)")
    p.add_argument("--worker", type=int, required=True)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--n-workers", type=int, default=2)
    p.add_argument("--dim", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--epoch", type=int, default=0,
                   help="starting session epoch (respawned workers pass "
                        "their previous epoch + 1)")
    p.add_argument("--stream", action="store_true",
                   help="synthesize batches locally from the problem's "
                        "registered stream (problems.py STREAMS) instead "
                        "of using its static data")
    args = p.parse_args(argv)

    # an id at-or-beyond the launch width is a LATE worker: it builds
    # the problem wide enough to contain its own row (registry problems
    # are per-worker-row stable, so row j is identical at any build
    # width >= j + 1) and opens with ADMIT instead of HELLO
    admit = args.worker >= args.n_workers
    build_n = max(args.n_workers, args.worker + 1)
    problem, _ = problems_lib.build(
        args.problem, n_workers=build_n, dim=args.dim,
        seed=args.seed)
    stream = (problems_lib.build_stream(
        args.problem, n_workers=build_n, dim=args.dim,
        seed=args.seed) if args.stream else None)
    fault = FaultConfig()
    rng = np.random.default_rng((args.seed, args.worker))
    epoch = args.epoch
    tries = 0
    while True:
        try:
            endpoint = transport_lib.TcpTransport.connect(
                args.host, args.port, args.worker, epoch=epoch,
                admit=admit)
        except OSError:
            tries += 1
            if tries > fault.backoff_tries:
                raise
            delay = min(fault.backoff_cap,
                        fault.backoff_base * 2.0 ** (tries - 1))
            time.sleep(delay * (0.5 + float(rng.random())))
            continue
        tries = 0
        try:
            worker_loop(problem, args.worker, endpoint,
                        epoch=epoch, fault=fault, stream=stream,
                        admit=admit)
            return 0
        except (ConnectionError, OSError):
            # the session was established and then broke: the master saw
            # (or will see) this session die, so the next one must
            # announce itself as new
            epoch += 1
            time.sleep(fault.backoff_base)


if __name__ == "__main__":
    raise SystemExit(main())
