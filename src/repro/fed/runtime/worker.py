"""The worker endpoint: Eq. 16 gradient compute loop + subprocess CLI.

A worker owns exactly its data shard.  Its loop is the dual of the
master's reply protocol: receive the refreshed local point
(x1_j, x2_j, x3_j), differentiate the local objective f1 there, push the
gradient triple, repeat until STOP.  The worker's point only changes
when the master consumes one of its pushes, so between activations the
local copy is bitwise the master's row — the worker never recomputes a
gradient the master won't use, and every gradient it pushes is evaluated
exactly where the scanned reference would evaluate it.

`main()` is the multi-process entry (`python -m repro.fed.runtime.worker
--problem quadratic --worker 0 --port P`): problem closures aren't
picklable, so subprocess workers rebuild the problem by name from
`problems.py` and connect over TCP.
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.types import TrilevelProblem
from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import transport as transport_lib


def worker_loop(problem: TrilevelProblem, worker: int,
                endpoint: transport_lib.WorkerEndpoint,
                max_pushes: Optional[int] = None) -> int:
    """Run worker `worker`'s compute loop until STOP (or `max_pushes`);
    returns the number of gradients pushed."""
    data_j = jax.tree.map(lambda d: jnp.asarray(d)[worker], problem.data)
    templates = (problem.x1_init, problem.x2_init, problem.x3_init)

    @jax.jit
    def grad_fn(x1, x2, x3):
        return jax.grad(
            lambda a, b, c: problem.f1(data_j, a, b, c),
            argnums=(0, 1, 2))(x1, x2, x3)

    n_pushes = 0
    while max_pushes is None or n_pushes < max_pushes:
        m = msg_lib.decode(endpoint.recv())
        if m.kind == msg_lib.STOP:
            break
        if m.kind != msg_lib.REFRESH:
            raise ValueError(f"worker got unexpected {m.kind!r} message")
        x1, x2, x3 = (jax.tree.map(jnp.asarray, r) for r in
                      msg_lib.refresh_rows(m, templates))
        grads = grad_fn(x1, x2, x3)
        n_pushes += 1
        endpoint.send(msg_lib.encode(
            msg_lib.push(worker, n_pushes, grads)))
    endpoint.close()
    return n_pushes


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Subprocess worker entry (TCP transport only)."""
    from repro.fed.runtime import problems as problems_lib

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--problem", default="quadratic",
                   help="problem registry name (problems.py)")
    p.add_argument("--worker", type=int, required=True)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--n-workers", type=int, default=2)
    p.add_argument("--dim", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    problem, _ = problems_lib.build(
        args.problem, n_workers=args.n_workers, dim=args.dim,
        seed=args.seed)
    endpoint = transport_lib.TcpTransport.connect(
        args.host, args.port, args.worker)
    worker_loop(problem, args.worker, endpoint)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
