"""Real asynchronous federation runtime.

The scheduled engines (`repro.core.engine`) SIMULATE asynchrony: a
seeded straggler model materializes the arrival process up front and a
single compiled scan applies it.  This package is the real thing — a
master endpoint owning the canonical `FlatCuts` polytope and the z
variables, plus `hyper.n_workers` worker endpoints that each compute the
Eq. 16 gradients at their own pace and push them over a serialized
message layer.  The master consumes pushes stale under the paper's
S-of-N / tau bounded-staleness arrival rule, applies the remaining
master/dual algebra (`repro.core.afto.afto_step_from_grads`), and
records the LIVE arrival process as a `Schedule`
(`repro.core.scheduler.ArrivalRecorder`) — the scheduler finally gets
feedback from optimization timing instead of an open-loop model.

Layering:

  messages.py   serializable wire format (json header + npz leaves,
                no pickle) — `Message`, push/refresh constructors, plus
                the fault-protocol surface (HELLO epochs, HEARTBEAT,
                local DISCONNECT frames).
  transport.py  pluggable byte movers: `InProcTransport` (queue pairs,
                deterministic tests) and `TcpTransport` (length-prefixed
                frames over sockets, real multi-process runs, reconnect
                accepts, broken connections surfaced as DISCONNECT).
  membership.py `FaultConfig` + `Membership` (the master's failure
                detector / session bookkeeping) and the exact worker
                resharding operators (`make_views` / `assemble_state`).
  master.py     the arrival rule + master step loop (`Master`) with
                liveness deadlines, degradation recording, durable
                checkpoint/resume of the whole runtime carry.
  worker.py     the worker compute loop (heartbeats, retransmits) +
                reconnecting subprocess CLI entry.
  chaos.py      seeded deterministic fault injection (`ChaosScript`)
                and the supervised crash/rejoin harness
                (`run_chaos_async`).
  problems.py   name -> (problem, hyper) registry so subprocess workers
                can rebuild the (unpicklable) closure-bearing problem.

Conformance contract: `run_async(..., replay=schedule)` over the
deterministic in-process transport reproduces the `run_scanned`
trajectory for that arrival order (up to lowering-level float noise in
the worker gradients), and the arrival process recorded by a free run
replays through `run_scanned` the same way — INCLUDING degraded runs:
worker deaths only shape which masks get recorded, never the step math,
so a chaos run's Schedule replays bit-exactly too.
`tests/test_runtime.py` and `tests/test_chaos.py` pin both directions.
"""
from repro.fed.runtime.chaos import ChaosCrash, ChaosScript, run_chaos_async
from repro.fed.runtime.master import Master, run_async
from repro.fed.runtime.membership import (FaultConfig, Membership,
                                          assemble_state, make_views,
                                          reshard_state)
from repro.fed.runtime.messages import Message, decode, encode
from repro.fed.runtime.transport import InProcTransport, TcpTransport

__all__ = ["Master", "run_async", "Message", "encode", "decode",
           "InProcTransport", "TcpTransport",
           "FaultConfig", "Membership", "make_views", "assemble_state",
           "reshard_state", "ChaosScript", "ChaosCrash",
           "run_chaos_async"]
