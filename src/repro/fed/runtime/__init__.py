"""Real asynchronous federation runtime.

The scheduled engines (`repro.core.engine`) SIMULATE asynchrony: a
seeded straggler model materializes the arrival process up front and a
single compiled scan applies it.  This package is the real thing — a
master endpoint owning the canonical `FlatCuts` polytope and the z
variables, plus `hyper.n_workers` worker endpoints that each compute the
Eq. 16 gradients at their own pace and push them over a serialized
message layer.  The master consumes pushes stale under the paper's
S-of-N / tau bounded-staleness arrival rule, applies the remaining
master/dual algebra (`repro.core.afto.afto_step_from_grads`), and
records the LIVE arrival process as a `Schedule`
(`repro.core.scheduler.ArrivalRecorder`) — the scheduler finally gets
feedback from optimization timing instead of an open-loop model.

Layering:

  messages.py   serializable wire format (json header + npz leaves,
                no pickle) — `Message`, push/refresh constructors.
  transport.py  pluggable byte movers: `InProcTransport` (queue pairs,
                deterministic tests) and `TcpTransport` (length-prefixed
                frames over sockets, real multi-process runs).
  master.py     the arrival rule + master step loop (`Master`).
  worker.py     the worker compute loop + subprocess CLI entry.
  problems.py   name -> (problem, hyper) registry so subprocess workers
                can rebuild the (unpicklable) closure-bearing problem.

Conformance contract: `run_async(..., replay=schedule)` over the
deterministic in-process transport reproduces the `run_scanned`
trajectory for that arrival order (up to lowering-level float noise in
the worker gradients), and the arrival process recorded by a free run
replays through `run_scanned` the same way.  `tests/test_runtime.py`
pins both directions.
"""
from repro.fed.runtime.master import Master, run_async
from repro.fed.runtime.messages import Message, decode, encode
from repro.fed.runtime.transport import InProcTransport, TcpTransport

__all__ = ["Master", "run_async", "Message", "encode", "decode",
           "InProcTransport", "TcpTransport"]
