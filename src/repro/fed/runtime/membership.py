"""Elastic membership: liveness tracking + exact worker resharding.

Two halves:

1. `Membership` — the master's failure-detector state machine.  Every
   frame from worker j refreshes its `last_seen` clock; a worker silent
   past `FaultConfig.death_timeout` (or surfaced as a transport
   `DISCONNECT`) is DECLARED DEAD: removed from the tau-forced arrival
   set, its pending gradient rows dropped (zero-filled rows are exact —
   Eq. 16 masks inactive rows bitwise), and the degradation recorded in
   the arrival `Schedule`'s `dead` mask so the trajectory still replays
   exactly through `run_scanned`.  A rejoin (re-HELLO with a bumped
   resume epoch, or a late frame from a presumed-dead worker) resurrects
   it with a fresh staleness clock.  Per-worker (epoch, seq) bookkeeping
   makes duplicated / retransmitted / dead-session frames exact no-ops.

2. Exact resharding — `make_views` / `assemble_state` partition the
   canonical `AFTOState` into per-shard worker views (each shard holds
   its own workers' stacked rows plus a local cut polytope from
   `cuts.shard_cuts`: replicated a-columns + own workers' b-columns) and
   reassemble them bitwise.  Because the column partition is exact, a
   membership change mid-trajectory (workers regrouped over a different
   shard count on permanent leave/join) is a pure re-layout: a resharded
   continuation matches the fixed-membership run bit-for-bit
   (`tests/test_membership.py` pins this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core import cuts as cuts_lib
from repro.core.types import (AFTOState, FlatCuts, InnerState2, InnerState3,
                              StaleView)


# ---------------------------------------------------------------------------
# fault-tolerance knobs (master + worker sides share one config)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Timeouts and pacing for the fault-tolerant runtime.

    The defaults are generous relative to the test problems' per-push
    compute (~ms) so healthy runs never trip a deadline; chaos tests
    shrink them to exercise the failure paths quickly.
    """
    heartbeat_every: float = 0.2    # worker liveness beacon period (idle)
    resend_every: float = 1.0       # worker push-retransmit period
    refresh_resend_every: float = 1.0   # master refresh-retransmit period
    death_timeout: float = 10.0     # silence before a worker is declared dead
    poll_interval: float = 0.02     # master recv poll while blocked
    all_dead_timeout: float = 30.0  # blocked with zero live workers -> error
    stop_timeout: float = 10.0      # STOP-resend shutdown drain deadline
    min_iter_time: float = 0.0      # master pacing floor (chaos smoke)
    backoff_base: float = 0.05      # worker reconnect backoff (seconds)
    backoff_cap: float = 2.0
    backoff_tries: int = 20


class Membership:
    """Per-worker liveness, session epochs and consumed-push sequence
    numbers — the master's view of who is alive, who is gone, and which
    frames are from dead sessions."""

    def __init__(self, n_workers: int, cfg: Optional[FaultConfig] = None,
                 clock=time.monotonic):
        self.n = int(n_workers)
        self.cfg = cfg or FaultConfig()
        self.clock = clock
        now = clock()
        self.alive = np.ones(self.n, dtype=bool)
        self.last_seen = np.full(self.n, now, dtype=np.float64)
        self.epoch = np.zeros(self.n, dtype=np.int64)
        self.consumed_seq = np.zeros(self.n, dtype=np.int64)
        self.deaths = 0
        self.rejoins = 0

    # -- liveness transitions ----------------------------------------------

    def saw(self, j: int) -> bool:
        """Any frame from worker j refreshes its clock; returns True if
        this resurrects a presumed-dead worker (it was slow, not gone)."""
        j = int(j)
        self.last_seen[j] = self.clock()
        if not self.alive[j]:
            self.alive[j] = True
            self.rejoins += 1
            return True
        return False

    def hello(self, j: int, epoch: int) -> bool:
        """Process a HELLO; returns True if the master must replay the
        worker's last consumed local point.

        Any post-launch HELLO is a session restart: the worker's push
        sequence restarts at 1 regardless of whether it remembered to
        bump its epoch, so the consumed counter resets whenever the
        announced epoch is current-or-newer and the rows are replayed
        unconditionally.  (The old rule replayed only on death or an
        epoch advance — an externally supervised restart that forgot
        `--epoch` kept its socket but never got its rows back, and its
        seq-1 pushes read as consumed duplicates: wedged until
        death_timeout.)  A STALE epoch still never regresses the
        session — rows are replayed but the live session's (epoch,
        consumed_seq) dedup state is untouched."""
        j = int(j)
        self.saw(j)
        if int(epoch) >= int(self.epoch[j]):
            self.epoch[j] = int(epoch)
            self.consumed_seq[j] = 0
        return True

    def disconnect(self, j: int) -> bool:
        """Transport surfaced a broken connection; returns True if the
        worker was alive (newly declared dead)."""
        j = int(j)
        newly = bool(self.alive[j])
        if newly:
            self.alive[j] = False
            self.deaths += 1
        return newly

    def overdue(self) -> List[int]:
        """Live workers silent past the death deadline."""
        now = self.clock()
        return [int(j) for j in range(self.n)
                if self.alive[j]
                and now - self.last_seen[j] > self.cfg.death_timeout]

    def mark_dead(self, j: int) -> None:
        j = int(j)
        if self.alive[j]:
            self.alive[j] = False
            self.deaths += 1

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    # -- elastic admission (ISSUE 10) ---------------------------------------

    def grow(self, n_new: int) -> None:
        """Widen the population to `n_new` workers.  New slots start
        DEAD with fresh session bookkeeping — `admit` (or a gap id's
        later ADMIT) resurrects them.  Growth is monotone; ids between
        the old width and the highest admitted id that never said ADMIT
        simply stay dead (they are excluded from the tau-forced set the
        same way a crashed worker is)."""
        n_new = int(n_new)
        if n_new < self.n:
            raise ValueError(
                f"grow: {n_new} < current population {self.n} "
                "(membership only grows)")
        if n_new == self.n:
            return
        add = n_new - self.n
        now = self.clock()
        self.alive = np.concatenate([self.alive, np.zeros(add, bool)])
        self.last_seen = np.concatenate(
            [self.last_seen, np.full(add, now, np.float64)])
        self.epoch = np.concatenate(
            [self.epoch, np.zeros(add, np.int64)])
        self.consumed_seq = np.concatenate(
            [self.consumed_seq, np.zeros(add, np.int64)])
        self.n = n_new

    def admit(self, j: int, epoch: int = 0) -> None:
        """Open an admitted worker's first session: alive, at the
        announced epoch, with a clean consumed counter."""
        j = int(j)
        self.alive[j] = True
        self.epoch[j] = int(epoch)
        self.consumed_seq[j] = 0
        self.last_seen[j] = self.clock()

    def observe_epoch(self, j: int, epoch: int) -> bool:
        """Adopt a newer session epoch seen on any frame (covers a lost
        rejoin HELLO: the first push of the new session advances the
        epoch and resets the consumed counter).  Returns True if the
        epoch advanced."""
        j = int(j)
        if int(epoch) > int(self.epoch[j]):
            self.epoch[j] = int(epoch)
            self.consumed_seq[j] = 0
            return True
        return False

    def fresh_push(self, j: int, epoch: int, seq: int) -> bool:
        """True iff a PUSH with this (epoch, seq) is new — from the
        worker's current session and not yet consumed.  Stale-session
        frames are dropped; a current-session duplicate seq means the
        worker never got its refresh (retransmit it)."""
        j = int(j)
        return (int(epoch) == int(self.epoch[j])
                and int(seq) > int(self.consumed_seq[j]))

    def consumed(self, j: int, seq: int) -> None:
        self.consumed_seq[int(j)] = int(seq)

    def reset_sessions(self) -> None:
        """Forget connection-scoped bookkeeping (epochs + consumed
        sequence numbers) — used when a resumed master faces a fresh
        worker population.  Liveness clocks restart too."""
        self.epoch[:] = 0
        self.consumed_seq[:] = 0
        self.alive[:] = True
        self.last_seen[:] = self.clock()

    def status(self) -> List[Dict]:
        """Per-worker liveness snapshot for the serve /status endpoint."""
        now = self.clock()
        return [{"worker": j,
                 "alive": bool(self.alive[j]),
                 "last_seen_age": float(now - self.last_seen[j]),
                 "epoch": int(self.epoch[j]),
                 "consumed_seq": int(self.consumed_seq[j])}
                for j in range(self.n)]

    # -- durable-master support --------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        return {"epoch": self.epoch.copy(),
                "consumed_seq": self.consumed_seq.copy(),
                "alive": self.alive.copy()}

    def load_state_dict(self, d: Dict[str, np.ndarray]) -> None:
        self.epoch = np.asarray(d["epoch"], np.int64).copy()
        self.consumed_seq = np.asarray(d["consumed_seq"], np.int64).copy()
        self.alive = np.asarray(d["alive"], bool).copy()
        # a grown snapshot restores at its grown width
        self.n = int(self.epoch.shape[0])
        self.last_seen = np.full(self.n, self.clock(), np.float64)


# ---------------------------------------------------------------------------
# exact resharding of the canonical state over worker groups
# ---------------------------------------------------------------------------

# every AFTOState piece with a leading worker axis (nested fields listed
# explicitly so a new stacked field fails the conformance test loudly
# instead of silently staying un-resharded)
_STACKED_TOP = ("X1", "X2", "X3", "theta")


@dataclasses.dataclass
class ShardView:
    """One shard's worker-partitioned slice of the canonical state:
    its workers' stacked rows plus the local cut polytopes (replicated
    a-columns + own workers' b-columns, `cuts.shard_spec` layout)."""
    index: int
    n_shards: int
    stacks: Dict   # field name -> (n_loc, ...) tree (incl. nested pieces)
    cuts_i: FlatCuts
    cuts_ii: FlatCuts


def _block(tree, w: int, n_loc: int):
    return jax.tree.map(lambda x: x[w * n_loc:(w + 1) * n_loc], tree)


def _n_workers_of(state: AFTOState) -> int:
    return int(np.shape(state.stale.t_hat)[0])


def make_views(state: AFTOState, n_shards: int) -> List[ShardView]:
    """Partition the canonical state into `n_shards` worker views.  The
    worker axis must divide evenly (contiguous groups — the same layout
    `Schedule.worker_shards` and the sharded engine use)."""
    n = _n_workers_of(state)
    if n % n_shards != 0:
        raise ValueError(
            f"{n} workers do not partition over {n_shards} shards")
    n_loc = n // n_shards
    ci = cuts_lib.shard_cuts(state.cuts_i, n_shards)
    cii = cuts_lib.shard_cuts(state.cuts_ii, n_shards)
    views = []
    for w in range(n_shards):
        stacks = {f: _block(getattr(state, f), w, n_loc)
                  for f in _STACKED_TOP}
        stacks["stale"] = StaleView(
            z1=_block(state.stale.z1, w, n_loc),
            z2=_block(state.stale.z2, w, n_loc),
            z3=_block(state.stale.z3, w, n_loc),
            lam=_block(state.stale.lam, w, n_loc),
            theta=_block(state.stale.theta, w, n_loc),
            t_hat=_block(state.stale.t_hat, w, n_loc))
        stacks["inner3_x3"] = _block(state.inner3.x3, w, n_loc)
        stacks["inner3_phi"] = _block(state.inner3.phi, w, n_loc)
        stacks["inner2_x2"] = _block(state.inner2.x2, w, n_loc)
        stacks["inner2_phi"] = _block(state.inner2.phi, w, n_loc)
        views.append(ShardView(
            index=w, n_shards=n_shards, stacks=stacks,
            cuts_i=FlatCuts(a=ci.a[w], c=ci.c, active=ci.active,
                            age=ci.age, spec=ci.spec),
            cuts_ii=FlatCuts(a=cii.a[w], c=cii.c, active=cii.active,
                             age=cii.age, spec=cii.spec)))
    return views


def _concat(trees):
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


def assemble_state(master_state: AFTOState,
                   views: List[ShardView]) -> AFTOState:
    """Reassemble the canonical state from per-shard views (inverse of
    `make_views`, bit-exact).  Master-replicated fields (z's, lam,
    gamma_k, inner consensus/slack pieces, t) come from `master_state`;
    every worker-partitioned piece and the cut matrices come from the
    views."""
    import jax.numpy as jnp
    views = sorted(views, key=lambda v: v.index)
    n_shards = len(views)
    if any(v.n_shards != n_shards for v in views) \
            or [v.index for v in views] != list(range(n_shards)):
        raise ValueError("views do not form a complete shard set")

    def cat(name):
        return _concat([v.stacks[name] for v in views])

    ci = FlatCuts(a=jnp.stack([v.cuts_i.a for v in views]),
                  c=views[0].cuts_i.c, active=views[0].cuts_i.active,
                  age=views[0].cuts_i.age, spec=views[0].cuts_i.spec)
    cii = FlatCuts(a=jnp.stack([v.cuts_ii.a for v in views]),
                   c=views[0].cuts_ii.c, active=views[0].cuts_ii.active,
                   age=views[0].cuts_ii.age, spec=views[0].cuts_ii.spec)
    stale_parts = [v.stacks["stale"] for v in views]
    return dataclasses.replace(
        master_state,
        X1=cat("X1"), X2=cat("X2"), X3=cat("X3"), theta=cat("theta"),
        stale=StaleView(
            z1=_concat([s.z1 for s in stale_parts]),
            z2=_concat([s.z2 for s in stale_parts]),
            z3=_concat([s.z3 for s in stale_parts]),
            lam=_concat([s.lam for s in stale_parts]),
            theta=_concat([s.theta for s in stale_parts]),
            t_hat=_concat([s.t_hat for s in stale_parts])),
        inner3=InnerState3(x3=cat("inner3_x3"),
                           z3=master_state.inner3.z3,
                           phi=cat("inner3_phi")),
        inner2=InnerState2(x2=cat("inner2_x2"),
                           z2=master_state.inner2.z2,
                           phi=cat("inner2_phi"),
                           s=master_state.inner2.s,
                           gamma=master_state.inner2.gamma),
        cuts_i=cuts_lib.unshard_cuts(ci, master_state.cuts_i.spec),
        cuts_ii=cuts_lib.unshard_cuts(cii, master_state.cuts_ii.spec))


def reshard_state(state: AFTOState, n_old: int, n_new: int) -> AFTOState:
    """Re-partition the canonical state from `n_old` worker groups to
    `n_new` — the membership-change operation.  Both directions go
    through the exact column partition, so the result is bit-identical
    to the input state: a continuation from it matches the
    fixed-membership run bitwise."""
    canonical = assemble_state(state, make_views(state, n_old))
    return assemble_state(canonical, make_views(canonical, n_new))


# ---------------------------------------------------------------------------
# elastic admission: growing the canonical state mid-run (ISSUE 10)
# ---------------------------------------------------------------------------

def grow_state(state: AFTOState, n_new: int) -> AFTOState:
    """Widen the canonical state's worker axis to `n_new` workers.

    Every worker-stacked piece gains zero-filled rows and both cut
    polytopes gain zero b-columns (`cuts.grow_cuts`) — exact, because
    an admitted worker's row stays arrival-masked out of every Eq. 16
    update until its first push is consumed, and a zero cut coefficient
    contributes nothing to any contraction.  The newcomers' stale
    consumption clocks `t_hat` start at the CURRENT master iteration
    `state.t` (the admission boundary): the master stamps their first
    rows with that t, so a streamed worker's locally folded batch
    agrees bitwise with the master's `batch_at` fold at its first
    consumption.  Master-replicated fields (z's, lam, gamma_k, inner
    consensus/slack pieces, t) are untouched."""
    import jax.numpy as jnp

    n_old = _n_workers_of(state)
    n_new = int(n_new)
    if n_new < n_old:
        raise ValueError(
            f"grow_state: {n_new} < current width {n_old} "
            "(membership only grows)")
    if n_new == n_old:
        return state
    add = n_new - n_old

    def pad(x):
        x = jnp.asarray(x)
        return jnp.pad(x, [(0, add)] + [(0, 0)] * (x.ndim - 1))

    def pad_tree(tree):
        return jax.tree.map(pad, tree)

    t_hat = jnp.concatenate([
        jnp.asarray(state.stale.t_hat),
        jnp.broadcast_to(
            jnp.asarray(state.t, state.stale.t_hat.dtype), (add,))])
    return dataclasses.replace(
        state,
        X1=pad_tree(state.X1), X2=pad_tree(state.X2),
        X3=pad_tree(state.X3), theta=pad_tree(state.theta),
        stale=StaleView(
            z1=pad_tree(state.stale.z1), z2=pad_tree(state.stale.z2),
            z3=pad_tree(state.stale.z3), lam=pad(state.stale.lam),
            theta=pad_tree(state.stale.theta), t_hat=t_hat),
        inner3=InnerState3(x3=pad_tree(state.inner3.x3),
                           z3=state.inner3.z3,
                           phi=pad_tree(state.inner3.phi)),
        inner2=InnerState2(x2=pad_tree(state.inner2.x2),
                           z2=state.inner2.z2,
                           phi=pad_tree(state.inner2.phi),
                           s=state.inner2.s,
                           gamma=state.inner2.gamma),
        cuts_i=cuts_lib.grow_cuts(state.cuts_i, n_new),
        cuts_ii=cuts_lib.grow_cuts(state.cuts_ii, n_new))


@dataclasses.dataclass
class ElasticConfig:
    """Elastic-admission wiring for the async master.

    `build(n) -> (problem, hyper)` rebuilds the problem at population
    width `n` — it MUST be per-worker-row stable: worker j's data row
    (and stream fold) is identical at every width that contains j, so
    an already-running worker's locally built problem agrees bitwise
    with the master's grown one (`problems.py` registry builders keep
    this contract).  `build_stream(n)` is the streamed-data analogue.
    `max_workers` bounds the admissible population: an ADMIT beyond it
    is dropped as corrupt."""
    build: Callable[[int], tuple]
    max_workers: int
    build_stream: Optional[Callable] = None


def run_scanned_elastic(build: Callable[[int], tuple], schedule,
                        metrics_fn=None, metrics_every: int = 10,
                        build_stream: Optional[Callable] = None,
                        state: Optional[AFTOState] = None):
    """Replay a (possibly widening) recorded Schedule through
    `run_scanned`, segment by population width.

    A widened schedule cannot replay at full width from t=0 — the theta
    consensus update is unmasked, so a not-yet-admitted worker's dual
    would drift away from the zero row the live run actually held.
    Instead each constant-width segment runs at its own width (columns
    truncated — the padded history is zero there, so truncation is
    exact), with `grow_state` applied at every admission boundary:
    bitwise the live elastic master's trajectory.  Fixed-membership
    schedules (width=None) take the plain `run_scanned` path
    untouched."""
    from repro.core.engine import RunResult, run_scanned

    if schedule.width is None:
        problem, hyper = build(schedule.n_workers)
        data = (build_stream(schedule.n_workers)
                if build_stream is not None else None)
        return run_scanned(problem, hyper, schedule,
                           metrics_fn=metrics_fn,
                           metrics_every=metrics_every,
                           state=state, data=data)

    width = np.asarray(schedule.width, np.int64)
    bounds = [0] + [int(i) for i in
                    (np.nonzero(np.diff(width))[0] + 1)] \
        + [schedule.n_iterations]
    # segments record EVERY iteration (metrics_every=1; recording is
    # read-only, the gap is a pure function of the carry) and the
    # global `metrics_every` stride is subsampled afterwards — a
    # segment-local stride would shift the record points off the
    # unsegmented run's whenever a boundary isn't stride-aligned
    history: Dict[str, list] = {}
    host_offset = 0.0
    for a, b in zip(bounds[:-1], bounds[1:]):
        w = int(width[a])
        if state is not None and _n_workers_of(state) < w:
            state = grow_state(state, w)
        seg = schedule.slice(a, b)
        seg = dataclasses.replace(
            seg, active=seg.active[:, :w],
            dead=None if seg.dead is None else seg.dead[:, :w],
            width=None)
        problem, hyper = build(w)
        data = build_stream(w) if build_stream is not None else None
        res = run_scanned(problem, hyper, seg, metrics_fn=metrics_fn,
                          metrics_every=1, state=state, data=data)
        state = res.state
        for k, v in res.history.items():
            col = np.asarray(v)
            if k == "t":
                col = col + a
            elif k == "host_time":
                col = col + host_offset
            history.setdefault(k, []).extend(list(col))
        host_offset = float(history["host_time"][-1])
    n_total = schedule.n_iterations
    keep = np.array([it for it in range(n_total)
                     if (it + 1) % metrics_every == 0
                     or it == n_total - 1], dtype=np.int64)
    return RunResult(state=state, history={
        k: np.asarray(v)[keep] for k, v in history.items()})
