"""Elastic membership: liveness tracking + exact worker resharding.

Two halves:

1. `Membership` — the master's failure-detector state machine.  Every
   frame from worker j refreshes its `last_seen` clock; a worker silent
   past `FaultConfig.death_timeout` (or surfaced as a transport
   `DISCONNECT`) is DECLARED DEAD: removed from the tau-forced arrival
   set, its pending gradient rows dropped (zero-filled rows are exact —
   Eq. 16 masks inactive rows bitwise), and the degradation recorded in
   the arrival `Schedule`'s `dead` mask so the trajectory still replays
   exactly through `run_scanned`.  A rejoin (re-HELLO with a bumped
   resume epoch, or a late frame from a presumed-dead worker) resurrects
   it with a fresh staleness clock.  Per-worker (epoch, seq) bookkeeping
   makes duplicated / retransmitted / dead-session frames exact no-ops.

2. Exact resharding — `make_views` / `assemble_state` partition the
   canonical `AFTOState` into per-shard worker views (each shard holds
   its own workers' stacked rows plus a local cut polytope from
   `cuts.shard_cuts`: replicated a-columns + own workers' b-columns) and
   reassemble them bitwise.  Because the column partition is exact, a
   membership change mid-trajectory (workers regrouped over a different
   shard count on permanent leave/join) is a pure re-layout: a resharded
   continuation matches the fixed-membership run bit-for-bit
   (`tests/test_membership.py` pins this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import cuts as cuts_lib
from repro.core.types import (AFTOState, FlatCuts, InnerState2, InnerState3,
                              StaleView)


# ---------------------------------------------------------------------------
# fault-tolerance knobs (master + worker sides share one config)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Timeouts and pacing for the fault-tolerant runtime.

    The defaults are generous relative to the test problems' per-push
    compute (~ms) so healthy runs never trip a deadline; chaos tests
    shrink them to exercise the failure paths quickly.
    """
    heartbeat_every: float = 0.2    # worker liveness beacon period (idle)
    resend_every: float = 1.0       # worker push-retransmit period
    refresh_resend_every: float = 1.0   # master refresh-retransmit period
    death_timeout: float = 10.0     # silence before a worker is declared dead
    poll_interval: float = 0.02     # master recv poll while blocked
    all_dead_timeout: float = 30.0  # blocked with zero live workers -> error
    stop_timeout: float = 10.0      # STOP-resend shutdown drain deadline
    min_iter_time: float = 0.0      # master pacing floor (chaos smoke)
    backoff_base: float = 0.05      # worker reconnect backoff (seconds)
    backoff_cap: float = 2.0
    backoff_tries: int = 20


class Membership:
    """Per-worker liveness, session epochs and consumed-push sequence
    numbers — the master's view of who is alive, who is gone, and which
    frames are from dead sessions."""

    def __init__(self, n_workers: int, cfg: Optional[FaultConfig] = None,
                 clock=time.monotonic):
        self.n = int(n_workers)
        self.cfg = cfg or FaultConfig()
        self.clock = clock
        now = clock()
        self.alive = np.ones(self.n, dtype=bool)
        self.last_seen = np.full(self.n, now, dtype=np.float64)
        self.epoch = np.zeros(self.n, dtype=np.int64)
        self.consumed_seq = np.zeros(self.n, dtype=np.int64)
        self.deaths = 0
        self.rejoins = 0

    # -- liveness transitions ----------------------------------------------

    def saw(self, j: int) -> bool:
        """Any frame from worker j refreshes its clock; returns True if
        this resurrects a presumed-dead worker (it was slow, not gone)."""
        j = int(j)
        self.last_seen[j] = self.clock()
        if not self.alive[j]:
            self.alive[j] = True
            self.rejoins += 1
            return True
        return False

    def hello(self, j: int, epoch: int) -> bool:
        """Process a HELLO; returns True if the master must replay the
        worker's last consumed local point (a rejoin: the worker was
        dead, or announces a new session epoch)."""
        j = int(j)
        was_dead = self.saw(j)
        if int(epoch) > int(self.epoch[j]):
            # new session: the worker restarted, its push sequence
            # restarts at 1 — reset the consumed counter so its fresh
            # pushes aren't discarded as duplicates
            self.epoch[j] = int(epoch)
            self.consumed_seq[j] = 0
            return True
        return was_dead

    def disconnect(self, j: int) -> bool:
        """Transport surfaced a broken connection; returns True if the
        worker was alive (newly declared dead)."""
        j = int(j)
        newly = bool(self.alive[j])
        if newly:
            self.alive[j] = False
            self.deaths += 1
        return newly

    def overdue(self) -> List[int]:
        """Live workers silent past the death deadline."""
        now = self.clock()
        return [int(j) for j in range(self.n)
                if self.alive[j]
                and now - self.last_seen[j] > self.cfg.death_timeout]

    def mark_dead(self, j: int) -> None:
        j = int(j)
        if self.alive[j]:
            self.alive[j] = False
            self.deaths += 1

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    def observe_epoch(self, j: int, epoch: int) -> bool:
        """Adopt a newer session epoch seen on any frame (covers a lost
        rejoin HELLO: the first push of the new session advances the
        epoch and resets the consumed counter).  Returns True if the
        epoch advanced."""
        j = int(j)
        if int(epoch) > int(self.epoch[j]):
            self.epoch[j] = int(epoch)
            self.consumed_seq[j] = 0
            return True
        return False

    def fresh_push(self, j: int, epoch: int, seq: int) -> bool:
        """True iff a PUSH with this (epoch, seq) is new — from the
        worker's current session and not yet consumed.  Stale-session
        frames are dropped; a current-session duplicate seq means the
        worker never got its refresh (retransmit it)."""
        j = int(j)
        return (int(epoch) == int(self.epoch[j])
                and int(seq) > int(self.consumed_seq[j]))

    def consumed(self, j: int, seq: int) -> None:
        self.consumed_seq[int(j)] = int(seq)

    def reset_sessions(self) -> None:
        """Forget connection-scoped bookkeeping (epochs + consumed
        sequence numbers) — used when a resumed master faces a fresh
        worker population.  Liveness clocks restart too."""
        self.epoch[:] = 0
        self.consumed_seq[:] = 0
        self.alive[:] = True
        self.last_seen[:] = self.clock()

    def status(self) -> List[Dict]:
        """Per-worker liveness snapshot for the serve /status endpoint."""
        now = self.clock()
        return [{"worker": j,
                 "alive": bool(self.alive[j]),
                 "last_seen_age": float(now - self.last_seen[j]),
                 "epoch": int(self.epoch[j]),
                 "consumed_seq": int(self.consumed_seq[j])}
                for j in range(self.n)]

    # -- durable-master support --------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        return {"epoch": self.epoch.copy(),
                "consumed_seq": self.consumed_seq.copy(),
                "alive": self.alive.copy()}

    def load_state_dict(self, d: Dict[str, np.ndarray]) -> None:
        self.epoch = np.asarray(d["epoch"], np.int64).copy()
        self.consumed_seq = np.asarray(d["consumed_seq"], np.int64).copy()
        self.alive = np.asarray(d["alive"], bool).copy()
        self.last_seen[:] = self.clock()


# ---------------------------------------------------------------------------
# exact resharding of the canonical state over worker groups
# ---------------------------------------------------------------------------

# every AFTOState piece with a leading worker axis (nested fields listed
# explicitly so a new stacked field fails the conformance test loudly
# instead of silently staying un-resharded)
_STACKED_TOP = ("X1", "X2", "X3", "theta")


@dataclasses.dataclass
class ShardView:
    """One shard's worker-partitioned slice of the canonical state:
    its workers' stacked rows plus the local cut polytopes (replicated
    a-columns + own workers' b-columns, `cuts.shard_spec` layout)."""
    index: int
    n_shards: int
    stacks: Dict   # field name -> (n_loc, ...) tree (incl. nested pieces)
    cuts_i: FlatCuts
    cuts_ii: FlatCuts


def _block(tree, w: int, n_loc: int):
    return jax.tree.map(lambda x: x[w * n_loc:(w + 1) * n_loc], tree)


def _n_workers_of(state: AFTOState) -> int:
    return int(np.shape(state.stale.t_hat)[0])


def make_views(state: AFTOState, n_shards: int) -> List[ShardView]:
    """Partition the canonical state into `n_shards` worker views.  The
    worker axis must divide evenly (contiguous groups — the same layout
    `Schedule.worker_shards` and the sharded engine use)."""
    n = _n_workers_of(state)
    if n % n_shards != 0:
        raise ValueError(
            f"{n} workers do not partition over {n_shards} shards")
    n_loc = n // n_shards
    ci = cuts_lib.shard_cuts(state.cuts_i, n_shards)
    cii = cuts_lib.shard_cuts(state.cuts_ii, n_shards)
    views = []
    for w in range(n_shards):
        stacks = {f: _block(getattr(state, f), w, n_loc)
                  for f in _STACKED_TOP}
        stacks["stale"] = StaleView(
            z1=_block(state.stale.z1, w, n_loc),
            z2=_block(state.stale.z2, w, n_loc),
            z3=_block(state.stale.z3, w, n_loc),
            lam=_block(state.stale.lam, w, n_loc),
            theta=_block(state.stale.theta, w, n_loc),
            t_hat=_block(state.stale.t_hat, w, n_loc))
        stacks["inner3_x3"] = _block(state.inner3.x3, w, n_loc)
        stacks["inner3_phi"] = _block(state.inner3.phi, w, n_loc)
        stacks["inner2_x2"] = _block(state.inner2.x2, w, n_loc)
        stacks["inner2_phi"] = _block(state.inner2.phi, w, n_loc)
        views.append(ShardView(
            index=w, n_shards=n_shards, stacks=stacks,
            cuts_i=FlatCuts(a=ci.a[w], c=ci.c, active=ci.active,
                            age=ci.age, spec=ci.spec),
            cuts_ii=FlatCuts(a=cii.a[w], c=cii.c, active=cii.active,
                             age=cii.age, spec=cii.spec)))
    return views


def _concat(trees):
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


def assemble_state(master_state: AFTOState,
                   views: List[ShardView]) -> AFTOState:
    """Reassemble the canonical state from per-shard views (inverse of
    `make_views`, bit-exact).  Master-replicated fields (z's, lam,
    gamma_k, inner consensus/slack pieces, t) come from `master_state`;
    every worker-partitioned piece and the cut matrices come from the
    views."""
    import jax.numpy as jnp
    views = sorted(views, key=lambda v: v.index)
    n_shards = len(views)
    if any(v.n_shards != n_shards for v in views) \
            or [v.index for v in views] != list(range(n_shards)):
        raise ValueError("views do not form a complete shard set")

    def cat(name):
        return _concat([v.stacks[name] for v in views])

    ci = FlatCuts(a=jnp.stack([v.cuts_i.a for v in views]),
                  c=views[0].cuts_i.c, active=views[0].cuts_i.active,
                  age=views[0].cuts_i.age, spec=views[0].cuts_i.spec)
    cii = FlatCuts(a=jnp.stack([v.cuts_ii.a for v in views]),
                   c=views[0].cuts_ii.c, active=views[0].cuts_ii.active,
                   age=views[0].cuts_ii.age, spec=views[0].cuts_ii.spec)
    stale_parts = [v.stacks["stale"] for v in views]
    return dataclasses.replace(
        master_state,
        X1=cat("X1"), X2=cat("X2"), X3=cat("X3"), theta=cat("theta"),
        stale=StaleView(
            z1=_concat([s.z1 for s in stale_parts]),
            z2=_concat([s.z2 for s in stale_parts]),
            z3=_concat([s.z3 for s in stale_parts]),
            lam=_concat([s.lam for s in stale_parts]),
            theta=_concat([s.theta for s in stale_parts]),
            t_hat=_concat([s.t_hat for s in stale_parts])),
        inner3=InnerState3(x3=cat("inner3_x3"),
                           z3=master_state.inner3.z3,
                           phi=cat("inner3_phi")),
        inner2=InnerState2(x2=cat("inner2_x2"),
                           z2=master_state.inner2.z2,
                           phi=cat("inner2_phi"),
                           s=master_state.inner2.s,
                           gamma=master_state.inner2.gamma),
        cuts_i=cuts_lib.unshard_cuts(ci, master_state.cuts_i.spec),
        cuts_ii=cuts_lib.unshard_cuts(cii, master_state.cuts_ii.spec))


def reshard_state(state: AFTOState, n_old: int, n_new: int) -> AFTOState:
    """Re-partition the canonical state from `n_old` worker groups to
    `n_new` — the membership-change operation.  Both directions go
    through the exact column partition, so the result is bit-identical
    to the input state: a continuation from it matches the
    fixed-membership run bitwise."""
    canonical = assemble_state(state, make_views(state, n_old))
    return assemble_state(canonical, make_views(canonical, n_new))
