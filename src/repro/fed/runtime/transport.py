"""Pluggable byte transports for the async runtime.

A transport moves opaque encoded frames (`messages.encode` bytes)
between one master endpoint and N worker endpoints; the master/worker
loops never see sockets or queues, only this interface:

  master endpoint:  recv(timeout) -> bytes | None,  send(j, bytes)
  worker endpoint:  recv() -> bytes,                send(bytes)

`InProcTransport` pairs the endpoints over `queue.Queue`s — fully
deterministic when the master replays a fixed arrival order, which is
what the conformance tests run on.  `TcpTransport` carries the same
frames over sockets with a 4-byte length prefix and a HELLO handshake
that maps connections to worker ids — the real multi-process path
(`launch/serve.py fed --transport tcp`).
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
from typing import Dict, List, Optional

from repro.fed.runtime import messages as msg_lib


class MasterEndpoint:
    """Master side of any transport: one inbound frame queue (workers
    are multiplexed) + per-worker outbound sends."""

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        raise NotImplementedError

    def send(self, worker: int, frame: bytes) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class WorkerEndpoint:
    """Worker side: blocking recv from the master + send to it."""

    def recv(self) -> bytes:
        raise NotImplementedError

    def send(self, frame: bytes) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# in-process transport (threads + queues)
# ---------------------------------------------------------------------------

class _InProcMaster(MasterEndpoint):
    def __init__(self, hub: "InProcTransport"):
        self._hub = hub

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._hub.to_master.get(timeout=timeout) \
                if timeout is not None else self._hub.to_master.get()
        except queue.Empty:
            return None

    def send(self, worker: int, frame: bytes) -> None:
        self._hub.to_worker[worker].put(frame)


class _InProcWorker(WorkerEndpoint):
    def __init__(self, hub: "InProcTransport", worker: int):
        self._hub, self._worker = hub, worker

    def recv(self) -> bytes:
        return self._hub.to_worker[self._worker].get()

    def send(self, frame: bytes) -> None:
        self._hub.to_master.put(frame)


class InProcTransport:
    """Queue-pair transport for same-process (threaded) runs.

    Frames still round-trip through `messages.encode`/`decode`, so every
    test on this transport exercises the real wire format."""

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self.to_master: "queue.Queue[bytes]" = queue.Queue()
        self.to_worker: List["queue.Queue[bytes]"] = [
            queue.Queue() for _ in range(self.n_workers)]

    def master_endpoint(self) -> MasterEndpoint:
        return _InProcMaster(self)

    def worker_endpoint(self, worker: int) -> WorkerEndpoint:
        return _InProcWorker(self, worker)


# ---------------------------------------------------------------------------
# TCP transport (length-prefixed frames)
# ---------------------------------------------------------------------------

def _send_frame(sock: socket.socket, frame: bytes) -> None:
    sock.sendall(struct.pack(">I", len(frame)) + frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = struct.unpack(">I", _recv_exact(sock, 4))
    return _recv_exact(sock, n)


class _TcpMaster(MasterEndpoint):
    """Accepts `n_workers` connections, resolves each to a worker id via
    its HELLO frame, then multiplexes per-connection reader threads into
    one inbound queue."""

    def __init__(self, host: str, port: int, n_workers: int):
        self.n_workers = n_workers
        self._server = socket.create_server((host, port))
        self.port = self._server.getsockname()[1]
        self._socks: Dict[int, socket.socket] = {}
        self._inbound: "queue.Queue[bytes]" = queue.Queue()
        self._threads: List[threading.Thread] = []

    def wait_for_workers(self) -> None:
        while len(self._socks) < self.n_workers:
            conn, _ = self._server.accept()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            m = msg_lib.decode(_recv_frame(conn))
            if m.kind != msg_lib.HELLO:
                raise ConnectionError(
                    f"expected hello handshake, got {m.kind!r}")
            j = int(m.meta["worker"])
            self._socks[j] = conn
            t = threading.Thread(target=self._reader, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _reader(self, conn: socket.socket) -> None:
        try:
            while True:
                self._inbound.put(_recv_frame(conn))
        except (ConnectionError, OSError):
            return   # worker hung up (normal after STOP)

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._inbound.get(timeout=timeout) \
                if timeout is not None else self._inbound.get()
        except queue.Empty:
            return None

    def send(self, worker: int, frame: bytes) -> None:
        _send_frame(self._socks[worker], frame)

    def close(self) -> None:
        for s in self._socks.values():
            try:
                s.close()
            except OSError:
                pass
        self._server.close()


class _TcpWorker(WorkerEndpoint):
    def __init__(self, host: str, port: int, worker: int):
        self._sock = socket.create_connection((host, port))
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        _send_frame(self._sock, msg_lib.encode(msg_lib.hello(worker)))

    def recv(self) -> bytes:
        return _recv_frame(self._sock)

    def send(self, frame: bytes) -> None:
        _send_frame(self._sock, frame)

    def close(self) -> None:
        self._sock.close()


class TcpTransport:
    """Socket transport for real multi-process runs.

    Master side: ``TcpTransport(n_workers).master_endpoint()`` binds an
    ephemeral port (read it back from ``.port``) and blocks in
    `wait_for_workers` until all workers have completed the HELLO
    handshake.  Worker side (separate process):
    ``TcpTransport.connect(host, port, worker)``.
    """

    def __init__(self, n_workers: int, host: str = "127.0.0.1",
                 port: int = 0):
        self.n_workers = int(n_workers)
        self.host, self.port = host, port
        self._master: Optional[_TcpMaster] = None

    def master_endpoint(self) -> _TcpMaster:
        if self._master is None:
            self._master = _TcpMaster(self.host, self.port, self.n_workers)
            self.port = self._master.port
        return self._master

    @staticmethod
    def connect(host: str, port: int, worker: int) -> WorkerEndpoint:
        return _TcpWorker(host, port, worker)
