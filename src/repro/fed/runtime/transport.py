"""Pluggable byte transports for the async runtime.

A transport moves opaque encoded frames (`messages.encode` bytes)
between one master endpoint and N worker endpoints; the master/worker
loops never see sockets or queues, only this interface:

  master endpoint:  recv(timeout) -> bytes | None,  send(j, bytes)
  worker endpoint:  recv(timeout) -> bytes | None,  send(bytes)

`InProcTransport` pairs the endpoints over `queue.Queue`s — fully
deterministic when the master replays a fixed arrival order, which is
what the conformance tests run on.  `TcpTransport` carries the same
frames over sockets with a 4-byte length prefix and a HELLO handshake
that maps connections to worker ids — the real multi-process path
(`launch/serve.py fed --transport tcp`).

Failure surface: a broken worker connection is never swallowed — the
master-side reader thread enqueues a synthetic `messages.disconnect(j)`
frame so the master loop can distinguish "slow" (heartbeats still
flowing) from "gone" (DISCONNECT / deadline exceeded).  After the
initial handshake the TCP master keeps accepting connections: a worker
that re-HELLOs (with a bumped resume epoch) replaces its old socket and
the HELLO frame is surfaced to the master loop, which replays the
worker's last consumed local point.
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
from typing import Dict, List, Optional

from repro.fed.runtime import messages as msg_lib


class MasterEndpoint:
    """Master side of any transport: one inbound frame queue (workers
    are multiplexed) + per-worker outbound sends."""

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        raise NotImplementedError

    def send(self, worker: int, frame: bytes) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class WorkerEndpoint:
    """Worker side: recv from the master (None on timeout) + send."""

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        raise NotImplementedError

    def send(self, frame: bytes) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# in-process transport (threads + queues)
# ---------------------------------------------------------------------------

class _InProcMaster(MasterEndpoint):
    def __init__(self, hub: "InProcTransport"):
        self._hub = hub

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._hub.to_master.get(timeout=timeout) \
                if timeout is not None else self._hub.to_master.get()
        except queue.Empty:
            return None

    def send(self, worker: int, frame: bytes) -> None:
        self._hub._ensure_queue(int(worker))
        self._hub.to_worker[worker].put(frame)


class _InProcWorker(WorkerEndpoint):
    def __init__(self, hub: "InProcTransport", worker: int):
        self._hub, self._worker = hub, worker

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._hub.to_worker[self._worker].get(timeout=timeout) \
                if timeout is not None \
                else self._hub.to_worker[self._worker].get()
        except queue.Empty:
            return None

    def send(self, frame: bytes) -> None:
        self._hub.to_master.put(frame)

    def close(self) -> None:
        # mirror the TCP reader's hangup surfacing: a closing worker
        # session enqueues its own DISCONNECT so the master's shutdown
        # drain (and death detection) sees in-proc departures too
        self._hub.to_master.put(msg_lib.encode(
            msg_lib.disconnect(self._worker)))


class InProcTransport:
    """Queue-pair transport for same-process (threaded) runs.

    Frames still round-trip through `messages.encode`/`decode`, so every
    test on this transport exercises the real wire format.  A rejoining
    worker simply requests `worker_endpoint(j)` again — the queues
    persist across worker sessions, like a master-side mailbox.  The
    mailbox list grows on demand: an elastic late-joiner with an id
    beyond the launch population registers its queue by asking for its
    endpoint (and the master's reply send registers it too, whichever
    side arrives first)."""

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self.to_master: "queue.Queue[bytes]" = queue.Queue()
        self.to_worker: List["queue.Queue[bytes]"] = [
            queue.Queue() for _ in range(self.n_workers)]
        self._grow_lock = threading.Lock()

    def _ensure_queue(self, worker: int) -> None:
        if worker < len(self.to_worker):
            return
        with self._grow_lock:
            while len(self.to_worker) <= worker:
                self.to_worker.append(queue.Queue())

    def master_endpoint(self) -> MasterEndpoint:
        return _InProcMaster(self)

    def worker_endpoint(self, worker: int) -> WorkerEndpoint:
        self._ensure_queue(int(worker))
        return _InProcWorker(self, int(worker))


# ---------------------------------------------------------------------------
# TCP transport (length-prefixed frames)
# ---------------------------------------------------------------------------

def _send_frame(sock: socket.socket, frame: bytes) -> None:
    sock.sendall(struct.pack(">I", len(frame)) + frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = struct.unpack(">I", _recv_exact(sock, 4))
    return _recv_exact(sock, n)


class _TcpMaster(MasterEndpoint):
    """Accepts `n_workers` connections, resolves each to a worker id via
    its HELLO frame, then multiplexes per-connection reader threads into
    one inbound queue.  After the initial handshake an accept thread
    keeps running so crashed workers can reconnect: a re-HELLO replaces
    the worker's socket and the HELLO frame is surfaced to the master
    loop (which owns the resume protocol)."""

    def __init__(self, host: str, port: int, n_workers: int,
                 max_workers: Optional[int] = None):
        self.n_workers = n_workers
        self.max_workers = (n_workers if max_workers is None
                            else max(int(max_workers), n_workers))
        self._server = socket.create_server((host, port))
        self.port = self._server.getsockname()[1]
        self._socks: Dict[int, socket.socket] = {}
        self._lock = threading.Lock()
        self._inbound: "queue.Queue[bytes]" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._closed = False

    def _handshake(self, conn: socket.socket):
        """Read + validate one opening frame; returns (worker id, raw
        frame).  HELLO ids must be inside the launch population; ADMIT
        ids (elastic late-joiners) must be inside [n_workers,
        max_workers).  The frame is NOT enqueued — callers decide.
        Every malformed-opening failure surfaces as `ConnectionError`
        so callers can close the probe socket and keep accepting."""
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        raw = _recv_frame(conn)
        try:
            m = msg_lib.decode(raw)
        except Exception as e:
            raise ConnectionError(
                f"undecodable handshake frame: {e}") from e
        if m.kind not in (msg_lib.HELLO, msg_lib.ADMIT):
            raise ConnectionError(
                f"expected hello/admit handshake, got {m.kind!r}")
        j = int(m.meta["worker"])
        if m.kind == msg_lib.HELLO and not 0 <= j < self.n_workers:
            raise ConnectionError(
                f"hello from out-of-range worker id {j} "
                f"(expected 0..{self.n_workers - 1})")
        if m.kind == msg_lib.ADMIT and \
                not self.n_workers <= j < self.max_workers:
            raise ConnectionError(
                f"admit from out-of-range worker id {j} "
                f"(expected {self.n_workers}..{self.max_workers - 1})")
        return j, raw

    def wait_for_workers(self, timeout: Optional[float] = None) -> None:
        """Block until every worker has completed the HELLO handshake.

        Rejects duplicate worker ids loudly (a duplicate id would
        silently adopt another worker's row assignment), and fails the
        launch with `TimeoutError` if the full population hasn't
        arrived within `timeout` seconds.  A MALFORMED opening (garbled
        frame, non-HELLO bytes, out-of-range id — e.g. a port-scanner
        probe) closes that socket and keeps accepting: a stray packet
        must not kill a healthy launch, and must not leak the accepted
        connection.  An eager ADMIT arriving during launch is installed
        and queued for the master's admission barrier; only ids inside
        the launch population count toward the handshake quorum.  On
        success, starts the reconnect accept loop."""
        import time as _time
        deadline = None if timeout is None else _time.monotonic() + timeout

        def _population():
            return {k for k in self._socks if k < self.n_workers}

        while len(_population()) < self.n_workers:
            if deadline is not None:
                self._server.settimeout(max(0.0,
                                            deadline - _time.monotonic()))
            try:
                conn, _ = self._server.accept()
            except (socket.timeout, TimeoutError):
                raise TimeoutError(
                    f"timed out waiting for workers: "
                    f"{len(_population())}/{self.n_workers} connected "
                    f"(missing {sorted(set(range(self.n_workers)) - _population())})")
            try:
                conn.settimeout(10.0)
                j, raw = self._handshake(conn)
                conn.settimeout(None)
            except (ConnectionError, OSError, TimeoutError):
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            if j in self._socks:
                conn.close()
                raise ConnectionError(
                    f"duplicate hello for worker id {j}; its socket is "
                    f"already registered")
            self._install(j, conn)
            if j >= self.n_workers:
                # an elastic late-joiner beat the launch: surface its
                # ADMIT so the running master can process the admission
                self._inbound.put(raw)
        self._server.settimeout(None)
        self._start_accept_loop()

    def _install(self, j: int, conn: socket.socket) -> None:
        with self._lock:
            old = self._socks.get(j)
            self._socks[j] = conn
        if old is not None:
            try:
                old.close()
            except OSError:
                pass
        t = threading.Thread(target=self._reader, args=(conn, j),
                             daemon=True)
        t.start()
        # prune finished reader threads (replaced sessions) so a
        # long-lived elastic serve process doesn't retain one dead
        # Thread object per rejoin forever
        self._threads = [th for th in self._threads if th.is_alive()]
        self._threads.append(t)

    def _start_accept_loop(self) -> None:
        t = threading.Thread(target=self._accept_loop, daemon=True)
        t.start()
        self._threads.append(t)

    def _accept_loop(self) -> None:
        """Post-launch accepts: reconnecting workers re-HELLO (with a
        resume epoch); the replacement socket is installed and the HELLO
        surfaced to the master loop for the row-replay protocol."""
        while not self._closed:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return          # server socket closed
            try:
                conn.settimeout(10.0)
                j, raw_hello = self._handshake(conn)
                conn.settimeout(None)
            except (ConnectionError, OSError, TimeoutError):
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            self._install(j, conn)
            # surface the original HELLO (it carries the resume epoch)
            # so the master loop can run the rejoin/row-replay protocol
            self._inbound.put(raw_hello)

    def _reader(self, conn: socket.socket, worker: int) -> None:
        try:
            while True:
                self._inbound.put(_recv_frame(conn))
        except (ConnectionError, OSError):
            # surface the hangup instead of swallowing it — but only if
            # this connection is still the worker's registered socket
            # (a replaced socket dying must not kill the fresh session)
            with self._lock:
                current = self._socks.get(worker) is conn
            if current and not self._closed:
                self._inbound.put(msg_lib.encode(
                    msg_lib.disconnect(worker)))

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._inbound.get(timeout=timeout) \
                if timeout is not None else self._inbound.get()
        except queue.Empty:
            return None

    def send(self, worker: int, frame: bytes) -> None:
        with self._lock:
            sock = self._socks.get(worker)
        if sock is None:
            raise ConnectionError(f"no connection for worker {worker}")
        try:
            _send_frame(sock, frame)
        except (OSError, ValueError) as e:
            raise ConnectionError(
                f"send to worker {worker} failed: {e}") from e

    def close(self) -> None:
        self._closed = True
        with self._lock:
            socks = list(self._socks.values())
        for s in socks:
            try:
                s.close()
            except OSError:
                pass
        self._server.close()


class _TcpWorker(WorkerEndpoint):
    def __init__(self, host: str, port: int, worker: int, epoch: int = 0,
                 admit: bool = False):
        self._sock = socket.create_connection((host, port))
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        opening = (msg_lib.admit(worker, epoch) if admit
                   else msg_lib.hello(worker, epoch))
        _send_frame(self._sock, msg_lib.encode(opening))

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        if timeout is None:
            self._sock.settimeout(None)
            return _recv_frame(self._sock)
        # Poll a single byte under the timeout, then block for the rest
        # of the frame: an idle timeout never desyncs the byte stream
        # (frames are small and sent whole, so the tail follows at once).
        self._sock.settimeout(timeout)
        try:
            first = self._sock.recv(1)
        except (socket.timeout, TimeoutError):
            return None
        if not first:
            raise ConnectionError("master closed the connection")
        self._sock.settimeout(None)
        (n,) = struct.unpack(">I", first + _recv_exact(self._sock, 3))
        return _recv_exact(self._sock, n)

    def send(self, frame: bytes) -> None:
        try:
            _send_frame(self._sock, frame)
        except (OSError, ValueError) as e:
            raise ConnectionError(f"send to master failed: {e}") from e

    def close(self) -> None:
        self._sock.close()


class TcpTransport:
    """Socket transport for real multi-process runs.

    Master side: ``TcpTransport(n_workers).master_endpoint()`` binds an
    ephemeral port (read it back from ``.port``) and blocks in
    `wait_for_workers` until all workers have completed the HELLO
    handshake (pass `timeout=` to fail a missing worker loudly).
    Worker side (separate process):
    ``TcpTransport.connect(host, port, worker, epoch)`` — reconnecting
    workers bump `epoch` so the master can replay their last consumed
    local point.
    """

    def __init__(self, n_workers: int, host: str = "127.0.0.1",
                 port: int = 0, max_workers: Optional[int] = None):
        self.n_workers = int(n_workers)
        self.max_workers = max_workers
        self.host, self.port = host, port
        self._master: Optional[_TcpMaster] = None

    def master_endpoint(self) -> _TcpMaster:
        if self._master is None:
            self._master = _TcpMaster(self.host, self.port,
                                      self.n_workers,
                                      max_workers=self.max_workers)
            self.port = self._master.port
        return self._master

    @staticmethod
    def connect(host: str, port: int, worker: int, epoch: int = 0,
                admit: bool = False) -> WorkerEndpoint:
        return _TcpWorker(host, port, worker, epoch, admit=admit)
