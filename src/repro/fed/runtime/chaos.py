"""Chaos-injection transport: seeded, scriptable, fully deterministic.

`ChaosScript` describes a fault program; `ChaosMasterEndpoint` /
`ChaosWorkerEndpoint` wrap ANY transport endpoint and apply it to
outgoing frames:

  drop       the frame is never delivered
  dup        the frame is delivered twice
  delay      the sender sleeps `delay_s` before delivering
  cut        the frame is truncated mid-frame (the receiver sees a
             corrupt frame it cannot decode — the queue-transport
             analogue of a connection dying mid-write)
  crash      the worker raises `ChaosCrash` INSTEAD of sending its
             n-th push — a scripted process death at a known point

Every decision is a pure function of (seed, role, worker, frame index)
through an independent counter-keyed PRNG stream, so a chaos run's
fault sequence is exactly reproducible — every failure path is a
replayable test, not a flake.  STOP frames are exempt from the run
faults (chaos targets the run, not the shutdown handshake) but get
their own seeded stream: `stop_cut_p` truncates the master's n-th STOP
to a given worker mid-frame — the fault that pins the master's
STOP-resend shutdown drain (a worker whose only STOP is lost would
otherwise spin forever; STOP has no worker-side retransmit to heal it).

`run_chaos_async` is the harness: an in-process master/worker
population where every endpoint is chaos-wrapped and each worker runs
under a supervisor thread that catches `ChaosCrash`, waits
`restart_delay`, and restarts the worker with a bumped resume epoch —
the full crash/rejoin cycle, deterministically scripted.  The recorded
arrival `Schedule` (with its degradation `dead` mask) replays
bit-exactly through `run_scanned` and through a fresh
`Master(replay=...)` — `tests/test_chaos.py` pins both.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import transport as transport_lib
from repro.fed.runtime.membership import FaultConfig


class ChaosCrash(RuntimeError):
    """Scripted worker death (raised instead of sending a push)."""

    def __init__(self, worker: int, push_seq: int):
        super().__init__(f"scripted crash: worker {worker} at "
                         f"push {push_seq}")
        self.worker, self.push_seq = worker, push_seq


_ROLE_MASTER, _ROLE_WORKER, _ROLE_STOP = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class ChaosScript:
    """A seeded fault program.  Probabilities are per outgoing frame;
    `crash_at_push` maps worker id -> the push SEQUENCE NUMBER at which
    that worker's FIRST session dies — triggered on the first
    transmission of that seq (retransmits of earlier pushes don't
    count), and only in the armed epoch-0 session, so every scripted
    crash happens exactly once."""
    seed: int = 0
    drop_p: float = 0.0
    dup_p: float = 0.0
    delay_p: float = 0.0
    delay_s: float = 0.005
    cut_p: float = 0.0
    stop_cut_p: float = 0.0     # per-STOP mid-frame truncation
    crash_at_push: Tuple[Tuple[int, int], ...] = ()

    def crash_point(self, worker: int) -> Optional[int]:
        for w, seq in self.crash_at_push:
            if int(w) == int(worker):
                return int(seq)
        return None

    def draw(self, role: int, worker: int, k: int) -> Dict[str, bool]:
        """The (deterministic) fault decisions for frame `k` of
        (role, worker)'s outgoing stream."""
        u = np.random.default_rng(
            (self.seed, role, int(worker), int(k))).random(4)
        return {"drop": bool(u[0] < self.drop_p),
                "dup": bool(u[1] < self.dup_p),
                "delay": bool(u[2] < self.delay_p),
                "cut": bool(u[3] < self.cut_p)}

    def stop_cut(self, worker: int, k: int) -> bool:
        """Deterministic: is the master's k-th STOP to `worker` cut?"""
        u = np.random.default_rng(
            (self.seed, _ROLE_STOP, int(worker), int(k))).random(1)
        return bool(u[0] < self.stop_cut_p)


def _apply_faults(deliver, frame: bytes, faults: Dict[str, bool],
                  delay_s: float) -> None:
    """Deliver `frame` through the scripted faults (drop wins over dup;
    cut truncates the frame so the receiver's decode fails)."""
    if faults["delay"]:
        time.sleep(delay_s)
    if faults["drop"]:
        return
    if faults["cut"]:
        deliver(frame[:max(1, len(frame) // 2)])
        return
    deliver(frame)
    if faults["dup"]:
        deliver(frame)


class ChaosMasterEndpoint(transport_lib.MasterEndpoint):
    """Wraps a master endpoint; outgoing refreshes run the script."""

    def __init__(self, inner: transport_lib.MasterEndpoint,
                 script: ChaosScript):
        self.inner, self.script = inner, script
        self._sent: Dict[int, int] = {}
        self._stops: Dict[int, int] = {}

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        return self.inner.recv(timeout)

    def send(self, worker: int, frame: bytes) -> None:
        if msg_lib.peek_kind(frame) == msg_lib.STOP:
            k = self._stops.get(worker, 0)
            self._stops[worker] = k + 1
            if self.script.stop_cut(worker, k):
                self.inner.send(worker, frame[:max(1, len(frame) // 2)])
            else:
                self.inner.send(worker, frame)
            return
        k = self._sent.get(worker, 0)
        self._sent[worker] = k + 1
        _apply_faults(lambda f: self.inner.send(worker, f), frame,
                      self.script.draw(_ROLE_MASTER, worker, k),
                      self.script.delay_s)

    def close(self) -> None:
        self.inner.close()


class ChaosWorkerEndpoint(transport_lib.WorkerEndpoint):
    """Wraps a worker endpoint; outgoing pushes/heartbeats run the
    script, and the scripted crash point raises instead of sending."""

    def __init__(self, inner: transport_lib.WorkerEndpoint, worker: int,
                 script: ChaosScript, armed: bool = True):
        self.inner, self.worker, self.script = inner, worker, script
        self.armed = armed          # False for restarted (clean) sessions
        self._sent = 0

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        return self.inner.recv(timeout)

    def send(self, frame: bytes) -> None:
        kind = msg_lib.peek_kind(frame)
        if kind == msg_lib.PUSH and self.armed:
            crash = self.script.crash_point(self.worker)
            seq = int((msg_lib.peek_meta(frame) or {}).get("n_pushes", 0))
            if crash is not None and seq == crash:
                raise ChaosCrash(self.worker, seq)
        k = self._sent
        self._sent += 1
        _apply_faults(self.inner.send, frame,
                      self.script.draw(_ROLE_WORKER, self.worker, k),
                      self.script.delay_s)

    def close(self) -> None:
        self.inner.close()


# ---------------------------------------------------------------------------
# the deterministic chaos harness
# ---------------------------------------------------------------------------

def run_chaos_async(problem, hyper, script: ChaosScript,
                    n_iterations: int = 50,
                    fault: Optional[FaultConfig] = None,
                    restart_delay: float = 0.1,
                    metrics_every: int = 10,
                    replay=None,
                    master_hook=None,
                    elastic=None,
                    admit_at: Tuple[Tuple[int, float], ...] = ()):
    """Run the async runtime with every endpoint chaos-wrapped and
    crashed workers supervised back to life (bumped resume epoch).

    `elastic` (an `ElasticConfig`) + `admit_at` — pairs of
    (worker id, spawn delay seconds) — additionally inject LATE workers:
    each is spawned after its delay in admit mode against a problem
    built at (id + 1) workers, goes through the real ADMIT/WELCOME
    boundary, and is supervised like any other worker (a crashed
    newcomer re-ADMITs with a bumped epoch).

    Returns the master's `RunResult`; `result.arrivals` carries the
    degraded (and possibly widened) Schedule that must replay exactly
    through `run_scanned` / `Master(replay=...)`.
    """
    from repro.fed.runtime import worker as worker_lib
    from repro.fed.runtime.master import Master

    fault = fault or FaultConfig(
        heartbeat_every=0.02, resend_every=0.1, refresh_resend_every=0.1,
        death_timeout=0.5, poll_interval=0.005, all_dead_timeout=10.0)
    n = hyper.n_workers
    hub = transport_lib.InProcTransport(n)
    stop_flag = threading.Event()

    def supervise(j: int, wp=None, admit: bool = False,
                  delay: float = 0.0) -> None:
        if delay > 0:
            time.sleep(delay)
        epoch = 0
        while not stop_flag.is_set():
            ep = ChaosWorkerEndpoint(hub.worker_endpoint(j), j, script,
                                     armed=(epoch == 0))
            try:
                worker_lib.worker_loop(wp if wp is not None else problem,
                                       j, ep, epoch=epoch,
                                       fault=fault, admit=admit)
                return                     # clean STOP
            except ChaosCrash:
                # the crash kills the session: surface a DISCONNECT the
                # way a TCP reader thread would, then resurrect after
                # the scripted delay with a bumped resume epoch
                hub.to_master.put(msg_lib.encode(msg_lib.disconnect(j)))
                time.sleep(restart_delay)
                epoch += 1

    threads = [threading.Thread(target=supervise, args=(j,), daemon=True)
               for j in range(n)]
    worker_ids = list(range(n))
    for j, delay in admit_at:
        assert elastic is not None, "admit_at needs an ElasticConfig"
        wp, _ = elastic.build(int(j) + 1)
        threads.append(threading.Thread(
            target=supervise, args=(int(j), wp, True, float(delay)),
            daemon=True))
        worker_ids.append(int(j))
    for t in threads:
        t.start()

    endpoint = ChaosMasterEndpoint(hub.master_endpoint(), script)
    master = Master(problem, hyper, endpoint, n_iterations,
                    metrics_every=metrics_every, replay=replay,
                    fault=fault, elastic=elastic)
    if master_hook is not None:
        master_hook(master)
    ok = False
    try:
        result = master.run()
        ok = True
    finally:
        stop_flag.set()
        if not ok:
            # unfaulted STOPs straight into the mailboxes so supervised
            # workers exit even when the master errored out mid-run (a
            # CLEAN run must not get this rescue — the master's own
            # STOP-resend shutdown drain is the tested dismissal path)
            for j in worker_ids:
                hub._ensure_queue(j)
                hub.to_worker[j].put(msg_lib.encode(msg_lib.stop()))
        endpoint.close()
    for t in threads:
        t.join(timeout=30.0)
    return result
