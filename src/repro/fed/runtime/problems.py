"""Problem registry for multi-process workers.

`TrilevelProblem` carries objective *closures*, which don't cross
process boundaries; subprocess workers and the serve front end instead
agree on a registry NAME (plus a few integer knobs) and rebuild the
identical problem on each side — same seeded data, same objectives, so
a worker's gradients land in exactly the rows the master expects.

Register new problems with `@register("name")`; a builder returns
`(problem, hyper)` for a given (n_workers, dim, seed).

Streamed data shares the same contract: `Stream` closures (the sampler)
don't cross process boundaries either, so `STREAMS` registers a sampler
builder under the SAME name and both the serving master and every
subprocess worker rebuild the identical `Stream` via
`build_stream(name, ...)` — same spec, same base key, so a worker's
locally synthesized batch row is bitwise the row the master folds.

Elastic admission adds a third clause to the contract: builders must be
PER-WORKER-ROW STABLE — worker j's data row is a function of (seed, j)
alone, identical at any build width > j.  A late worker builds the
problem at `j + 1` workers and must own exactly the row the master's
grown problem holds at index j (streams already satisfy this: the fold
is on the global worker index).  Drawing `normal(key, (n_workers, ...))`
in one shot VIOLATES it — the whole tensor reshuffles when n_workers
changes — so seed rows with `fold_in(key, j)` instead.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Hyper, TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream

REGISTRY: Dict[str, Callable] = {}
STREAMS: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


def register_stream(name: str):
    """Register a per-worker batch sampler `sample(key) -> data_row`
    builder under problem name `name`; the builder takes (dim, seed)."""
    def deco(fn):
        STREAMS[name] = fn
        return fn
    return deco


def build(name: str, n_workers: int = 4, dim: int = 3,
          seed: int = 0) -> Tuple[TrilevelProblem, Hyper]:
    """Rebuild registry problem `name` deterministically from its knobs."""
    if name not in REGISTRY:
        raise KeyError(
            f"unknown problem {name!r}; registered: {sorted(REGISTRY)}")
    return REGISTRY[name](n_workers=n_workers, dim=dim, seed=seed)


def build_stream(name: str, n_workers: int = 4, dim: int = 3,
                 seed: int = 0) -> Stream:
    """Rebuild problem `name`'s `Stream` deterministically from the same
    knobs as `build` — the cross-process agreement point for `--stream`
    runs (master and subprocess workers each call this)."""
    if name not in STREAMS:
        raise KeyError(
            f"problem {name!r} has no registered stream; "
            f"streamed: {sorted(STREAMS)}")
    sample = STREAMS[name](dim=dim, seed=seed)
    # decouple the stream's key sequence from the static data key (which
    # uses raw PRNGKey(seed) and fold_in(key, 1) above)
    base_key = jax.random.fold_in(jax.random.PRNGKey(seed), 1000)
    return stream_lib.make_stream(sample, n_workers, base_key)


def elastic_config(name: str, max_workers: int, dim: int = 3,
                   seed: int = 0, stream: bool = False):
    """An `ElasticConfig` whose builders rebuild registry problem
    `name` at any width from the same knobs — the standard wiring for
    `Master(elastic=...)` / `serve fed --max-workers` (registry builders
    are per-worker-row stable by contract, see module docstring)."""
    from repro.fed.runtime.membership import ElasticConfig

    return ElasticConfig(
        build=lambda n: build(name, n_workers=n, dim=dim, seed=seed),
        max_workers=int(max_workers),
        build_stream=((lambda n: build_stream(
            name, n_workers=n, dim=dim, seed=seed)) if stream else None))


@register("quadratic")
def quadratic(n_workers: int = 4, dim: int = 3,
              seed: int = 0) -> Tuple[TrilevelProblem, Hyper]:
    """The tiny seeded quadratic trilevel problem used across the test
    suite and the quickstart — the canonical smoke problem.

    The data is seeded PER WORKER ROW (`fold_in(key, j)`), so row j is
    bitwise identical at every build width > j — the row-stability
    contract elastic admission relies on (module docstring)."""
    key = jax.random.PRNGKey(seed)
    row_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(n_workers))
    data = {
        "A": jax.vmap(
            lambda k: jax.random.normal(k, (dim, dim)))(row_keys) * 0.3,
        "b": jax.vmap(
            lambda k: jax.random.normal(jax.random.fold_in(k, 1),
                                        (dim,)))(row_keys),
    }

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    problem = TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=n_workers,
        x1_init=jnp.zeros(dim), x2_init=jnp.zeros(dim),
        x3_init=jnp.zeros(dim))
    hyper = Hyper(n_workers=n_workers, s_active=max(1, n_workers - 1),
                  tau=5, k_inner=3, p_max=6, t_pre=5, t1=100,
                  eta_x=0.05, eta_z=0.05, d1=dim)
    return problem, hyper


@register_stream("quadratic")
def quadratic_stream(dim: int = 3, seed: int = 0) -> Callable:
    """Fresh per-iteration (A, b) draws with the static problem's scale
    — the smoke stream for `serve fed --stream` and the CI replay gate."""
    del seed  # the base key is owned by build_stream; samplers are pure

    def sample(key):
        ka, kb = jax.random.split(key)
        return {"A": jax.random.normal(ka, (dim, dim)) * 0.3,
                "b": jax.random.normal(kb, (dim,))}

    return sample
