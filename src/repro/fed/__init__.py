from repro.fed.sharding import (batch_spec, cache_specs, data_axis,
                                param_specs, to_named)
from repro.fed.sketch import sketch, sketch_dot, unsketch
from repro.fed.trilevel_llm import (FedHyper, FedLLMState, LLMCutSet,
                                    afto_llm_step, cut_refresh_llm,
                                    init_fed_state, plain_train_step)
