from repro.apps.robust_hpo import (RobustHPOTask, make_robust_hpo_problem)
from repro.apps.domain_adaptation import (DomainAdaptTask,
                                          make_domain_adaptation_problem)
