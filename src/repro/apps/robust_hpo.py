"""Distributed robust hyperparameter optimization (paper §5.1, Eq. 31).

Trilevel structure:
  level 1 (min over phi): validation MSE of the trained model,
  level 2 (max over p):   adversarial input perturbation p = [p_1..p_N]
                          (worker j owns block j), penalized by c||p_j||^2,
  level 3 (min over w):   perturbed training MSE + e^phi * ||w||_{1*}.

Mapping onto the generic TrilevelProblem (everything minimizes, so the
level-2 objective is negated):
  x1 = phi (log-regularization scalar), x2 = p (stacked blocks, (N, n_tr,
  d) — each worker's local copy carries all blocks, per the consensus
  reformulation Eq. 3), x3 = MLP weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Hyper, TrilevelProblem
from repro.data.synthetic import RegressionData, make_regression
from repro.models.simple import mlp_apply, mlp_init, smoothed_l1


@dataclasses.dataclass
class RobustHPOTask:
    problem: TrilevelProblem
    data: RegressionData
    hidden: int

    def test_mse(self, w, noise_std: float = 0.0, seed: int = 0):
        x = jnp.asarray(self.data.x_test)
        if noise_std > 0:
            rng = np.random.default_rng(seed)
            x = x + noise_std * jnp.asarray(
                rng.normal(size=x.shape).astype(np.float32))
        pred = mlp_apply(w, x)[:, 0]
        return jnp.mean((pred - jnp.asarray(self.data.y_test)) ** 2)


def make_robust_hpo_problem(dataset: str, n_workers: int, hidden: int = 16,
                            adv_penalty: float = 1.0, seed: int = 0
                            ) -> RobustHPOTask:
    data = make_regression(dataset, n_workers, seed=seed)
    n_tr, d = data.x_train.shape[1], data.x_train.shape[2]

    worker_ids = np.arange(n_workers, dtype=np.int32)
    pdata = {
        "xtr": jnp.asarray(data.x_train), "ytr": jnp.asarray(data.y_train),
        "xval": jnp.asarray(data.x_val), "yval": jnp.asarray(data.y_val),
        "wid": jnp.asarray(worker_ids),
    }

    def train_mse(d_j, p_block, w):
        pred = mlp_apply(w, d_j["xtr"] + p_block)[:, 0]
        return jnp.mean((pred - d_j["ytr"]) ** 2)

    def f1(d_j, x1, x2, x3):
        pred = mlp_apply(x3, d_j["xval"])[:, 0]
        return jnp.mean((pred - d_j["yval"]) ** 2)

    def f2(d_j, x1, x2, x3):
        # argmax -> negate.  Worker j perturbs only its own block.
        p_j = jnp.take(x2, d_j["wid"], axis=0)
        return -(train_mse(d_j, p_j, x3)
                 - adv_penalty * jnp.mean(p_j ** 2))

    def f3(d_j, x1, x2, x3):
        p_j = jnp.take(x2, d_j["wid"], axis=0)
        reg = jnp.exp(x1["phi"][0]) * smoothed_l1(x3)
        return train_mse(d_j, p_j, x3) + reg / max(n_workers, 1)

    key = jax.random.PRNGKey(seed)
    w0 = mlp_init(key, (d, hidden, 1))
    problem = TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=pdata, n_workers=n_workers,
        x1_init={"phi": jnp.array([-3.0], jnp.float32)},
        x2_init=jnp.zeros((n_workers, n_tr, d), jnp.float32),
        x3_init=w0)
    return RobustHPOTask(problem=problem, data=data, hidden=hidden)


def default_hyper(task: RobustHPOTask, n_workers: int, s_active: int,
                  tau: int, **overrides) -> Hyper:
    base = dict(
        n_workers=n_workers, s_active=s_active, tau=tau,
        k_inner=4, p_max=8, t_pre=10, t1=400,
        eta_x=0.05, eta_z=0.05, eta_lambda=0.01, eta_theta=0.01,
        eta_dual_inner=0.01, kappa2=0.5, kappa3=0.5, rho2=0.5,
        eps_i=1e-3, eps_ii=1e-3, mu_i=0.5, mu_ii=0.5,
        alpha1=25.0, alpha2=25.0, alpha3=25.0, alpha4=25.0, alpha5=25.0,
        d1=1)
    base.update(overrides)
    return Hyper(**base)
