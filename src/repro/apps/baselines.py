"""Distributed *bilevel* baselines for Table 2: ADBO and FedNest.

Both are reimplementations (no public offline code): they solve the
robust-HPO task as a BILEVEL problem — hyperparameter phi upper, weights
w lower — without the adversarial middle level, which is exactly why the
paper's trilevel AFTO achieves better *noisy-test* MSE (Table 2): the
baselines never train against perturbations.

* FedNest  (Tarzanagh et al., 2022): synchronous federated bilevel SGD;
  inner local SGD + averaging for w, one-step inverse-Hessian-free
  hypergradient for phi.
* ADBO     (Jiao et al., 2022b): asynchronous distributed bilevel with
  (convex, mu=0) cutting planes; we reuse the AFTO machinery restricted
  to two levels — i.e. the paper's own claim that mu-cuts generalize the
  ADBO cut — with the same straggler scheduler for a fair async compare.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.robust_hpo import RobustHPOTask
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.models.simple import mlp_apply, smoothed_l1
from repro.utils.tree import tree_axpy


# ---------------------------------------------------------------------------
# FedNest-style federated bilevel SGD
# ---------------------------------------------------------------------------

def run_fednest(task: RobustHPOTask, n_iterations: int = 200,
                inner_steps: int = 4, eta_w: float = 0.05,
                eta_phi: float = 0.02, seed: int = 0) -> Dict[str, List]:
    prob = task.problem
    data = prob.data
    n = prob.n_workers

    def local_inner(w, phi):
        """inner_steps of local SGD on the regularized train loss."""
        def loss(w, d_j):
            pred = mlp_apply(w, d_j["xtr"])[:, 0]
            return jnp.mean((pred - d_j["ytr"]) ** 2) \
                + jnp.exp(phi[0]) * smoothed_l1(w) / n

        def one_worker(d_j, w):
            def body(w, _):
                g = jax.grad(loss)(w, d_j)
                return tree_axpy(-eta_w, g, w), None
            w, _ = jax.lax.scan(body, w, None, length=inner_steps)
            return w

        ws = jax.vmap(lambda d_j: one_worker(d_j, w))(data)
        return jax.tree.map(lambda x: jnp.mean(x, 0), ws)  # FedAvg

    def val_loss(w):
        def per(d_j):
            pred = mlp_apply(w, d_j["xval"])[:, 0]
            return jnp.mean((pred - d_j["yval"]) ** 2)
        return jnp.mean(jax.vmap(per)(data))

    @jax.jit
    def step(w, phi):
        w_new = local_inner(w, phi)
        # hypergradient (IFT-free 1-step approx): d val / d phi through
        # one unrolled inner update
        def outer(phi):
            return val_loss(local_inner(jax.lax.stop_gradient(w), phi))
        g_phi = jax.grad(outer)(phi)
        return w_new, phi - eta_phi * g_phi

    w = prob.x3_init
    phi = prob.x1_init["phi"]
    hist = {"t": [], "sim_time": [], "val_mse": []}
    # synchronous: every iteration costs the slowest worker's latency
    sched = StragglerScheduler(StragglerConfig(
        n_workers=n, s_active=n, tau=1000, n_stragglers=1, seed=seed))
    for it in range(n_iterations):
        _, sim_t = sched.next_active()
        w, phi = step(w, phi)
        if (it + 1) % 10 == 0:
            hist["t"].append(it + 1)
            hist["sim_time"].append(sim_t)
            hist["val_mse"].append(float(val_loss(w)))
    return {"w": w, "phi": phi, "history": hist}


# ---------------------------------------------------------------------------
# ADBO-style asynchronous bilevel with convex cutting planes
# ---------------------------------------------------------------------------

def run_adbo(task: RobustHPOTask, n_iterations: int = 200,
             s_active: int = None, tau: int = 10, seed: int = 0,
             **hyper_overrides) -> Dict[str, List]:
    """ADBO == the paper's machinery with the middle level removed and
    mu = 0 (convex cuts).  We emulate it by fixing x2 = 0 (no adversarial
    level) and mu_i = mu_ii = 0 in the same AFTO loop."""
    from repro.apps.robust_hpo import default_hyper
    from repro.core import runner as runner_lib
    from repro.core.scheduler import StragglerConfig

    prob = task.problem
    n = prob.n_workers
    s = s_active if s_active is not None else max(1, n - 1)

    frozen = dataclasses.replace(
        prob,
        f2=lambda d_j, x1, x2, x3: 0.5 * jnp.sum(x2 ** 2),  # pins p at 0
        x2_init=jnp.zeros_like(prob.x2_init))
    hyper = default_hyper(task, n, s, tau, mu_i=0.0, mu_ii=0.0,
                          **hyper_overrides)
    cfg = StragglerConfig(n_workers=n, s_active=s, tau=tau,
                          n_stragglers=1, seed=seed)

    def metrics(state):
        def per(d_j, x3_j):
            pred = mlp_apply(x3_j, d_j["xval"])[:, 0]
            return jnp.mean((pred - d_j["yval"]) ** 2)
        return {"val_mse": jnp.mean(jax.vmap(per)(prob.data, state.X3))}

    res = runner_lib.run(runner_lib.RunSpec(
        problem=frozen, hyper=hyper, scheduler=cfg,
        n_iterations=n_iterations, metrics_fn=metrics))
    # consensus weights = average of worker copies
    w = jax.tree.map(lambda x: jnp.mean(x, 0), res.state.X3)
    return {"w": w, "phi": res.state.z1["phi"], "history": res.history}
