"""Distributed domain adaptation for pretrain & finetune (paper §5.2, Eq. 32).

Trilevel structure:
  level 1 (min over phi): finetune loss (phi = reweighting net params),
  level 2 (min over v):   finetune loss + lambda ||v - w||^2 (proximal),
  level 3 (min over w):   reweighted pretraining loss, weights
                          R(x_i; phi) in (0, 1) from the reweighting net.

All three networks are LeNet-5 (as in the paper); the pretrain domain is
"SVHN-like" and the finetune domain "MNIST-like" synthetic digits (see
repro.data.synthetic for why synthetic).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Hyper, TrilevelProblem
from repro.data.synthetic import DigitsData, make_digits
from repro.models.simple import (accuracy, cross_entropy, lenet_apply,
                                 lenet_init)


@dataclasses.dataclass
class DomainAdaptTask:
    problem: TrilevelProblem
    data: DigitsData
    prox_lambda: float

    def test_metrics(self, v):
        logits = lenet_apply(v, jnp.asarray(self.data.x_test))
        labels = jnp.asarray(self.data.y_test)
        return {"test_acc": accuracy(logits, labels),
                "test_loss": cross_entropy(logits, labels)}


def _tree_sq_dist(a, b):
    return sum(jnp.sum((x - y) ** 2)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def make_domain_adaptation_problem(n_workers: int,
                                   pretrain_domain: str = "svhn",
                                   n_pretrain_per: int = 48,
                                   n_finetune_per: int = 24,
                                   prox_lambda: float = 0.1,
                                   seed: int = 0) -> DomainAdaptTask:
    data = make_digits(n_workers, n_pretrain_per=n_pretrain_per,
                       n_finetune_per=n_finetune_per,
                       pretrain_domain=pretrain_domain, seed=seed)
    pdata = {
        "xpt": jnp.asarray(data.x_pretrain),
        "ypt": jnp.asarray(data.y_pretrain),
        "xft": jnp.asarray(data.x_finetune),
        "yft": jnp.asarray(data.y_finetune),
    }

    def reweight(phi, x):
        """R(x; phi) in (0,1): sigmoid of the reweighting net's score."""
        score = lenet_apply(phi, x)  # (B, 10)
        return jax.nn.sigmoid(jnp.mean(score, axis=-1))

    def finetune_loss(d_j, v):
        return cross_entropy(lenet_apply(v, d_j["xft"]), d_j["yft"])

    def f1(d_j, x1, x2, x3):
        return finetune_loss(d_j, x2)

    def f2(d_j, x1, x2, x3):
        return finetune_loss(d_j, x2) \
            + prox_lambda * _tree_sq_dist(x2, x3)

    def f3(d_j, x1, x2, x3):
        logits = lenet_apply(x3, d_j["xpt"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, d_j["ypt"][:, None], -1)[:, 0]
        per_sample = logz - gold
        w = reweight(x1, d_j["xpt"])
        return jnp.mean(w * per_sample)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    problem = TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=pdata, n_workers=n_workers,
        x1_init=lenet_init(k1), x2_init=lenet_init(k2),
        x3_init=lenet_init(k3))
    return DomainAdaptTask(problem=problem, data=data,
                           prox_lambda=prox_lambda)


def default_hyper(n_workers: int, s_active: int, tau: int,
                  **overrides) -> Hyper:
    base = dict(
        n_workers=n_workers, s_active=s_active, tau=tau,
        k_inner=2, p_max=6, t_pre=20, t1=400,
        eta_x=0.1, eta_z=0.1, eta_lambda=0.005, eta_theta=0.005,
        eta_dual_inner=0.005, kappa2=0.1, kappa3=0.1, rho2=0.1,
        eps_i=1e-2, eps_ii=1e-2, mu_i=0.5, mu_ii=0.5,
        alpha1=400.0, alpha2=400.0, alpha3=400.0, alpha4=25.0,
        alpha5=400.0, d1=61706)
    base.update(overrides)
    return Hyper(**base)
