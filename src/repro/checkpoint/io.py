"""Checkpointing: pytree -> (manifest.json + arrays.npz).

Orbax is not available offline; this covers the framework's needs:
sharding-agnostic host save/restore with structure and dtype fidelity,
atomic writes, and step-numbered directories with retention.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_numpy_storable(x):
    """npz can't roundtrip ml_dtypes (bfloat16 etc.); store such leaves
    as float32 (bf16 -> f32 is exact) and restore via the manifest."""
    arr = np.asarray(x)
    if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return np.asarray(x, dtype=np.float32), str(arr.dtype)
    try:
        np.dtype(str(arr.dtype))
        return arr, str(arr.dtype)
    except TypeError:
        return np.asarray(x, dtype=np.float32), str(arr.dtype)


def save_checkpoint(directory: str, tree: Any, step: int,
                    keep: int = 3) -> str:
    """Writes <directory>/step_<step>/{manifest.json, arrays.npz}."""
    leaves, treedef = _flatten(tree)
    stored = [_to_numpy_storable(l) for l in leaves]
    arrays = {f"leaf_{i}": a for i, (a, _) in enumerate(stored)}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [d for _, d in stored],
        "shapes": [list(a.shape) for a, _ in stored],
    }
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory if os.path.isdir(directory)
                           else None, prefix=".ckpt_tmp_")
    os.makedirs(directory, exist_ok=True)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # retention
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    return steps[-1] if steps else None


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Any:
    """Restores into `template`'s structure (shapes/dtypes asserted)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(template)
    restored = []
    for i, tpl in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(np.shape(tpl)), \
            f"leaf {i}: ckpt {arr.shape} != template {np.shape(tpl)}"
        restored.append(jax.numpy.asarray(arr, dtype=tpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored)
