"""Checkpointing: pytree -> (manifest.json + arrays.npz).

Orbax is not available offline; this covers the framework's needs:
sharding-agnostic host save/restore with structure and dtype fidelity,
atomic writes, and step-numbered directories with retention.

Two checkpoint families share the directory layout:

  - `save_checkpoint`/`load_checkpoint`: template-shaped pytrees (the
    training scan carry) — the caller supplies the structure on load.
  - `save_array_dict`/`load_array_dict`: self-describing flat
    name -> ndarray dicts (the async master's durable runtime carry,
    whose pieces — recorded arrival history, pending push map — have no
    static template).  Array-dict manifests carry a crc32 of the array
    payload; a truncated or corrupted checkpoint raises
    `CheckpointError` instead of resuming from garbage.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint is missing, truncated, or fails its checksum."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_numpy_storable(x):
    """npz can't roundtrip ml_dtypes (bfloat16 etc.); store such leaves
    as float32 (bf16 -> f32 is exact) and restore via the manifest."""
    arr = np.asarray(x)
    if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return np.asarray(x, dtype=np.float32), str(arr.dtype)
    try:
        np.dtype(str(arr.dtype))
        return arr, str(arr.dtype)
    except TypeError:
        return np.asarray(x, dtype=np.float32), str(arr.dtype)


def save_checkpoint(directory: str, tree: Any, step: int,
                    keep: int = 3) -> str:
    """Writes <directory>/step_<step>/{manifest.json, arrays.npz}."""
    leaves, treedef = _flatten(tree)
    stored = [_to_numpy_storable(l) for l in leaves]
    arrays = {f"leaf_{i}": a for i, (a, _) in enumerate(stored)}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [d for _, d in stored],
        "shapes": [list(a.shape) for a, _ in stored],
    }
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory if os.path.isdir(directory)
                           else None, prefix=".ckpt_tmp_")
    os.makedirs(directory, exist_ok=True)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # retention
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    return steps[-1] if steps else None


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Any:
    """Restores into `template`'s structure (shapes/dtypes asserted)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(template)
    restored = []
    for i, tpl in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(np.shape(tpl)), \
            f"leaf {i}: ckpt {arr.shape} != template {np.shape(tpl)}"
        restored.append(jax.numpy.asarray(arr, dtype=tpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored)


# ---------------------------------------------------------------------------
# self-describing array-dict checkpoints (durable runtime state)
# ---------------------------------------------------------------------------

def save_array_dict(directory: str, arrays: Dict[str, np.ndarray],
                    step: int, keep: int = 3) -> str:
    """Write a flat name -> ndarray dict as
    <directory>/step_<step>/{manifest.json, arrays.npz} (atomic, with
    retention).  Unlike `save_checkpoint`, the names travel with the
    data — no template is needed to load, so variable-length state
    (recorded histories, pending maps) round-trips as-is."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".ckpt_tmp_")
    try:
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path,
                 **{k: np.asarray(v) for k, v in arrays.items()})
        with open(npz_path, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest = {"step": int(step), "format": "array_dict",
                    "keys": sorted(arrays), "crc32": crc}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def load_array_dict(directory: str,
                    step: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Load an array-dict checkpoint (latest step if unspecified).

    Raises `CheckpointError` — never garbage — when the checkpoint is
    missing, the manifest is unreadable, the npz payload fails its
    crc32, or the stored keys don't match the manifest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoints in {directory!r}")
    path = os.path.join(directory, f"step_{step:08d}")
    man_path = os.path.join(path, "manifest.json")
    npz_path = os.path.join(path, "arrays.npz")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointError(
            f"unreadable manifest at {man_path}: {e}") from e
    if manifest.get("format") != "array_dict":
        raise CheckpointError(
            f"{path} is not an array-dict checkpoint "
            f"(format={manifest.get('format')!r}); use load_checkpoint")
    try:
        with open(npz_path, "rb") as f:
            payload = f.read()
    except OSError as e:
        raise CheckpointError(
            f"missing array payload at {npz_path}: {e}") from e
    crc = zlib.crc32(payload)
    if crc != int(manifest.get("crc32", -1)):
        raise CheckpointError(
            f"checksum mismatch for {npz_path}: stored "
            f"{manifest.get('crc32')}, computed {crc} — the checkpoint "
            f"is corrupt or truncated")
    import io as _io
    try:
        with np.load(_io.BytesIO(payload), allow_pickle=False) as npz:
            out = {k: npz[k] for k in npz.files}
    except Exception as e:
        raise CheckpointError(
            f"undecodable array payload at {npz_path}: {e}") from e
    if sorted(out) != list(manifest.get("keys", [])):
        raise CheckpointError(
            f"key set mismatch in {path}: manifest lists "
            f"{len(manifest.get('keys', []))} keys, payload has "
            f"{len(out)}")
    return out
