"""Learning-rate schedules (step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        return jnp.asarray(peak * frac, jnp.float32)
    return fn


def cosine_decay(init: float, decay_steps: int, alpha: float = 0.0):
    def fn(step):
        frac = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(init * ((1 - alpha) * cos + alpha), jnp.float32)
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        warm = peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.asarray(jnp.where(step < warmup_steps, warm, cos),
                           jnp.float32)
    return fn
