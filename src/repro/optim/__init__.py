from repro.optim.optimizers import (Optimizer, sgd, momentum, adam, adamw,
                                    clip_by_global_norm, chain)
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)
