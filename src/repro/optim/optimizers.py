"""Minimal functional optimizers (optax is not available offline).

Each optimizer is an (init, update) pair:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

The paper's own AFTO updates are plain projected gradient steps on the
regularized Lagrangian (Eqs. 16-21) and do not use these; the optimizers
serve the baselines (FedNest/ADBO), the plain `train_step` used for
roofline comparisons, and the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _scale(lr):
    if callable(lr):
        return lr
    return lambda step: lr


def sgd(lr) -> Optimizer:
    lr_fn = _scale(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"]
        upd = jax.tree.map(lambda g: -lr_fn(step) * g, grads)
        return upd, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = _scale(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params=None):
        step, mu = state["step"], state["mu"]
        mu = jax.tree.map(lambda m, g: beta * m + g, mu, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr_fn(step) * (beta * m + g),
                               mu, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_fn(step) * m, mu)
        return upd, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0,
          state_dtype=jnp.float32) -> Optimizer:
    lr_fn = _scale(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        m = jax.tree.map(lambda m0, g: b1 * m0 + (1 - b1)
                         * g.astype(state_dtype), state["m"], grads)
        v = jax.tree.map(lambda v0, g: b2 * v0 + (1 - b2)
                         * jnp.square(g.astype(state_dtype)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(mh, vh, p):
            u = -(lr_fn(step) * (mh / bc1)
                  / (jnp.sqrt(vh / bc2) + eps))
            if weight_decay:
                u = u - lr_fn(step) * weight_decay * p.astype(state_dtype)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, m, v,
                               params if params is not None
                               else jax.tree.map(jnp.zeros_like, m))
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params=None):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params=None):
        new_states = []
        upd = grads
        for o, s in zip(opts, state):
            upd, ns = o.update(upd, s, params)
            new_states.append(ns)
        return upd, tuple(new_states)

    return Optimizer(init, update)
