"""mu-weak-convexity utilities (Definitions 3.1/3.2).

A differentiable f is mu-weakly convex iff f + (mu/2)||.||^2 is convex,
i.e. the Hessian's smallest eigenvalue is >= -mu everywhere.  The paper
assumes a known mu for h_I/h_II (Appendix E); `estimate_mu` provides a
practical sampled lower bound via Hessian-vector products so users can
set `Hyper.mu_i/mu_ii` from data.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_axpy, tree_dot, tree_norm_sq


def curvature_along(fn: Callable, point, direction):
    """d^T H d / ||d||^2 at `point` via forward-over-reverse."""
    g = lambda p: jax.grad(fn)(p)
    _, hvp = jax.jvp(g, (point,), (direction,))
    return tree_dot(direction, hvp) / jnp.maximum(tree_norm_sq(direction),
                                                  1e-30)


def estimate_mu(fn: Callable, point, key, n_samples: int = 16,
                radius: float = 0.5):
    """max(0, -min sampled curvature): a practical mu estimate.

    Samples random directions at random perturbations of `point`; a valid
    mu must dominate the most negative curvature of fn.
    """
    leaves, treedef = jax.tree.flatten(point)

    def sample(key):
        k1, k2 = jax.random.split(key)
        ds = [jax.random.normal(jax.random.fold_in(k1, i), l.shape, l.dtype)
              for i, l in enumerate(leaves)]
        ps = [l + radius * jax.random.normal(
            jax.random.fold_in(k2, i), l.shape, l.dtype)
            for i, l in enumerate(leaves)]
        d = jax.tree.unflatten(treedef, ds)
        p = jax.tree.unflatten(treedef, ps)
        return curvature_along(fn, p, d)

    curvs = jax.vmap(sample)(jax.random.split(key, n_samples))
    return jnp.maximum(0.0, -jnp.min(curvs))


def first_order_gap(fn: Callable, x, x_ref, mu):
    """Def. 3.2 residual: f(x) - [f(x') + <g(x'), x-x'> - mu/2||x-x'||^2].

    Nonnegative for all (x, x') iff fn is mu-weakly convex; used by the
    property tests to verify cut validity.
    """
    g = jax.grad(fn)(x_ref)
    d = jax.tree.map(jnp.subtract, x, x_ref)
    lin = fn(x_ref) + tree_dot(g, d) - 0.5 * mu * tree_norm_sq(d)
    return fn(x) - lin
