"""K-round federated ADMM rollouts: the phi_I / phi_II estimates (Eqs. 5-12).

Each communication round is one Jacobi ADMM sweep:
  workers  : x' <- x' - eta_x * grad_x L_p          (Eq. 5)
  master   : z' <- z' - eta_z * grad_z L_p          (Eq. 6, at the *old* x)
  master   : dual ascent at the new primal point    (Eq. 7)
The K-round result is the inner-solution estimate (Eq. 8); constraint
functions h_I / h_II are squared distances to it (Eqs. 9/12) and are
differentiable w.r.t. the outer variables *through the rollout* (JAX vjp
through the scan), which is exactly what the mu-cut gradients need.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import lagrangian as lag
from repro.core.types import (FlatCuts, Hyper, InnerState2, InnerState3,
                              TrilevelProblem)
from repro.kernels import ops as kernel_ops
from repro.utils.tree import (tree_axpy, tree_norm_sq, tree_sub)


# ---------------------------------------------------------------------------
# level 3
# ---------------------------------------------------------------------------

def rollout3(problem: TrilevelProblem, hyper: Hyper, z1, z2,
             init: InnerState3) -> InnerState3:
    """K rounds of Eq. 5-7; differentiable w.r.t. (z1, z2)."""

    def round_fn(st: InnerState3, _):
        g_x = jax.grad(lambda x3: lag.l_p3(
            problem, hyper, z1, z2,
            InnerState3(x3=x3, z3=st.z3, phi=st.phi)))(st.x3)
        x3_new = tree_axpy(-hyper.eta_x, g_x, st.x3)
        # Eq. 6: master step at the OLD worker variables
        g_z = jax.grad(lambda z3: lag.l_p3(
            problem, hyper, z1, z2,
            InnerState3(x3=st.x3, z3=z3, phi=st.phi)))(st.z3)
        z3_new = tree_axpy(-hyper.eta_z, g_z, st.z3)
        # Eq. 7: dual ascent at the new primal point (the worker count
        # comes from the stacked x3, so a shard-local stack works too)
        phi_new = jax.tree.map(
            lambda p, x, z: p + hyper.eta_dual_inner * (
                x - jnp.broadcast_to(z[None], x.shape)),
            st.phi, x3_new, z3_new)
        return InnerState3(x3=x3_new, z3=z3_new, phi=phi_new), None

    final, _ = jax.lax.scan(round_fn, init, None, length=hyper.k_inner)
    return final


def h_i(problem: TrilevelProblem, hyper: Hyper,
        X3, z3, z1, z2, init: InnerState3):
    """h_I({x3_j}, z1, z2', z3) = ||[{x3_j}; z3] - phi_I(z1, z2')||^2."""
    est = rollout3(problem, hyper, z1, z2,
                   jax.lax.stop_gradient(init))
    return tree_norm_sq(tree_sub(X3, est.x3)) \
        + tree_norm_sq(tree_sub(z3, est.z3))


# ---------------------------------------------------------------------------
# level 2
# ---------------------------------------------------------------------------

def _rollout2_fused(problem: TrilevelProblem, hyper: Hyper, z1, z3, X3,
                    cuts_i: FlatCuts, init: InnerState2) -> InnerState2:
    """The `hyper.use_fused_inner` round body: one fused Pallas round.

    Per round the oracle (`rollout2`'s scan body) launches three passes
    over the (P, D) cut matrix — the z2 cut-gradient inside grad(l_p2)
    plus two `eval_cuts` for the slack/dual steps.  Here the whole cut
    algebra of a round (weight pass, masked z2 descent, re-evaluation,
    slack + gamma epilogue) runs in `kernels.fused_cut_round`, which
    streams A exactly twice.  The small cut-free algebra (per-worker f2,
    consensus terms, phi ascent) stays in XLA via `lag.l_p2_base`; its
    x2/z2 gradients equal the full-l_p2 ones minus the cut term the
    kernel applies, so the composed update matches the oracle to f32
    tolerance (gradient accumulation order differs, not the math).
    Differentiable to arbitrary order: the fused op carries a JVP built
    on the `cut_ad` primitive decomposition (see ops.fused_cut_round).
    """
    spec = cuts_i.spec
    # Constant a2-column selector: 1 on z2's columns of the flattened
    # point, 0 elsewhere (z1/z3/X3 do not move within the inner rollout).
    mask = cuts_lib.flatten_point(
        spec, None, jax.tree.map(jnp.ones_like, init.z2), None, None, None)

    def round_fn(st: InnerState2, _):
        g_x = jax.grad(lambda x2: lag.l_p2_base(
            problem, hyper, z1, z3, X3,
            InnerState2(x2=x2, z2=st.z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.x2)
        x2_new = tree_axpy(-hyper.eta_x, g_x, st.x2)

        # Eq. 6 master step, cut-free part only; the cut gradient is
        # applied inside the fused kernel (masked to the a2 columns).
        g_z_cons = jax.grad(lambda z2: lag.l_p2_base(
            problem, hyper, z1, z3, X3,
            InnerState2(x2=st.x2, z2=z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.z2)
        v_old = cuts_lib.flatten_point(spec, z1, st.z2, z3, None, X3)
        g_other = cuts_lib.flatten_point(
            spec, None, g_z_cons, None, None, None)
        v_new, _cv1, s_new, gamma_new = kernel_ops.fused_cut_round(
            cuts_i.a, v_old, g_other, mask, cuts_i.c, cuts_i.active,
            st.s, st.gamma,
            eta_z=hyper.eta_z, eta_s=hyper.eta_s,
            eta_dual=hyper.eta_dual_inner, rho2=hyper.rho2)
        z2_new = cuts_lib.unflatten_coeff(spec, v_new)[1]

        phi_new = jax.tree.map(
            lambda p, x, z: p + hyper.eta_dual_inner * (
                x - jnp.broadcast_to(z[None], x.shape)),
            st.phi, x2_new, z2_new)
        return InnerState2(x2=x2_new, z2=z2_new, phi=phi_new, s=s_new,
                           gamma=gamma_new), None

    final, _ = jax.lax.scan(round_fn, init, None, length=hyper.k_inner)
    return final


def rollout2(problem: TrilevelProblem, hyper: Hyper, z1, z3, X3,
             cuts_i: FlatCuts, init: InnerState2) -> InnerState2:
    """K rounds of Jacobi ADMM on Eq. 11 (with slack/cut multipliers);
    differentiable w.r.t. (z1, z3, X3).

    With `hyper.use_fused_inner` the per-round cut algebra runs in the
    fused two-pass Pallas round kernel (`_rollout2_fused`); the default
    scan-of-jnp body below is the parity oracle
    (tests/test_inner_fused.py checks the two agree through values,
    first gradients, and the h_II grad-of-grad)."""
    if hyper.use_fused_inner:
        return _rollout2_fused(problem, hyper, z1, z3, X3, cuts_i, init)

    def round_fn(st: InnerState2, _):
        g_x = jax.grad(lambda x2: lag.l_p2(
            problem, hyper, z1, z3, X3, cuts_i,
            InnerState2(x2=x2, z2=st.z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.x2)
        x2_new = tree_axpy(-hyper.eta_x, g_x, st.x2)

        g_z = jax.grad(lambda z2: lag.l_p2(
            problem, hyper, z1, z3, X3, cuts_i,
            InnerState2(x2=st.x2, z2=z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.z2)
        z2_new = tree_axpy(-hyper.eta_z, g_z, st.z2)

        # slack: projected descent, s >= 0 (only on active cut slots)
        cutval = cuts_lib.eval_cuts(cuts_i, z1, z2_new, z3, X3=X3)
        g_s = (st.gamma + hyper.rho2 * (cutval + st.s)) * cuts_i.active
        s_new = jnp.maximum(0.0, st.s - hyper.eta_s * g_s) * cuts_i.active

        # duals at the new primal point (worker count from the stack)
        phi_new = jax.tree.map(
            lambda p, x, z: p + hyper.eta_dual_inner * (
                x - jnp.broadcast_to(z[None], x.shape)),
            st.phi, x2_new, z2_new)
        cutval_new = cuts_lib.eval_cuts(cuts_i, z1, z2_new, z3, X3=X3)
        gamma_new = jnp.maximum(
            0.0, st.gamma + hyper.eta_dual_inner * (cutval_new + s_new)) \
            * cuts_i.active
        return InnerState2(x2=x2_new, z2=z2_new, phi=phi_new, s=s_new,
                           gamma=gamma_new), None

    final, _ = jax.lax.scan(round_fn, init, None, length=hyper.k_inner)
    return final


def h_ii(problem: TrilevelProblem, hyper: Hyper,
         X2, z2, z1, z3, X3, cuts_i: FlatCuts, init: InnerState2):
    """h_II({x2_j},{x3_j},z) = ||[{x2_j}; z2] - phi_II(z1, z3, {x3_j})||^2."""
    est = rollout2(problem, hyper, z1, z3, X3, cuts_i,
                   jax.lax.stop_gradient(init))
    return tree_norm_sq(tree_sub(X2, est.x2)) \
        + tree_norm_sq(tree_sub(z2, est.z2))
