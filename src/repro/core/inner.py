"""K-round federated ADMM rollouts: the phi_I / phi_II estimates (Eqs. 5-12).

Each communication round is one Jacobi ADMM sweep:
  workers  : x' <- x' - eta_x * grad_x L_p          (Eq. 5)
  master   : z' <- z' - eta_z * grad_z L_p          (Eq. 6, at the *old* x)
  master   : dual ascent at the new primal point    (Eq. 7)
The K-round result is the inner-solution estimate (Eq. 8); constraint
functions h_I / h_II are squared distances to it (Eqs. 9/12) and are
differentiable w.r.t. the outer variables *through the rollout* (JAX vjp
through the scan), which is exactly what the mu-cut gradients need.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import lagrangian as lag
from repro.core.types import (FlatCuts, Hyper, InnerState2, InnerState3,
                              TrilevelProblem)
from repro.utils.tree import (tree_axpy, tree_norm_sq, tree_sub)


# ---------------------------------------------------------------------------
# level 3
# ---------------------------------------------------------------------------

def rollout3(problem: TrilevelProblem, hyper: Hyper, z1, z2,
             init: InnerState3) -> InnerState3:
    """K rounds of Eq. 5-7; differentiable w.r.t. (z1, z2)."""

    def round_fn(st: InnerState3, _):
        g_x = jax.grad(lambda x3: lag.l_p3(
            problem, hyper, z1, z2,
            InnerState3(x3=x3, z3=st.z3, phi=st.phi)))(st.x3)
        x3_new = tree_axpy(-hyper.eta_x, g_x, st.x3)
        # Eq. 6: master step at the OLD worker variables
        g_z = jax.grad(lambda z3: lag.l_p3(
            problem, hyper, z1, z2,
            InnerState3(x3=st.x3, z3=z3, phi=st.phi)))(st.z3)
        z3_new = tree_axpy(-hyper.eta_z, g_z, st.z3)
        # Eq. 7: dual ascent at the new primal point (the worker count
        # comes from the stacked x3, so a shard-local stack works too)
        phi_new = jax.tree.map(
            lambda p, x, z: p + hyper.eta_dual_inner * (
                x - jnp.broadcast_to(z[None], x.shape)),
            st.phi, x3_new, z3_new)
        return InnerState3(x3=x3_new, z3=z3_new, phi=phi_new), None

    final, _ = jax.lax.scan(round_fn, init, None, length=hyper.k_inner)
    return final


def h_i(problem: TrilevelProblem, hyper: Hyper,
        X3, z3, z1, z2, init: InnerState3):
    """h_I({x3_j}, z1, z2', z3) = ||[{x3_j}; z3] - phi_I(z1, z2')||^2."""
    est = rollout3(problem, hyper, z1, z2,
                   jax.lax.stop_gradient(init))
    return tree_norm_sq(tree_sub(X3, est.x3)) \
        + tree_norm_sq(tree_sub(z3, est.z3))


# ---------------------------------------------------------------------------
# level 2
# ---------------------------------------------------------------------------

def rollout2(problem: TrilevelProblem, hyper: Hyper, z1, z3, X3,
             cuts_i: FlatCuts, init: InnerState2) -> InnerState2:
    """K rounds of Jacobi ADMM on Eq. 11 (with slack/cut multipliers);
    differentiable w.r.t. (z1, z3, X3)."""

    def round_fn(st: InnerState2, _):
        g_x = jax.grad(lambda x2: lag.l_p2(
            problem, hyper, z1, z3, X3, cuts_i,
            InnerState2(x2=x2, z2=st.z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.x2)
        x2_new = tree_axpy(-hyper.eta_x, g_x, st.x2)

        g_z = jax.grad(lambda z2: lag.l_p2(
            problem, hyper, z1, z3, X3, cuts_i,
            InnerState2(x2=st.x2, z2=z2, phi=st.phi, s=st.s,
                        gamma=st.gamma)))(st.z2)
        z2_new = tree_axpy(-hyper.eta_z, g_z, st.z2)

        # slack: projected descent, s >= 0 (only on active cut slots)
        cutval = cuts_lib.eval_cuts(cuts_i, z1, z2_new, z3, X3=X3)
        g_s = (st.gamma + hyper.rho2 * (cutval + st.s)) * cuts_i.active
        s_new = jnp.maximum(0.0, st.s - hyper.eta_s * g_s) * cuts_i.active

        # duals at the new primal point (worker count from the stack)
        phi_new = jax.tree.map(
            lambda p, x, z: p + hyper.eta_dual_inner * (
                x - jnp.broadcast_to(z[None], x.shape)),
            st.phi, x2_new, z2_new)
        cutval_new = cuts_lib.eval_cuts(cuts_i, z1, z2_new, z3, X3=X3)
        gamma_new = jnp.maximum(
            0.0, st.gamma + hyper.eta_dual_inner * (cutval_new + s_new)) \
            * cuts_i.active
        return InnerState2(x2=x2_new, z2=z2_new, phi=phi_new, s=s_new,
                           gamma=gamma_new), None

    final, _ = jax.lax.scan(round_fn, init, None, length=hyper.k_inner)
    return final


def h_ii(problem: TrilevelProblem, hyper: Hyper,
         X2, z2, z1, z3, X3, cuts_i: FlatCuts, init: InnerState2):
    """h_II({x2_j},{x3_j},z) = ||[{x2_j}; z2] - phi_II(z1, z3, {x3_j})||^2."""
    est = rollout2(problem, hyper, z1, z3, X3, cuts_i,
                   jax.lax.stop_gradient(init))
    return tree_norm_sq(tree_sub(X2, est.x2)) \
        + tree_norm_sq(tree_sub(z2, est.z2))
