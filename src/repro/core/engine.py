"""Compiled trajectory engine: the whole AFTO run in one `lax.scan`.

The straggler scheduler is a seeded host-side simulation with no feedback
from the optimization state, so its entire arrival process can be
materialized up front (`StragglerScheduler.precompute`) and the
T-iteration trajectory of Alg. 1 driven inside a single donated-buffer
`jax.lax.scan`:

  * `afto_step` every master iteration (Eqs. 16-21),
  * `cut_refresh` via `lax.cond` on every t_pre-th iteration with
    t < t1 (Eqs. 23-25),
  * gap / cut-count / user metrics accumulated into preallocated
    history arrays at `metrics_every` strides (again under `lax.cond`,
    so the stationarity gap is only computed at record steps).

One XLA dispatch replaces T host round-trips, which is what lets the
paper's wall-clock claims be measured instead of being drowned in
Python dispatch overhead (`benchmarks/engine_speed.py` quantifies it).

`metrics_fn` must be JAX-traceable here (it is traced into the scan
body); host-callback metrics still work through the eager path of
`repro.core.runner.run(mode="eager")`.

Compiled trajectories are cached per (problem, hyper, metrics_fn,
schedule length, record layout), so repeated runs — e.g. the AFTO/SFTO
sweeps in the benchmarks — pay tracing + compilation once.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import afto as afto_lib
from repro.core import stationarity as stat_lib
from repro.core.scheduler import Schedule
from repro.core.types import AFTOState, Hyper, TrilevelProblem


@dataclasses.dataclass
class RunResult:
    state: AFTOState
    history: Dict


def record_slots(n_iterations: int,
                 metrics_every: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side record layout matching the eager runner.

    Returns (record_its, slots): `record_its` are the iterations whose
    metrics are recorded — every `metrics_every`-th plus the final one —
    and `slots[it]` is the history-array row for iteration `it` (-1 when
    iteration `it` records nothing).
    """
    record_its = np.array(
        [it for it in range(n_iterations)
         if (it + 1) % metrics_every == 0 or it == n_iterations - 1],
        dtype=np.int64)
    slots = np.full((n_iterations,), -1, np.int32)
    slots[record_its] = np.arange(len(record_its), dtype=np.int32)
    return record_its, slots


def _hyper_key(hyper: Hyper) -> tuple:
    return tuple(sorted(
        (f.name, getattr(hyper, f.name))
        for f in dataclasses.fields(hyper)))


# Compiled-trajectory cache.  Keyed on object identity for problem /
# metrics_fn (both are kept alive by the cache entry itself, so ids
# cannot be recycled while a key references them) and structurally on
# the hyper scalars and record layout.
_CACHE: Dict[tuple, tuple] = {}
_CACHE_MAX = 16


def _build_scan(problem: TrilevelProblem, hyper: Hyper,
                metrics_fn: Optional[Callable], keys, donate: bool):
    def step_body(carry, xs):
        st, hist = carry
        mask, it, slot = xs
        st = afto_lib.afto_step(problem, hyper, st, mask)
        do_refresh = ((it + 1) % hyper.t_pre == 0) & (it < hyper.t1)
        st = jax.lax.cond(
            do_refresh,
            lambda s: afto_lib.cut_refresh(problem, hyper, s),
            lambda s: s, st)

        def write(h):
            vals = {
                "gap_sq": stat_lib.stationarity_gap_sq(problem, hyper, st),
                "n_cuts_i": jnp.sum(st.cuts_i.active),
                "n_cuts_ii": jnp.sum(st.cuts_ii.active),
            }
            if metrics_fn is not None:
                vals.update(metrics_fn(st))
            return {k: h[k].at[slot].set(
                jnp.asarray(vals[k], jnp.float32)) for k in keys}

        hist = jax.lax.cond(slot >= 0, write, lambda h: h, hist)
        return (st, hist), None

    def scan_all(st, hist, masks, its, slots):
        (st, hist), _ = jax.lax.scan(step_body, (st, hist),
                                     (masks, its, slots))
        return st, hist

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(scan_all, donate_argnums=donate_argnums)


def _metric_keys(problem, hyper, metrics_fn, state):
    keys = ["gap_sq", "n_cuts_i", "n_cuts_ii"]
    if metrics_fn is not None:
        extra = jax.eval_shape(metrics_fn, state)
        keys += [k for k in extra if k not in keys]
    return tuple(keys)


def run_scanned(problem: TrilevelProblem, hyper: Hyper, schedule: Schedule,
                metrics_fn: Optional[Callable] = None,
                metrics_every: int = 10,
                state: Optional[AFTOState] = None) -> RunResult:
    """Run the full AFTO trajectory over `schedule` in one compiled scan.

    Produces the same history layout as the eager runner: arrays
    (instead of Python lists) keyed by t / sim_time / host_time /
    gap_sq / n_cuts_i / n_cuts_ii / max_staleness plus any `metrics_fn`
    keys.  `host_time` is prorated from the single dispatch's total —
    per-iteration host timestamps do not exist inside a compiled
    trajectory.
    """
    n_iterations = schedule.n_iterations
    donate = state is None
    if state is None:
        # init_state aliases some buffers across fields (e.g. z3 and
        # inner3.z3); donation requires distinct buffers, so copy once.
        state = jax.tree.map(jnp.array, afto_lib.init_state(problem, hyper))
    record_its, slots = record_slots(n_iterations, metrics_every)
    n_records = len(record_its)

    keys = _metric_keys(problem, hyper, metrics_fn, state)
    cache_key = (id(problem), id(metrics_fn), _hyper_key(hyper),
                 n_iterations, metrics_every, donate)
    hit = _CACHE.pop(cache_key, None)
    if hit is None:
        fn = _build_scan(problem, hyper, metrics_fn, keys, donate)
        hit = (fn, problem, metrics_fn)   # keep-alive refs pin the ids
        while len(_CACHE) >= _CACHE_MAX:
            _CACHE.pop(next(iter(_CACHE)))
    _CACHE[cache_key] = hit
    fn = hit[0]

    hist0 = {k: jnp.zeros((n_records,), jnp.float32) for k in keys}
    masks = jnp.asarray(schedule.active, jnp.float32)
    its = jnp.arange(n_iterations, dtype=jnp.int32)

    t_start = time.perf_counter()
    state, hist = fn(state, hist0, masks, its, jnp.asarray(slots))
    jax.block_until_ready(state)
    elapsed = time.perf_counter() - t_start

    history = {k: np.asarray(v) for k, v in hist.items()}
    history["t"] = (record_its + 1).astype(np.float64)
    history["sim_time"] = np.asarray(schedule.sim_time)[record_its]
    history["max_staleness"] = np.asarray(
        schedule.max_staleness)[record_its].astype(np.float64)
    history["host_time"] = elapsed * (record_its + 1) / n_iterations
    return RunResult(state=state, history=history)
