"""Compiled trajectory engine: whole AFTO runs (and sweeps) in one
`lax.scan` dispatch.

The straggler scheduler is a seeded host-side simulation with no feedback
from the optimization state, so its entire arrival process can be
materialized up front (`StragglerScheduler.precompute`) and the
T-iteration trajectory of Alg. 1 driven inside a single donated-buffer
`jax.lax.scan`:

  * `afto_step` every master iteration (Eqs. 16-21),
  * `cut_refresh` via `lax.cond` on every t_pre-th iteration with
    t < t1 (Eqs. 23-25),
  * gap / cut-count / user metrics accumulated into preallocated
    history arrays at `metrics_every` strides (under `lax.cond`, and the
    stationarity gap is *fused* with the step: it reuses the step's
    canonical cut operator and cut values instead of recomputing them —
    see `afto_step_aux` / `stationarity_gap_sq(aux=...)`).

The scan carry holds each polytope as canonical `FlatCuts` — two dense
(P, D)/(P,) array groups instead of ~10 stacked block trees — so the
carry is small, `cut_refresh` writes rows in place, and the dense
matrix shards by worker columns (a tree of stacked blocks does not).

`run_scanned` drives one trajectory; `run_swept` vmaps the same scan
body over a leading run axis R (stacked initial states, stacked schedule
masks, per-run data and sweepable hyper scalars) so a whole benchmark
sweep — every (seed, method) cell — is ONE donated XLA dispatch
returning (R,)-leading states and histories.

Both accept `mesh=` (a `jax.sharding.Mesh` with a "worker" axis) and
then run shard_map-distributed: worker-stacked state, per-worker data,
schedule-mask columns and the polytope b-columns partition over the
axis while master state replicates, and the only cross-shard traffic is
the cut-scalar / z-sized psums of the paper's cut exchange (the refresh
math lives in `repro.core.sharded`; partitioning rules in
`repro.fed.sharding.afto_state_specs`).  Sharded trajectories match the
replicated engines to f32 tolerance (`tests/test_sharded_engine.py`).

Both engines also accept `data=`: replacement `problem.data` arrays
(traced, not closed over — the compiled trajectory is reused across
datasets of one layout), or a `repro.data.stream.Stream`, in which case
every iteration's worker batches are SYNTHESIZED INSIDE the scan body
from fold-in PRNG keys (`stream.batch_at(spec, key, state.stale.t_hat,
...)`).  The stream's base key rides the donated carry untouched and
each worker's row folds on its absolute consumption time (the carried
pre-step `state.stale.t_hat`), so any chunk partition of a trajectory
(state-continued `run_scanned` calls) sees the bit-identical batch
sequence, and the worker-mesh engines draw each shard's own global
worker rows locally — streaming adds NO data collectives
(`tests/test_stream.py` is the conformance harness).

`metrics_fn` must be JAX-traceable here (it is traced into the scan
body); host-callback metrics still work through the eager path of
`repro.core.runner.run(mode="eager")`.

Compiled trajectories are cached per (problem, hyper, metrics_fn,
schedule length, record layout), so repeated runs — e.g. the AFTO/SFTO
sweeps in the benchmarks — pay tracing + compilation once.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import afto as afto_lib
from repro.core import cuts as cuts_lib
from repro.core import sharded as sharded_lib
from repro.core import stationarity as stat_lib
from repro.core.scheduler import Schedule
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream


@dataclasses.dataclass
class RunResult:
    state: AFTOState
    history: Dict
    # the LIVE arrival process recorded by the async runtime
    # (`repro.fed.runtime`), as a replayable `Schedule`; None for the
    # scheduled engines, whose arrival order was an input
    arrivals: Any = None


@dataclasses.dataclass
class SweepResult:
    """R trajectories from one dispatch: every state leaf and per-run
    history array carries a leading (R,) axis ("t" is shared)."""
    state: AFTOState
    history: Dict

    @property
    def n_runs(self) -> int:
        return int(jax.tree.leaves(self.state)[0].shape[0])

    def run(self, r: int) -> RunResult:
        """Row r as a RunResult with the single-run history layout."""
        state_r = jax.tree.map(lambda x: x[r], self.state)
        hist_r = {k: (v[r] if getattr(v, "ndim", 1) == 2 else v)
                  for k, v in self.history.items()}
        return RunResult(state=state_r, history=hist_r)


def record_slots(n_iterations: int,
                 metrics_every: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side record layout matching the eager runner.

    Returns (record_its, slots): `record_its` are the iterations whose
    metrics are recorded — every `metrics_every`-th plus the final one —
    and `slots[it]` is the history-array row for iteration `it` (-1 when
    iteration `it` records nothing).
    """
    record_its = np.array(
        [it for it in range(n_iterations)
         if (it + 1) % metrics_every == 0 or it == n_iterations - 1],
        dtype=np.int64)
    slots = np.full((n_iterations,), -1, np.int32)
    slots[record_its] = np.arange(len(record_its), dtype=np.int32)
    return record_its, slots


def _hyper_key(hyper: Hyper) -> tuple:
    return tuple(sorted(
        (f.name, getattr(hyper, f.name))
        for f in dataclasses.fields(hyper)))


# Compiled-trajectory caches.  Keyed on object identity for problem /
# metrics_fn (both are kept alive by the cache entry itself, so ids
# cannot be recycled while a key references them) and structurally on
# the hyper scalars and record layout.
_CACHE: Dict[tuple, tuple] = {}
_SWEEP_CACHE: Dict[tuple, tuple] = {}
_CACHE_MAX = 16


def _cached_build(cache: Dict[tuple, tuple], key: tuple, build,
                  keep_alive: tuple):
    """Fetch the compiled trajectory for `key`, building on miss; the
    `keep_alive` refs ride in the entry so the ids in `key` cannot be
    recycled while the entry lives.  Re-inserting on hit keeps the dict
    in LRU order for the size-capped eviction."""
    hit = cache.pop(key, None)
    if hit is None:
        hit = (build(),) + keep_alive
        while len(cache) >= _CACHE_MAX:
            cache.pop(next(iter(cache)))
    cache[key] = hit
    return hit[0]

# How many times each builder actually traced a new scan/sweep — the
# retrace regression tests assert this stays flat across warm calls
# (the *_sharded counters cover the worker-mesh shard_map paths, the
# *_streamed ones the in-scan data-stream paths: a stream's key is
# traced, so re-seeding must never rebuild).
BUILD_COUNTS = {"scan": 0, "sweep": 0, "scan_sharded": 0,
                "sweep_sharded": 0, "scan_streamed": 0,
                "sweep_streamed": 0, "scan_sharded_streamed": 0,
                "sweep_sharded_streamed": 0}


def _data_key(data):
    """Structural cache-key component for the `data=` argument: streams
    key on their static spec (the traced key never retraces), host
    arrays on their layout."""
    if data is None:
        return None
    if isinstance(data, Stream):
        return ("stream", data.spec)
    leaves, tdef = jax.tree_util.tree_flatten(data)
    return ("host", tdef,
            tuple((tuple(map(int, l.shape)), str(l.dtype))
                  for l in leaves))


def _check_stream(stream: Stream, hyper: Hyper) -> None:
    if stream.spec is None:
        raise ValueError("Stream has no spec; build with "
                         "repro.data.stream.make_stream")
    if stream.spec.n_workers != hyper.n_workers:
        raise ValueError(
            f"stream spans {stream.spec.n_workers} workers but "
            f"hyper.n_workers={hyper.n_workers}")

# Hyper fields that determine array shapes or unrolled loop lengths;
# they must be Python constants at trace time and cannot be swept.
_STATIC_HYPER_FIELDS = frozenset({"n_workers", "p_max", "k_inner", "d1"})


def _make_step_body(problem: TrilevelProblem, hyper: Hyper,
                    metrics_fn: Optional[Callable], keys,
                    axis: Optional[str] = None,
                    stream_spec=None, n_shards: Optional[int] = None):
    """The per-iteration scan body shared by run_scanned and run_swept.

    axis: worker mesh axis when tracing inside the shard_map'd engines —
    `problem`/state/mask then carry this shard's workers only and the
    refresh dispatches to the sharded cut generation.

    stream_spec: when set, the carry grows a (constant) stream key and
    each iteration's `problem.data` is synthesized in-scan from fold-in
    keys on each worker's absolute consumption time (the pre-step
    `state.stale.t_hat` — worker j's row is folded at the iteration its
    current local point was handed out, which is what a self-paced
    async worker can reproduce from its REFRESH frame alone).  Still
    chunk-partition invariant (t_hat rides the carry), and on a mesh
    each shard draws only its own global worker rows (t_hat is
    worker-stacked, so the shard's slice arrives with the state;
    `axis_index * n_local` offset), so streaming adds no collectives.

    The refresh predicate also runs on `state.t` (identical to the old
    xs-iteration form for fresh starts), so state-continued chunked
    dispatches refresh exactly where the unchunked trajectory does."""
    if stream_spec is not None:
        n_local = (stream_spec.n_workers if axis is None
                   else stream_spec.n_workers // n_shards)

    def step_body(carry, xs):
        mask, slot = xs
        if stream_spec is None:
            st, hist = carry
            prob = problem
        else:
            st, hist, key = carry
            off = 0 if axis is None else jax.lax.axis_index(axis) * n_local
            prob = dataclasses.replace(
                problem,
                data=stream_lib.batch_at(stream_spec, key,
                                         st.stale.t_hat, off, n_local))
        st, step_aux = afto_lib.afto_step_aux(prob, hyper, st, mask,
                                              axis=axis)
        # post-step st.t is the 1-based master iteration count
        do_refresh = (st.t % hyper.t_pre == 0) & (st.t - 1 < hyper.t1)
        refresh = (
            (lambda s: afto_lib.cut_refresh(prob, hyper, s))
            if axis is None else
            (lambda s: sharded_lib.cut_refresh_sharded(prob, hyper, s,
                                                       axis)))
        st = jax.lax.cond(do_refresh, refresh, lambda s: s, st)

        def write(h):
            # the gap reuses the step's flat cut operator + cut values;
            # a refresh rewrote the polytope, so recompute them there.
            aux = jax.lax.cond(
                do_refresh,
                lambda s, _a: stat_lib.make_gap_aux(prob, hyper, s,
                                                    axis=axis),
                lambda _s, a: a, st, step_aux)
            vals = {
                "gap_sq": stat_lib.stationarity_gap_sq(
                    prob, hyper, st, aux=aux, axis=axis),
                "n_cuts_i": jnp.sum(st.cuts_i.active),
                "n_cuts_ii": jnp.sum(st.cuts_ii.active),
            }
            if metrics_fn is not None:
                vals.update(metrics_fn(st))
            return {k: h[k].at[slot].set(
                jnp.asarray(vals[k], jnp.float32)) for k in keys}

        hist = jax.lax.cond(slot >= 0, write, lambda h: h, hist)
        return ((st, hist) if stream_spec is None
                else (st, hist, key)), None

    return step_body


def _build_scan(problem: TrilevelProblem, hyper: Hyper,
                metrics_fn: Optional[Callable], keys, donate: bool,
                stream_spec=None):
    BUILD_COUNTS["scan_streamed" if stream_spec else "scan"] += 1

    def scan_all(st, hist, data, key, masks, slots):
        prob = problem if data is None else \
            dataclasses.replace(problem, data=data)
        step_body = _make_step_body(prob, hyper, metrics_fn, keys,
                                    stream_spec=stream_spec)
        carry = (st, hist) if stream_spec is None else (st, hist, key)
        carry, _ = jax.lax.scan(step_body, carry, (masks, slots))
        return carry[0], carry[1]

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(scan_all, donate_argnums=donate_argnums)


def _metric_keys(problem, hyper, metrics_fn, state):
    keys = ["gap_sq", "n_cuts_i", "n_cuts_ii"]
    if metrics_fn is not None:
        extra = jax.eval_shape(metrics_fn, state)
        keys += [k for k in extra if k not in keys]
    return tuple(keys)


# ---------------------------------------------------------------------------
# worker-mesh sharded dispatch (shard_map over the cut-exchange axis)
# ---------------------------------------------------------------------------

def _worker_axis_size(mesh) -> int:
    shape = dict(mesh.shape)
    if sharded_lib.WORKER_AXIS not in shape:
        raise ValueError(
            f"mesh must carry a {sharded_lib.WORKER_AXIS!r} axis; got "
            f"axes {tuple(shape)} (see repro.launch.mesh.make_worker_mesh)")
    return shape[sharded_lib.WORKER_AXIS]


def _check_mesh(mesh, hyper: Hyper) -> int:
    w = _worker_axis_size(mesh)
    if hyper.n_workers % w != 0:
        raise ValueError(
            f"n_workers={hyper.n_workers} must divide over the "
            f"{w}-shard worker mesh")
    return w


def _shard_state(state: AFTOState, n_shards: int) -> AFTOState:
    """Host-side sharded view: polytopes become the stacked-local column
    groups of `cuts.shard_cuts`; every other leaf keeps its global shape
    (the shard_map in_specs split the worker-stacked axes)."""
    return dataclasses.replace(
        state,
        cuts_i=cuts_lib.shard_cuts(state.cuts_i, n_shards),
        cuts_ii=cuts_lib.shard_cuts(state.cuts_ii, n_shards))


def _unshard_state(state: AFTOState, spec_i, spec_ii) -> AFTOState:
    return dataclasses.replace(
        state,
        cuts_i=cuts_lib.unshard_cuts(state.cuts_i, spec_i),
        cuts_ii=cuts_lib.unshard_cuts(state.cuts_ii, spec_ii))


def _map_cuts(state: AFTOState, fn) -> AFTOState:
    return dataclasses.replace(
        state,
        cuts_i=dataclasses.replace(state.cuts_i, a=fn(state.cuts_i.a)),
        cuts_ii=dataclasses.replace(state.cuts_ii, a=fn(state.cuts_ii.a)))


def _state_specs(state_sharded, lead=()):
    from repro.fed import sharding as shd
    return shd.afto_state_specs(state_sharded,
                                axis=sharded_lib.WORKER_AXIS, lead=lead)


def _build_scan_sharded(problem: TrilevelProblem, hyper: Hyper,
                        metrics_fn: Optional[Callable], keys,
                        donate: bool, mesh, state_specs,
                        stream_spec=None, n_shards: Optional[int] = None):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    BUILD_COUNTS["scan_sharded_streamed" if stream_spec
                 else "scan_sharded"] += 1
    axis = sharded_lib.WORKER_AXIS

    def scan_all(st, hist, data, key, masks, slots):
        # drop the shard_map-local leading worker axis of the cut blocks
        st = _map_cuts(st, lambda a: a[0])
        prob = problem if data is None else \
            dataclasses.replace(problem, data=data)
        step_body = _make_step_body(prob, hyper, metrics_fn, keys,
                                    axis=axis, stream_spec=stream_spec,
                                    n_shards=n_shards)
        carry = (st, hist) if stream_spec is None else (st, hist, key)
        carry, _ = jax.lax.scan(step_body, carry, (masks, slots))
        st, hist = carry[0], carry[1]
        return _map_cuts(st, lambda a: a[None]), hist

    hist_specs = {k: P() for k in keys}
    from repro.fed import sharding as shd
    # streamed shards draw their own rows in-scan: no data input at all,
    # and the (replicated) base key is the only stream state.
    data_specs = None if stream_spec is not None else \
        shd.worker_data_specs(problem.data, axis=axis)
    key_spec = None if stream_spec is None else P()
    fn = shard_map(
        scan_all, mesh=mesh,
        in_specs=(state_specs, hist_specs, data_specs, key_spec,
                  P(None, axis), P()),
        out_specs=(state_specs, hist_specs),
        check_rep=False)
    donate_argnums = (0, 1) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


def _stitch_histories(parts, offsets, elapsed_offsets) -> Dict:
    """Concatenate per-chunk histories into one absolute-iteration
    record: "t" shifts by each chunk's start, host_time accumulates the
    wall-clock spent before the chunk."""
    out: Dict = {}
    for k in parts[0]:
        segs = []
        for h, off, el in zip(parts, offsets, elapsed_offsets):
            v = np.asarray(h[k])
            if k == "t":
                v = v + off
            elif k == "host_time":
                v = v + el
            segs.append(v)
        out[k] = np.concatenate(segs)
    return out


def run_chunked(problem: TrilevelProblem, hyper: Hyper, schedule: Schedule,
                chunk_size: int,
                chunk_hook: Optional[Callable] = None,
                metrics_fn: Optional[Callable] = None,
                metrics_every: int = 10,
                state: Optional[AFTOState] = None,
                mesh=None, data=None) -> RunResult:
    """`run_scanned` split into state-continued `chunk_size`-iteration
    dispatches, with `chunk_hook(state, t_abs)` called on the LIVE carry
    at every chunk boundary (including the final one).

    The hook sees the post-chunk state and may return a replacement
    state (or None to keep it) — the push/pull seam the async runtime
    and the elastic-checkpoint path hang off: push = read the carry out
    (checkpoint it, ship cut rows to a master), pull = splice refreshed
    master state back in before the next dispatch.  Chunking is exact
    for fresh starts by the continuation contract (the refresh predicate
    and the streamed batches key on carried absolute counters —
    `state.t` and the per-worker `state.stale.t_hat`), so
    a hook that returns None reproduces the unchunked trajectory
    bit-for-bit; warm equal-size chunks reuse one compiled trace.

    History records per chunk (every `metrics_every`-th iteration plus
    each chunk's final one), stitched to absolute iterations.
    """
    n_iterations = schedule.n_iterations
    chunk_size = max(1, int(chunk_size))
    parts, offsets, elapsed = [], [], []
    spent = 0.0
    for a in range(0, n_iterations, chunk_size):
        b = min(a + chunk_size, n_iterations)
        res = run_scanned(problem, hyper, schedule.slice(a, b),
                          metrics_fn=metrics_fn,
                          metrics_every=metrics_every, state=state,
                          mesh=mesh, data=data)
        state = res.state
        parts.append(res.history)
        offsets.append(a)
        elapsed.append(spent)
        spent += float(res.history["host_time"][-1])
        if chunk_hook is not None:
            replacement = chunk_hook(state, b)
            if replacement is not None:
                state = replacement
    return RunResult(state=state,
                     history=_stitch_histories(parts, offsets, elapsed))


def run_scanned(problem: TrilevelProblem, hyper: Hyper, schedule: Schedule,
                metrics_fn: Optional[Callable] = None,
                metrics_every: int = 10,
                state: Optional[AFTOState] = None,
                mesh=None, data=None) -> RunResult:
    """Run the full AFTO trajectory over `schedule` in one compiled scan.

    Produces the same history layout as the eager runner: arrays
    (instead of Python lists) keyed by t / sim_time / host_time /
    gap_sq / n_cuts_i / n_cuts_ii / max_staleness plus any `metrics_fn`
    keys.  `host_time` is prorated from the single dispatch's total —
    per-iteration host timestamps do not exist inside a compiled
    trajectory.

    mesh: a `jax.sharding.Mesh` with a "worker" axis distributes the
    federation via shard_map — worker-stacked state, schedule-mask
    columns, per-worker data and the polytope b-columns partition over
    the axis; only cut scalars / z-sized reductions cross it (see
    `repro.core.sharded`).  `hyper.n_workers` must be divisible by the
    axis size; results match the single-device scan to f32 tolerance
    (the returned state is reassembled to the canonical global layout).
    `metrics_fn` is traced on the shard-local state view — metrics over
    master variables (z's, lam, cut masks) are exact and replicated;
    a metric that reads the worker stacks computes a PER-SHARD partial
    value, and the history records whichever shard's buffer backs the
    replicated-out layout (shard 0 in practice — the engine cannot
    know how to reduce an arbitrary user metric).  psum inside your
    metrics_fn over `repro.core.sharded.WORKER_AXIS` if you need the
    global value.

    data: replacement `problem.data` arrays (traced — the compiled
    trajectory is shared across datasets of one layout), or a
    `repro.data.stream.Stream` whose per-iteration worker batches are
    synthesized INSIDE the scan from fold-in keys on the absolute
    `state.t` (chunk-partition invariant; on a mesh each shard draws
    its own global worker rows with no data collectives).  Re-seeding a
    stream (`dataclasses.replace(stream, key=...)`) never retraces.
    """
    n_iterations = schedule.n_iterations
    n_shards = None if mesh is None else _check_mesh(mesh, hyper)
    stream = data if isinstance(data, Stream) else None
    if stream is not None:
        _check_stream(stream, hyper)
    host_data = None if (data is None or stream is not None) else \
        jax.tree.map(jnp.asarray, data)
    stream_spec = None if stream is None else stream.spec
    donate = state is None
    if state is None:
        # init_state aliases some buffers across fields (e.g. z3 and
        # inner3.z3); donation requires distinct buffers, so copy once.
        state = jax.tree.map(jnp.array, afto_lib.init_state(problem, hyper))
    record_its, slots = record_slots(n_iterations, metrics_every)
    n_records = len(record_its)

    keys = _metric_keys(problem, hyper, metrics_fn, state)
    cache_key = (id(problem), id(metrics_fn), _hyper_key(hyper),
                 n_iterations, metrics_every, donate, mesh,
                 _data_key(data))
    if mesh is None:
        fn = _cached_build(
            _CACHE, cache_key,
            lambda: _build_scan(problem, hyper, metrics_fn, keys, donate,
                                stream_spec=stream_spec),
            (problem, metrics_fn, stream_spec))
    else:
        spec_i, spec_ii = state.cuts_i.spec, state.cuts_ii.spec
        state = _shard_state(state, n_shards)
        fn = _cached_build(
            _CACHE, cache_key,
            lambda: _build_scan_sharded(problem, hyper, metrics_fn, keys,
                                        donate, mesh,
                                        _state_specs(state),
                                        stream_spec=stream_spec,
                                        n_shards=n_shards),
            (problem, metrics_fn, mesh, stream_spec))

    hist0 = {k: jnp.zeros((n_records,), jnp.float32) for k in keys}
    masks = jnp.asarray(schedule.active, jnp.float32)
    key = None if stream is None else jnp.asarray(stream.key)

    t_start = time.perf_counter()
    if mesh is None:
        state, hist = fn(state, hist0, host_data, key, masks,
                         jnp.asarray(slots))
    else:
        data_arg = None if stream is not None else (
            host_data if host_data is not None
            else jax.tree.map(jnp.asarray, problem.data))
        state, hist = fn(state, hist0, data_arg, key, masks,
                         jnp.asarray(slots))
        state = _unshard_state(state, spec_i, spec_ii)
    jax.block_until_ready(state)
    elapsed = time.perf_counter() - t_start

    history = {k: np.asarray(v) for k, v in hist.items()}
    history["t"] = (record_its + 1).astype(np.float64)
    history["sim_time"] = np.asarray(schedule.sim_time)[record_its]
    history["max_staleness"] = np.asarray(
        schedule.max_staleness)[record_its].astype(np.float64)
    history["host_time"] = elapsed * (record_its + 1) / n_iterations
    return RunResult(state=state, history=history)


# ---------------------------------------------------------------------------
# batched sweeps: R trajectories in one vmapped dispatch
# ---------------------------------------------------------------------------

def _build_sweep(problem: TrilevelProblem, hyper: Hyper,
                 metrics_fn: Optional[Callable], keys,
                 sweep_names: tuple, has_data: bool, init_inside: bool,
                 stream_spec=None):
    BUILD_COUNTS["sweep_streamed" if stream_spec else "sweep"] += 1

    def one_run(st, hist, masks, sweep_vals, data, key, slots):
        prob = problem if data is None else \
            dataclasses.replace(problem, data=data)
        hyp = dataclasses.replace(
            hyper, **dict(zip(sweep_names, sweep_vals))) \
            if sweep_names else hyper
        step_body = _make_step_body(prob, hyp, metrics_fn, keys,
                                    stream_spec=stream_spec)
        carry = (st, hist) if stream_spec is None else (st, hist, key)
        carry, _ = jax.lax.scan(step_body, carry, (masks, slots))
        return carry[0], carry[1]

    def vmapped(st, hist, masks, sweep_vals, data, key, slots):
        # one stream is SHARED by all runs (same data per row, parity
        # with run_scanned); per-run variation comes from the schedules
        return jax.vmap(
            one_run,
            in_axes=(0, 0, 0, 0, 0 if has_data else None, None, None))(
                st, hist, masks, sweep_vals, data, key, slots)

    if not init_inside:
        return jax.jit(vmapped, donate_argnums=(0, 1))

    # default-init sweeps build the stacked initial state inside the
    # compiled dispatch (masks carries R statically) — the ~60 tiny
    # init_state + tile host dispatches otherwise dominate the whole
    # warm sweep at quickstart scale.
    def sweep_all(hist, masks, sweep_vals, data, key, slots):
        st0 = afto_lib.init_state(problem, hyper)
        st = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], masks.shape[:1] + x.shape).astype(x.dtype), st0)
        return vmapped(st, hist, masks, sweep_vals, data, key, slots)

    return jax.jit(sweep_all, donate_argnums=(0,))


def _build_sweep_sharded(problem: TrilevelProblem, hyper: Hyper,
                         metrics_fn: Optional[Callable], keys,
                         sweep_names: tuple, has_data: bool, mesh,
                         state_specs, stream_spec=None,
                         n_shards: Optional[int] = None):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    BUILD_COUNTS["sweep_sharded_streamed" if stream_spec
                 else "sweep_sharded"] += 1
    axis = sharded_lib.WORKER_AXIS

    def one_run(st, hist, masks, sweep_vals, data, key, slots):
        prob = problem if data is None else \
            dataclasses.replace(problem, data=data)
        hyp = dataclasses.replace(
            hyper, **dict(zip(sweep_names, sweep_vals))) \
            if sweep_names else hyper
        step_body = _make_step_body(prob, hyp, metrics_fn, keys,
                                    axis=axis, stream_spec=stream_spec,
                                    n_shards=n_shards)
        carry = (st, hist) if stream_spec is None else (st, hist, key)
        carry, _ = jax.lax.scan(step_body, carry, (masks, slots))
        return carry[0], carry[1]

    def sweep_all(st, hist, data, key, masks, sweep_vals, slots):
        # (R, 1, P, D_loc) cut blocks -> (R, P, D_loc) inside the shard
        st = _map_cuts(st, lambda a: a[:, 0])
        st, hist = jax.vmap(
            one_run,
            in_axes=(0, 0, 0, 0, 0 if has_data else None, None, None))(
                st, hist, masks, sweep_vals, data, key, slots)
        return _map_cuts(st, lambda a: a[:, None]), hist

    hist_specs = {k: P() for k in keys}
    from repro.fed import sharding as shd
    data_lead = (None,) if has_data else ()
    data_specs = None if stream_spec is not None else \
        shd.worker_data_specs(problem.data, axis=axis, lead=data_lead)
    key_spec = None if stream_spec is None else P()
    sweep_specs = tuple(P() for _ in sweep_names)
    fn = shard_map(
        sweep_all, mesh=mesh,
        in_specs=(state_specs, hist_specs, data_specs, key_spec,
                  P(None, None, axis), sweep_specs, P()),
        out_specs=(state_specs, hist_specs),
        check_rep=False)
    return jax.jit(fn, donate_argnums=(0, 1))


def run_swept(problem: TrilevelProblem, hyper: Hyper,
              schedules: Sequence[Schedule],
              metrics_fn: Optional[Callable] = None,
              metrics_every: int = 10,
              states: Optional[AFTOState] = None,
              data=None,
              sweep_hypers: Optional[Dict] = None,
              mesh=None) -> SweepResult:
    """Run R = len(schedules) whole trajectories in ONE vmapped dispatch.

    The scan body of `run_scanned` is `jax.vmap`'d over a leading run
    axis: stacked initial states, stacked schedule masks, per-run data
    slices and per-run hyper scalars; the iteration/slot streams are
    shared.  All schedules must have the same length and worker count.

      states       optional stacked AFTOState ((R,)-leading leaves, e.g.
                   per-seed inits via utils.tree.tree_stack); defaults to
                   R copies of `init_state`.  Copied internally — the
                   dispatch donates its own buffers, never the caller's.
      data         optional replacement for `problem.data` with a
                   leading (R,) axis per leaf (per-seed datasets), OR a
                   `repro.data.stream.Stream` — then every run's batches
                   are synthesized in-scan from the SHARED stream (each
                   row sees the data a `run_scanned(data=stream)` of its
                   schedule would; per-run variation comes from the
                   schedules/hypers, and re-seeding the stream never
                   retraces).
      sweep_hypers dict of Hyper field name -> (R,) values, threaded
                   into the traced step per run.  Shape-determining
                   fields (n_workers/p_max/k_inner/d1) stay static and
                   cannot be swept.  Sweeping t_pre/t1 is allowed but
                   costs: the refresh predicate becomes per-run, the
                   vmapped `lax.cond` lowers to a select, and the full
                   `cut_refresh` (inner rollouts + second-order grads)
                   executes every iteration for every run — correct
                   results, single-run-engine perf lost.

    History layout: per-run keys (gap_sq, n_cuts_*, sim_time,
    max_staleness, host_time, metrics_fn keys) are (R, n_records)
    arrays; "t" is shared (n_records,).  `host_time` is an
    elapsed/R-proration: the single dispatch interleaves all R
    trajectories, so per-run host seconds do not exist — each run is
    charged an equal 1/R share of the dispatch wall-clock, prorated
    over iterations exactly like the single-run engine.

    mesh: worker mesh as in `run_scanned` — the run axis is vmapped
    INSIDE the shard_map body, so the R trajectories still dispatch
    once while the federation partitions over the "worker" axis.  The
    sharded sweep always materializes the stacked initial states on the
    host (the fused in-dispatch default-init is a replicated-engine
    optimization).
    """
    schedules = list(schedules)
    if not schedules:
        raise ValueError("run_swept needs at least one schedule")
    n_runs = len(schedules)
    n_iterations = schedules[0].n_iterations
    for s in schedules[1:]:
        if (s.n_iterations, s.n_workers) != (n_iterations,
                                             schedules[0].n_workers):
            raise ValueError(
                "all swept schedules must share n_iterations/n_workers")

    sweep_hypers = dict(sweep_hypers or {})
    field_names = {f.name for f in dataclasses.fields(Hyper)}
    for name in sweep_hypers:
        if name not in field_names:
            raise ValueError(f"unknown hyper field {name!r}")
        if name in _STATIC_HYPER_FIELDS:
            raise ValueError(
                f"hyper field {name!r} is shape-determining and cannot "
                "be swept; run separate sweeps instead")
    sweep_names = tuple(sorted(sweep_hypers))
    sweep_vals = tuple(jnp.asarray(sweep_hypers[k]) for k in sweep_names)
    for name, v in zip(sweep_names, sweep_vals):
        if v.shape != (n_runs,):
            raise ValueError(
                f"sweep_hypers[{name!r}] must have shape ({n_runs},), "
                f"got {v.shape}")

    n_shards = None if mesh is None else _check_mesh(mesh, hyper)
    dkey = _data_key(data)
    stream = data if isinstance(data, Stream) else None
    stream_spec = None
    if stream is not None:
        _check_stream(stream, hyper)
        stream_spec = stream.spec
        data = None
    if mesh is not None and states is None:
        st0 = afto_lib.init_state(problem, hyper)
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (n_runs,) + x.shape).astype(x.dtype), st0)
    init_inside = states is None
    if not init_inside:
        # private copy: the swept dispatch donates its inputs
        states = jax.tree.map(jnp.array, states)
    if data is not None:
        data = jax.tree.map(jnp.asarray, data)
        for leaf in jax.tree.leaves(data):
            if leaf.shape[:1] != (n_runs,):
                raise ValueError(
                    "swept data leaves need a leading (R,) axis")

    record_its, slots = record_slots(n_iterations, metrics_every)
    n_records = len(record_its)
    if metrics_fn is None:
        state_one = None           # _metric_keys won't trace anything
    elif init_inside:
        state_one = jax.eval_shape(
            lambda: afto_lib.init_state(problem, hyper))
    else:
        state_one = jax.tree.map(lambda x: x[0], states)
    keys = _metric_keys(problem, hyper, metrics_fn, state_one)

    cache_key = (id(problem), id(metrics_fn), _hyper_key(hyper),
                 sweep_names, dkey, init_inside, n_runs,
                 n_iterations, metrics_every, mesh)
    if mesh is not None:
        spec_i = states.cuts_i.spec
        spec_ii = states.cuts_ii.spec
        states = dataclasses.replace(
            states,
            cuts_i=jax.vmap(lambda fc: cuts_lib.shard_cuts(fc, n_shards))(
                states.cuts_i),
            cuts_ii=jax.vmap(lambda fc: cuts_lib.shard_cuts(fc, n_shards))(
                states.cuts_ii))
        fn = _cached_build(
            _SWEEP_CACHE, cache_key,
            lambda: _build_sweep_sharded(
                problem, hyper, metrics_fn, keys, sweep_names,
                data is not None, mesh, _state_specs(states, lead=(None,)),
                stream_spec=stream_spec, n_shards=n_shards),
            (problem, metrics_fn, mesh, stream_spec))
    else:
        fn = _cached_build(
            _SWEEP_CACHE, cache_key,
            lambda: _build_sweep(problem, hyper, metrics_fn, keys,
                                 sweep_names, data is not None,
                                 init_inside, stream_spec=stream_spec),
            (problem, metrics_fn, stream_spec))

    hist0 = {k: jnp.zeros((n_runs, n_records), jnp.float32) for k in keys}
    masks = jnp.asarray(
        np.stack([s.active for s in schedules]), jnp.float32)
    key = None if stream is None else jnp.asarray(stream.key)

    t_start = time.perf_counter()
    if mesh is not None:
        run_data = None if stream is not None else (
            data if data is not None
            else jax.tree.map(jnp.asarray, problem.data))
        state, hist = fn(states, hist0, run_data, key, masks, sweep_vals,
                         jnp.asarray(slots))
        state = dataclasses.replace(
            state,
            cuts_i=jax.vmap(
                lambda fc: cuts_lib.unshard_cuts(fc, spec_i))(state.cuts_i),
            cuts_ii=jax.vmap(
                lambda fc: cuts_lib.unshard_cuts(fc, spec_ii))(
                    state.cuts_ii))
    elif init_inside:
        state, hist = fn(hist0, masks, sweep_vals, data, key,
                         jnp.asarray(slots))
    else:
        state, hist = fn(states, hist0, masks, sweep_vals, data, key,
                         jnp.asarray(slots))
    jax.block_until_ready(state)
    elapsed = time.perf_counter() - t_start

    history = {k: np.asarray(v) for k, v in hist.items()}
    history["t"] = (record_its + 1).astype(np.float64)
    history["sim_time"] = np.stack(
        [np.asarray(s.sim_time)[record_its] for s in schedules])
    history["max_staleness"] = np.stack(
        [np.asarray(s.max_staleness)[record_its].astype(np.float64)
         for s in schedules])
    # one dispatch covers R trajectories: charge each run elapsed/R
    # (an approximation — the runs execute interleaved, not serially).
    history["host_time"] = np.broadcast_to(
        (elapsed / n_runs) * (record_its + 1) / n_iterations,
        (n_runs, n_records)).copy()
    return SweepResult(state=state, history=history)
