"""Compiled trajectory engine: whole AFTO runs (and sweeps) in one
`lax.scan` dispatch.

The straggler scheduler is a seeded host-side simulation with no feedback
from the optimization state, so its entire arrival process can be
materialized up front (`StragglerScheduler.precompute`) and the
T-iteration trajectory of Alg. 1 driven inside a single donated-buffer
`jax.lax.scan`:

  * `afto_step` every master iteration (Eqs. 16-21),
  * `cut_refresh` via `lax.cond` on every t_pre-th iteration with
    t < t1 (Eqs. 23-25),
  * gap / cut-count / user metrics accumulated into preallocated
    history arrays at `metrics_every` strides (under `lax.cond`, and the
    stationarity gap is *fused* with the step: it reuses the step's
    canonical cut operator and cut values instead of recomputing them —
    see `afto_step_aux` / `stationarity_gap_sq(aux=...)`).

The scan carry holds each polytope as canonical `FlatCuts` — two dense
(P, D)/(P,) array groups instead of ~10 stacked block trees — so the
carry is small, `cut_refresh` writes rows in place, and the dense
matrix is directly shardable over a future worker-mesh `shard_map`
(a tree of stacked blocks is not).

`run_scanned` drives one trajectory; `run_swept` vmaps the same scan
body over a leading run axis R (stacked initial states, stacked schedule
masks, per-run data and sweepable hyper scalars) so a whole benchmark
sweep — every (seed, method) cell — is ONE donated XLA dispatch
returning (R,)-leading states and histories.

`metrics_fn` must be JAX-traceable here (it is traced into the scan
body); host-callback metrics still work through the eager path of
`repro.core.runner.run(mode="eager")`.

Compiled trajectories are cached per (problem, hyper, metrics_fn,
schedule length, record layout), so repeated runs — e.g. the AFTO/SFTO
sweeps in the benchmarks — pay tracing + compilation once.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import afto as afto_lib
from repro.core import stationarity as stat_lib
from repro.core.scheduler import Schedule
from repro.core.types import AFTOState, Hyper, TrilevelProblem


@dataclasses.dataclass
class RunResult:
    state: AFTOState
    history: Dict


@dataclasses.dataclass
class SweepResult:
    """R trajectories from one dispatch: every state leaf and per-run
    history array carries a leading (R,) axis ("t" is shared)."""
    state: AFTOState
    history: Dict

    @property
    def n_runs(self) -> int:
        return int(jax.tree.leaves(self.state)[0].shape[0])

    def run(self, r: int) -> RunResult:
        """Row r as a RunResult with the single-run history layout."""
        state_r = jax.tree.map(lambda x: x[r], self.state)
        hist_r = {k: (v[r] if getattr(v, "ndim", 1) == 2 else v)
                  for k, v in self.history.items()}
        return RunResult(state=state_r, history=hist_r)


def record_slots(n_iterations: int,
                 metrics_every: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side record layout matching the eager runner.

    Returns (record_its, slots): `record_its` are the iterations whose
    metrics are recorded — every `metrics_every`-th plus the final one —
    and `slots[it]` is the history-array row for iteration `it` (-1 when
    iteration `it` records nothing).
    """
    record_its = np.array(
        [it for it in range(n_iterations)
         if (it + 1) % metrics_every == 0 or it == n_iterations - 1],
        dtype=np.int64)
    slots = np.full((n_iterations,), -1, np.int32)
    slots[record_its] = np.arange(len(record_its), dtype=np.int32)
    return record_its, slots


def _hyper_key(hyper: Hyper) -> tuple:
    return tuple(sorted(
        (f.name, getattr(hyper, f.name))
        for f in dataclasses.fields(hyper)))


# Compiled-trajectory caches.  Keyed on object identity for problem /
# metrics_fn (both are kept alive by the cache entry itself, so ids
# cannot be recycled while a key references them) and structurally on
# the hyper scalars and record layout.
_CACHE: Dict[tuple, tuple] = {}
_SWEEP_CACHE: Dict[tuple, tuple] = {}
_CACHE_MAX = 16


def _cached_build(cache: Dict[tuple, tuple], key: tuple, build,
                  keep_alive: tuple):
    """Fetch the compiled trajectory for `key`, building on miss; the
    `keep_alive` refs ride in the entry so the ids in `key` cannot be
    recycled while the entry lives.  Re-inserting on hit keeps the dict
    in LRU order for the size-capped eviction."""
    hit = cache.pop(key, None)
    if hit is None:
        hit = (build(),) + keep_alive
        while len(cache) >= _CACHE_MAX:
            cache.pop(next(iter(cache)))
    cache[key] = hit
    return hit[0]

# How many times each builder actually traced a new scan/sweep — the
# retrace regression tests assert this stays flat across warm calls.
BUILD_COUNTS = {"scan": 0, "sweep": 0}

# Hyper fields that determine array shapes or unrolled loop lengths;
# they must be Python constants at trace time and cannot be swept.
_STATIC_HYPER_FIELDS = frozenset({"n_workers", "p_max", "k_inner", "d1"})


def _make_step_body(problem: TrilevelProblem, hyper: Hyper,
                    metrics_fn: Optional[Callable], keys):
    """The per-iteration scan body shared by run_scanned and run_swept."""
    def step_body(carry, xs):
        st, hist = carry
        mask, it, slot = xs
        st, step_aux = afto_lib.afto_step_aux(problem, hyper, st, mask)
        do_refresh = ((it + 1) % hyper.t_pre == 0) & (it < hyper.t1)
        st = jax.lax.cond(
            do_refresh,
            lambda s: afto_lib.cut_refresh(problem, hyper, s),
            lambda s: s, st)

        def write(h):
            # the gap reuses the step's flat cut operator + cut values;
            # a refresh rewrote the polytope, so recompute them there.
            aux = jax.lax.cond(
                do_refresh,
                lambda s, _a: stat_lib.make_gap_aux(problem, hyper, s),
                lambda _s, a: a, st, step_aux)
            vals = {
                "gap_sq": stat_lib.stationarity_gap_sq(
                    problem, hyper, st, aux=aux),
                "n_cuts_i": jnp.sum(st.cuts_i.active),
                "n_cuts_ii": jnp.sum(st.cuts_ii.active),
            }
            if metrics_fn is not None:
                vals.update(metrics_fn(st))
            return {k: h[k].at[slot].set(
                jnp.asarray(vals[k], jnp.float32)) for k in keys}

        hist = jax.lax.cond(slot >= 0, write, lambda h: h, hist)
        return (st, hist), None

    return step_body


def _build_scan(problem: TrilevelProblem, hyper: Hyper,
                metrics_fn: Optional[Callable], keys, donate: bool):
    BUILD_COUNTS["scan"] += 1
    step_body = _make_step_body(problem, hyper, metrics_fn, keys)

    def scan_all(st, hist, masks, its, slots):
        (st, hist), _ = jax.lax.scan(step_body, (st, hist),
                                     (masks, its, slots))
        return st, hist

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(scan_all, donate_argnums=donate_argnums)


def _metric_keys(problem, hyper, metrics_fn, state):
    keys = ["gap_sq", "n_cuts_i", "n_cuts_ii"]
    if metrics_fn is not None:
        extra = jax.eval_shape(metrics_fn, state)
        keys += [k for k in extra if k not in keys]
    return tuple(keys)


def run_scanned(problem: TrilevelProblem, hyper: Hyper, schedule: Schedule,
                metrics_fn: Optional[Callable] = None,
                metrics_every: int = 10,
                state: Optional[AFTOState] = None) -> RunResult:
    """Run the full AFTO trajectory over `schedule` in one compiled scan.

    Produces the same history layout as the eager runner: arrays
    (instead of Python lists) keyed by t / sim_time / host_time /
    gap_sq / n_cuts_i / n_cuts_ii / max_staleness plus any `metrics_fn`
    keys.  `host_time` is prorated from the single dispatch's total —
    per-iteration host timestamps do not exist inside a compiled
    trajectory.
    """
    n_iterations = schedule.n_iterations
    donate = state is None
    if state is None:
        # init_state aliases some buffers across fields (e.g. z3 and
        # inner3.z3); donation requires distinct buffers, so copy once.
        state = jax.tree.map(jnp.array, afto_lib.init_state(problem, hyper))
    record_its, slots = record_slots(n_iterations, metrics_every)
    n_records = len(record_its)

    keys = _metric_keys(problem, hyper, metrics_fn, state)
    cache_key = (id(problem), id(metrics_fn), _hyper_key(hyper),
                 n_iterations, metrics_every, donate)
    fn = _cached_build(
        _CACHE, cache_key,
        lambda: _build_scan(problem, hyper, metrics_fn, keys, donate),
        (problem, metrics_fn))

    hist0 = {k: jnp.zeros((n_records,), jnp.float32) for k in keys}
    masks = jnp.asarray(schedule.active, jnp.float32)
    its = jnp.arange(n_iterations, dtype=jnp.int32)

    t_start = time.perf_counter()
    state, hist = fn(state, hist0, masks, its, jnp.asarray(slots))
    jax.block_until_ready(state)
    elapsed = time.perf_counter() - t_start

    history = {k: np.asarray(v) for k, v in hist.items()}
    history["t"] = (record_its + 1).astype(np.float64)
    history["sim_time"] = np.asarray(schedule.sim_time)[record_its]
    history["max_staleness"] = np.asarray(
        schedule.max_staleness)[record_its].astype(np.float64)
    history["host_time"] = elapsed * (record_its + 1) / n_iterations
    return RunResult(state=state, history=history)


# ---------------------------------------------------------------------------
# batched sweeps: R trajectories in one vmapped dispatch
# ---------------------------------------------------------------------------

def _build_sweep(problem: TrilevelProblem, hyper: Hyper,
                 metrics_fn: Optional[Callable], keys,
                 sweep_names: tuple, has_data: bool, init_inside: bool):
    BUILD_COUNTS["sweep"] += 1

    def one_run(st, hist, masks, sweep_vals, data, its, slots):
        prob = problem if data is None else \
            dataclasses.replace(problem, data=data)
        hyp = dataclasses.replace(
            hyper, **dict(zip(sweep_names, sweep_vals))) \
            if sweep_names else hyper
        step_body = _make_step_body(prob, hyp, metrics_fn, keys)
        (st, hist), _ = jax.lax.scan(step_body, (st, hist),
                                     (masks, its, slots))
        return st, hist

    def vmapped(st, hist, masks, sweep_vals, data, its, slots):
        return jax.vmap(
            one_run,
            in_axes=(0, 0, 0, 0, 0 if has_data else None, None, None))(
                st, hist, masks, sweep_vals, data, its, slots)

    if not init_inside:
        return jax.jit(vmapped, donate_argnums=(0, 1))

    # default-init sweeps build the stacked initial state inside the
    # compiled dispatch (masks carries R statically) — the ~60 tiny
    # init_state + tile host dispatches otherwise dominate the whole
    # warm sweep at quickstart scale.
    def sweep_all(hist, masks, sweep_vals, data, its, slots):
        st0 = afto_lib.init_state(problem, hyper)
        st = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], masks.shape[:1] + x.shape).astype(x.dtype), st0)
        return vmapped(st, hist, masks, sweep_vals, data, its, slots)

    return jax.jit(sweep_all, donate_argnums=(0,))


def run_swept(problem: TrilevelProblem, hyper: Hyper,
              schedules: Sequence[Schedule],
              metrics_fn: Optional[Callable] = None,
              metrics_every: int = 10,
              states: Optional[AFTOState] = None,
              data=None,
              sweep_hypers: Optional[Dict] = None) -> SweepResult:
    """Run R = len(schedules) whole trajectories in ONE vmapped dispatch.

    The scan body of `run_scanned` is `jax.vmap`'d over a leading run
    axis: stacked initial states, stacked schedule masks, per-run data
    slices and per-run hyper scalars; the iteration/slot streams are
    shared.  All schedules must have the same length and worker count.

      states       optional stacked AFTOState ((R,)-leading leaves, e.g.
                   per-seed inits via utils.tree.tree_stack); defaults to
                   R copies of `init_state`.  Copied internally — the
                   dispatch donates its own buffers, never the caller's.
      data         optional replacement for `problem.data` with a
                   leading (R,) axis per leaf (per-seed datasets).
      sweep_hypers dict of Hyper field name -> (R,) values, threaded
                   into the traced step per run.  Shape-determining
                   fields (n_workers/p_max/k_inner/d1) stay static and
                   cannot be swept.  Sweeping t_pre/t1 is allowed but
                   costs: the refresh predicate becomes per-run, the
                   vmapped `lax.cond` lowers to a select, and the full
                   `cut_refresh` (inner rollouts + second-order grads)
                   executes every iteration for every run — correct
                   results, single-run-engine perf lost.

    History layout: per-run keys (gap_sq, n_cuts_*, sim_time,
    max_staleness, host_time, metrics_fn keys) are (R, n_records)
    arrays; "t" is shared (n_records,).  `host_time` is an
    elapsed/R-proration: the single dispatch interleaves all R
    trajectories, so per-run host seconds do not exist — each run is
    charged an equal 1/R share of the dispatch wall-clock, prorated
    over iterations exactly like the single-run engine.
    """
    schedules = list(schedules)
    if not schedules:
        raise ValueError("run_swept needs at least one schedule")
    n_runs = len(schedules)
    n_iterations = schedules[0].n_iterations
    for s in schedules[1:]:
        if (s.n_iterations, s.n_workers) != (n_iterations,
                                             schedules[0].n_workers):
            raise ValueError(
                "all swept schedules must share n_iterations/n_workers")

    sweep_hypers = dict(sweep_hypers or {})
    field_names = {f.name for f in dataclasses.fields(Hyper)}
    for name in sweep_hypers:
        if name not in field_names:
            raise ValueError(f"unknown hyper field {name!r}")
        if name in _STATIC_HYPER_FIELDS:
            raise ValueError(
                f"hyper field {name!r} is shape-determining and cannot "
                "be swept; run separate sweeps instead")
    sweep_names = tuple(sorted(sweep_hypers))
    sweep_vals = tuple(jnp.asarray(sweep_hypers[k]) for k in sweep_names)
    for name, v in zip(sweep_names, sweep_vals):
        if v.shape != (n_runs,):
            raise ValueError(
                f"sweep_hypers[{name!r}] must have shape ({n_runs},), "
                f"got {v.shape}")

    init_inside = states is None
    if not init_inside:
        # private copy: the swept dispatch donates its inputs
        states = jax.tree.map(jnp.array, states)
    if data is not None:
        data = jax.tree.map(jnp.asarray, data)
        for leaf in jax.tree.leaves(data):
            if leaf.shape[:1] != (n_runs,):
                raise ValueError(
                    "swept data leaves need a leading (R,) axis")

    record_its, slots = record_slots(n_iterations, metrics_every)
    n_records = len(record_its)
    if metrics_fn is None:
        state_one = None           # _metric_keys won't trace anything
    elif init_inside:
        state_one = jax.eval_shape(
            lambda: afto_lib.init_state(problem, hyper))
    else:
        state_one = jax.tree.map(lambda x: x[0], states)
    keys = _metric_keys(problem, hyper, metrics_fn, state_one)

    cache_key = (id(problem), id(metrics_fn), _hyper_key(hyper),
                 sweep_names, data is not None, init_inside, n_runs,
                 n_iterations, metrics_every)
    fn = _cached_build(
        _SWEEP_CACHE, cache_key,
        lambda: _build_sweep(problem, hyper, metrics_fn, keys, sweep_names,
                             data is not None, init_inside),
        (problem, metrics_fn))

    hist0 = {k: jnp.zeros((n_runs, n_records), jnp.float32) for k in keys}
    masks = jnp.asarray(
        np.stack([s.active for s in schedules]), jnp.float32)
    its = jnp.arange(n_iterations, dtype=jnp.int32)

    t_start = time.perf_counter()
    if init_inside:
        state, hist = fn(hist0, masks, sweep_vals, data, its,
                         jnp.asarray(slots))
    else:
        state, hist = fn(states, hist0, masks, sweep_vals, data, its,
                         jnp.asarray(slots))
    jax.block_until_ready(state)
    elapsed = time.perf_counter() - t_start

    history = {k: np.asarray(v) for k, v in hist.items()}
    history["t"] = (record_its + 1).astype(np.float64)
    history["sim_time"] = np.stack(
        [np.asarray(s.sim_time)[record_its] for s in schedules])
    history["max_staleness"] = np.stack(
        [np.asarray(s.max_staleness)[record_its].astype(np.float64)
         for s in schedules])
    # one dispatch covers R trajectories: charge each run elapsed/R
    # (an approximation — the runs execute interleaved, not serially).
    history["host_time"] = np.broadcast_to(
        (elapsed / n_runs) * (record_its + 1) / n_iterations,
        (n_runs, n_records)).copy()
    return SweepResult(state=state, history=history)
