"""AFTO: the asynchronous federated master-worker iteration (Alg. 1).

One `afto_step` is Eqs. 16-21 at a given active-worker mask; `cut_refresh`
is the T_pre-periodic hyper-polytope update (Eqs. 23-25).  Both are pure,
jit-able functions of (state, mask); asynchrony (who is active when, and
what simulated wall-clock each iteration costs) lives in
`repro.core.scheduler` on the host.

Both polytopes live in `AFTOState` as canonical `FlatCuts` (one dense
(P, D) matrix each): every cut contraction in the step reads the stored
matrix directly, and `cut_refresh` writes the two new cuts as single
rows — nothing here calls `flat_spec`/`flatten_cuts`, so the scanned
trajectory never re-materializes the operator from block trees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import inner as inner_lib
from repro.core import lagrangian as lag
from repro.core.types import (AFTOState, Hyper, InnerState2,
                              InnerState3, StaleView, TrilevelProblem)
from repro.utils.tree import (tree_axpy, tree_sub, tree_zeros_like)


# ---------------------------------------------------------------------------
# projections (Eq. 20/21)
# ---------------------------------------------------------------------------

def proj_lambda(lam, hyper: Hyper):
    return jnp.clip(lam, 0.0, jnp.sqrt(hyper.alpha4))


def proj_theta(theta, hyper: Hyper):
    r = jnp.sqrt(hyper.alpha5) / hyper.d1
    return jax.tree.map(lambda th: jnp.clip(th, -r, r), theta)


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def _stack_n(tpl, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape)
                        .astype(x.dtype), tpl)


def init_state(problem: TrilevelProblem, hyper: Hyper) -> AFTOState:
    n, p = hyper.n_workers, hyper.p_max
    z1, z2, z3 = problem.x1_init, problem.x2_init, problem.x3_init
    X1, X2, X3 = (_stack_n(z1, n), _stack_n(z2, n), _stack_n(z3, n))
    theta = tree_zeros_like(X1)
    cuts_i = cuts_lib.empty_cuts(p, n, z1, z2, z3)
    cuts_ii = cuts_lib.empty_cuts(p, n, z1, z2, z3)
    inner3 = InnerState3(x3=X3, z3=z3, phi=tree_zeros_like(X3))
    inner2 = InnerState2(x2=X2, z2=z2, phi=tree_zeros_like(X2),
                         s=jnp.zeros((p,), jnp.float32),
                         gamma=jnp.zeros((p,), jnp.float32))
    stale = StaleView(z1=_stack_n(z1, n), z2=_stack_n(z2, n),
                      z3=_stack_n(z3, n),
                      lam=jnp.zeros((n, p), jnp.float32),
                      theta=tree_zeros_like(X1),
                      t_hat=jnp.zeros((n,), jnp.int32))
    return AFTOState(X1=X1, X2=X2, X3=X3, z1=z1, z2=z2, z3=z3,
                     theta=theta, lam=jnp.zeros((p,), jnp.float32),
                     cuts_i=cuts_i, cuts_ii=cuts_ii,
                     gamma_k=jnp.zeros((p,), jnp.float32),
                     inner3=inner3, inner2=inner2, stale=stale,
                     t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# one master iteration (Eqs. 16-21)
# ---------------------------------------------------------------------------

def afto_step(problem: TrilevelProblem, hyper: Hyper, state: AFTOState,
              active, axis: str = None) -> AFTOState:
    """Eq. 16 (masked worker updates at stale views) + Eqs. 17-21 (master).

    active: (N,) {0,1} float mask of workers whose update arrives now.
    """
    return afto_step_aux(problem, hyper, state, active, axis=axis)[0]


def local_f1_grads(problem: TrilevelProblem, X1, X2, X3) -> Tuple:
    """The data-dependent worker gradients of Eq. 16: grad f1(data_j, .)
    at each worker's local point, stacked over the leading worker axis.

    This is THE federated payload of one master iteration — everything
    else in `afto_step` (the stale-dual corrections, the master z/dual
    updates) is cheap cut/consensus algebra the master applies itself.
    The async runtime (`repro.fed.runtime`) has each worker process
    compute its own row of this stack at its own pace and push it to
    the master, which completes the step via `afto_step_from_grads`.
    """
    def f1_grads(data_j, x1_j, x2_j, x3_j):
        return jax.grad(
            lambda a, b, c: problem.f1(data_j, a, b, c),
            argnums=(0, 1, 2))(x1_j, x2_j, x3_j)

    return jax.vmap(f1_grads)(problem.data, X1, X2, X3)


def afto_step_aux(problem: TrilevelProblem, hyper: Hyper, state: AFTOState,
                  active, axis: str = None) -> Tuple[AFTOState, dict]:
    """`afto_step` plus the step's cut-algebra intermediates.

    The returned aux dict carries the flattened II-polytope operator and
    the cut values at the *post-step* point — exactly the products the
    stationarity gap needs at record iterations, so the compiled engine
    can fuse the gap into its record branch without recomputing them
    (`repro.core.stationarity.stationarity_gap_sq(aux=...)`).  Valid only
    while the polytope is unchanged (i.e. before any `cut_refresh`).

    axis, when set, is the worker mesh axis of a `shard_map`'d trajectory
    (`repro.core.sharded`): `state`/`problem.data`/`active` then carry
    only this shard's workers, the polytopes hold the local b-columns,
    and the ONLY cross-shard traffic is the cut-scalar psum and the
    theta-sum feeding the master z1 update — every Eq. 16 worker
    contraction stays shard-local.
    """
    # ---- workers (Eq. 16): gradients of \hat L_p at each worker's stale view
    g1_f, g2_f, g3_f = local_f1_grads(problem, state.X1, state.X2, state.X3)
    return afto_step_from_grads(problem, hyper, state, active,
                                (g1_f, g2_f, g3_f), axis=axis)


def afto_step_from_grads(problem: TrilevelProblem, hyper: Hyper,
                         state: AFTOState, active, f1_grads,
                         axis: str = None) -> Tuple[AFTOState, dict]:
    """The master half of Eq. 16-21 given precomputed worker f1-grads.

    `f1_grads` is the `(g1_f, g2_f, g3_f)` stack triple of
    `local_f1_grads`; rows of inactive workers are masked out and may
    hold anything finite (the async master zero-fills them).  With
    `f1_grads = local_f1_grads(problem, X1, X2, X3)` this is exactly
    `afto_step_aux` — the split exists so a runtime master can apply
    worker-pushed gradients stale without recomputing them.
    """
    t = state.t
    g1_f, g2_f, g3_f = f1_grads

    # consensus dual term (stale own theta) and cut terms (stale lambda):
    # the per-worker b-block sums are column slices of the canonical
    # (P, D) matrix contracted with the (N, P) stale weight table.
    g1 = jax.tree.map(jnp.add, g1_f, state.stale.theta)
    g2 = jax.tree.map(jnp.add, g2_f,
                      cuts_lib.cut_coeff_per_worker(
                          state.cuts_ii, state.stale.lam, "b2"))
    g3 = jax.tree.map(jnp.add, g3_f,
                      cuts_lib.cut_coeff_per_worker(
                          state.cuts_ii, state.stale.lam, "b3"))

    def masked_step(X, g, eta):
        return jax.tree.map(
            lambda x, gg: x - eta * _bmask(active, x) * gg, X, g)

    X1 = masked_step(state.X1, g1, hyper.eta_x)
    X2 = masked_step(state.X2, g2, hyper.eta_x)
    X3 = masked_step(state.X3, g3, hyper.eta_x)

    # ---- master Gauss-Seidel primal updates (Eqs. 17-19)
    # The canonical (P, D) operator serves the whole master step AS
    # STORED: the a-block gradients for z1/z2/z3 all come out of a
    # single w @ A mat-vec, and the same matrix feeds the cut_eval
    # kernel below — no per-step re-flatten.
    lam_a = state.lam * state.cuts_ii.active
    spec = state.cuts_ii.spec
    a_flat = state.cuts_ii.a
    ga1, ga2, ga3, _, _ = cuts_lib.cut_weighted_coeff_flat(
        spec, a_flat, lam_a)

    theta_sum = jax.tree.map(lambda th: jnp.sum(th, axis=0), state.theta)
    if axis is not None:
        theta_sum = jax.lax.psum(theta_sum, axis)
    gz1 = tree_axpy(-1.0, theta_sum, ga1)
    z1 = tree_axpy(-hyper.eta_z, gz1, state.z1)
    z2 = tree_axpy(-hyper.eta_z, ga2, state.z2)
    z3 = tree_axpy(-hyper.eta_z, ga3, state.z3)

    # ---- dual updates with projection (Eqs. 20/21)
    if axis is None:
        cutval = cuts_lib.eval_cuts_flat(
            a_flat, cuts_lib.flatten_point(spec, z1, z2, z3, X2, X3),
            state.cuts_ii.c, state.cuts_ii.active)
    else:
        cutval = cuts_lib.eval_cuts_worker_split(
            state.cuts_ii, z1, z2, z3, X2, X3, axis)
    lam = proj_lambda(
        state.lam + hyper.eta_lambda * (cutval - hyper.c1(t) * state.lam),
        hyper) * state.cuts_ii.active

    def theta_step(th_j, x1_j):
        g = tree_sub(x1_j, z1)
        return jax.tree.map(
            lambda t0, gg: t0 + hyper.eta_theta * (gg - hyper.c2(t) * t0),
            th_j, g)

    theta = proj_theta(jax.vmap(theta_step)(state.theta, X1), hyper)

    # ---- refresh stale views of the (now-active) workers
    def snap(stale_stack, fresh):
        return jax.tree.map(
            lambda s, f: jnp.where(
                _bmask(active, s) > 0,
                jnp.broadcast_to(f[None], s.shape).astype(s.dtype), s),
            stale_stack, fresh)

    stale = StaleView(
        z1=snap(state.stale.z1, z1),
        z2=snap(state.stale.z2, z2),
        z3=snap(state.stale.z3, z3),
        lam=jnp.where(active[:, None] > 0, lam[None, :], state.stale.lam),
        theta=jax.tree.map(
            lambda s, f: jnp.where(_bmask(active, s) > 0, f, s),
            state.stale.theta, theta),
        t_hat=jnp.where(active > 0, t + 1, state.stale.t_hat),
    )

    new_state = dataclasses.replace(
        state, X1=X1, X2=X2, X3=X3, z1=z1, z2=z2, z3=z3,
        theta=theta, lam=lam, stale=stale, t=t + 1)
    return new_state, {"flat_ii": a_flat, "cutval": cutval}


def _bmask(active, x):
    """Broadcast the (N,) mask against a leaf with leading worker axis."""
    return active.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)


# ---------------------------------------------------------------------------
# cut refresh (Eqs. 23-25, Alg. 1 middle block)
# ---------------------------------------------------------------------------

def cut_refresh(problem: TrilevelProblem, hyper: Hyper,
                state: AFTOState) -> AFTOState:
    """Generate one I-layer and one II-layer mu-cut at the current point,
    then drop inactive cuts.  Runs every t_pre master iterations, t < t1.

    Each `add_cut` is one row write into the canonical (P, D) matrix
    (only the NEW cut's coefficient dict is flattened); the drop rule is
    a row mask — the block trees are never materialized here, so the
    refresh runs inside the scan without touching `flat_spec`."""
    t = state.t

    # warm-start the inner states at the current outer point (duals kept)
    inner3 = InnerState3(x3=state.X3, z3=state.z3, phi=state.inner3.phi)

    # ---- I-layer cut (Eq. 23) at (X3, z1, z2, z3)
    hi_fn = lambda X3, z3, z1, z2: inner_lib.h_i(
        problem, hyper, X3, z3, z1, z2, inner3)
    h0_i, grads_i = jax.value_and_grad(hi_fn, argnums=(0, 1, 2, 3))(
        state.X3, state.z3, state.z1, state.z2)
    gX3, gz3, gz1, gz2 = grads_i
    # derivation-correct bound (see cuts.py docstring): a1 + a2 + (N+1) a3
    bound_i = hyper.alpha1 + hyper.alpha2 + (hyper.n_workers + 1) * hyper.alpha3
    coeffs_i, c_i = cuts_lib.make_cut(
        h0_i,
        {"a1": gz1, "a2": gz2, "a3": gz3, "b3": gX3},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3, "b3": state.X3},
        hyper.eps_i, hyper.mu_i, bound_i)
    cuts_i = cuts_lib.add_cut(state.cuts_i, coeffs_i, c_i, t)

    # ---- level-2 rollout under the updated I-polytope (for h_II and the
    #      gamma-based drop rule)
    inner2 = InnerState2(x2=state.X2, z2=state.z2, phi=state.inner2.phi,
                         s=state.inner2.s * cuts_i.active,
                         gamma=state.inner2.gamma * cuts_i.active)

    # ---- II-layer cut (Eq. 24) at (X2, X3, z1, z2, z3)
    hii_fn = lambda X2, z2, z1, z3, X3: inner_lib.h_ii(
        problem, hyper, X2, z2, z1, z3, X3, cuts_i, inner2)
    h0_ii, grads_ii = jax.value_and_grad(hii_fn, argnums=(0, 1, 2, 3, 4))(
        state.X2, state.z2, state.z1, state.z3, state.X3)
    gX2, gz2b, gz1b, gz3b, gX3b = grads_ii
    bound_ii = hyper.alpha1 + (hyper.n_workers + 1) * (hyper.alpha2
                                                       + hyper.alpha3)
    coeffs_ii, c_ii = cuts_lib.make_cut(
        h0_ii,
        {"a1": gz1b, "a2": gz2b, "a3": gz3b, "b2": gX2, "b3": gX3b},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3,
         "b2": state.X2, "b3": state.X3},
        hyper.eps_ii, hyper.mu_ii, bound_ii)
    cuts_ii = cuts_lib.add_cut(state.cuts_ii, coeffs_ii, c_ii, t)

    # run the inner-2 rollout once to obtain gamma^K for the drop rule
    inner2_k = inner_lib.rollout2(problem, hyper, state.z1, state.z3,
                                  state.X3, cuts_i, inner2)
    gamma_k = inner2_k.gamma

    # ---- drop inactive cuts (Eq. 25); never drop the cut just added
    fresh_i = (cuts_i.age == t).astype(jnp.float32)
    cuts_i = cuts_lib.drop_inactive(cuts_i, gamma_k + fresh_i)
    fresh_ii = (cuts_ii.age == t).astype(jnp.float32)
    cuts_ii = cuts_lib.drop_inactive(cuts_ii, state.lam + fresh_ii)

    lam = state.lam * cuts_ii.active
    inner3_k = inner_lib.rollout3(problem, hyper, state.z1, state.z2, inner3)

    return dataclasses.replace(
        state, cuts_i=cuts_i, cuts_ii=cuts_ii, lam=lam, gamma_k=gamma_k,
        inner3=inner3_k, inner2=inner2_k)
