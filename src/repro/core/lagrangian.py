"""Augmented / regularized Lagrangians (paper Eqs. 4, 11, 14, 15).

The hyper-polyhedral cut terms in `l_p2` / `l_p` contract the canonical
`FlatCuts` (P, D) matrix directly (`cuts.eval_cuts` assembles only the
point vector), so they stay one wide mat-vec on the hot path and remain
differentiable through the inner ADMM rollouts — including the Eq.
23/24 grad-of-grad at cut refresh, which since the `kernels.cut_ad`
primitive closure runs on the Pallas kernels on TPU instead of forcing
the jnp fallback.  The `CutSet` block-tree view is accepted too at the
compatibility boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core.types import (AFTOState, FlatCuts, Hyper, InnerState2,
                              InnerState3, TrilevelProblem)
from repro.utils.tree import tree_dot, tree_norm_sq, tree_sub


# ---------------------------------------------------------------------------
# Level-3 augmented Lagrangian (Eq. 4)
# ---------------------------------------------------------------------------

def l_p3(problem: TrilevelProblem, hyper: Hyper, z1, z2, st: InnerState3):
    """sum_j f3_j(z1, z2', x3_j') + <phi_j, x3_j'-z3'> + kappa3/2 ||.||^2."""
    def per_worker(data_j, x3_j, phi_j):
        f = problem.f3(data_j, z1, z2, x3_j)
        r = tree_sub(x3_j, st.z3)
        return f + tree_dot(phi_j, r) + 0.5 * hyper.kappa3 * tree_norm_sq(r)

    vals = jax.vmap(per_worker)(problem.data, st.x3, st.phi)
    return jnp.sum(vals)


# ---------------------------------------------------------------------------
# Level-2 augmented Lagrangian with I-layer cut terms (Eq. 11)
# ---------------------------------------------------------------------------

def l_p2_base(problem: TrilevelProblem, hyper: Hyper, z1, z3, X3,
              st: InnerState2):
    """The cut-free part of Eq. 11: sum_j f2_j + consensus terms.

    Split out so the fused inner round (`inner.rollout2` with
    `hyper.use_fused_inner`) can take the Eq. 5/6 gradients of the small
    per-worker/consensus algebra in XLA while the (P, D) cut terms run
    inside the fused Pallas round kernel.  `l_p2 = l_p2_base + cut
    terms` exactly (the cut terms are independent of x2, so x2
    gradients of the two forms are identical)."""
    def per_worker(data_j, x2_j, phi_j, x3_j):
        f = problem.f2(data_j, z1, x2_j, x3_j)
        r = tree_sub(x2_j, st.z2)
        return f + tree_dot(phi_j, r) + 0.5 * hyper.kappa2 * tree_norm_sq(r)

    vals = jax.vmap(per_worker)(problem.data, st.x2, st.phi, X3)
    return jnp.sum(vals)


def l_p2(problem: TrilevelProblem, hyper: Hyper, z1, z3, X3,
         cuts_i: FlatCuts, st: InnerState2):
    """sum_j f2_j + consensus terms + gamma/rho2 terms over the I-polytope.

    The I-layer cut value is evaluated at (X3, z1, z2'=st.z2, z3): the cut's
    a2-block multiplies the *inner* consensus variable z2' while X3/z3 come
    from the outer iteration (see Eq. 11's hat-h_{I,l} arguments).
    """
    total = l_p2_base(problem, hyper, z1, z3, X3, st)

    cutval = cuts_lib.eval_cuts(cuts_i, z1, st.z2, z3, X2=None, X3=X3)
    viol = (cutval + st.s) * cuts_i.active
    total = total + jnp.sum(st.gamma * viol) \
        + 0.5 * hyper.rho2 * jnp.sum(viol ** 2)
    return total


# ---------------------------------------------------------------------------
# Top-level Lagrangian over the hyper-polyhedral problem (Eq. 14/15)
# ---------------------------------------------------------------------------

def l_p(problem: TrilevelProblem, state_vars, cuts_ii: FlatCuts, lam, theta):
    """L_p (Eq. 14) at explicit variables.

    state_vars = (X1, X2, X3, z1, z2, z3); theta is stacked (N, ...).
    """
    X1, X2, X3, z1, z2, z3 = state_vars
    f1_sum = problem.sum_f(problem.f1, X1, X2, X3)

    def cons(theta_j, x1_j):
        return tree_dot(theta_j, tree_sub(x1_j, z1))
    cons_sum = jnp.sum(jax.vmap(cons)(theta, X1))

    cutval = cuts_lib.eval_cuts(cuts_ii, z1, z2, z3, X2=X2, X3=X3)
    return f1_sum + cons_sum + jnp.sum(lam * cutval)


def l_p_hat(problem: TrilevelProblem, hyper: Hyper, t, state_vars,
            cuts_ii: FlatCuts, lam, theta):
    """Regularized Lagrangian (Eq. 15)."""
    base = l_p(problem, state_vars, cuts_ii, lam, theta)
    reg_lam = 0.5 * hyper.c1(t) * jnp.sum((lam * cuts_ii.active) ** 2)

    def th_sq(theta_j):
        return tree_norm_sq(theta_j)
    reg_th = 0.5 * hyper.c2(t) * jnp.sum(jax.vmap(th_sq)(theta))
    return base - reg_lam - reg_th
