"""Host loop: schedule active sets, step, refresh cuts, record history."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import afto as afto_lib
from repro.core import stationarity as stat_lib
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.core.types import AFTOState, Hyper, TrilevelProblem


@dataclasses.dataclass
class RunResult:
    state: AFTOState
    history: Dict[str, List[float]]


def run(problem: TrilevelProblem, hyper: Hyper,
        scheduler_cfg: Optional[StragglerConfig] = None,
        n_iterations: int = 200,
        metrics_fn: Optional[Callable] = None,
        metrics_every: int = 10,
        state: Optional[AFTOState] = None,
        jit: bool = True) -> RunResult:
    """Run AFTO for `n_iterations` master iterations.

    metrics_fn(state) -> dict of scalars, evaluated every `metrics_every`
    iterations; simulated wall-clock (scheduler) and host wall-clock are
    always recorded.
    """
    if scheduler_cfg is None:
        scheduler_cfg = StragglerConfig(
            n_workers=hyper.n_workers, s_active=hyper.s_active,
            tau=hyper.tau)
    sched = StragglerScheduler(scheduler_cfg)

    step = afto_lib.afto_step
    refresh = afto_lib.cut_refresh
    gap = stat_lib.stationarity_gap_sq
    if jit:
        step = jax.jit(lambda s, m: afto_lib.afto_step(problem, hyper, s, m))
        refresh = jax.jit(lambda s: afto_lib.cut_refresh(problem, hyper, s))
        gap = jax.jit(lambda s: stat_lib.stationarity_gap_sq(
            problem, hyper, s))
    else:
        step = lambda s, m: afto_lib.afto_step(problem, hyper, s, m)
        refresh = lambda s: afto_lib.cut_refresh(problem, hyper, s)
        gap = lambda s: stat_lib.stationarity_gap_sq(problem, hyper, s)

    if state is None:
        state = afto_lib.init_state(problem, hyper)

    hist: Dict[str, List[float]] = {
        "t": [], "sim_time": [], "host_time": [], "gap_sq": [],
        "n_cuts_i": [], "n_cuts_ii": [], "max_staleness": []}
    t_start = time.perf_counter()

    for it in range(n_iterations):
        mask, sim_t = sched.next_active()
        state = step(state, jnp.asarray(mask))
        if (it + 1) % hyper.t_pre == 0 and it < hyper.t1:
            state = refresh(state)

        if (it + 1) % metrics_every == 0 or it == n_iterations - 1:
            hist["t"].append(it + 1)
            hist["sim_time"].append(float(sim_t))
            hist["host_time"].append(time.perf_counter() - t_start)
            hist["gap_sq"].append(float(gap(state)))
            hist["n_cuts_i"].append(float(jnp.sum(state.cuts_i.active)))
            hist["n_cuts_ii"].append(float(jnp.sum(state.cuts_ii.active)))
            hist["max_staleness"].append(float(sched.max_staleness()))
            if metrics_fn is not None:
                for k, v in metrics_fn(state).items():
                    hist.setdefault(k, []).append(float(v))

    return RunResult(state=state, history=hist)
