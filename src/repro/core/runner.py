"""Trajectory dispatcher behind the unified `RunSpec` API.

`RunSpec` is THE run configuration: problem, hyper, engine selection,
arrival schedule, data source, worker mesh and chunking in one frozen,
typed object.  `run(spec)` is the canonical entry; every engine hangs
off `spec.engine`:

  "scan"   (default) materialize the straggler schedule up front and
           execute the whole trajectory inside one compiled `lax.scan`
           (`repro.core.engine.run_scanned`); with `chunk_size` set the
           trajectory splits into state-continued dispatches with
           `chunk_hook` called on the live carry at chunk boundaries
           (`repro.core.engine.run_chunked`).  `metrics_fn` must be
           JAX-traceable.
  "sweep"  R whole trajectories (per-seed schedules, per-run
           data/hypers) in one vmapped dispatch
           (`repro.core.engine.run_swept`).
  "eager"  the per-iteration host loop: arbitrary host-side
           `metrics_fn` callbacks and per-iteration host timestamps.
  "async"  the REAL asynchronous federation runtime
           (`repro.fed.runtime`): a master plus `hyper.n_workers`
           worker endpoints exchanging serialized messages over a
           pluggable transport — workers compute Eq. 16 gradients at
           their own pace, the master applies them stale under the
           S-of-N / tau arrival rule and records the LIVE arrival
           process (returned as `RunResult.arrivals`).  Passing
           `schedule` replays that arrival order deterministically —
           the conformance mode that reproduces `run_scanned`.

The historical kwargs form ``run(problem, hyper, mode=..., ...)`` still
works as a thin shim (it builds a `RunSpec` and emits a
`DeprecationWarning`); new call sites should construct the spec.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import afto as afto_lib
from repro.core import engine as engine_lib
from repro.core import stationarity as stat_lib
from repro.core.engine import RunResult, SweepResult
from repro.core.scheduler import (Schedule, StragglerConfig,
                                  StragglerScheduler)
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream

ENGINES = ("scan", "sweep", "eager", "async")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run, fully specified.

    Engine-shape fields (what used to be the `run(...)` kwarg sprawl):

      problem / hyper   the trilevel problem and algorithm hypers.
      engine            "scan" | "sweep" | "eager" | "async".
      n_iterations      master iterations T (ignored when `schedule`
                        fixes the length).
      scheduler         `StragglerConfig` for the simulated arrival
                        process (defaults to hyper's N/S/tau); unused
                        by engine="async", whose arrivals are real.
      schedule          a materialized `Schedule`: the arrival order to
                        run ("scan") or to replay deterministically
                        ("async" conformance mode).
      schedules/seeds   per-run arrival processes for engine="sweep"
                        (one of them; `seeds` re-seeds `scheduler`).
      metrics_fn        extra per-record metrics; JAX-traceable except
                        on the eager loop.
      metrics_every     record stride.
      state             initial `AFTOState` (continuation runs).
      sweep_states/sweep_hypers  per-run initial states / swept hyper
                        scalars for engine="sweep".
      data              replacement `problem.data` arrays or a
                        `repro.data.stream.Stream` (in-scan synthesis);
                        for sweeps, leaves carry a leading (R,) axis.
      mesh              `jax.sharding.Mesh` with a "worker" axis: the
                        shard_map-distributed engines ("scan"/"sweep").
      jit               False drops to the un-jitted eager loop
                        (debugging).
      chunk_size        engine="scan": split the trajectory into
                        state-continued dispatches of this many
                        iterations.
      chunk_hook        `(state, t_abs) -> state | None`, called on the
                        live carry at every chunk boundary (checkpoint
                        / push-pull seam; requires `chunk_size`).
      transport         engine="async": a `repro.fed.runtime.transport`
                        hub (defaults to an in-process queue transport
                        with one thread per worker).

    Frozen: derive variants with `dataclasses.replace(spec, ...)`.
    """
    problem: TrilevelProblem
    hyper: Hyper
    engine: str = "scan"
    n_iterations: int = 200
    scheduler: Optional[StragglerConfig] = None
    schedule: Optional[Schedule] = None
    schedules: Optional[Sequence[Schedule]] = None
    seeds: Optional[Sequence[int]] = None
    metrics_fn: Optional[Callable] = None
    metrics_every: int = 10
    state: Optional[AFTOState] = None
    sweep_states: Optional[AFTOState] = None
    sweep_hypers: Optional[Mapping] = None
    data: Any = None
    mesh: Any = None
    jit: bool = True
    chunk_size: Optional[int] = None
    chunk_hook: Optional[Callable] = None
    transport: Any = None

    def resolved_scheduler(self) -> StragglerConfig:
        if self.scheduler is not None:
            return self.scheduler
        return StragglerConfig(n_workers=self.hyper.n_workers,
                               s_active=self.hyper.s_active,
                               tau=self.hyper.tau)

    def resolved_iterations(self) -> int:
        if self.schedule is not None:
            return self.schedule.n_iterations
        return self.n_iterations


_LEGACY_KWARGS = {
    "scheduler_cfg": "scheduler", "mode": "engine",
    "n_iterations": "n_iterations", "metrics_fn": "metrics_fn",
    "metrics_every": "metrics_every", "state": "state", "jit": "jit",
    "schedule": "schedule", "schedules": "schedules", "seeds": "seeds",
    "sweep_states": "sweep_states", "sweep_data": "data",
    "sweep_hypers": "sweep_hypers", "mesh": "mesh", "data": "data",
}


def spec_from_kwargs(problem: TrilevelProblem, hyper: Hyper,
                     **kwargs) -> RunSpec:
    """A `RunSpec` from the historical `run(problem, hyper, ...)` kwarg
    surface (`mode`->`engine`, `scheduler_cfg`->`scheduler`,
    `sweep_data`->`data`).  Raises on unknown kwargs and on passing both
    `data` and `sweep_data` (they were one parameter in disguise)."""
    if "data" in kwargs and kwargs.get("sweep_data") is not None \
            and kwargs["data"] is not None:
        raise ValueError(
            "pass per-run data via either `data` or `sweep_data`, "
            "not both")
    fields: Dict[str, Any] = {}
    for name, value in kwargs.items():
        new = _LEGACY_KWARGS.get(name)
        if new is None:
            raise TypeError(f"run() got an unexpected keyword argument "
                            f"{name!r}")
        if value is None and new in fields:
            continue
        if new in fields and fields[new] is not None and value is not None:
            raise ValueError(
                "pass per-run data via either `data` or `sweep_data`, "
                "not both")
        if value is not None or new not in fields:
            fields[new] = value
    return RunSpec(problem=problem, hyper=hyper, **fields)


def run(spec, hyper: Optional[Hyper] = None, **kwargs):
    """Run AFTO.  Canonical form: ``run(RunSpec(...))``.

    The legacy kwargs form ``run(problem, hyper, mode="scan", ...)``
    still works (a shim builds the spec) but is deprecated — see the
    README's kwargs->RunSpec migration table.
    """
    if isinstance(spec, RunSpec):
        if hyper is not None or kwargs:
            raise TypeError(
                "run(spec) takes no extra arguments; derive a new spec "
                "with dataclasses.replace(spec, ...)")
        return run_spec(spec)
    if hyper is None:
        raise TypeError("run(problem, hyper, ...) needs a Hyper (or pass "
                        "a RunSpec)")
    warnings.warn(
        "run(problem, hyper, mode=..., ...) kwargs are deprecated; build "
        "a repro.core.RunSpec and call run(spec) (see the README "
        "migration table)", DeprecationWarning, stacklevel=2)
    return run_spec(spec_from_kwargs(spec, hyper, **kwargs))


def run_spec(spec: RunSpec):
    """Dispatch a `RunSpec` to its engine (the canonical entry's body)."""
    problem, hyper = spec.problem, spec.hyper
    engine = spec.engine
    scheduler_cfg = spec.resolved_scheduler()
    n_iterations = spec.resolved_iterations()
    if engine not in ENGINES:
        raise ValueError(
            f"unknown mode {engine!r}; expected 'scan'|'sweep'|'eager'"
            "|'async'")
    if not spec.jit:
        if engine == "sweep":
            raise ValueError("mode='sweep' requires jit")
        if engine == "async":
            raise ValueError("mode='async' requires jit")
        engine = "eager"   # un-jitted debugging only exists on the host loop
    if spec.chunk_hook is not None and spec.chunk_size is None:
        raise ValueError("chunk_hook requires chunk_size")
    if spec.chunk_size is not None and engine != "scan":
        raise ValueError("chunk_size/chunk_hook require engine='scan'")

    if engine == "async":
        from repro.fed import runtime as runtime_lib
        if spec.mesh is not None:
            raise ValueError("mesh= requires mode='scan' or 'sweep'")
        return runtime_lib.run_async(
            problem, hyper, n_iterations=n_iterations,
            metrics_fn=spec.metrics_fn, metrics_every=spec.metrics_every,
            state=spec.state, replay=spec.schedule,
            transport=spec.transport, data=spec.data)

    if engine == "sweep":
        if spec.state is not None or spec.schedule is not None:
            raise ValueError(
                "mode='sweep' takes per-run sweep_states/schedules; the "
                "single-run state/schedule parameters would be silently "
                "ignored")
        if spec.schedules is not None and spec.seeds is not None:
            raise ValueError(
                "pass either explicit `schedules` or `seeds` (which "
                "materialize one schedule per seed), not both")
        schedules = spec.schedules
        if schedules is None:
            seed_list = list(spec.seeds) if spec.seeds is not None \
                else [scheduler_cfg.seed]
            schedules = [
                StragglerScheduler(
                    dataclasses.replace(scheduler_cfg, seed=s)
                ).precompute(n_iterations)
                for s in seed_list]
        return engine_lib.run_swept(
            problem, hyper, schedules, metrics_fn=spec.metrics_fn,
            metrics_every=spec.metrics_every, states=spec.sweep_states,
            data=spec.data, sweep_hypers=spec.sweep_hypers, mesh=spec.mesh)

    if engine == "scan":
        schedule = spec.schedule
        if schedule is None:
            schedule = StragglerScheduler(scheduler_cfg).precompute(
                n_iterations)
        if spec.chunk_size is not None:
            return engine_lib.run_chunked(
                problem, hyper, schedule, spec.chunk_size,
                chunk_hook=spec.chunk_hook, metrics_fn=spec.metrics_fn,
                metrics_every=spec.metrics_every, state=spec.state,
                mesh=spec.mesh, data=spec.data)
        return engine_lib.run_scanned(
            problem, hyper, schedule, metrics_fn=spec.metrics_fn,
            metrics_every=spec.metrics_every, state=spec.state,
            mesh=spec.mesh, data=spec.data)
    if spec.mesh is not None:
        raise ValueError("mesh= requires mode='scan' or 'sweep'")
    return _run_eager(spec, scheduler_cfg, n_iterations)


def _run_eager(spec: RunSpec, scheduler_cfg: StragglerConfig,
               n_iterations: int) -> RunResult:
    """The per-iteration host loop (engine="eager"): host `metrics_fn`
    callbacks, per-iteration host timestamps, and the host-fed reference
    the streamed engines are parity-tested against."""
    problem, hyper = spec.problem, spec.hyper
    schedule, state, data = spec.schedule, spec.state, spec.data
    metrics_every, metrics_fn = spec.metrics_every, spec.metrics_fn
    use_jit = spec.jit

    sched = StragglerScheduler(scheduler_cfg)

    stream = data if isinstance(data, Stream) else None
    if data is not None and stream is None:
        problem = dataclasses.replace(
            problem, data=jax.tree.map(jnp.asarray, data))

    def _with(d):
        return problem if d is None else dataclasses.replace(
            problem, data=d)

    step = lambda s, m, d=None: afto_lib.afto_step(_with(d), hyper, s, m)
    refresh = lambda s, d=None: afto_lib.cut_refresh(_with(d), hyper, s)
    gap = lambda s, d=None: stat_lib.stationarity_gap_sq(
        _with(d), hyper, s)
    if use_jit:
        step, refresh, gap = jax.jit(step), jax.jit(refresh), jax.jit(gap)

    if state is None:
        state = afto_lib.init_state(problem, hyper)

    hist: Dict[str, List[float]] = {
        "t": [], "sim_time": [], "host_time": [], "gap_sq": [],
        "n_cuts_i": [], "n_cuts_ii": [], "max_staleness": []}
    # afto_step increments t by exactly 1, so the absolute count is host
    # arithmetic — no per-iteration device sync for the refresh predicate
    t0_abs = int(state.t)
    t_start = time.perf_counter()

    for it in range(n_iterations):
        if schedule is not None:
            mask, sim_t = schedule.active[it], float(schedule.sim_time[it])
        else:
            mask, sim_t = sched.next_active()
        # same iteration's batch for step / refresh / gap, each worker
        # row keyed on its pre-step consumption time state.stale.t_hat —
        # exactly what the streamed scan body does
        batch = None if stream is None else \
            stream_lib.next_batch(stream, state.stale.t_hat)
        state = step(state, jnp.asarray(mask), batch)
        # refresh on the absolute post-step count (== it + 1 for fresh
        # runs), matching the engine — continued states refresh where
        # the unchunked trajectory would
        t_post = t0_abs + it + 1
        if t_post % hyper.t_pre == 0 and t_post - 1 < hyper.t1:
            state = refresh(state, batch)

        if (it + 1) % metrics_every == 0 or it == n_iterations - 1:
            hist["t"].append(it + 1)
            hist["sim_time"].append(float(sim_t))
            hist["host_time"].append(time.perf_counter() - t_start)
            hist["gap_sq"].append(float(gap(state, batch)))
            hist["n_cuts_i"].append(float(jnp.sum(state.cuts_i.active)))
            hist["n_cuts_ii"].append(float(jnp.sum(state.cuts_ii.active)))
            hist["max_staleness"].append(float(
                schedule.max_staleness[it] if schedule is not None
                else sched.max_staleness()))
            if metrics_fn is not None:
                for k, v in metrics_fn(state).items():
                    hist.setdefault(k, []).append(float(v))

    return RunResult(state=state, history=hist)
