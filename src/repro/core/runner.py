"""Trajectory dispatcher: compiled-scan engine, batched sweep, or eager
host loop.

`run(mode="scan")` (the default) materializes the straggler schedule up
front and executes the whole trajectory inside one compiled `lax.scan`
(`repro.core.engine.run_scanned`) — this is the fast path; `metrics_fn`
must be JAX-traceable.  `run(mode="sweep")` batches R trajectories
(per-seed schedules, per-run data/hypers) into one vmapped dispatch
(`repro.core.engine.run_swept`).  `run(mode="eager")` keeps the original
per-iteration host loop, which supports arbitrary host-side
`metrics_fn` callbacks and per-iteration host timestamps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import afto as afto_lib
from repro.core import engine as engine_lib
from repro.core import stationarity as stat_lib
from repro.core.engine import RunResult, SweepResult
from repro.core.scheduler import (Schedule, StragglerConfig,
                                  StragglerScheduler)
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.data import stream as stream_lib
from repro.data.stream import Stream


def run(problem: TrilevelProblem, hyper: Hyper,
        scheduler_cfg: Optional[StragglerConfig] = None,
        n_iterations: int = 200,
        metrics_fn: Optional[Callable] = None,
        metrics_every: int = 10,
        state: Optional[AFTOState] = None,
        jit: bool = True,
        mode: str = "scan",
        schedule: Optional[Schedule] = None,
        schedules: Optional[Sequence[Schedule]] = None,
        seeds: Optional[Sequence[int]] = None,
        sweep_states: Optional[AFTOState] = None,
        sweep_data=None,
        sweep_hypers: Optional[Dict] = None,
        mesh=None,
        data=None):
    """Run AFTO for `n_iterations` master iterations.

    mode="scan": one compiled `lax.scan` over a precomputed arrival
    schedule (pass `schedule` to reuse one; otherwise it is materialized
    from `scheduler_cfg`).  metrics_fn(state) -> dict of scalars must be
    jit-traceable and is evaluated inside the scan every `metrics_every`
    iterations.

    mesh (scan/sweep modes): a `jax.sharding.Mesh` with a "worker" axis
    runs the trajectory shard_map-distributed — per-worker state, data,
    schedule-mask columns and polytope b-columns partition over the
    axis; only the cut scalars and master z-reductions are psum'd (see
    `repro.core.engine.run_scanned` / `repro.core.sharded`).

    mode="sweep": R whole trajectories in one vmapped dispatch
    (returns a `SweepResult`).  Pass `schedules` (one per run), or
    `seeds` — each seed re-seeds `scheduler_cfg`'s arrival process.
    `sweep_states` / `sweep_data` / `sweep_hypers` forward to
    `engine.run_swept` for per-run initial states, per-run problem data
    and swept hyper scalars.

    mode="eager": the per-iteration host loop; metrics_fn may be an
    arbitrary host callback.  Simulated wall-clock (scheduler) and host
    wall-clock are always recorded in every mode.

    data (all modes): replacement `problem.data` arrays, or a
    `repro.data.stream.Stream` — per-iteration worker batches drawn
    from fold-in keys on the absolute `state.t` (inside the scan for
    the compiled engines; materialized per iteration on the eager
    loop, which is the host-fed reference the streamed engines are
    parity-tested against).  In sweep mode `data` and `sweep_data` are
    the same parameter (pass one of them).
    """
    if scheduler_cfg is None:
        scheduler_cfg = StragglerConfig(
            n_workers=hyper.n_workers, s_active=hyper.s_active,
            tau=hyper.tau)
    if schedule is not None:
        n_iterations = schedule.n_iterations
    if not jit:
        if mode == "sweep":
            raise ValueError("mode='sweep' requires jit")
        mode = "eager"   # un-jitted debugging only exists on the host loop

    if mode == "sweep":
        if state is not None or schedule is not None:
            raise ValueError(
                "mode='sweep' takes per-run sweep_states/schedules; the "
                "single-run state/schedule parameters would be silently "
                "ignored")
        if schedules is not None and seeds is not None:
            raise ValueError(
                "pass either explicit `schedules` or `seeds` (which "
                "materialize one schedule per seed), not both")
        if schedules is None:
            seed_list = list(seeds) if seeds is not None \
                else [scheduler_cfg.seed]
            schedules = [
                StragglerScheduler(
                    dataclasses.replace(scheduler_cfg, seed=s)
                ).precompute(n_iterations)
                for s in seed_list]
        if data is not None and sweep_data is not None:
            raise ValueError(
                "pass per-run data via either `data` or `sweep_data`, "
                "not both")
        return engine_lib.run_swept(
            problem, hyper, schedules, metrics_fn=metrics_fn,
            metrics_every=metrics_every, states=sweep_states,
            data=data if data is not None else sweep_data,
            sweep_hypers=sweep_hypers, mesh=mesh)

    if mode == "scan":
        if schedule is None:
            schedule = StragglerScheduler(scheduler_cfg).precompute(
                n_iterations)
        return engine_lib.run_scanned(
            problem, hyper, schedule, metrics_fn=metrics_fn,
            metrics_every=metrics_every, state=state, mesh=mesh,
            data=data)
    if mode != "eager":
        raise ValueError(
            f"unknown mode {mode!r}; expected 'scan'|'sweep'|'eager'")
    if mesh is not None:
        raise ValueError("mesh= requires mode='scan' or 'sweep'")

    sched = StragglerScheduler(scheduler_cfg)

    stream = data if isinstance(data, Stream) else None
    if data is not None and stream is None:
        problem = dataclasses.replace(
            problem, data=jax.tree.map(jnp.asarray, data))

    def _with(d):
        return problem if d is None else dataclasses.replace(
            problem, data=d)

    step = lambda s, m, d=None: afto_lib.afto_step(_with(d), hyper, s, m)
    refresh = lambda s, d=None: afto_lib.cut_refresh(_with(d), hyper, s)
    gap = lambda s, d=None: stat_lib.stationarity_gap_sq(
        _with(d), hyper, s)
    if jit:
        step, refresh, gap = jax.jit(step), jax.jit(refresh), jax.jit(gap)

    if state is None:
        state = afto_lib.init_state(problem, hyper)

    hist: Dict[str, List[float]] = {
        "t": [], "sim_time": [], "host_time": [], "gap_sq": [],
        "n_cuts_i": [], "n_cuts_ii": [], "max_staleness": []}
    # afto_step increments t by exactly 1, so the absolute count is host
    # arithmetic — no per-iteration device sync for the refresh predicate
    t0_abs = int(state.t)
    t_start = time.perf_counter()

    for it in range(n_iterations):
        if schedule is not None:
            mask, sim_t = schedule.active[it], float(schedule.sim_time[it])
        else:
            mask, sim_t = sched.next_active()
        # same iteration's batch for step / refresh / gap, keyed on the
        # pre-step state.t — exactly what the streamed scan body does
        batch = None if stream is None else \
            stream_lib.next_batch(stream, state.t)
        state = step(state, jnp.asarray(mask), batch)
        # refresh on the absolute post-step count (== it + 1 for fresh
        # runs), matching the engine — continued states refresh where
        # the unchunked trajectory would
        t_post = t0_abs + it + 1
        if t_post % hyper.t_pre == 0 and t_post - 1 < hyper.t1:
            state = refresh(state, batch)

        if (it + 1) % metrics_every == 0 or it == n_iterations - 1:
            hist["t"].append(it + 1)
            hist["sim_time"].append(float(sim_t))
            hist["host_time"].append(time.perf_counter() - t_start)
            hist["gap_sq"].append(float(gap(state, batch)))
            hist["n_cuts_i"].append(float(jnp.sum(state.cuts_i.active)))
            hist["n_cuts_ii"].append(float(jnp.sum(state.cuts_ii.active)))
            hist["max_staleness"].append(float(
                schedule.max_staleness[it] if schedule is not None
                else sched.max_staleness()))
            if metrics_fn is not None:
                for k, v in metrics_fn(state).items():
                    hist.setdefault(k, []).append(float(v))

    return RunResult(state=state, history=hist)
