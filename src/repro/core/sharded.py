"""Worker-mesh sharded AFTO: the cut refresh (Eqs. 23-25) under shard_map.

The trajectory engine shards the federation over a mesh axis ``worker``:
each shard carries n_loc = N / n_shards workers' variable stacks
(X1/X2/X3, theta, stale views, inner duals), its own workers' slice of
``problem.data``, and a local polytope view holding the replicated
a-columns plus its workers' b-columns (`cuts.shard_cuts`).  Master
variables (z1/z2/z3, lam, cut c/active/age, t) are replicated.  The
per-iteration step then needs exactly two collectives — the cut-scalar
psum and the theta-sum psum (`afto.afto_step_aux(axis=...)`) — which is
the cut exchange the paper federates.

This module implements the remaining, harder piece: the T_pre-periodic
cut refresh.  Its inner ADMM rollouts (Eqs. 5-12) run SHARD-LOCALLY —
each round's worker updates touch only local x-stacks, and the master
z-updates reduce the per-shard gradient partials with one psum per round
(the paper's K communication rounds).  The mu-cut coefficients then need
d h_I / d(z1, z2) and d h_II / d(z1, z3, {x3_j}) THROUGH those rollouts.
jax cannot autodiff across a raw `lax.psum` on this code path (its
transpose under shard_map is another psum, which double-counts), so the
rollout VJPs are assembled by hand from shard-local `jax.vjp` calls:

  * forward rounds are split into a varying worker part, a replicated
    master part, and the psum'd aggregates that connect them;
  * the backward scan transposes each round locally and inserts the one
    collective the true adjoint requires — a psum of the cotangent
    contributions that flowed through varying (per-worker) consumption
    of replicated values;
  * inputs consumed BOTH per-worker and via replicated master algebra
    (z1 in h_II: worker objectives AND a1-columns) ride two explicit
    channels so the varying channel is psum'd and the replicated channel
    counted once.

The per-worker cut coefficients (b-blocks: 2(x_j - est_j)) and the
h-gradients w.r.t. each worker's variables stay shard-local throughout —
only z-sized gradient partials and (P,)-sized cut scalars cross the
mesh, matching the paper's communication complexity.

Everything here is validated against the single-device engine to f32
tolerance by `tests/test_sharded_engine.py` (step-by-step, across
refresh / eviction / straggler masks).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import lagrangian as lag
from repro.core.types import (AFTOState, FlatCuts, Hyper, InnerState2,
                              InnerState3, TrilevelProblem)
from repro.utils.tree import (tree_add, tree_axpy, tree_dot, tree_norm_sq,
                              tree_sub, tree_zeros_like)

WORKER_AXIS = "worker"


def _psum(x, axis):
    return jax.lax.psum(x, axis)


def _bcast(z, x):
    """Broadcast an unstacked leaf against a worker-stacked one."""
    return jnp.broadcast_to(z[None], x.shape)


# ---------------------------------------------------------------------------
# level-3 rollout (Eqs. 5-7), sharded forward + hand-assembled VJP
# ---------------------------------------------------------------------------
#
# Round decomposition (st = InnerState3(x3 local, z3 replicated, phi
# local); l_p3 over the LOCAL workers only):
#   agg  = psum( d l_p3_loc / d z3 )                     [master uplink]
#   z3'  = z3 - eta_z * agg                              [replicated]
#   x3'  = x3 - eta_x * d l_p3_loc / d x3                [shard-local]
#   phi' = phi + eta_dual * (x3' - z3')                  [shard-local]

def _l_p3_local(problem, hyper, z1, z2, x3, z3, phi):
    """Per-worker sum of Eq. 4 over THIS shard's workers: exactly
    `lagrangian.l_p3` on the local stacks, re-exposed as a plain fn of
    explicit args so jax.vjp transposes exactly the pieces we need."""
    return lag.l_p3(problem, hyper, z1, z2,
                    InnerState3(x3=x3, z3=z3, phi=phi))


def _roll3_stats(problem, hyper, z1, z2, st):
    """Shard-partial master gradient d l_p3_loc / d z3 at the OLD round
    point (Eq. 6 steps at the old worker variables)."""
    return jax.grad(lambda z3: _l_p3_local(problem, hyper, z1, z2,
                                           st.x3, z3, st.phi))(st.z3)


def _roll3_worker(problem, hyper, z1, z2, x3, z3_old, phi, z3_new):
    g_x = jax.grad(lambda x3_: _l_p3_local(problem, hyper, z1, z2,
                                           x3_, z3_old, phi))(x3)
    x3n = tree_axpy(-hyper.eta_x, g_x, x3)
    phin = jax.tree.map(
        lambda p, x, z: p + hyper.eta_dual_inner * (x - _bcast(z, x)),
        phi, x3n, z3_new)
    return x3n, phin


def rollout3_sharded_fwd(problem, hyper, z1, z2, init: InnerState3,
                         axis: str) -> Tuple[InnerState3, tuple]:
    """K sharded rounds of Eqs. 5-7.  Returns (final, residuals): the
    per-round carries PLUS the already-psum'd aggregates, so the
    backward scan transposes without re-running any forward collective
    (`traffic_record` counts on this)."""
    def round_fn(st, _):
        agg = _psum(_roll3_stats(problem, hyper, z1, z2, st), axis)
        z3n = tree_axpy(-hyper.eta_z, agg, st.z3)
        x3n, phin = _roll3_worker(problem, hyper, z1, z2, st.x3, st.z3,
                                  st.phi, z3n)
        return InnerState3(x3=x3n, z3=z3n, phi=phin), (st, agg)

    return jax.lax.scan(round_fn, init, None, length=hyper.k_inner)


def rollout3_sharded_vjp(problem, hyper, z1, z2, residuals, ct_final,
                         axis: str):
    """d(rollout3)/d(z1, z2) against `ct_final` cotangents.

    ct_final.x3/.phi are shard-local-true, ct_final.z3 replicated-true.
    Each backward round transposes the worker/master/stats pieces with
    local jax.vjp and psums exactly the cotangent mass that crossed a
    varying consumption of a replicated value.  z1/z2 enter only through
    per-worker objectives, so their accumulated cotangents take a single
    final psum."""
    az = (tree_zeros_like(z1), tree_zeros_like(z2))

    def bwd_round(ct_acc, res_r):
        st_r, agg = res_r
        ct, (az1, az2) = ct_acc
        z3n = tree_axpy(-hyper.eta_z, agg, st_r.z3)

        _, w_vjp = jax.vjp(
            lambda z1_, z2_, x3, z3_old, phi, z3_new: _roll3_worker(
                problem, hyper, z1_, z2_, x3, z3_old, phi, z3_new),
            z1, z2, st_r.x3, st_r.z3, st_r.phi, z3n)
        d_z1w, d_z2w, d_x3, d_z3old_w, d_phi, d_z3n_w = w_vjp(
            (ct.x3, ct.phi))

        # master transpose: z3' = z3 - eta_z * agg
        ct_z3n = tree_add(ct.z3, _psum(d_z3n_w, axis))
        d_z3old_m = ct_z3n
        ct_agg = jax.tree.map(lambda g: -hyper.eta_z * g, ct_z3n)

        _, s_vjp = jax.vjp(
            lambda z1_, z2_, x3, z3_old, phi: _roll3_stats(
                problem, hyper, z1_, z2_,
                InnerState3(x3=x3, z3=z3_old, phi=phi)),
            z1, z2, st_r.x3, st_r.z3, st_r.phi)
        d_z1s, d_z2s, d_x3s, d_z3old_s, d_phis = s_vjp(ct_agg)

        ct_z3_true = tree_add(
            d_z3old_m, _psum(tree_add(d_z3old_w, d_z3old_s), axis))
        ct_new = InnerState3(x3=tree_add(d_x3, d_x3s),
                             z3=ct_z3_true,
                             phi=tree_add(d_phi, d_phis))
        return (ct_new, (tree_add(az1, tree_add(d_z1w, d_z1s)),
                         tree_add(az2, tree_add(d_z2w, d_z2s)))), None

    (ct0, (az1, az2)), _ = jax.lax.scan(
        bwd_round, (ct_final, az), residuals, reverse=True)
    del ct0                                   # init is stop-gradient'd
    return _psum(az1, axis), _psum(az2, axis)


# ---------------------------------------------------------------------------
# level-2 rollout (Eq. 11), sharded forward + hand-assembled VJP
# ---------------------------------------------------------------------------
#
# Extra structure vs level 3: the I-polytope cut terms.  The cut value
# splits as  a-part(z1, z2', z3) + psum(b-part(X3_loc))  where the
# b-part is round-invariant (X3 is a rollout input), so it is ONE
# pre-aggregate `b_agg`; the a-part and the (gamma, s) multiplier
# algebra are replicated master computation with CLOSED-FORM z2
# gradients (sum_l (gamma_l + rho2 viol_l) active_l a2_l), which keeps
# every jax.grad/vjp here collective-free.

def _l_p2_worker_local(problem, hyper, z1, x2, z2, phi, X3):
    def per_worker(data_j, x2_j, phi_j, x3_j):
        f = problem.f2(data_j, z1, x2_j, x3_j)
        r = tree_sub(x2_j, z2)
        return f + tree_dot(phi_j, r) + 0.5 * hyper.kappa2 * tree_norm_sq(r)

    return jnp.sum(jax.vmap(per_worker)(problem.data, x2, phi, X3))


def _cut_b_partial(cuts_i: FlatCuts, X3):
    """This shard's b-column contribution to the I-cut values (the
    per-worker cut scalars of Eq. 11; layer-I cuts carry zero b2)."""
    return cuts_lib.b_cols_matvec(cuts_i, None, X3)


def _cut_a_values(cuts_i: FlatCuts, z1, z2, z3, b_agg):
    """Replicated cut values: a-column contraction + the psum'd b-part."""
    raw = cuts_lib.a_cols_matvec(cuts_i, z1, z2, z3) + b_agg - cuts_i.c
    return raw * cuts_i.active


def _roll2_master(hyper, cuts_i, z1, z3, b_agg, z2, s, gamma, agg1):
    """Replicated master algebra of one Eq. 11 round: z2 step (psum'd
    worker partials + closed-form cut gradient at the OLD z2), then the
    slack / cut-multiplier updates at the new z2."""
    cutval_old = _cut_a_values(cuts_i, z1, z2, z3, b_agg)
    viol_old = (cutval_old + s) * cuts_i.active
    g_cut = cuts_lib.cut_weighted_coeff(
        cuts_i, gamma + hyper.rho2 * viol_old, "a2")
    z2n = tree_axpy(-hyper.eta_z, tree_add(agg1, g_cut), z2)

    cutval = _cut_a_values(cuts_i, z1, z2n, z3, b_agg)
    g_s = (gamma + hyper.rho2 * (cutval + s)) * cuts_i.active
    sn = jnp.maximum(0.0, s - hyper.eta_s * g_s) * cuts_i.active
    gamman = jnp.maximum(
        0.0, gamma + hyper.eta_dual_inner * (cutval + sn)) * cuts_i.active
    return z2n, sn, gamman


def _roll2_stats(problem, hyper, z1, x2, z2, phi, X3):
    """Shard-partial d l_p2_worker / d z2 at the old round point."""
    return jax.grad(lambda z2_: _l_p2_worker_local(
        problem, hyper, z1, x2, z2_, phi, X3))(z2)


def _roll2_worker(problem, hyper, z1, x2, z2_old, phi, X3, z2_new):
    g_x = jax.grad(lambda x2_: _l_p2_worker_local(
        problem, hyper, z1, x2_, z2_old, phi, X3))(x2)
    x2n = tree_axpy(-hyper.eta_x, g_x, x2)
    phin = jax.tree.map(
        lambda p, x, z: p + hyper.eta_dual_inner * (x - _bcast(z, x)),
        phi, x2n, z2_new)
    return x2n, phin


def rollout2_sharded_fwd(problem, hyper, z1, z3, X3, cuts_i: FlatCuts,
                         init: InnerState2, axis: str):
    """K sharded rounds of Eq. 11.  Returns (final, residuals, b_agg) —
    residuals carry each round's state AND its psum'd agg1, so the
    backward scan re-runs no forward collective."""
    b_agg = _psum(_cut_b_partial(cuts_i, X3), axis)

    def round_fn(st, _):
        agg1 = _psum(_roll2_stats(problem, hyper, z1, st.x2, st.z2,
                                  st.phi, X3), axis)
        z2n, sn, gamman = _roll2_master(hyper, cuts_i, z1, z3, b_agg,
                                        st.z2, st.s, st.gamma, agg1)
        x2n, phin = _roll2_worker(problem, hyper, z1, st.x2, st.z2,
                                  st.phi, X3, z2n)
        return InnerState2(x2=x2n, z2=z2n, phi=phin, s=sn,
                           gamma=gamman), (st, agg1)

    final, residuals = jax.lax.scan(round_fn, init, None,
                                    length=hyper.k_inner)
    return final, residuals, b_agg


def rollout2_sharded_vjp(problem, hyper, z1, z3, X3, cuts_i, residuals,
                         b_agg, ct_final: InnerState2, axis: str):
    """d(rollout2)/d(z1, z3, X3) against `ct_final`.

    z1 is consumed per-worker (f2) AND through the replicated a1-column
    algebra, so its cotangent accumulates on two channels — the varying
    one is psum'd, the replicated one counted once.  z3 only appears in
    the a3-columns (replicated channel); X3 only in per-worker terms and
    the b-column pre-aggregate (both shard-local-true)."""
    zero_rc = (tree_zeros_like(ct_final.z2), jnp.zeros_like(ct_final.s),
               jnp.zeros_like(ct_final.gamma))
    acc0 = (tree_zeros_like(z1), tree_zeros_like(z1),   # z1 var / rep
            tree_zeros_like(z3),                        # z3 rep
            tree_zeros_like(X3),                        # X3 var
            jnp.zeros_like(b_agg))                      # b_agg rep

    def bwd_round(ct_acc, res_r):
        st_r, agg1 = res_r
        (ct_x2, ct_phi, ct_rc), (az1v, az1r, az3r, ax3, abagg) = ct_acc
        ct_z2, ct_s, ct_gamma = ct_rc

        z2n, _, _ = _roll2_master(hyper, cuts_i, z1, z3, b_agg,
                                  st_r.z2, st_r.s, st_r.gamma, agg1)

        _, w_vjp = jax.vjp(
            lambda z1_, x2, z2_old, phi, X3_, z2_new: _roll2_worker(
                problem, hyper, z1_, x2, z2_old, phi, X3_, z2_new),
            z1, st_r.x2, st_r.z2, st_r.phi, X3, z2n)
        d_z1w, d_x2, d_z2old_w, d_phi, d_x3w, d_z2n_w = w_vjp(
            (ct_x2, ct_phi))

        # master transpose (replicated computation, counted once)
        ct_z2n_true = tree_add(ct_z2, _psum(d_z2n_w, axis))
        _, m_vjp = jax.vjp(
            lambda z1_, z3_, bagg_, z2, s, gamma, agg1_: _roll2_master(
                hyper, cuts_i, z1_, z3_, bagg_, z2, s, gamma, agg1_),
            z1, z3, b_agg, st_r.z2, st_r.s, st_r.gamma, agg1)
        (d_z1m, d_z3m, d_bagg, d_z2old_m, d_s, d_gamma,
         ct_agg1) = m_vjp((ct_z2n_true, ct_s, ct_gamma))

        _, s_vjp = jax.vjp(
            lambda z1_, x2, z2, phi, X3_: _roll2_stats(
                problem, hyper, z1_, x2, z2, phi, X3_),
            z1, st_r.x2, st_r.z2, st_r.phi, X3)
        d_z1s, d_x2s, d_z2old_s, d_phis, d_x3s = s_vjp(ct_agg1)

        ct_z2_true = tree_add(
            d_z2old_m, _psum(tree_add(d_z2old_w, d_z2old_s), axis))
        ct_new = (tree_add(d_x2, d_x2s), tree_add(d_phi, d_phis),
                  (ct_z2_true, d_s, d_gamma))
        acc = (tree_add(az1v, tree_add(d_z1w, d_z1s)),
               tree_add(az1r, d_z1m),
               tree_add(az3r, d_z3m),
               tree_add(ax3, tree_add(d_x3w, d_x3s)),
               abagg + d_bagg)
        return (ct_new, acc), None

    ct0 = (ct_final.x2, ct_final.phi,
           (ct_final.z2, ct_final.s, ct_final.gamma))
    ((_, _, _), (az1v, az1r, az3r, ax3, abagg)), _ = jax.lax.scan(
        bwd_round, (ct0, acc0), residuals, reverse=True)

    # b_agg = psum(local b-contraction(X3)): the replicated cotangent
    # flows back to every shard's own columns in full.
    _, b_vjp = jax.vjp(lambda X3_: _cut_b_partial(cuts_i, X3_), X3)
    ct_x3 = tree_add(ax3, b_vjp(abagg)[0])
    ct_z1 = tree_add(_psum(az1v, axis), az1r)
    return ct_z1, az3r, ct_x3


# ---------------------------------------------------------------------------
# mu-cut constants with worker-sharded blocks
# ---------------------------------------------------------------------------

_B_KEYS = ("b2", "b3")


def make_cut_sharded(h0, grads, point, eps, mu, bound_alpha, axis):
    """`cuts.make_cut` with the b-block inner products / norms psum'd:
    a-block terms are replicated (counted once), worker-block terms are
    shard-partial."""
    gv_rep = jnp.float32(0.0)
    sq_rep = jnp.float32(0.0)
    gv_loc = jnp.float32(0.0)
    sq_loc = jnp.float32(0.0)
    for k, g in grads.items():
        if k in _B_KEYS:
            gv_loc = gv_loc + tree_dot(g, point[k])
            sq_loc = sq_loc + tree_norm_sq(point[k])
        else:
            gv_rep = gv_rep + tree_dot(g, point[k])
            sq_rep = sq_rep + tree_norm_sq(point[k])
    loc = _psum(jnp.stack([gv_loc, sq_loc]), axis)
    gv0 = gv_rep + loc[0]
    v0_sq = sq_rep + loc[1]
    c = eps + mu * (bound_alpha + v0_sq) - h0 + gv0
    return grads, c


# ---------------------------------------------------------------------------
# the sharded cut refresh (Eqs. 23-25)
# ---------------------------------------------------------------------------

def cut_refresh_sharded(problem: TrilevelProblem, hyper: Hyper,
                        state: AFTOState, axis: str = WORKER_AXIS
                        ) -> AFTOState:
    """`afto.cut_refresh` on a worker mesh: same math, f32-tolerance
    identical trajectories (property-tested against the single-device
    refresh).  `problem.data` and every stacked state leaf carry only
    this shard's workers; the polytopes are the local column views.

    The h_I / h_II gradients w.r.t. each shard's OWN worker variables
    ({x3_j} for Eq. 23, {x2_j}/{x3_j} for Eq. 24) are closed-form or
    locally-transposed — each worker computes its own b-block cut
    coefficients, which is exactly the paper's federated cut generation;
    the z-block (a-column) coefficients are reduced with psums via the
    hand-assembled rollout VJPs above."""
    t = state.t

    # warm-start the inner states at the current outer point (duals kept)
    inner3 = InnerState3(x3=state.X3, z3=state.z3, phi=state.inner3.phi)

    # ---- I-layer cut (Eq. 23) at (X3, z1, z2, z3)
    est3, res3 = rollout3_sharded_fwd(problem, hyper, state.z1, state.z2,
                                      inner3, axis)
    dx3 = tree_sub(state.X3, est3.x3)
    dz3 = tree_sub(state.z3, est3.z3)
    h0_i = _psum(tree_norm_sq(dx3), axis) + tree_norm_sq(dz3)
    gX3 = jax.tree.map(lambda d: 2.0 * d, dx3)       # local closed form
    gz3 = jax.tree.map(lambda d: 2.0 * d, dz3)       # replicated closed form
    ct3 = InnerState3(x3=jax.tree.map(lambda d: -2.0 * d, dx3),
                      z3=jax.tree.map(lambda d: -2.0 * d, dz3),
                      phi=tree_zeros_like(est3.phi))
    gz1, gz2 = rollout3_sharded_vjp(problem, hyper, state.z1, state.z2,
                                    res3, ct3, axis)

    bound_i = hyper.alpha1 + hyper.alpha2 + (hyper.n_workers + 1) * hyper.alpha3
    coeffs_i, c_i = make_cut_sharded(
        h0_i,
        {"a1": gz1, "a2": gz2, "a3": gz3, "b3": gX3},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3, "b3": state.X3},
        hyper.eps_i, hyper.mu_i, bound_i, axis)
    cuts_i = cuts_lib.add_cut(state.cuts_i, coeffs_i, c_i, t)

    # ---- level-2 rollout under the updated I-polytope
    inner2 = InnerState2(x2=state.X2, z2=state.z2, phi=state.inner2.phi,
                         s=state.inner2.s * cuts_i.active,
                         gamma=state.inner2.gamma * cuts_i.active)
    est2, res2, b_agg = rollout2_sharded_fwd(
        problem, hyper, state.z1, state.z3, state.X3, cuts_i, inner2, axis)

    # ---- II-layer cut (Eq. 24) at (X2, X3, z1, z2, z3)
    dx2 = tree_sub(state.X2, est2.x2)
    dz2 = tree_sub(state.z2, est2.z2)
    h0_ii = _psum(tree_norm_sq(dx2), axis) + tree_norm_sq(dz2)
    gX2 = jax.tree.map(lambda d: 2.0 * d, dx2)
    gz2b = jax.tree.map(lambda d: 2.0 * d, dz2)
    ct2 = InnerState2(x2=jax.tree.map(lambda d: -2.0 * d, dx2),
                      z2=jax.tree.map(lambda d: -2.0 * d, dz2),
                      phi=tree_zeros_like(est2.phi),
                      s=jnp.zeros_like(est2.s),
                      gamma=jnp.zeros_like(est2.gamma))
    gz1b, gz3b, gX3b = rollout2_sharded_vjp(
        problem, hyper, state.z1, state.z3, state.X3, cuts_i, res2,
        b_agg, ct2, axis)

    bound_ii = hyper.alpha1 + (hyper.n_workers + 1) * (hyper.alpha2
                                                       + hyper.alpha3)
    coeffs_ii, c_ii = make_cut_sharded(
        h0_ii,
        {"a1": gz1b, "a2": gz2b, "a3": gz3b, "b2": gX2, "b3": gX3b},
        {"a1": state.z1, "a2": state.z2, "a3": state.z3,
         "b2": state.X2, "b3": state.X3},
        hyper.eps_ii, hyper.mu_ii, bound_ii, axis)
    cuts_ii = cuts_lib.add_cut(state.cuts_ii, coeffs_ii, c_ii, t)

    # the warm-started rollouts above ARE Eq. 8/12's inner estimates; the
    # single-device refresh recomputes them via CSE-merged second calls.
    gamma_k = est2.gamma

    # ---- drop inactive cuts (Eq. 25); never drop the cut just added
    fresh_i = (cuts_i.age == t).astype(jnp.float32)
    cuts_i = cuts_lib.drop_inactive(cuts_i, gamma_k + fresh_i)
    fresh_ii = (cuts_ii.age == t).astype(jnp.float32)
    cuts_ii = cuts_lib.drop_inactive(cuts_ii, state.lam + fresh_ii)

    lam = state.lam * cuts_ii.active
    return dataclasses.replace(
        state, cuts_i=cuts_i, cuts_ii=cuts_ii, lam=lam, gamma_k=gamma_k,
        inner3=est3, inner2=est2)


# ---------------------------------------------------------------------------
# communication accounting (per-step bytes the mesh actually exchanges)
# ---------------------------------------------------------------------------

def traffic_record(spec, hyper: Hyper) -> dict:
    """Analytic per-step / per-refresh all-reduce payloads in bytes (one
    logical direction, f32): an exact count of the psums the sharded
    engine performs — cut scalars, z-sized gradient partials, scalar
    norms.  Everything else (worker stacks, b-columns, data) stays
    shard-local.
    """
    na = cuts_lib.n_a_leaves(spec)
    z1 = sum(spec.sizes[:spec.nleaves[0]])
    z2 = sum(spec.sizes[spec.nleaves[0]:spec.nleaves[0]
                        + spec.nleaves[1]])
    z3 = sum(spec.sizes[spec.nleaves[0] + spec.nleaves[1]:na])
    p = hyper.p_max
    k = hyper.k_inner
    # afto_step_aux: cut-scalar psum + theta-sum psum
    step = 4 * (p + z1)
    # cut_refresh_sharded, in execution order:
    #   rollout3 fwd            k rounds x z3-sized agg
    #   rollout3 vjp            k rounds x 2 z3-sized ct psums
    #                           + final z1 + z2 accumulator psums
    #   h0_i / make_cut_i       1 + 2 scalars
    #   rollout2 fwd            1 b_agg (P,) + k rounds x z2-sized agg1
    #   rollout2 vjp            k rounds x 2 z2-sized ct psums
    #                           + final z1 accumulator psum
    #   h0_ii / make_cut_ii     1 + 2 scalars
    refresh = 4 * (3 * k * z3 + 3 * k * z2 + 2 * z1 + z2 + p + 6)
    # record branch: worker-norm scalar + theta-sum (make_gap_aux adds
    # one more (P,) cut-scalar psum only when the same iteration also
    # refreshed, i.e. step's aux was invalidated)
    gap = 4 * (1 + z1)
    return {"step_bytes": step, "refresh_bytes": refresh,
            "gap_bytes": gap}
