"""mu-cut construction and hyper-polyhedral polytope maintenance.

A mu-cut (paper Eq. 23/24) linearizes a mu-weakly-convex constraint
function h(v) <= eps at the current point v0:

    h(v) >= h(v0) + <g, v - v0> - (mu/2) ||v - v0||^2          (Def. 3.2)
         >= h(v0) + <g, v - v0> - mu (||v||^2 + ||v0||^2)      (C-S bound)
         >= h(v0) + <g, v - v0> - mu (B_alpha + ||v0||^2),     (Asm. 4.4)

so h(v) <= eps implies the *linear* inequality

    <g, v>  <=  eps + mu (B_alpha + ||v0||^2) - h(v0) + <g, v0>  =: c.

NOTE on the paper's Eq. 23 constant: the printed bound is
``mu((N+1)a1 + a2 + a3 + ...)`` but the C-S/boundedness derivation over
the level-I stack ({x_{3,j}}, z1, z2', z3) gives ``a1 + a2 + (N+1)a3``
(N worker copies of x3 plus z3, one copy each of z1/z2').  We implement
the derivation; Eq. 24's printed constant matches the derivation and is
used as printed.  With mu=0 both reduce to the classical convex cut.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import CutSet
from repro.utils.tree import (tree_dot, tree_norm_sq, tree_zeros_like)


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def empty_cutset(p_max: int, n_workers: int, z1_tpl, z2_tpl, z3_tpl) -> CutSet:
    """All-zero, all-inactive polytope with (P,)/(P,N,...) stacked slots."""
    def stack_p(tpl):
        return jax.tree.map(
            lambda x: jnp.zeros((p_max,) + x.shape, x.dtype), tpl)

    def stack_pn(tpl):
        return jax.tree.map(
            lambda x: jnp.zeros((p_max, n_workers) + x.shape, x.dtype), tpl)

    return CutSet(
        a1=stack_p(z1_tpl), a2=stack_p(z2_tpl), a3=stack_p(z3_tpl),
        b2=stack_pn(z2_tpl), b3=stack_pn(z3_tpl),
        c=jnp.zeros((p_max,), jnp.float32),
        active=jnp.zeros((p_max,), jnp.float32),
        age=jnp.full((p_max,), -1, jnp.int32),
    )


def make_cut(h0, grads, point, eps, mu, bound_alpha):
    """Assemble the linear cut <g, v> <= c from h's value/grads at `point`.

    grads/point are dicts with keys from {"a1","a2","a3","b2","b3"}; missing
    blocks are treated as zero.  Returns (coeff_dict, c).
    """
    gv0 = jnp.float32(0.0)
    v0_sq = jnp.float32(0.0)
    for k, g in grads.items():
        gv0 = gv0 + tree_dot(g, point[k])
        v0_sq = v0_sq + tree_norm_sq(point[k])
    c = eps + mu * (bound_alpha + v0_sq) - h0 + gv0
    return grads, c


def add_cut(cuts: CutSet, coeffs, c, t) -> CutSet:
    """Write the cut into the first inactive slot (or evict the oldest).

    Shape-stable: slot choice is a traced argmin; missing coefficient
    blocks stay zero.
    """
    # prefer inactive slots; among active, evict the oldest.  Integer
    # scores: adding 1e9 in f32 loses the age low bits (spacing at 1e9
    # is 64) and mis-evicts — caught by the hypothesis capacity test.
    score = jnp.where(cuts.active > 0, cuts.age,
                      jnp.int32(-(2 ** 30)))
    slot = jnp.argmin(score)

    def write_block(cur, new):
        if new is None:
            return cur
        return jax.tree.map(lambda buf, g: buf.at[slot].set(g), cur, new)

    return CutSet(
        a1=write_block(cuts.a1, coeffs.get("a1")),
        a2=write_block(cuts.a2, coeffs.get("a2")),
        a3=write_block(cuts.a3, coeffs.get("a3")),
        b2=write_block(cuts.b2, coeffs.get("b2")),
        b3=write_block(cuts.b3, coeffs.get("b3")),
        c=cuts.c.at[slot].set(jnp.asarray(c, cuts.c.dtype)),
        active=cuts.active.at[slot].set(1.0),
        age=cuts.age.at[slot].set(jnp.asarray(t, jnp.int32)),
    )


def clear_slot_blocks(cuts: CutSet, slot) -> CutSet:
    """Zero all coefficient blocks of `slot` (used when evicting)."""
    def z(tree):
        return jax.tree.map(lambda buf: buf.at[slot].set(jnp.zeros_like(buf[slot])), tree)
    return CutSet(a1=z(cuts.a1), a2=z(cuts.a2), a3=z(cuts.a3),
                  b2=z(cuts.b2), b3=z(cuts.b3), c=cuts.c,
                  active=cuts.active, age=cuts.age)


def drop_inactive(cuts: CutSet, multipliers, tol: float = 1e-8) -> CutSet:
    """Eq. 25: drop cut l when its multiplier is (numerically) zero."""
    keep = (jnp.abs(multipliers) > tol).astype(cuts.active.dtype)
    return CutSet(a1=cuts.a1, a2=cuts.a2, a3=cuts.a3, b2=cuts.b2, b3=cuts.b3,
                  c=cuts.c, active=cuts.active * keep, age=cuts.age)


# ---------------------------------------------------------------------------
# flattened layout: the whole coefficient space as one (P, D) matrix
# ---------------------------------------------------------------------------
#
# The per-iteration cut algebra (eval_cuts, the Lagrangian cut terms and
# the weighted-coefficient gradients) is a handful of contractions of the
# same (P, D) operator against D-length variable vectors.  Flattening the
# five coefficient block trees (a1/a2/a3 with leading (P,), b2/b3 with
# leading (P, N)) into one contiguous f32 matrix turns all of them into
# the wide mat-vec the Pallas `cut_eval` kernel is shaped for, and makes
# the whole thing batch cleanly under the sweep vmap.  Column order is
# the jax.tree leaf order of (a1, a2, a3, b2, b3).

_BLOCK_NAMES = ("a1", "a2", "a3", "b2", "b3")


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Layout of the flattened cut coefficient space.

    Per-leaf entries run over the concatenated leaves of the five blocks
    (a1, a2, a3, b2, b3) in order; `shapes` are the *point* shapes (the
    coefficient leaf shape without its leading (P,) cut axis, so b-block
    shapes keep the worker axis).
    """
    tdefs: Tuple[Any, ...]          # one treedef per block
    nleaves: Tuple[int, ...]        # leaves per block
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]
    d_total: int


# Specs are tiny and purely shape-derived, so one cache entry per cut-set
# layout (i.e. per problem) is enough; keyed structurally so traced and
# concrete CutSets share entries.
_SPEC_CACHE: Dict[tuple, FlatSpec] = {}


def flat_spec(cuts: CutSet) -> FlatSpec:
    """The (cached) flattening spec for this CutSet's layout."""
    blocks = tuple(getattr(cuts, name) for name in _BLOCK_NAMES)
    flat = [jax.tree.flatten(b) for b in blocks]
    key = tuple(
        (tdef, tuple((l.shape, str(l.dtype)) for l in leaves))
        for leaves, tdef in flat)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        leaves = [l for ls, _ in flat for l in ls]
        shapes = tuple(l.shape[1:] for l in leaves)
        sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
        offsets = tuple(np.concatenate([[0], np.cumsum(sizes)[:-1]])
                        .astype(int)) if sizes else ()
        spec = FlatSpec(
            tdefs=tuple(tdef for _, tdef in flat),
            nleaves=tuple(len(ls) for ls, _ in flat),
            shapes=shapes,
            dtypes=tuple(l.dtype for l in leaves),
            sizes=sizes, offsets=offsets, d_total=sum(sizes))
        _SPEC_CACHE[key] = spec
    return spec


def flatten_cuts(cuts: CutSet, spec: Optional[FlatSpec] = None):
    """All coefficient blocks as one contiguous (P, D) f32 matrix.

    The reshape sizes come from `spec`, so passing a spec from a
    different layout fails loudly instead of silently misaligning
    columns."""
    if spec is None:
        spec = flat_spec(cuts)
    leaves = [l for name in _BLOCK_NAMES
              for l in jax.tree.leaves(getattr(cuts, name))]
    p = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(p, size).astype(jnp.float32)
         for l, size in zip(leaves, spec.sizes)], axis=1)


def flatten_point(spec: FlatSpec, z1, z2, z3, X2=None, X3=None):
    """The variable point (z1, z2, z3, {x2_j}, {x3_j}) as a (D,) f32
    vector in the spec's column order.  X2/X3 may be None (zero block,
    e.g. layer-I cuts carry no b2 coefficients)."""
    parts = []
    i = 0
    for b_idx, block in enumerate((z1, z2, z3, X2, X3)):
        n = spec.nleaves[b_idx]
        if block is None:
            parts.extend(jnp.zeros((spec.sizes[i + k],), jnp.float32)
                         for k in range(n))
        else:
            leaves = jax.tree.leaves(block)
            parts.extend(l.reshape(-1).astype(jnp.float32) for l in leaves)
        i += n
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)


def unflatten_coeff(spec: FlatSpec, vec):
    """Inverse of the column layout for a single (D,) vector: returns the
    (a1, a2, a3, b2, b3) block trees (point shapes, original dtypes)."""
    out = []
    i = 0
    for b_idx in range(len(_BLOCK_NAMES)):
        n = spec.nleaves[b_idx]
        leaves = [
            vec[spec.offsets[i + k]:spec.offsets[i + k] + spec.sizes[i + k]]
            .reshape(spec.shapes[i + k]).astype(spec.dtypes[i + k])
            for k in range(n)]
        out.append(jax.tree.unflatten(spec.tdefs[b_idx], leaves))
        i += n
    return tuple(out)


def eval_cuts_flat(a_flat, v_flat, c, active, impl: str = None):
    """Per-slot cut values from flattened operands: the `cut_eval`
    mat-vec  (A @ v - c) * active.  impl=None auto-routes (Mosaic kernel
    on TPU, the identical-math XLA mat-vec off-TPU — see ops.cut_eval)
    on forward-only hot paths; impl="ref" (plain jnp, transposable to
    any order) is required on differentiated paths."""
    from repro.kernels import ops
    return ops.cut_eval(a_flat, v_flat, c, active, impl=impl)


def cut_weighted_coeff_flat(spec: FlatSpec, a_flat, weights):
    """sum_l w_l * coeff_l for EVERY block at once: one (P,)x(P,D)
    mat-vec, unflattened to the (a1, a2, a3, b2, b3) block trees.  The
    b-block results keep the worker axis (N, ...), i.e. worker j's entry
    is sum_l w_l * b_{l,j}."""
    return unflatten_coeff(
        spec, weights.astype(jnp.float32) @ a_flat)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def _dot_p(stacked, v):
    """<a_l, v> for every cut slot l: stacked has leading (P,) axis."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda a, x: jnp.sum(
            a.reshape(a.shape[0], -1).astype(jnp.float32)
            * x.reshape(-1).astype(jnp.float32)[None, :], axis=-1),
        stacked, v))
    return sum(leaves) if leaves else 0.0


def _dot_pn(stacked, V):
    """sum_j <b_{l,j}, v_j>: stacked has leading (P,N) axes, V has (N,)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda b, x: jnp.einsum(
            "pnd,nd->p",
            b.reshape(b.shape[0], b.shape[1], -1).astype(jnp.float32),
            x.reshape(x.shape[0], -1).astype(jnp.float32)),
        stacked, V))
    return sum(leaves) if leaves else 0.0


def eval_cuts(cuts: CutSet, z1, z2, z3, X2=None, X3=None):
    """Per-slot cut values  <a,z> + sum_j <b,x_j> - c  (0 for inactive).

    Routed through the flattened (P, D) layout as one `cut_eval`-shaped
    mat-vec via `repro.kernels.ops.cut_eval`.  Uses the transposable
    impl="ref" route because this entry point sits inside the inner
    Lagrangians, which are differentiated to second order at cut refresh
    (see ops.cut_eval); the forward-only hot paths (afto_step, the
    stationarity gap) call `eval_cuts_flat` with the Pallas kernel.
    `eval_cuts_tree` is the tree-op reference this is tested against."""
    spec = flat_spec(cuts)
    v = flatten_point(spec, z1, z2, z3, X2, X3)
    return eval_cuts_flat(flatten_cuts(cuts, spec), v, cuts.c, cuts.active,
                          impl="ref")


def eval_cuts_tree(cuts: CutSet, z1, z2, z3, X2=None, X3=None):
    """Tree-op reference implementation of `eval_cuts` (kept for tests
    and as documentation of the per-block contraction)."""
    val = _dot_p(cuts.a1, z1) + _dot_p(cuts.a2, z2) + _dot_p(cuts.a3, z3)
    if X2 is not None:
        val = val + _dot_pn(cuts.b2, X2)
    if X3 is not None:
        val = val + _dot_pn(cuts.b3, X3)
    return (val - cuts.c) * cuts.active


def cut_weighted_coeff(cuts: CutSet, weights, block: str):
    """sum_l w_l * coeff_block_l  — the gradient of sum_l w_l * cutval_l
    w.r.t. the variable corresponding to `block` ("a1".."b3").

    For b-blocks the result keeps the worker axis (N, ...).
    """
    w = weights * cuts.active
    tree = getattr(cuts, block)
    if block.startswith("a"):
        return jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0))
            .astype(a.dtype), tree)
    return jax.tree.map(
        lambda b: jnp.tensordot(w, b.astype(jnp.float32), axes=(0, 0))
        .astype(b.dtype), tree)


def n_active(cuts: CutSet):
    return jnp.sum(cuts.active)
