"""mu-cut construction and hyper-polyhedral polytope maintenance.

A mu-cut (paper Eq. 23/24) linearizes a mu-weakly-convex constraint
function h(v) <= eps at the current point v0:

    h(v) >= h(v0) + <g, v - v0> - (mu/2) ||v - v0||^2          (Def. 3.2)
         >= h(v0) + <g, v - v0> - mu (||v||^2 + ||v0||^2)      (C-S bound)
         >= h(v0) + <g, v - v0> - mu (B_alpha + ||v0||^2),     (Asm. 4.4)

so h(v) <= eps implies the *linear* inequality

    <g, v>  <=  eps + mu (B_alpha + ||v0||^2) - h(v0) + <g, v0>  =: c.

NOTE on the paper's Eq. 23 constant: the printed bound is
``mu((N+1)a1 + a2 + a3 + ...)`` but the C-S/boundedness derivation over
the level-I stack ({x_{3,j}}, z1, z2', z3) gives ``a1 + a2 + (N+1)a3``
(N worker copies of x3 plus z3, one copy each of z1/z2').  We implement
the derivation; Eq. 24's printed constant matches the derivation and is
used as printed.  With mu=0 both reduce to the classical convex cut.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import CutSet
from repro.utils.tree import (tree_dot, tree_norm_sq, tree_zeros_like)


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def empty_cutset(p_max: int, n_workers: int, z1_tpl, z2_tpl, z3_tpl) -> CutSet:
    """All-zero, all-inactive polytope with (P,)/(P,N,...) stacked slots."""
    def stack_p(tpl):
        return jax.tree.map(
            lambda x: jnp.zeros((p_max,) + x.shape, x.dtype), tpl)

    def stack_pn(tpl):
        return jax.tree.map(
            lambda x: jnp.zeros((p_max, n_workers) + x.shape, x.dtype), tpl)

    return CutSet(
        a1=stack_p(z1_tpl), a2=stack_p(z2_tpl), a3=stack_p(z3_tpl),
        b2=stack_pn(z2_tpl), b3=stack_pn(z3_tpl),
        c=jnp.zeros((p_max,), jnp.float32),
        active=jnp.zeros((p_max,), jnp.float32),
        age=jnp.full((p_max,), -1, jnp.int32),
    )


def make_cut(h0, grads, point, eps, mu, bound_alpha):
    """Assemble the linear cut <g, v> <= c from h's value/grads at `point`.

    grads/point are dicts with keys from {"a1","a2","a3","b2","b3"}; missing
    blocks are treated as zero.  Returns (coeff_dict, c).
    """
    gv0 = jnp.float32(0.0)
    v0_sq = jnp.float32(0.0)
    for k, g in grads.items():
        gv0 = gv0 + tree_dot(g, point[k])
        v0_sq = v0_sq + tree_norm_sq(point[k])
    c = eps + mu * (bound_alpha + v0_sq) - h0 + gv0
    return grads, c


def add_cut(cuts: CutSet, coeffs, c, t) -> CutSet:
    """Write the cut into the first inactive slot (or evict the oldest).

    Shape-stable: slot choice is a traced argmin; missing coefficient
    blocks stay zero.
    """
    # prefer inactive slots; among active, evict the oldest.  Integer
    # scores: adding 1e9 in f32 loses the age low bits (spacing at 1e9
    # is 64) and mis-evicts — caught by the hypothesis capacity test.
    score = jnp.where(cuts.active > 0, cuts.age,
                      jnp.int32(-(2 ** 30)))
    slot = jnp.argmin(score)

    def write_block(cur, new):
        if new is None:
            return cur
        return jax.tree.map(lambda buf, g: buf.at[slot].set(g), cur, new)

    return CutSet(
        a1=write_block(cuts.a1, coeffs.get("a1")),
        a2=write_block(cuts.a2, coeffs.get("a2")),
        a3=write_block(cuts.a3, coeffs.get("a3")),
        b2=write_block(cuts.b2, coeffs.get("b2")),
        b3=write_block(cuts.b3, coeffs.get("b3")),
        c=cuts.c.at[slot].set(jnp.asarray(c, cuts.c.dtype)),
        active=cuts.active.at[slot].set(1.0),
        age=cuts.age.at[slot].set(jnp.asarray(t, jnp.int32)),
    )


def clear_slot_blocks(cuts: CutSet, slot) -> CutSet:
    """Zero all coefficient blocks of `slot` (used when evicting)."""
    def z(tree):
        return jax.tree.map(lambda buf: buf.at[slot].set(jnp.zeros_like(buf[slot])), tree)
    return CutSet(a1=z(cuts.a1), a2=z(cuts.a2), a3=z(cuts.a3),
                  b2=z(cuts.b2), b3=z(cuts.b3), c=cuts.c,
                  active=cuts.active, age=cuts.age)


def drop_inactive(cuts: CutSet, multipliers, tol: float = 1e-8) -> CutSet:
    """Eq. 25: drop cut l when its multiplier is (numerically) zero."""
    keep = (jnp.abs(multipliers) > tol).astype(cuts.active.dtype)
    return CutSet(a1=cuts.a1, a2=cuts.a2, a3=cuts.a3, b2=cuts.b2, b3=cuts.b3,
                  c=cuts.c, active=cuts.active * keep, age=cuts.age)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def _dot_p(stacked, v):
    """<a_l, v> for every cut slot l: stacked has leading (P,) axis."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda a, x: jnp.sum(
            a.reshape(a.shape[0], -1).astype(jnp.float32)
            * x.reshape(-1).astype(jnp.float32)[None, :], axis=-1),
        stacked, v))
    return sum(leaves) if leaves else 0.0


def _dot_pn(stacked, V):
    """sum_j <b_{l,j}, v_j>: stacked has leading (P,N) axes, V has (N,)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda b, x: jnp.einsum(
            "pnd,nd->p",
            b.reshape(b.shape[0], b.shape[1], -1).astype(jnp.float32),
            x.reshape(x.shape[0], -1).astype(jnp.float32)),
        stacked, V))
    return sum(leaves) if leaves else 0.0


def eval_cuts(cuts: CutSet, z1, z2, z3, X2=None, X3=None):
    """Per-slot cut values  <a,z> + sum_j <b,x_j> - c  (0 for inactive)."""
    val = _dot_p(cuts.a1, z1) + _dot_p(cuts.a2, z2) + _dot_p(cuts.a3, z3)
    if X2 is not None:
        val = val + _dot_pn(cuts.b2, X2)
    if X3 is not None:
        val = val + _dot_pn(cuts.b3, X3)
    return (val - cuts.c) * cuts.active


def cut_weighted_coeff(cuts: CutSet, weights, block: str):
    """sum_l w_l * coeff_block_l  — the gradient of sum_l w_l * cutval_l
    w.r.t. the variable corresponding to `block` ("a1".."b3").

    For b-blocks the result keeps the worker axis (N, ...).
    """
    w = weights * cuts.active
    tree = getattr(cuts, block)
    if block.startswith("a"):
        return jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0))
            .astype(a.dtype), tree)
    return jax.tree.map(
        lambda b: jnp.tensordot(w, b.astype(jnp.float32), axes=(0, 0))
        .astype(b.dtype), tree)


def n_active(cuts: CutSet):
    return jnp.sum(cuts.active)
