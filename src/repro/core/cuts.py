"""mu-cut construction and hyper-polyhedral polytope maintenance.

A mu-cut (paper Eq. 23/24) linearizes a mu-weakly-convex constraint
function h(v) <= eps at the current point v0:

    h(v) >= h(v0) + <g, v - v0> - (mu/2) ||v - v0||^2          (Def. 3.2)
         >= h(v0) + <g, v - v0> - mu (||v||^2 + ||v0||^2)      (C-S bound)
         >= h(v0) + <g, v - v0> - mu (B_alpha + ||v0||^2),     (Asm. 4.4)

so h(v) <= eps implies the *linear* inequality

    <g, v>  <=  eps + mu (B_alpha + ||v0||^2) - h(v0) + <g, v0>  =: c.

NOTE on the paper's Eq. 23 constant: the printed bound is
``mu((N+1)a1 + a2 + a3 + ...)`` but the C-S/boundedness derivation over
the level-I stack ({x_{3,j}}, z1, z2', z3) gives ``a1 + a2 + (N+1)a3``
(N worker copies of x3 plus z3, one copy each of z1/z2').  We implement
the derivation; Eq. 24's printed constant matches the derivation and is
used as printed.  With mu=0 both reduce to the classical convex cut.

STORAGE MODEL (canonical flat layout)
-------------------------------------
The polytope is stored as `FlatCuts`: one dense f32 `(P, D)` coefficient
matrix `a` plus `c`/`active`/`age` rows and a static `FlatSpec` column
layout.  Maintenance is incremental —

  * `add_cut`       one `dynamic_update_slice` row write (only the NEW
                    cut's coefficient dict is flattened),
  * `drop_inactive` a row mask on `active`,
  * eviction        the same row write over the oldest slot —

so no per-iteration consumer ever re-materializes the matrix from block
trees.  `eval_cuts`, `cut_weighted_coeff`, `cut_coeff_per_worker` and
the Lagrangian / stationarity cut terms all contract `fc.a` directly
(the `cut_eval`-shaped wide mat-vec).

The tree-of-trees `CutSet` survives only as a derived COMPATIBILITY
VIEW: `to_tree(fc)` materializes per-block coefficient trees (tests,
external callers, the tree-op reference implementations) and
`from_tree(cs)` flattens back.  Flattening thus happens in exactly two
places: at cut construction (the new row) and at the `to_tree` /
`from_tree` boundary — never inside `afto_step`, `cut_refresh` or
`stationarity_gap_sq`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import CutSet, FlatCuts, FlatSpec
from repro.utils.tree import tree_dot, tree_norm_sq


_BLOCK_NAMES = ("a1", "a2", "a3", "b2", "b3")


def _warn_cutset(entry: str) -> None:
    """The tree-of-trees `CutSet` public surface is DEPRECATED.

    `FlatCuts` is the only supported polytope storage; `to_tree` /
    `from_tree` are the only supported conversions for callers that
    still need the block-tree view.  The CutSet dispatch branches below
    emit this warning and will be removed once external callers have
    migrated (the `eval_cuts_tree` reference implementation stays, as a
    test oracle, without a warning)."""
    warnings.warn(
        f"{entry} on the tree-of-trees CutSet view is deprecated; use "
        "the canonical FlatCuts storage (convert with cuts.from_tree / "
        "cuts.to_tree at the boundary)", DeprecationWarning, stacklevel=3)

# Specs are tiny and purely shape-derived, so one cache entry per cut-set
# layout (i.e. per problem) is enough; keyed structurally so traced and
# concrete cut sets share entries.  Two caches (template-keyed and
# stacked-block-keyed) may hold equal-content FlatSpec objects; jit
# compares specs by value, so that is fine.
_SPEC_CACHE: Dict[tuple, FlatSpec] = {}
_TPL_SPEC_CACHE: Dict[tuple, FlatSpec] = {}


def _build_spec(flat_blocks, point_shapes, dtypes) -> FlatSpec:
    sizes = tuple(int(np.prod(s)) if s else 1 for s in point_shapes)
    offsets = tuple(np.concatenate([[0], np.cumsum(sizes)[:-1]])
                    .astype(int)) if sizes else ()
    return FlatSpec(
        tdefs=tuple(tdef for _, tdef in flat_blocks),
        nleaves=tuple(len(ls) for ls, _ in flat_blocks),
        shapes=tuple(point_shapes),
        dtypes=tuple(dtypes),
        sizes=sizes, offsets=offsets, d_total=sum(sizes))


def spec_from_templates(n_workers: int, z1_tpl, z2_tpl, z3_tpl) -> FlatSpec:
    """The (cached) FlatSpec for a polytope over these variable templates.

    Column order is the jax.tree leaf order of (a1, a2, a3, b2, b3);
    b-block point shapes carry the leading worker axis (N, ...)."""
    tpls = (z1_tpl, z2_tpl, z3_tpl, z2_tpl, z3_tpl)
    flat = [jax.tree.flatten(t) for t in tpls]
    key = (int(n_workers), tuple(
        (tdef, tuple((l.shape, str(l.dtype)) for l in leaves))
        for leaves, tdef in flat))
    spec = _TPL_SPEC_CACHE.get(key)
    if spec is None:
        shapes, dtypes = [], []
        for b_idx, (leaves, _) in enumerate(flat):
            lead = (int(n_workers),) if b_idx >= 3 else ()
            shapes.extend(lead + l.shape for l in leaves)
            dtypes.extend(l.dtype for l in leaves)
        spec = _build_spec(flat, shapes, dtypes)
        _TPL_SPEC_CACHE[key] = spec
    return spec


def _leaf_range(spec: FlatSpec, b_idx: int) -> Tuple[int, int]:
    """Contiguous per-leaf index range of block `b_idx` in the spec."""
    start = sum(spec.nleaves[:b_idx])
    return start, start + spec.nleaves[b_idx]


# ---------------------------------------------------------------------------
# construction + incremental maintenance (canonical FlatCuts path)
# ---------------------------------------------------------------------------

def empty_cuts(p_max: int, n_workers: int, z1_tpl, z2_tpl, z3_tpl
               ) -> FlatCuts:
    """All-zero, all-inactive polytope in the canonical flat layout."""
    spec = spec_from_templates(n_workers, z1_tpl, z2_tpl, z3_tpl)
    return FlatCuts(
        a=jnp.zeros((p_max, spec.d_total), jnp.float32),
        c=jnp.zeros((p_max,), jnp.float32),
        active=jnp.zeros((p_max,), jnp.float32),
        age=jnp.full((p_max,), -1, jnp.int32),
        spec=spec)


def empty_cutset(p_max: int, n_workers: int, z1_tpl, z2_tpl, z3_tpl
                 ) -> CutSet:
    """DEPRECATED compatibility constructor for the block-tree view;
    build `empty_cuts` (FlatCuts) and use `to_tree` where a tree view is
    genuinely needed."""
    _warn_cutset("empty_cutset")
    return to_tree(empty_cuts(p_max, n_workers, z1_tpl, z2_tpl, z3_tpl))


def make_cut(h0, grads, point, eps, mu, bound_alpha):
    """Assemble the linear cut <g, v> <= c from h's value/grads at `point`.

    grads/point are dicts with keys from {"a1","a2","a3","b2","b3"}; missing
    blocks are treated as zero.  Returns (coeff_dict, c).
    """
    gv0 = jnp.float32(0.0)
    v0_sq = jnp.float32(0.0)
    for k, g in grads.items():
        gv0 = gv0 + tree_dot(g, point[k])
        v0_sq = v0_sq + tree_norm_sq(point[k])
    c = eps + mu * (bound_alpha + v0_sq) - h0 + gv0
    return grads, c


def flatten_coeffs(spec: FlatSpec, coeffs: Dict[str, Any]):
    """One cut's coefficient dict as a (D,) f32 row in spec column order
    (missing blocks zero).  This is THE construction-time flatten: the
    only place a new cut's trees are linearized."""
    return flatten_point(spec, coeffs.get("a1"), coeffs.get("a2"),
                         coeffs.get("a3"), coeffs.get("b2"),
                         coeffs.get("b3"))


def _next_slot(active, age):
    """First inactive slot, else the oldest active one (eviction).

    Integer scores: adding 1e9 in f32 loses the age low bits (spacing at
    1e9 is 64) and mis-evicts — caught by the hypothesis capacity test."""
    score = jnp.where(active > 0, age, jnp.int32(-(2 ** 30)))
    return jnp.argmin(score)


def add_cut(cuts, coeffs, c, t):
    """Write the cut into the first inactive slot (or evict the oldest).

    On the canonical `FlatCuts` this is ONE row write: the new cut's
    coefficient dict is flattened to a (D,) row and
    `lax.dynamic_update_slice`d into the matrix (shape-stable, traced
    slot).  Evicted rows are fully overwritten, so no stale coefficients
    survive.  A `CutSet` argument takes the DEPRECATED per-block tree
    write (warns; convert with `from_tree` instead)."""
    slot = _next_slot(cuts.active, cuts.age)
    if not isinstance(cuts, FlatCuts):
        _warn_cutset("add_cut")
    if isinstance(cuts, FlatCuts):
        row = flatten_coeffs(cuts.spec, coeffs)
        return FlatCuts(
            a=jax.lax.dynamic_update_slice(cuts.a, row[None, :], (slot, 0)),
            c=cuts.c.at[slot].set(jnp.asarray(c, cuts.c.dtype)),
            active=cuts.active.at[slot].set(1.0),
            age=cuts.age.at[slot].set(jnp.asarray(t, jnp.int32)),
            spec=cuts.spec)

    def write_block(cur, new):
        if new is None:
            return jax.tree.map(
                lambda buf: buf.at[slot].set(jnp.zeros_like(buf[slot])), cur)
        return jax.tree.map(lambda buf, g: buf.at[slot].set(g), cur, new)

    return CutSet(
        a1=write_block(cuts.a1, coeffs.get("a1")),
        a2=write_block(cuts.a2, coeffs.get("a2")),
        a3=write_block(cuts.a3, coeffs.get("a3")),
        b2=write_block(cuts.b2, coeffs.get("b2")),
        b3=write_block(cuts.b3, coeffs.get("b3")),
        c=cuts.c.at[slot].set(jnp.asarray(c, cuts.c.dtype)),
        active=cuts.active.at[slot].set(1.0),
        age=cuts.age.at[slot].set(jnp.asarray(t, jnp.int32)),
    )


def drop_inactive(cuts, multipliers, tol: float = 1e-8):
    """Eq. 25: drop cut l when its multiplier is (numerically) zero.
    A pure row mask on `active` — coefficients stay in place (an
    inactive row contributes nothing; a later add overwrites it)."""
    keep = (jnp.abs(multipliers) > tol).astype(cuts.active.dtype)
    return dataclasses.replace(cuts, active=cuts.active * keep)


def n_active(cuts):
    return jnp.sum(cuts.active)


# ---------------------------------------------------------------------------
# to_tree / from_tree: the compatibility boundary
# ---------------------------------------------------------------------------

def to_tree(fc: FlatCuts) -> CutSet:
    """Materialize the derived block-tree `CutSet` view (lazy: only
    called at the compatibility boundary, never on the scanned path)."""
    spec = fc.spec
    p = fc.a.shape[0]
    blocks = []
    i = 0
    for b_idx in range(len(_BLOCK_NAMES)):
        n = spec.nleaves[b_idx]
        leaves = [
            fc.a[:, spec.offsets[i + k]:spec.offsets[i + k]
                 + spec.sizes[i + k]]
            .reshape((p,) + spec.shapes[i + k]).astype(spec.dtypes[i + k])
            for k in range(n)]
        blocks.append(jax.tree.unflatten(spec.tdefs[b_idx], leaves))
        i += n
    a1, a2, a3, b2, b3 = blocks
    return CutSet(a1=a1, a2=a2, a3=a3, b2=b2, b3=b3,
                  c=fc.c, active=fc.active, age=fc.age)


def from_tree(cs: CutSet) -> FlatCuts:
    """Flatten a block-tree `CutSet` into the canonical `FlatCuts`."""
    spec = flat_spec(cs)
    return FlatCuts(a=flatten_cuts(cs, spec), c=cs.c, active=cs.active,
                    age=cs.age, spec=spec)


# ---------------------------------------------------------------------------
# flattened layout plumbing (spec inference + point/coeff flattening)
# ---------------------------------------------------------------------------

def flat_spec(cuts) -> FlatSpec:
    """The (cached) flattening spec for this cut set's layout.  On the
    canonical `FlatCuts` this is just `cuts.spec`; for the block-tree
    view it is derived (and cached) from the stacked leaf shapes."""
    if isinstance(cuts, FlatCuts):
        return cuts.spec
    blocks = tuple(getattr(cuts, name) for name in _BLOCK_NAMES)
    flat = [jax.tree.flatten(b) for b in blocks]
    key = tuple(
        (tdef, tuple((l.shape, str(l.dtype)) for l in leaves))
        for leaves, tdef in flat)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        leaves = [l for ls, _ in flat for l in ls]
        shapes = tuple(l.shape[1:] for l in leaves)
        dtypes = tuple(l.dtype for l in leaves)
        spec = _build_spec(flat, shapes, dtypes)
        _SPEC_CACHE[key] = spec
    return spec


def flatten_cuts(cuts, spec: Optional[FlatSpec] = None):
    """All coefficient blocks as one contiguous (P, D) f32 matrix.

    On `FlatCuts` this is the stored matrix itself (no work).  For the
    block-tree view the reshape sizes come from `spec`, so passing a
    spec from a different layout fails loudly instead of silently
    misaligning columns."""
    if isinstance(cuts, FlatCuts):
        return cuts.a
    if spec is None:
        spec = flat_spec(cuts)
    leaves = [l for name in _BLOCK_NAMES
              for l in jax.tree.leaves(getattr(cuts, name))]
    p = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(p, size).astype(jnp.float32)
         for l, size in zip(leaves, spec.sizes)], axis=1)


def flatten_point(spec: FlatSpec, z1, z2, z3, X2=None, X3=None):
    """The variable point (z1, z2, z3, {x2_j}, {x3_j}) as a (D,) f32
    vector in the spec's column order.  X2/X3 may be None (zero block,
    e.g. layer-I cuts carry no b2 coefficients)."""
    parts = []
    i = 0
    for b_idx, block in enumerate((z1, z2, z3, X2, X3)):
        n = spec.nleaves[b_idx]
        if block is None:
            parts.extend(jnp.zeros((spec.sizes[i + k],), jnp.float32)
                         for k in range(n))
        else:
            leaves = jax.tree.leaves(block)
            parts.extend(l.reshape(-1).astype(jnp.float32) for l in leaves)
        i += n
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)


def unflatten_coeff(spec: FlatSpec, vec):
    """Inverse of the column layout for a single (D,) vector: returns the
    (a1, a2, a3, b2, b3) block trees (point shapes, original dtypes)."""
    out = []
    i = 0
    for b_idx in range(len(_BLOCK_NAMES)):
        n = spec.nleaves[b_idx]
        leaves = [
            vec[spec.offsets[i + k]:spec.offsets[i + k] + spec.sizes[i + k]]
            .reshape(spec.shapes[i + k]).astype(spec.dtypes[i + k])
            for k in range(n)]
        out.append(jax.tree.unflatten(spec.tdefs[b_idx], leaves))
        i += n
    return tuple(out)


# ---------------------------------------------------------------------------
# worker-axis column sharding (the b-block columns partition by worker)
# ---------------------------------------------------------------------------
#
# Column order within the canonical (P, D) matrix is (a1, a2, a3, b2, b3):
# every a-block column depends only on master variables (replicated on a
# worker mesh), while each b-block leaf flattens its (N, ...) point shape
# worker-major — so worker j's coefficients are contiguous within every
# b-leaf and the b-columns split cleanly into per-worker groups.  A shard
# therefore carries [all a-columns | its own workers' b-columns], which is
# a valid local FlatCuts over a `shard_spec` with n_loc = N / n_shards.

def n_a_leaves(spec: FlatSpec) -> int:
    """Number of leaves in the master (a1, a2, a3) blocks."""
    return sum(spec.nleaves[:3])


def b_col_start(spec: FlatSpec) -> int:
    """First column of the worker (b2, b3) blocks."""
    na = n_a_leaves(spec)
    return spec.offsets[na] if na < len(spec.offsets) else spec.d_total


def shard_spec(spec: FlatSpec, n_shards: int) -> FlatSpec:
    """The per-shard column layout: a-leaves unchanged, b-leaves carry
    n_loc = N / n_shards workers."""
    na = n_a_leaves(spec)
    shapes = []
    for i, shp in enumerate(spec.shapes):
        if i < na:
            shapes.append(shp)
        else:
            n = shp[0]
            if n % n_shards != 0:
                raise ValueError(
                    f"worker axis {n} not divisible by {n_shards} shards")
            shapes.append((n // n_shards,) + shp[1:])
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(np.concatenate([[0], np.cumsum(sizes)[:-1]])
                    .astype(int)) if sizes else ()
    return FlatSpec(tdefs=spec.tdefs, nleaves=spec.nleaves,
                    shapes=tuple(shapes), dtypes=spec.dtypes,
                    sizes=sizes, offsets=offsets, d_total=sum(sizes))


def shard_cuts(fc: FlatCuts, n_shards: int) -> FlatCuts:
    """Partition the canonical matrix by worker columns: returns a
    FlatCuts whose `a` is (n_shards, P, D_loc) — shard w holds the
    a-columns (replicated) plus worker-group w's b-columns — with the
    `shard_spec` local layout.  `c`/`active`/`age` stay replicated.
    The column partition is exact: `unshard_cuts` inverts bit-identically.
    """
    spec = fc.spec
    lspec = shard_spec(spec, n_shards)
    p = fc.a.shape[0]
    na = n_a_leaves(spec)
    parts = []
    for i in range(len(spec.sizes)):
        col = fc.a[:, spec.offsets[i]:spec.offsets[i] + spec.sizes[i]]
        if i < na:
            parts.append(jnp.broadcast_to(col[None],
                                          (n_shards, p, spec.sizes[i])))
        else:
            parts.append(col.reshape(p, n_shards, lspec.sizes[i])
                         .transpose(1, 0, 2))
    return FlatCuts(a=jnp.concatenate(parts, axis=-1), c=fc.c,
                    active=fc.active, age=fc.age, spec=lspec)


def grow_spec(spec: FlatSpec, n_new: int) -> FlatSpec:
    """The column layout after growing the worker axis to `n_new`:
    a-leaves unchanged, each b-leaf's leading worker dimension widened.
    Growth only — shrinking would discard live b-columns."""
    na = n_a_leaves(spec)
    shapes = []
    for i, shp in enumerate(spec.shapes):
        if i < na:
            shapes.append(shp)
        else:
            if int(shp[0]) > int(n_new):
                raise ValueError(
                    f"grow_spec: worker axis {shp[0]} > target {n_new} "
                    "(membership only grows)")
            shapes.append((int(n_new),) + shp[1:])
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(np.concatenate([[0], np.cumsum(sizes)[:-1]])
                    .astype(int)) if sizes else ()
    return FlatSpec(tdefs=spec.tdefs, nleaves=spec.nleaves,
                    shapes=tuple(shapes), dtypes=spec.dtypes,
                    sizes=sizes, offsets=offsets, d_total=sum(sizes))


def grow_cuts(fc: FlatCuts, n_new: int) -> FlatCuts:
    """Widen the polytope's worker axis to `n_new` workers: a-columns
    and `c`/`active`/`age` are copied, existing workers' b-columns keep
    their coefficients, and the admitted workers' b-columns are zero —
    exact, because a zero coefficient contributes nothing to any cut
    contraction (the newcomers' rows enter every <b_j, x_j> term with
    weight 0 until a refresh writes real coefficients)."""
    spec = fc.spec
    gspec = grow_spec(spec, n_new)
    p = fc.a.shape[0]
    na = n_a_leaves(spec)
    parts = []
    for i in range(len(spec.sizes)):
        col = fc.a[:, spec.offsets[i]:spec.offsets[i] + spec.sizes[i]]
        if i < na:
            parts.append(col)
        else:
            n_old = spec.shapes[i][0]
            per = spec.sizes[i] // max(1, n_old)
            wide = jnp.zeros((p, int(n_new), per), fc.a.dtype)
            wide = wide.at[:, :n_old].set(col.reshape(p, n_old, per))
            parts.append(wide.reshape(p, gspec.sizes[i]))
    return FlatCuts(a=jnp.concatenate(parts, axis=-1), c=fc.c,
                    active=fc.active, age=fc.age, spec=gspec)


def unshard_cuts(fc: FlatCuts, spec: FlatSpec) -> FlatCuts:
    """Inverse of `shard_cuts`: reassemble the canonical (P, D) matrix
    from the (n_shards, P, D_loc) per-shard column groups (`spec` is the
    global layout)."""
    lspec = fc.spec
    p = fc.a.shape[1]
    na = n_a_leaves(spec)
    cols = []
    for i in range(len(spec.sizes)):
        col = fc.a[:, :, lspec.offsets[i]:lspec.offsets[i] + lspec.sizes[i]]
        if i < na:
            cols.append(col[0])
        else:
            cols.append(col.transpose(1, 0, 2).reshape(p, spec.sizes[i]))
    return FlatCuts(a=jnp.concatenate(cols, axis=-1), c=fc.c,
                    active=fc.active, age=fc.age, spec=spec)


def a_cols_matvec(fc: FlatCuts, z1, z2, z3):
    """Raw (unmasked, un-offset) master contraction A_a @ [z1; z2; z3]
    over the a-columns only.  THE single definition of the a/b column
    split — the sharded step, refresh and rollouts all route through
    this + `b_cols_matvec` so the boundary cannot drift between them."""
    da = b_col_start(fc.spec)
    va = flatten_point(fc.spec, z1, z2, z3, None, None)[:da]
    return fc.a[:, :da].astype(jnp.float32) @ va


def b_cols_matvec(fc: FlatCuts, X2, X3):
    """Raw per-slot worker contraction sum_j <b_j, x_j> over this view's
    b-columns (shard-partial when `fc` is a `shard_cuts` local view)."""
    da = b_col_start(fc.spec)
    vb = flatten_point(fc.spec, None, None, None, X2, X3)[da:]
    return fc.a[:, da:].astype(jnp.float32) @ vb


def eval_cuts_worker_split(fc: FlatCuts, z1, z2, z3, X2, X3, axis: str):
    """Global cut values from a worker-sharded polytope: the replicated
    a-column contraction runs shard-locally while the local b-column
    contribution — the per-worker cut scalars, the only quantity Alg. 1
    federates every iteration — is `psum`'d over the worker mesh axis.
    Forward-only (raw psum has no usable transpose on this jax;
    differentiated sharded paths hand-assemble their VJPs in
    `repro.core.sharded`)."""
    cut_b = jax.lax.psum(b_cols_matvec(fc, X2, X3), axis)
    return (a_cols_matvec(fc, z1, z2, z3) + cut_b - fc.c) * fc.active


# ---------------------------------------------------------------------------
# evaluation / contraction (all consume the flat matrix directly)
# ---------------------------------------------------------------------------

def eval_cuts_flat(a_flat, v_flat, c, active, impl: str = None):
    """Per-slot cut values from flattened operands: the `cut_eval`
    mat-vec  (A @ v - c) * active.  impl=None auto-routes (Mosaic
    kernels on TPU, the identical-math XLA mat-vec off-TPU — see
    ops.cut_eval).  The kernel route is differentiable to arbitrary
    order through the `kernels.cut_ad` primitive closure, so the same
    auto-routing serves forward-only hot paths AND the grad-of-grad'd
    inner-Lagrangian paths; impl="ref" remains as the jnp test
    oracle."""
    from repro.kernels import ops
    return ops.cut_eval(a_flat, v_flat, c, active, impl=impl)


def eval_cuts(cuts, z1, z2, z3, X2=None, X3=None):
    """Per-slot cut values  <a,z> + sum_j <b,x_j> - c  (0 for inactive).

    Contracts the canonical (P, D) matrix against the flattened point —
    no cut re-flattening (only the point vector is assembled).  Routes
    through the auto impl (Mosaic kernels on TPU, jnp elsewhere): this
    entry point sits inside the inner Lagrangians, which are
    differentiated to second order at cut refresh, and the
    `kernels.cut_ad` primitive closure keeps the kernel route
    transposable/linearizable to any order — the old forced impl="ref"
    fallback is gone.  A block-tree `CutSet` argument is DEPRECATED
    (warns, flattens first; convert with `from_tree` at the boundary
    instead)."""
    if isinstance(cuts, FlatCuts):
        spec, a_flat = cuts.spec, cuts.a
    else:
        _warn_cutset("eval_cuts")
        spec = flat_spec(cuts)
        a_flat = flatten_cuts(cuts, spec)
    v = flatten_point(spec, z1, z2, z3, X2, X3)
    return eval_cuts_flat(a_flat, v, cuts.c, cuts.active, impl=None)


def cut_weighted_coeff_flat(spec: FlatSpec, a_flat, weights):
    """sum_l w_l * coeff_l for EVERY block at once: one (P,)x(P,D)
    mat-vec, unflattened to the (a1, a2, a3, b2, b3) block trees.  The
    b-block results keep the worker axis (N, ...), i.e. worker j's entry
    is sum_l w_l * b_{l,j}."""
    return unflatten_coeff(
        spec, weights.astype(jnp.float32) @ a_flat)


def cut_coeff_per_worker(fc: FlatCuts, weights_np, block: str):
    """sum_l w[j,l] * b_{l,j}  ->  tree with leading worker axis (N, ...).

    The per-worker (stale-weight) contraction of Eq. 16, read straight
    off the canonical matrix: each b-block leaf is a (P, N, ...) column
    slice of `fc.a`, contracted with the (N, P) weight table."""
    spec = fc.spec
    w = (weights_np * fc.active[None, :]).astype(jnp.float32)   # (N, P)
    b_idx = _BLOCK_NAMES.index(block)
    lo, hi = _leaf_range(spec, b_idx)
    p = fc.a.shape[0]
    leaves = []
    for i in range(lo, hi):
        col = fc.a[:, spec.offsets[i]:spec.offsets[i] + spec.sizes[i]]
        col = col.reshape((p,) + spec.shapes[i])                # (P, N, ...)
        leaves.append(jnp.einsum("np,pn...->n...", w, col)
                      .astype(spec.dtypes[i]))
    return jax.tree.unflatten(spec.tdefs[b_idx], leaves)


def cut_weighted_coeff(cuts, weights, block: str):
    """sum_l w_l * coeff_block_l  — the gradient of sum_l w_l * cutval_l
    w.r.t. the variable corresponding to `block` ("a1".."b3").

    For b-blocks the result keeps the worker axis (N, ...).  On the
    canonical `FlatCuts` this slices the block's columns out of the
    matrix; the block-tree path is the DEPRECATED reference the flat one
    is tested against (warns on CutSet input).
    """
    w = weights * cuts.active
    if not isinstance(cuts, FlatCuts):
        _warn_cutset("cut_weighted_coeff")
    if isinstance(cuts, FlatCuts):
        spec = cuts.spec
        b_idx = _BLOCK_NAMES.index(block)
        lo, hi = _leaf_range(spec, b_idx)
        wf = w.astype(jnp.float32)
        leaves = [
            (wf @ cuts.a[:, spec.offsets[i]:spec.offsets[i] + spec.sizes[i]])
            .reshape(spec.shapes[i]).astype(spec.dtypes[i])
            for i in range(lo, hi)]
        return jax.tree.unflatten(spec.tdefs[b_idx], leaves)
    tree = getattr(cuts, block)
    if block.startswith("a"):
        return jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0))
            .astype(a.dtype), tree)
    return jax.tree.map(
        lambda b: jnp.tensordot(w, b.astype(jnp.float32), axes=(0, 0))
        .astype(b.dtype), tree)


# ---------------------------------------------------------------------------
# tree-op reference implementations (tests / documentation of the math)
# ---------------------------------------------------------------------------

def _dot_p(stacked, v):
    """<a_l, v> for every cut slot l: stacked has leading (P,) axis."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda a, x: jnp.sum(
            a.reshape(a.shape[0], -1).astype(jnp.float32)
            * x.reshape(-1).astype(jnp.float32)[None, :], axis=-1),
        stacked, v))
    return sum(leaves) if leaves else 0.0


def _dot_pn(stacked, V):
    """sum_j <b_{l,j}, v_j>: stacked has leading (P,N) axes, V has (N,)."""
    leaves = jax.tree.leaves(jax.tree.map(
        lambda b, x: jnp.einsum(
            "pnd,nd->p",
            b.reshape(b.shape[0], b.shape[1], -1).astype(jnp.float32),
            x.reshape(x.shape[0], -1).astype(jnp.float32)),
        stacked, V))
    return sum(leaves) if leaves else 0.0


def eval_cuts_tree(cuts, z1, z2, z3, X2=None, X3=None):
    """Tree-op reference implementation of `eval_cuts` (kept for tests
    and as documentation of the per-block contraction).  Accepts either
    layout (FlatCuts is viewed through `to_tree` first)."""
    if isinstance(cuts, FlatCuts):
        cuts = to_tree(cuts)
    val = _dot_p(cuts.a1, z1) + _dot_p(cuts.a2, z2) + _dot_p(cuts.a3, z3)
    if X2 is not None:
        val = val + _dot_pn(cuts.b2, X2)
    if X3 is not None:
        val = val + _dot_pn(cuts.b3, X3)
    return (val - cuts.c) * cuts.active
