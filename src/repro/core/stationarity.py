"""Stationarity gap (Definitions 4.1/4.2, Eqs. 26/27).

The cut-dependent terms ride on the CANONICAL (P, D) cut operator
carried in `AFTOState` (`state.cuts_ii.a` — read as stored, never
re-flattened): one `w @ A` mat-vec yields the z-block gradients AND the
per-worker b-block sums, and the cut values come from the `cut_eval`
kernel.  At record iterations inside the compiled engine the step has
already produced both products (`afto_step_aux`), so the gap accepts
them via `aux=` instead of recomputing — only the f1 gradients at the
post-step point remain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import afto as afto_lib
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.utils.tree import tree_norm_sq, tree_sub, tree_axpy


def make_gap_aux(problem: TrilevelProblem, hyper: Hyper, state: AFTOState,
                 axis: str = None):
    """The cut products the gap needs: the flattened II-polytope operator
    and the cut values at `state`'s point.  Structure-identical to the
    aux returned by `afto_step_aux`, so the engine can select between
    them under `lax.cond` (it must recompute when a `cut_refresh`
    rewrote the polytope after the step).  The operator is the stored
    canonical matrix — only the point vector is assembled here.  With a
    worker mesh `axis` the b-column contribution to the cut values is
    psum'd (see `cuts.eval_cuts_worker_split`)."""
    a_flat = state.cuts_ii.a
    if axis is None:
        cutval = cuts_lib.eval_cuts_flat(
            a_flat,
            cuts_lib.flatten_point(state.cuts_ii.spec, state.z1, state.z2,
                                   state.z3, state.X2, state.X3),
            state.cuts_ii.c, state.cuts_ii.active)
    else:
        cutval = cuts_lib.eval_cuts_worker_split(
            state.cuts_ii, state.z1, state.z2, state.z3,
            state.X2, state.X3, axis)
    return {"flat_ii": a_flat, "cutval": cutval}


def stationarity_gap_sq(problem: TrilevelProblem, hyper: Hyper,
                        state: AFTOState, aux=None, axis: str = None):
    """|| grad G^t ||^2 of the *unregularized* L_p (Eq. 26).

    aux, when given, must be `make_gap_aux`-shaped products valid at
    `state` (the engine passes the step's own).  With a worker mesh
    `axis`, the per-worker gradient-block norms are computed shard-
    locally and only their scalar sums cross the mesh (one psum)."""
    if aux is None:
        aux = make_gap_aux(problem, hyper, state, axis=axis)
    lam_a = state.lam * state.cuts_ii.active
    spec = state.cuts_ii.spec
    # one mat-vec: a-block gradients for the master z's plus the
    # per-worker b-block sums (lam is shared across workers here, so the
    # stale per-worker contraction collapses to the same product).
    ga1, ga2, ga3, gb2, gb3 = cuts_lib.cut_weighted_coeff_flat(
        spec, aux["flat_ii"], lam_a)

    # worker blocks
    def f1_grads(data_j, x1_j, x2_j, x3_j):
        return jax.grad(lambda a, b, c: problem.f1(data_j, a, b, c),
                        argnums=(0, 1, 2))(x1_j, x2_j, x3_j)

    g1_f, g2_f, g3_f = jax.vmap(f1_grads)(
        problem.data, state.X1, state.X2, state.X3)
    g1 = jax.tree.map(jnp.add, g1_f, state.theta)
    g2 = jax.tree.map(jnp.add, g2_f, gb2)
    g3 = jax.tree.map(jnp.add, g3_f, gb3)
    gap_workers = tree_norm_sq(g1) + tree_norm_sq(g2) + tree_norm_sq(g3)

    def theta_res(th_j, x1_j):
        stepped = jax.tree.map(
            lambda t0, g: t0 + hyper.eta_theta * g, th_j,
            tree_sub(x1_j, state.z1))
        proj = afto_lib.proj_theta(stepped, hyper)
        return tree_norm_sq(jax.tree.map(
            lambda a, b: (a - b) / hyper.eta_theta, th_j, proj))

    gap_workers = gap_workers + jnp.sum(
        jax.vmap(theta_res)(state.theta, state.X1))

    # master z blocks (replicated on a worker mesh; only the theta sum
    # and the per-worker scalar norms above cross the mesh)
    theta_sum = jax.tree.map(lambda th: jnp.sum(th, axis=0), state.theta)
    if axis is not None:
        gap_workers = jax.lax.psum(gap_workers, axis)
        theta_sum = jax.lax.psum(theta_sum, axis)
    gz1 = tree_axpy(-1.0, theta_sum, ga1)
    gap = gap_workers + tree_norm_sq(gz1) + tree_norm_sq(ga2) \
        + tree_norm_sq(ga3)

    # projected dual residuals (Eq. 27)
    cutval = aux["cutval"]
    lam_res = (state.lam - afto_lib.proj_lambda(
        state.lam + hyper.eta_lambda * cutval, hyper)) / hyper.eta_lambda
    gap = gap + jnp.sum((lam_res * state.cuts_ii.active) ** 2)
    return gap
