"""Stationarity gap (Definitions 4.1/4.2, Eqs. 26/27)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cuts as cuts_lib
from repro.core import afto as afto_lib
from repro.core.types import AFTOState, Hyper, TrilevelProblem
from repro.utils.tree import tree_norm_sq, tree_sub, tree_axpy


def stationarity_gap_sq(problem: TrilevelProblem, hyper: Hyper,
                        state: AFTOState):
    """|| grad G^t ||^2 of the *unregularized* L_p (Eq. 26)."""
    lam_a = state.lam * state.cuts_ii.active

    # worker blocks
    def f1_grads(data_j, x1_j, x2_j, x3_j):
        return jax.grad(lambda a, b, c: problem.f1(data_j, a, b, c),
                        argnums=(0, 1, 2))(x1_j, x2_j, x3_j)

    g1_f, g2_f, g3_f = jax.vmap(f1_grads)(
        problem.data, state.X1, state.X2, state.X3)
    g1 = jax.tree.map(jnp.add, g1_f, state.theta)
    lam_np = jnp.broadcast_to(lam_a[None], (hyper.n_workers,) + lam_a.shape)
    g2 = jax.tree.map(jnp.add, g2_f,
                      afto_lib._cut_coeff_per_worker(state.cuts_ii, lam_np,
                                                     "b2"))
    g3 = jax.tree.map(jnp.add, g3_f,
                      afto_lib._cut_coeff_per_worker(state.cuts_ii, lam_np,
                                                     "b3"))
    gap = tree_norm_sq(g1) + tree_norm_sq(g2) + tree_norm_sq(g3)

    # master z blocks
    theta_sum = jax.tree.map(lambda th: jnp.sum(th, axis=0), state.theta)
    gz1 = tree_axpy(-1.0, theta_sum,
                    cuts_lib.cut_weighted_coeff(state.cuts_ii, lam_a, "a1"))
    gz2 = cuts_lib.cut_weighted_coeff(state.cuts_ii, lam_a, "a2")
    gz3 = cuts_lib.cut_weighted_coeff(state.cuts_ii, lam_a, "a3")
    gap = gap + tree_norm_sq(gz1) + tree_norm_sq(gz2) + tree_norm_sq(gz3)

    # projected dual residuals (Eq. 27)
    cutval = cuts_lib.eval_cuts(state.cuts_ii, state.z1, state.z2, state.z3,
                                X2=state.X2, X3=state.X3)
    lam_res = (state.lam - afto_lib.proj_lambda(
        state.lam + hyper.eta_lambda * cutval, hyper)) / hyper.eta_lambda
    gap = gap + jnp.sum((lam_res * state.cuts_ii.active) ** 2)

    def theta_res(th_j, x1_j):
        stepped = jax.tree.map(
            lambda t0, g: t0 + hyper.eta_theta * g, th_j,
            tree_sub(x1_j, state.z1))
        proj = afto_lib.proj_theta(stepped, hyper)
        return tree_norm_sq(jax.tree.map(
            lambda a, b: (a - b) / hyper.eta_theta, th_j, proj))

    gap = gap + jnp.sum(jax.vmap(theta_res)(state.theta, state.X1))
    return gap
