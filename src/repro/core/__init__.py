"""AFTO core: the paper's contribution (mu-cuts + async federated loop)."""
from repro.core.types import (AFTOState, CutSet, FlatCuts, FlatSpec, Hyper,
                              InnerState2, InnerState3, StaleView,
                              TrilevelProblem)
from repro.core.afto import (afto_step, afto_step_aux, afto_step_from_grads,
                             cut_refresh, init_state, local_f1_grads)
from repro.core.engine import (SweepResult, record_slots, run_chunked,
                               run_scanned, run_swept)
from repro.core.runner import RunResult, RunSpec, run, spec_from_kwargs
from repro.core.scheduler import (ArrivalRecorder, Schedule, StragglerConfig,
                                  StragglerScheduler)
from repro.core.stationarity import stationarity_gap_sq
from repro.core.weakly_convex import estimate_mu, first_order_gap
from repro.core import cuts, inner, lagrangian
