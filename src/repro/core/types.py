"""Core state containers for AFTO (all registered as pytrees).

Notation follows the paper:
  x_{i,j}  - worker j's local copy of level-i variables  -> stacked trees
             with a leading worker axis N ("X1", "X2", "X3").
  z_i      - master consensus variables                  -> plain trees.
  theta_j  - duals for the consensus constraint x_{1,j}=z1 (Eq. 14).
  lambda_l - duals for the II-layer polytope cuts (Eq. 14).
  P_I/P_II - hyper-polyhedral cut sets (fixed capacity + active mask so
             every shape is jit-stable; Add/Drop write slots, Eq. 25).

Cut storage is CANONICALLY FLAT: `FlatCuts` keeps the whole polytope as
one dense `(P, D)` coefficient matrix (plus `c`/`active`/`age` rows and
a static `FlatSpec` describing the column layout), which is what every
hot-path consumer (`afto_step`, the Lagrangian cut terms, the
stationarity gap, the `cut_eval` Pallas kernel, the sweep vmap)
contracts against directly.  The tree-of-trees `CutSet` remains only as
a *derived compatibility view* (`cuts.to_tree` / `cuts.from_tree`) for
tests and external callers that want per-block coefficient trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields))
    return cls


@dataclasses.dataclass
class Hyper:
    """Algorithm hyper-parameters (static under jit)."""
    n_workers: int = 4
    s_active: int = 3           # S: master proceeds after S worker updates
    tau: int = 10               # max staleness
    k_inner: int = 4            # K communication rounds for phi estimates
    p_max: int = 8              # cut-set capacity per layer
    t_pre: int = 10             # add cuts every t_pre master iterations
    t1: int = 200               # stop adding cuts after t1 iterations
    # step sizes (paper Eq. 5-7, 16-21)
    eta_x: float = 0.05
    eta_z: float = 0.05
    eta_lambda: float = 0.05
    eta_theta: float = 0.05
    eta_dual_inner: float = 0.05   # eta_phi for the inner ADMM duals
    eta_s: float = 0.05            # slack update step (level-2 inner)
    # penalties (Eq. 4, 11)
    kappa2: float = 1.0
    kappa3: float = 1.0
    rho2: float = 1.0
    # relaxation + weak-convexity constants (Eq. 23/24)
    eps_i: float = 1e-3
    eps_ii: float = 1e-3
    mu_i: float = 0.1
    mu_ii: float = 0.1
    # variable bound constants, ||x_i||^2 <= alpha_i (Assumption 4.4)
    alpha1: float = 100.0
    alpha2: float = 100.0
    alpha3: float = 100.0
    alpha4: float = 100.0       # lambda in [0, sqrt(alpha4)]
    alpha5: float = 100.0       # ||theta||_inf <= sqrt(alpha5)/d1
    # regularization floors c_1, c_2 (Eq. 15)
    c1_floor: float = 1e-3
    c2_floor: float = 1e-3
    d1: int = 1                 # dim of x1 (for the theta projection radius)
    # route the level-2 inner rollout's cut algebra through the fused
    # two-pass Pallas round kernel (kernels/inner_round.py).  The fused
    # op auto-routes like cut_eval (Mosaic on TPU, the identical-math
    # jnp decomposition elsewhere) and stays differentiable to any
    # order, so h_II / cut-refresh grad-of-grad work through it; False
    # keeps the scan-of-jnp oracle round body (the default, and the
    # parity reference in tests/test_inner_fused.py).
    use_fused_inner: bool = False

    def __post_init__(self):
        # Fail fast on arrival-rule parameters the runtime can never
        # satisfy (s_active > n_workers deadlocks the quorum wait;
        # tau < 1 admits no arrival process).  Swept hypers rebuild this
        # dataclass with traced field values — only concrete ints are
        # judged (shape-determining fields are static and always are).
        if all(isinstance(v, int) for v in
               (self.n_workers, self.s_active, self.tau)):
            from repro.core.scheduler import validate_arrival_params
            validate_arrival_params(self.s_active, self.tau,
                                    self.n_workers, what="Hyper")

    def c1(self, t):
        return jnp.maximum(self.c1_floor,
                           1.0 / (self.eta_lambda * (t + 1.0) ** 0.25))

    def c2(self, t):
        return jnp.maximum(self.c2_floor,
                           1.0 / (self.eta_theta * (t + 1.0) ** 0.25))


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static column layout of the flattened cut coefficient space.

    Per-leaf entries run over the concatenated leaves of the five blocks
    (a1, a2, a3, b2, b3) in order; `shapes` are the *point* shapes (the
    coefficient leaf shape without its leading (P,) cut axis, so b-block
    shapes keep the worker axis).  Frozen and hashable, so it can be a
    jit-static meta field of `FlatCuts` and ride scan carries unchanged.
    """
    tdefs: Tuple[Any, ...]          # one treedef per block
    nleaves: Tuple[int, ...]        # leaves per block
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]
    d_total: int


@dataclasses.dataclass
class FlatCuts:
    """CANONICAL cut storage: the polytope as one dense (P, D) operator.

    a      : (P, D) f32 coefficient matrix; row l is cut l's flattened
             (a1, a2, a3, b2, b3) blocks in `spec` column order.
    c      : (P,) offsets;  active: (P,) {0,1};  age: (P,) insertion time.
    spec   : static `FlatSpec` column layout (meta field — not a leaf).

    `add_cut` is a single row write, `drop_inactive`/eviction are row
    masks, and every per-iteration contraction (`eval_cuts`, the
    weighted-coefficient gradients, the per-worker b-block sums) reads
    `a` directly — nothing re-flattens per step.  `cuts.to_tree` derives
    the block-tree `CutSet` view when structured access is needed.
    """
    a: jnp.ndarray
    c: jnp.ndarray
    active: jnp.ndarray
    age: jnp.ndarray
    spec: Any = None


_register(FlatCuts, ["a", "c", "active", "age"], meta_fields=["spec"])


@dataclasses.dataclass
class CutSet:
    """DERIVED block-tree view of a polytope (compatibility boundary):
    { <a1,z1>+<a2,z2>+<a3,z3> + sum_j (<b2_j,x2_j> + <b3_j,x3_j>) <= c }.

    a_i : trees shaped like z_i with leading cut axis (P,)
    b_i : trees shaped like x_i with leading axes (P, N)
    c   : (P,) offsets;  active: (P,) {0,1} mask;  age: (P,) insertion time.
    Layer-I cuts simply carry zero b2/a2' blocks where a variable does not
    participate.

    The engine carries `FlatCuts`; materialize this view with
    `cuts.to_tree(fc)` (and go back with `cuts.from_tree(cs)`).  The
    tree-op reference implementations (`cuts.eval_cuts_tree`,
    `cuts.cut_weighted_coeff` on a CutSet) operate on this layout.
    """
    a1: Any
    a2: Any
    a3: Any
    b2: Any
    b3: Any
    c: jnp.ndarray
    active: jnp.ndarray
    age: jnp.ndarray


_register(CutSet, ["a1", "a2", "a3", "b2", "b3", "c", "active", "age"])


@dataclasses.dataclass
class InnerState3:
    """Level-3 inner ADMM state (Eq. 4-8): x3'_j, z3', duals phi3_j."""
    x3: Any        # (N, ...) stacked
    z3: Any
    phi: Any       # (N, ...) stacked duals


_register(InnerState3, ["x3", "z3", "phi"])


@dataclasses.dataclass
class InnerState2:
    """Level-2 inner ADMM state (Eq. 11): x2'_j, z2', duals phi2_j,
    slacks s_l >= 0 and cut multipliers gamma_l for the I-layer polytope."""
    x2: Any
    z2: Any
    phi: Any
    s: jnp.ndarray       # (P,)
    gamma: jnp.ndarray   # (P,)


_register(InnerState2, ["x2", "z2", "phi", "s", "gamma"])


@dataclasses.dataclass
class StaleView:
    """Per-worker snapshots of the master state taken at each worker's last
    active iteration t_hat_j (Eq. 16's L_p^{t_hat_j})."""
    z1: Any              # (N, ...) stacked
    z2: Any
    z3: Any
    lam: jnp.ndarray     # (N, P)
    theta: Any           # (N, ...) own dual snapshot
    t_hat: jnp.ndarray   # (N,) int32 — last active iteration per worker


_register(StaleView, ["z1", "z2", "z3", "lam", "theta", "t_hat"])


@dataclasses.dataclass
class AFTOState:
    X1: Any              # (N, ...) worker-local variables
    X2: Any
    X3: Any
    z1: Any
    z2: Any
    z3: Any
    theta: Any           # (N, ...) consensus duals (Eq. 14)
    lam: jnp.ndarray     # (P,) II-layer cut duals
    cuts_i: FlatCuts     # I-layer polytope, canonical (P, D) flat storage
    cuts_ii: FlatCuts    # II-layer polytope, canonical (P, D) flat storage
    gamma_k: jnp.ndarray  # (P,) last inner gamma (drop rule, Eq. 25)
    inner3: InnerState3   # warm-started level-3 inner state
    inner2: InnerState2   # warm-started level-2 inner state
    stale: StaleView
    t: jnp.ndarray        # master iteration counter (int32 scalar)


_register(AFTOState, ["X1", "X2", "X3", "z1", "z2", "z3", "theta", "lam",
                      "cuts_i", "cuts_ii", "gamma_k", "inner3", "inner2",
                      "stale", "t"])


@dataclasses.dataclass(frozen=True)
class TrilevelProblem:
    """A distributed trilevel problem (Eq. 2/3).

    f1/f2/f3 are *per-worker* objectives with signature
        f(data_j, x1, x2, x3) -> scalar
    where data_j is worker j's slice of `data` (leading axis N per leaf).
    The global objective at each level is the sum over workers.
    """
    f1: Callable
    f2: Callable
    f3: Callable
    data: Any
    n_workers: int
    x1_init: Any
    x2_init: Any
    x3_init: Any

    def sum_f(self, f, X1, X2, X3):
        """sum_j f(data_j, x1_j, x2_j, x3_j) with stacked per-worker args."""
        vals = jax.vmap(f, in_axes=(0, 0, 0, 0))(self.data, X1, X2, X3)
        return jnp.sum(vals)
