"""Host-side asynchrony: straggler model, active sets, simulated clock.

JAX programs are SPMD-synchronous, so the *semantics* of asynchrony (Eq.
16's stale views, the S-of-N arrival rule, the tau-staleness bound) are
expressed inside the jitted `afto_step`, while *who arrives when* and the
wall-clock cost of each master iteration are simulated here with a
deterministic seeded latency model.  Setting ``s_active == n_workers``
recovers SFTO (the synchronous baseline in Fig. 1/2).
"""
from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The arrival process of `n_iterations` master iterations, materialized.

    Because the straggler model is a seeded simulation with no feedback
    from the optimization state, the entire process can be computed up
    front and handed to the compiled trajectory engine
    (`repro.core.engine.run_scanned`) as plain arrays.
    """
    active: np.ndarray         # (T, N) float32 arrival masks
    sim_time: np.ndarray       # (T,) float64 completion sim-times
    max_staleness: np.ndarray  # (T,) int64 max staleness after each iter
    # Degradation marker (fault-tolerant runtime): (T, N) {0,1} mask of
    # workers DECLARED DEAD as of each iteration.  `max_staleness` is
    # computed among live workers only, so a degraded trajectory still
    # satisfies the tau bound among survivors; `active` alone drives the
    # step math, so a degraded schedule replays exactly through
    # `run_scanned`.  None for simulated / pre-fault-era schedules.
    dead: Optional[np.ndarray] = None

    @property
    def n_iterations(self) -> int:
        return int(self.active.shape[0])

    @property
    def n_workers(self) -> int:
        return int(self.active.shape[1])

    def slice(self, a: int, b: int) -> "Schedule":
        """Iterations [a, b) as a standalone Schedule — the chunk view
        used by state-continued chunked dispatches (all per-iteration
        arrays sliced together)."""
        return dataclasses.replace(
            self, active=self.active[a:b], sim_time=self.sim_time[a:b],
            max_staleness=self.max_staleness[a:b],
            dead=None if self.dead is None else self.dead[a:b])

    def worker_shards(self, n_shards: int) -> np.ndarray:
        """Host-side inspection helper: the arrival masks grouped by
        worker-mesh shard, (n_shards, T, N / n_shards).  Row w holds the
        same contiguous column block the sharded engine's in_spec
        assigns shard w (the engine itself slices via shard_map and does
        not call this; `sim_time`/`max_staleness` are master-side and
        stay global).  Raises if the worker axis doesn't partition."""
        n = self.n_workers
        if n % n_shards != 0:
            raise ValueError(
                f"{n} workers do not partition over {n_shards} shards")
        t = self.n_iterations
        return np.ascontiguousarray(
            self.active.reshape(t, n_shards, n // n_shards)
            .transpose(1, 0, 2))


class ArrivalRecorder:
    """Materializes a LIVE arrival process into a `Schedule`.

    The simulated `StragglerScheduler` below is an open-loop model: it
    draws arrival times from a seeded latency distribution with no
    feedback from the optimization.  The async runtime
    (`repro.fed.runtime`) replaces it with the real thing — worker
    processes push updates when their actual computation finishes — and
    records each master iteration here, so the observed process comes
    back out as a first-class `Schedule`: replayable through
    `run_scanned` (the runtime's conformance anchor) and inspectable
    with the same tooling as the simulated schedules.
    """

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self._active: List[np.ndarray] = []
        self._sim_time: List[float] = []
        self._staleness: List[int] = []
        self._dead: List[np.ndarray] = []
        self.last_active = np.zeros(self.n_workers, dtype=np.int64)
        self.dead = np.zeros(self.n_workers, dtype=bool)

    @property
    def t(self) -> int:
        return len(self._active)

    def mark_dead(self, j: int) -> None:
        """Declare worker j dead: it is excluded from the staleness
        diagnostics (and from the master's tau-forced set) until it
        rejoins.  Recorded per iteration as the schedule's `dead` mask."""
        self.dead[int(j)] = True

    def mark_alive(self, j: int) -> None:
        """Resurrect worker j (rejoin).  Its staleness clock restarts at
        the current iteration — a rejoined worker gets the full tau
        window to produce its first push, exactly like a worker whose
        push was just consumed."""
        j = int(j)
        self.dead[j] = False
        self.last_active[j] = self.t

    def record(self, active_mask, sim_time: float) -> int:
        """Append one master iteration's arrival set; returns the max
        staleness after the iteration (the paper's tau diagnostic,
        computed among live workers only)."""
        mask = np.asarray(active_mask, np.float32).reshape(self.n_workers)
        self._active.append(mask)
        self._sim_time.append(float(sim_time))
        self._dead.append(self.dead.astype(np.float32).copy())
        t = self.t
        self.last_active[mask > 0] = t
        live = ~self.dead
        stale = int(np.max((t - self.last_active)[live])) if live.any() \
            else 0
        self._staleness.append(stale)
        return stale

    def staleness(self) -> np.ndarray:
        """Per-worker staleness going INTO the next iteration (t+1 -
        last_active): the quantity the tau-forcing rule bounds.  Dead
        workers' entries keep growing — mask with the liveness view
        before forcing on them."""
        return (self.t + 1) - self.last_active

    def to_schedule(self) -> Schedule:
        """The recorded process as a `Schedule` (empty recorders yield
        zero-length schedules)."""
        n = self.n_workers
        return Schedule(
            active=(np.stack(self._active) if self._active
                    else np.zeros((0, n), np.float32)),
            sim_time=np.asarray(self._sim_time, np.float64),
            max_staleness=np.asarray(self._staleness, np.int64),
            dead=(np.stack(self._dead) if self._dead
                  else np.zeros((0, n), np.float32)))

    # -- durable-master support (checkpoint/io.py array dicts) -------------

    def state_dict(self) -> dict:
        """The recorder's full mutable state as a flat name -> ndarray
        dict (the checkpointable form of the live arrival process)."""
        n = self.n_workers
        return {
            "active": (np.stack(self._active) if self._active
                       else np.zeros((0, n), np.float32)),
            "sim_time": np.asarray(self._sim_time, np.float64),
            "staleness": np.asarray(self._staleness, np.int64),
            "dead_hist": (np.stack(self._dead) if self._dead
                          else np.zeros((0, n), np.float32)),
            "last_active": self.last_active.copy(),
            "dead": self.dead.copy(),
        }

    def load_state_dict(self, d: dict) -> None:
        """Inverse of `state_dict`: restore the recorded history and the
        liveness clocks in place."""
        self._active = [np.asarray(r, np.float32)
                        for r in np.asarray(d["active"])]
        self._sim_time = [float(x) for x in np.asarray(d["sim_time"])]
        self._staleness = [int(x) for x in np.asarray(d["staleness"])]
        self._dead = [np.asarray(r, np.float32)
                      for r in np.asarray(d["dead_hist"])]
        self.last_active = np.asarray(d["last_active"], np.int64).copy()
        self.dead = np.asarray(d["dead"], bool).copy()


@dataclasses.dataclass
class StragglerConfig:
    n_workers: int
    s_active: int                 # S
    tau: int                      # staleness bound
    n_stragglers: int = 0
    straggler_slowdown: float = 5.0
    base_latency: float = 1.0     # mean per-iteration worker latency
    jitter: float = 0.2           # lognormal sigma
    seed: int = 0


class StragglerScheduler:
    """Event-driven simulation of the parameter-server arrival process.

    Each worker finishes its local update ``latency_j`` after the last
    broadcast it received.  The master proceeds once S workers have
    arrived; any worker about to exceed the staleness bound tau is waited
    for regardless (the paper requires every worker to communicate at
    least once every tau iterations).
    """

    def __init__(self, cfg: StragglerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        slow = np.ones(cfg.n_workers)
        slow[: cfg.n_stragglers] = cfg.straggler_slowdown
        self.rng.shuffle(slow)
        self.slowdown = slow
        # worker j's pending update becomes available at ready[j]
        self.now = 0.0
        self.ready = self._draw_latency()
        self.last_active = np.zeros(cfg.n_workers, dtype=np.int64)
        self.t = 0

    def _draw_latency(self) -> np.ndarray:
        c = self.cfg
        lat = c.base_latency * self.slowdown * self.rng.lognormal(
            mean=0.0, sigma=c.jitter, size=c.n_workers)
        return self.now + lat

    def next_active(self) -> Tuple[np.ndarray, float]:
        """Returns ((N,) float mask, iteration completion sim-time)."""
        c = self.cfg
        self.t += 1
        staleness = self.t - self.last_active
        forced = staleness >= c.tau                    # must arrive now

        order = np.argsort(self.ready)
        chosen = set(np.nonzero(forced)[0].tolist())
        for j in order:
            if len(chosen) >= max(c.s_active, len(chosen)):
                break
            chosen.add(int(j))
        chosen_idx = np.array(sorted(chosen), dtype=np.int64)

        # master waits for the slowest chosen worker
        t_done = float(np.max(self.ready[chosen_idx]))
        # any other worker already finished by then also gets included
        extra = np.nonzero(self.ready <= t_done)[0]
        active_idx = np.union1d(chosen_idx, extra)

        self.now = t_done
        mask = np.zeros(c.n_workers, dtype=np.float32)
        mask[active_idx] = 1.0
        self.last_active[active_idx] = self.t
        # active workers start a fresh local computation after broadcast
        new_ready = self._draw_latency()
        self.ready = np.where(mask > 0, new_ready, self.ready)
        return mask, self.now

    def max_staleness(self) -> int:
        return int(np.max(self.t - self.last_active))

    def precompute(self, n_iterations: int) -> Schedule:
        """Materialize the next `n_iterations` of the arrival process.

        Steps a deep copy of the current scheduler state, so `self` is
        left untouched; the result is bit-identical to calling
        `next_active()` `n_iterations` times on this scheduler.
        """
        clone = copy.deepcopy(self)
        n = self.cfg.n_workers
        active = np.empty((n_iterations, n), np.float32)
        sim_time = np.empty((n_iterations,), np.float64)
        staleness = np.empty((n_iterations,), np.int64)
        for i in range(n_iterations):
            mask, t_done = clone.next_active()
            active[i] = mask
            sim_time[i] = t_done
            staleness[i] = clone.max_staleness()
        return Schedule(active=active, sim_time=sim_time,
                        max_staleness=staleness)
