"""Host-side asynchrony: straggler model, active sets, simulated clock.

JAX programs are SPMD-synchronous, so the *semantics* of asynchrony (Eq.
16's stale views, the S-of-N arrival rule, the tau-staleness bound) are
expressed inside the jitted `afto_step`, while *who arrives when* and the
wall-clock cost of each master iteration are simulated here with a
deterministic seeded latency model.  Setting ``s_active == n_workers``
recovers SFTO (the synchronous baseline in Fig. 1/2).
"""
from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The arrival process of `n_iterations` master iterations, materialized.

    Because the straggler model is a seeded simulation with no feedback
    from the optimization state, the entire process can be computed up
    front and handed to the compiled trajectory engine
    (`repro.core.engine.run_scanned`) as plain arrays.
    """
    active: np.ndarray         # (T, N) float32 arrival masks
    sim_time: np.ndarray       # (T,) float64 completion sim-times
    max_staleness: np.ndarray  # (T,) int64 max staleness after each iter
    # Degradation marker (fault-tolerant runtime): (T, N) {0,1} mask of
    # workers DECLARED DEAD as of each iteration.  `max_staleness` is
    # computed among live workers only, so a degraded trajectory still
    # satisfies the tau bound among survivors; `active` alone drives the
    # step math, so a degraded schedule replays exactly through
    # `run_scanned`.  None for simulated / pre-fault-era schedules.
    dead: Optional[np.ndarray] = None
    # Arrival-control audit trail (live runtime): the EFFECTIVE quorum
    # and forcing horizon the master actually used at each iteration —
    # fixed (s_active, tau) without a policy, `ArrivalPolicy`'s
    # per-iteration proposals with one.  Pure bookkeeping: `active`
    # alone drives the step math, so adapted trajectories replay
    # exactly; these columns make the adaptation inspectable and ride
    # slices/checkpoints losslessly.  None for simulated schedules.
    s_eff: Optional[np.ndarray] = None      # (T,) int64
    tau_eff: Optional[np.ndarray] = None    # (T,) int64
    # Elastic-membership marker (live admission): the worker-population
    # width at each iteration.  A schedule recorded through a mid-run
    # admission keeps FULL-width columns (historical rows of `active`
    # are zero-padded, `dead` one-padded — a worker that did not exist
    # yet is recorded dead), and `width` says where the population grew,
    # so the trajectory replays exactly as per-width segments (run at
    # width[0], `membership.grow_state` at each increase, continue).
    # None for fixed-membership schedules — a run that never admits is
    # structurally (and bitwise) unchanged by the elastic code paths.
    width: Optional[np.ndarray] = None      # (T,) int64

    @property
    def n_iterations(self) -> int:
        return int(self.active.shape[0])

    @property
    def n_workers(self) -> int:
        return int(self.active.shape[1])

    def slice(self, a: int, b: int) -> "Schedule":
        """Iterations [a, b) as a standalone Schedule — the chunk view
        used by state-continued chunked dispatches (all per-iteration
        arrays sliced together)."""
        return dataclasses.replace(
            self, active=self.active[a:b], sim_time=self.sim_time[a:b],
            max_staleness=self.max_staleness[a:b],
            dead=None if self.dead is None else self.dead[a:b],
            s_eff=None if self.s_eff is None else self.s_eff[a:b],
            tau_eff=None if self.tau_eff is None else self.tau_eff[a:b],
            width=None if self.width is None else self.width[a:b])

    def worker_shards(self, n_shards: int) -> np.ndarray:
        """Host-side inspection helper: the arrival masks grouped by
        worker-mesh shard, (n_shards, T, N / n_shards).  Row w holds the
        same contiguous column block the sharded engine's in_spec
        assigns shard w (the engine itself slices via shard_map and does
        not call this; `sim_time`/`max_staleness` are master-side and
        stay global).  Raises if the worker axis doesn't partition."""
        n = self.n_workers
        if n % n_shards != 0:
            raise ValueError(
                f"{n} workers do not partition over {n_shards} shards")
        t = self.n_iterations
        return np.ascontiguousarray(
            self.active.reshape(t, n_shards, n // n_shards)
            .transpose(1, 0, 2))


class ArrivalRecorder:
    """Materializes a LIVE arrival process into a `Schedule`.

    The simulated `StragglerScheduler` below is an open-loop model: it
    draws arrival times from a seeded latency distribution with no
    feedback from the optimization.  The async runtime
    (`repro.fed.runtime`) replaces it with the real thing — worker
    processes push updates when their actual computation finishes — and
    records each master iteration here, so the observed process comes
    back out as a first-class `Schedule`: replayable through
    `run_scanned` (the runtime's conformance anchor) and inspectable
    with the same tooling as the simulated schedules.
    """

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self._active: List[np.ndarray] = []
        self._sim_time: List[float] = []
        self._staleness: List[int] = []
        self._dead: List[np.ndarray] = []
        # per-iteration effective (quorum, forcing horizon); -1 marks an
        # iteration recorded without them (pre-policy-era history)
        self._s_eff: List[int] = []
        self._tau_eff: List[int] = []
        # per-iteration population width (elastic membership); the
        # schedule's `width` column is emitted only if it ever changed
        self._width: List[int] = []
        self.last_active = np.zeros(self.n_workers, dtype=np.int64)
        self.dead = np.zeros(self.n_workers, dtype=bool)

    @property
    def t(self) -> int:
        return len(self._active)

    def mark_dead(self, j: int) -> None:
        """Declare worker j dead: it is excluded from the staleness
        diagnostics (and from the master's tau-forced set) until it
        rejoins.  Recorded per iteration as the schedule's `dead` mask."""
        self.dead[int(j)] = True

    def mark_alive(self, j: int) -> None:
        """Resurrect worker j (rejoin).  Its staleness clock restarts at
        the current iteration — a rejoined worker gets the full tau
        window to produce its first push, exactly like a worker whose
        push was just consumed."""
        j = int(j)
        self.dead[j] = False
        self.last_active[j] = self.t

    def widen(self, n_new: int) -> None:
        """Grow the worker axis to `n_new` (elastic admission).  The
        recorded history keeps full-width columns: historical `active`
        rows are zero-padded and `dead` rows one-padded — a worker that
        did not exist yet never arrived and is recorded dead — so the
        widened schedule's pre-admission segment, truncated back to the
        old width, is bitwise the schedule the narrow run recorded.
        Admitted workers start dead (the master's `mark_alive` on the
        ADMIT boundary resurrects them) with a fresh staleness clock."""
        n_new = int(n_new)
        if n_new < self.n_workers:
            raise ValueError(
                f"widen: {n_new} < current width {self.n_workers} "
                "(membership only grows)")
        if n_new == self.n_workers:
            return
        add = n_new - self.n_workers
        self._active = [np.concatenate([r, np.zeros(add, np.float32)])
                        for r in self._active]
        self._dead = [np.concatenate([r, np.ones(add, np.float32)])
                      for r in self._dead]
        self.last_active = np.concatenate(
            [self.last_active, np.full(add, self.t, np.int64)])
        self.dead = np.concatenate([self.dead, np.ones(add, bool)])
        self.n_workers = n_new

    def record(self, active_mask, sim_time: float,
               s_eff: Optional[int] = None,
               tau_eff: Optional[int] = None) -> int:
        """Append one master iteration's arrival set; returns the max
        staleness after the iteration (the paper's tau diagnostic,
        computed among live workers only).  `s_eff`/`tau_eff` are the
        effective quorum / forcing horizon the master used for this
        iteration (the `ArrivalPolicy` audit columns); omitted entries
        record as -1."""
        mask = np.asarray(active_mask, np.float32).reshape(self.n_workers)
        self._active.append(mask)
        self._sim_time.append(float(sim_time))
        self._dead.append(self.dead.astype(np.float32).copy())
        self._s_eff.append(-1 if s_eff is None else int(s_eff))
        self._tau_eff.append(-1 if tau_eff is None else int(tau_eff))
        self._width.append(self.n_workers)
        t = self.t
        self.last_active[mask > 0] = t
        live = ~self.dead
        stale = int(np.max((t - self.last_active)[live])) if live.any() \
            else 0
        self._staleness.append(stale)
        return stale

    def staleness(self) -> np.ndarray:
        """Per-worker staleness going INTO the next iteration (t+1 -
        last_active): the quantity the tau-forcing rule bounds.  Dead
        workers' entries keep growing — mask with the liveness view
        before forcing on them."""
        return (self.t + 1) - self.last_active

    def to_schedule(self) -> Schedule:
        """The recorded process as a `Schedule` (empty recorders yield
        zero-length schedules).  The effective-(s, tau) columns are
        emitted whenever any iteration recorded them (-1 rows mark the
        ones that didn't); all-unrecorded histories keep them None."""
        n = self.n_workers
        s_eff = np.asarray(self._s_eff, np.int64)
        tau_eff = np.asarray(self._tau_eff, np.int64)
        have_eff = bool((s_eff >= 0).any() or (tau_eff >= 0).any())
        width = np.asarray(self._width, np.int64)
        widened = bool(width.size and (width != width[0]).any())
        return Schedule(
            active=(np.stack(self._active) if self._active
                    else np.zeros((0, n), np.float32)),
            sim_time=np.asarray(self._sim_time, np.float64),
            max_staleness=np.asarray(self._staleness, np.int64),
            dead=(np.stack(self._dead) if self._dead
                  else np.zeros((0, n), np.float32)),
            s_eff=s_eff if have_eff else None,
            tau_eff=tau_eff if have_eff else None,
            width=width if widened else None)

    def recent(self, k: int = 8) -> List[dict]:
        """The last `k` recorded iterations as status rows (the
        `/status` endpoint's arrival table): per-iteration arrival set,
        the effective (s, tau) used, and the staleness diagnostic."""
        t0 = max(0, self.t - int(k))
        return [{
            "t": i + 1,
            "arrived": np.nonzero(self._active[i] > 0)[0].tolist(),
            "s_eff": int(self._s_eff[i]),
            "tau_eff": int(self._tau_eff[i]),
            "max_staleness": int(self._staleness[i]),
        } for i in range(t0, self.t)]

    # -- durable-master support (checkpoint/io.py array dicts) -------------

    def state_dict(self) -> dict:
        """The recorder's full mutable state as a flat name -> ndarray
        dict (the checkpointable form of the live arrival process)."""
        n = self.n_workers
        return {
            "active": (np.stack(self._active) if self._active
                       else np.zeros((0, n), np.float32)),
            "sim_time": np.asarray(self._sim_time, np.float64),
            "staleness": np.asarray(self._staleness, np.int64),
            "dead_hist": (np.stack(self._dead) if self._dead
                          else np.zeros((0, n), np.float32)),
            "s_eff": np.asarray(self._s_eff, np.int64),
            "tau_eff": np.asarray(self._tau_eff, np.int64),
            "width": np.asarray(self._width, np.int64),
            "last_active": self.last_active.copy(),
            "dead": self.dead.copy(),
        }

    def load_state_dict(self, d: dict) -> None:
        """Inverse of `state_dict`: restore the recorded history and the
        liveness clocks in place.  Checkpoints written before the
        effective-(s, tau) columns existed restore with -1 (unrecorded)
        rows."""
        self._active = [np.asarray(r, np.float32)
                        for r in np.asarray(d["active"])]
        self._sim_time = [float(x) for x in np.asarray(d["sim_time"])]
        self._staleness = [int(x) for x in np.asarray(d["staleness"])]
        self._dead = [np.asarray(r, np.float32)
                      for r in np.asarray(d["dead_hist"])]
        t = len(self._active)
        self._s_eff = [int(x) for x in np.asarray(
            d.get("s_eff", np.full(t, -1, np.int64)))]
        self._tau_eff = [int(x) for x in np.asarray(
            d.get("tau_eff", np.full(t, -1, np.int64)))]
        self.last_active = np.asarray(d["last_active"], np.int64).copy()
        self.dead = np.asarray(d["dead"], bool).copy()
        # a checkpointed GROWN recorder restores at its grown width;
        # pre-elastic checkpoints default to a constant-width history
        self.n_workers = int(self.last_active.shape[0])
        self._width = [int(x) for x in np.asarray(
            d.get("width", np.full(t, self.n_workers, np.int64)))]


def validate_arrival_params(s_active: int, tau: int, n_workers: int,
                            what: str = "arrival config") -> None:
    """Fail fast on arrival-rule parameters that can never be satisfied.

    `s_active > n_workers` makes the quorum wait a deadlock (the live
    population can never reach s_eff) and `tau < 1` forces every worker
    every iteration's entry into an always-violated staleness bound —
    both used to slip through construction silently and hang the first
    `_wait_arrivals`/`next_active` instead of raising here."""
    if not 1 <= int(s_active) <= int(n_workers):
        raise ValueError(
            f"{what}: s_active={s_active} must be in "
            f"[1, n_workers={n_workers}] — the S-of-N quorum can never "
            f"be met otherwise (deadlocked arrival wait)")
    if int(tau) < 1:
        raise ValueError(
            f"{what}: tau={tau} must be >= 1 — the staleness bound "
            f"admits no arrival process otherwise")


@dataclasses.dataclass
class StragglerConfig:
    n_workers: int
    s_active: int                 # S
    tau: int                      # staleness bound
    n_stragglers: int = 0
    straggler_slowdown: float = 5.0
    base_latency: float = 1.0     # mean per-iteration worker latency
    jitter: float = 0.2           # lognormal sigma
    seed: int = 0

    def __post_init__(self):
        validate_arrival_params(self.s_active, self.tau, self.n_workers,
                                what="StragglerConfig")


def quorum(forced: np.ndarray, order, s_active: int) -> np.ndarray:
    """The paper's arrival quorum, as a pure function: every tau-forced
    worker, plus the earliest-finishing others (in `order`) until at
    least `s_active` workers are chosen.  Returns sorted worker ids of
    size max(n_forced, s_active) (property-tested in
    tests/test_scheduler.py)."""
    chosen = set(int(j) for j in np.nonzero(np.asarray(forced))[0])
    for j in order:
        if len(chosen) >= s_active:
            break
        chosen.add(int(j))
    return np.array(sorted(chosen), dtype=np.int64)


@dataclasses.dataclass
class ArrivalPolicy:
    """Closed-loop arrival control from the recorded staleness, within
    the paper's proven envelope.

    The paper fixes (S, tau) up front; the runtime records the real
    arrival process (`ArrivalRecorder`), so the master can close the
    loop: each iteration it feeds the observed per-worker staleness in
    and gets an EFFECTIVE (s_eff, tau_eff) back.  The proposals never
    leave the bound the convergence proof needs — 1 <= s_eff (clipped
    to the live population by the master) and 1 <= tau_eff <= tau, so
    every forced arrival still happens at or before the paper's tau —
    and the step math only ever sees arrival masks, so adapted
    trajectories replay exactly; the per-iteration pair lands on the
    `Schedule`'s s_eff/tau_eff audit columns.

    The rule (cf. the arrival-rule lineage in *Asynchronous Distributed
    Bilevel Optimization*): staleness PRESSURE — any live worker within
    one iteration of the forcing horizon — means the population is
    heterogeneous enough that tau-forcing is about to serialize the
    master on the straggler, so wait for MORE workers per iteration
    (raise s_eff; arrivals stay fresher) and force one iteration
    earlier (tighten tau_eff, spending slack the bound allows).  After
    `relax_after` consecutive pressure-free iterations the boost decays
    one notch back toward the configured (s_active, tau).
    """
    s_active: int
    tau: int
    relax_after: int = 4
    max_boost: Optional[int] = None   # default: tau - 1 (keeps tau_eff >= 1)
    _boost: int = dataclasses.field(default=0, repr=False)
    _calm: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        if self.tau < 1 or self.s_active < 1:
            raise ValueError(
                f"ArrivalPolicy needs s_active >= 1 and tau >= 1; got "
                f"s_active={self.s_active}, tau={self.tau}")
        if self.max_boost is None:
            self.max_boost = max(0, int(self.tau) - 1)

    def propose(self, staleness, alive) -> Tuple[int, int]:
        """One iteration of feedback: observed per-worker staleness (the
        recorder's `staleness()`) + liveness mask in, effective
        (s_eff, tau_eff) out.  Call once per master iteration."""
        alive = np.asarray(alive, bool)
        live_stale = np.asarray(staleness)[alive]
        worst = int(live_stale.max()) if live_stale.size else 0
        tau_now = max(1, self.tau - self._boost)
        if worst >= tau_now - 1:
            self._boost = min(self._boost + 1, self.max_boost)
            self._calm = 0
        else:
            self._calm += 1
            if self._calm >= self.relax_after and self._boost > 0:
                self._boost -= 1
                self._calm = 0
        s_eff = max(1, self.s_active + self._boost)
        tau_eff = max(1, self.tau - self._boost)
        return s_eff, tau_eff


class StragglerScheduler:
    """Event-driven simulation of the parameter-server arrival process.

    Each worker finishes its local update ``latency_j`` after the last
    broadcast it received.  The master proceeds once S workers have
    arrived; any worker about to exceed the staleness bound tau is waited
    for regardless (the paper requires every worker to communicate at
    least once every tau iterations).
    """

    def __init__(self, cfg: StragglerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        slow = np.ones(cfg.n_workers)
        slow[: cfg.n_stragglers] = cfg.straggler_slowdown
        self.rng.shuffle(slow)
        self.slowdown = slow
        # worker j's pending update becomes available at ready[j]
        self.now = 0.0
        self.ready = self._draw_latency()
        self.last_active = np.zeros(cfg.n_workers, dtype=np.int64)
        self.t = 0

    def _draw_latency(self) -> np.ndarray:
        c = self.cfg
        lat = c.base_latency * self.slowdown * self.rng.lognormal(
            mean=0.0, sigma=c.jitter, size=c.n_workers)
        return self.now + lat

    def next_active(self) -> Tuple[np.ndarray, float]:
        """Returns ((N,) float mask, iteration completion sim-time)."""
        c = self.cfg
        self.t += 1
        staleness = self.t - self.last_active
        forced = staleness >= c.tau                    # must arrive now

        chosen_idx = quorum(forced, np.argsort(self.ready), c.s_active)

        # master waits for the slowest chosen worker
        t_done = float(np.max(self.ready[chosen_idx]))
        # any other worker already finished by then also gets included
        extra = np.nonzero(self.ready <= t_done)[0]
        active_idx = np.union1d(chosen_idx, extra)

        self.now = t_done
        mask = np.zeros(c.n_workers, dtype=np.float32)
        mask[active_idx] = 1.0
        self.last_active[active_idx] = self.t
        # active workers start a fresh local computation after broadcast
        new_ready = self._draw_latency()
        self.ready = np.where(mask > 0, new_ready, self.ready)
        return mask, self.now

    def max_staleness(self) -> int:
        return int(np.max(self.t - self.last_active))

    def precompute(self, n_iterations: int) -> Schedule:
        """Materialize the next `n_iterations` of the arrival process.

        Steps a deep copy of the current scheduler state, so `self` is
        left untouched; the result is bit-identical to calling
        `next_active()` `n_iterations` times on this scheduler.
        """
        clone = copy.deepcopy(self)
        n = self.cfg.n_workers
        active = np.empty((n_iterations, n), np.float32)
        sim_time = np.empty((n_iterations,), np.float64)
        staleness = np.empty((n_iterations,), np.int64)
        for i in range(n_iterations):
            mask, t_done = clone.next_active()
            active[i] = mask
            sim_time[i] = t_done
            staleness[i] = clone.max_staleness()
        return Schedule(active=active, sim_time=sim_time,
                        max_staleness=staleness)
