"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py  : pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py     : jit'd public wrappers (interpret=True off-TPU)
ref.py     : pure-jnp oracles (the correctness source of truth)
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (cut_eval, flash_attention, mlstm_chunk,
                               mlstm_sequence)
