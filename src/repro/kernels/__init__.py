"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py  : pl.pallas_call + explicit BlockSpec VMEM tiling
cut_ad.py  : {mv, vm, outer} primitive closure (kernel-backed autodiff
             to arbitrary order for the cut contraction)
ops.py     : jit'd public wrappers (interpret=True off-TPU)
ref.py     : pure-jnp oracles (the correctness source of truth)
"""
from repro.kernels import cut_ad, ops, ref
from repro.kernels.ops import (cut_eval, flash_attention, fused_cut_round,
                               mlstm_chunk, mlstm_sequence)
