"""Pallas TPU kernel: blockwise (flash) GQA attention forward.

Streams KV in (block_k x head_dim) VMEM tiles against a resident
(block_q x head_dim) query tile with the usual running-max/denominator
online softmax, so the (S x T) score matrix never exists in HBM —
this is the kernel that replaces the dry-run's naive attention on real
TPUs (and the §Perf chunked-attention iteration mirrors it in jnp).

Grid: (batch, q_heads, q_blocks, k_blocks), k innermost/sequential.
Causal + sliding-window masking happens on block offsets inside the
kernel; GQA maps q-head h to kv-head h // (H // Hkv) in the BlockSpec
index maps, so no KV replication is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -2.0 ** 20


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q, block_k, causal, window, scale):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, hd)

    s = q @ k.T                                       # (bq, bk)

    qb = pl.program_id(2)
    q_pos = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(kb == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B,S,H,hd); k/v: (B,T,Hkv,hd) -> (B,S,H,hd).  S % block_q == 0
    and T % block_k == 0 (the ops wrapper pads)."""
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / (hd ** 0.5)

    qt = q.transpose(0, 2, 1, 3)       # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3)       # (B,Hkv,T,hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, s // block_q, t // block_k)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, causal=causal,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, qb, kb: (bb, hh, qb, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qb, kb, g=g: (bb, hh // g, kb, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qb, kb, g=g: (bb, hh // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, qb, kb: (bb, hh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # denominator l
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
