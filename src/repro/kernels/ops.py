"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the kernel body executes in Python
via the Pallas interpreter — bit-accurate semantics, no Mosaic); on a
real TPU backend pass interpret=False (or rely on the default) to get
the compiled kernels.  Models select kernels via `use_pallas` flags; the
dry-run keeps the jnp oracles (Mosaic cannot AOT-lower on CPU).

Autodiff contract for the cut path: `cut_eval` (and the fused inner
round) are differentiable THROUGH the kernels to arbitrary order.  The
forward, the hand-written backward kernels (the `da = g a^T` rank-1 and
`dv = g^T A` row-reduction in `kernels/cut_eval.py`) and every
higher-order term route through the {mv, vm, outer} primitive closure in
`kernels.cut_ad`, whose JVP/transpose rules recurse into each other —
so the grad-of-grad'd inner-Lagrangian paths (cut refresh, Eqs. 23/24)
no longer force `impl="ref"`.  (The old caveat that a linearized
`pallas_call` has no JVP rule is resolved by the primitives, not by a
`custom_jvp`-over-`custom_vjp` composition — the latter has no transpose
for its tangent calls and dies under reverse mode.)
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.kernels import cut_ad as _cut_ad
from repro.kernels import cut_eval as _cut_eval_mod
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import inner_round as _round_mod
from repro.kernels import mlstm_chunk as _mlstm_mod

# trace-count pins (CI-style regression guards): incremented at TRACE
# time, so a warm jit cache keeps them flat and an unroll regression
# (e.g. mlstm_sequence falling back to a host chunk loop) multiplies
# the per-trace count.
TRACE_COUNTS: collections.Counter = collections.Counter()


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# cut_eval — the (P, D) cut contraction, AD-complete through the kernel
# ---------------------------------------------------------------------------
# The custom-VJP plumbing that used to live here (kernel forward, jnp
# backward, no JVP) is replaced by the cut_ad primitive closure: the
# backward algebra da = (g*active) v^T / dv = (g*active)^T A now runs on
# the hand-written rank1/vecmat kernels via the mv transpose rule, and
# the epilogue (- c) * active is plain jnp whose autodiff supplies
# dc/dactive.

@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "impl"))
def cut_eval(a, v, c, active, block_d: int = None,
             interpret: bool = None, impl: str = None):
    """(A @ v - c) * active — the single routing point for cut mat-vecs.

    impl="pallas": the Pallas kernels (interpret off-TPU, Mosaic on TPU)
    via the `cut_ad` primitives — forward, reverse, and arbitrary-order
    grad-of-grad all stay kernel-backed, and the sweep vmap batches
    natively.  impl="ref": the identical-math jnp mat-vec (the test
    oracle).  impl=None auto-routes: the Mosaic kernels on TPU, the jnp
    form elsewhere — off-TPU the kernel only exists in interpret mode,
    an emulation-order correctness tool (measured 3-8x slower per call
    at quickstart D and ~1000x at paper-scale D), while XLA compiles the
    jnp form to the same wide contraction the kernel implements.

    block_d defaults to the kernel's full tile; the kernel itself clamps
    the tile to the (128-aligned) variable space, so small cut spaces
    aren't padded to a full paper-scale tile."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return (a.astype(jnp.float32) @ v.astype(jnp.float32) - c) * active
    interpret = _default_interpret() if interpret is None else interpret
    if block_d is None:
        block_d = _cut_eval_mod.BLOCK_D
    raw = _cut_ad.matvec(a, v, block_d=block_d, interpret=interpret)
    return (raw - c) * active


# ---------------------------------------------------------------------------
# fused level-2 inner-ADMM cut round
# ---------------------------------------------------------------------------

def _fused_round_math(mv, vm, a, v, g_other, mask, c, active, s, gamma,
                      eta_z, eta_s, eta_dual, rho2):
    """The round algebra on abstract mv/vm contractions — instantiated
    with jnp (the oracle) or the cut_ad primitives (the kernel-backed
    tangent path).  Mirrors `inner.rollout2`'s round body exactly."""
    cv0 = (mv(a, v) - c) * active
    viol = (cv0 + s) * active
    w = (gamma + rho2 * viol) * active
    v_new = v - eta_z * (g_other + vm(w, a) * mask)
    cv1 = (mv(a, v_new) - c) * active
    g_s = (gamma + rho2 * (cv1 + s)) * active
    s_new = jnp.maximum(0.0, s - eta_s * g_s) * active
    gamma_new = jnp.maximum(0.0, gamma + eta_dual * (cv1 + s_new)) * active
    return v_new, cv1, s_new, gamma_new


def _fused_round_ref(a, v, g_other, mask, c, active, s, gamma, *,
                     eta_z, eta_s, eta_dual, rho2):
    af = a.astype(jnp.float32)
    return _fused_round_math(
        lambda A, x: af @ x.astype(jnp.float32),
        lambda g, A: g.astype(jnp.float32) @ af,
        a, v.astype(jnp.float32), g_other.astype(jnp.float32),
        mask.astype(jnp.float32), c, active, s, gamma,
        eta_z, eta_s, eta_dual, rho2)


def _fused_round_prims(block_d, interpret, eta_z, eta_s, eta_dual, rho2,
                       a, v, g_other, mask, c, active, s, gamma):
    """The same round decomposed onto the cut_ad primitives: three
    kernel-backed contractions, transposable/differentiable to any
    order.  This is the tangent (and hence the whole AD) path of the
    fused op; the monolithic two-pass kernel stays on the primal."""
    mv = functools.partial(_cut_ad.matvec, block_d=block_d,
                           interpret=interpret)
    vm = functools.partial(_cut_ad.vecmat, block_d=block_d,
                           interpret=interpret)
    return _fused_round_math(
        lambda A, x: mv(A, x), lambda g, A: vm(g, A),
        a, v.astype(jnp.float32), g_other.astype(jnp.float32),
        mask.astype(jnp.float32), c, active, s, gamma,
        eta_z, eta_s, eta_dual, rho2)


@functools.partial(jax.custom_jvp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _fused_round_p(block_d, interpret, eta_z, eta_s, eta_dual, rho2,
                   a, v, g_other, mask, c, active, s, gamma):
    return _round_mod.fused_cut_round(
        a, v, g_other, mask, c, active, s, gamma,
        eta_z=eta_z, eta_s=eta_s, eta_dual=eta_dual, rho2=rho2,
        block_d=block_d, interpret=interpret)


@_fused_round_p.defjvp
def _fused_round_jvp(block_d, interpret, eta_z, eta_s, eta_dual, rho2,
                     primals, tangents):
    # primal through the two-pass fused kernel; tangents through the
    # primitive decomposition (same math, one extra streamed pass),
    # which the cut_ad closure keeps transposable — so reverse mode and
    # grad-of-grad through the fused round stay kernel-backed.
    primal_out = _fused_round_p(block_d, interpret, eta_z, eta_s,
                                eta_dual, rho2, *primals)
    fn = functools.partial(_fused_round_prims, block_d, interpret,
                           eta_z, eta_s, eta_dual, rho2)
    _, tangent_out = jax.jvp(fn, primals, tangents)
    return primal_out, tangent_out


@functools.partial(jax.jit, static_argnames=(
    "eta_z", "eta_s", "eta_dual", "rho2", "block_d", "interpret", "impl"))
def fused_cut_round(a, v, g_other, mask, c, active, s, gamma, *,
                    eta_z: float, eta_s: float, eta_dual: float,
                    rho2: float, block_d: int = None,
                    interpret: bool = None, impl: str = None):
    """One fused level-2 inner-ADMM cut round (see kernels/inner_round).

    Returns (v_new, cutval_new, s_new, gamma_new).  impl="pallas": the
    single two-pass Pallas kernel on the primal, the `cut_ad` primitive
    decomposition on every tangent/cotangent (differentiable to any
    order).  impl="ref": the identical-math jnp decomposition — the
    scan-of-jnp oracle `inner.rollout2` uses off-TPU.  impl=None
    auto-routes like `cut_eval`."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _fused_round_ref(a, v, g_other, mask, c, active, s, gamma,
                                eta_z=eta_z, eta_s=eta_s,
                                eta_dual=eta_dual, rho2=rho2)
    interpret = _default_interpret() if interpret is None else interpret
    if block_d is None:
        block_d = _cut_eval_mod.BLOCK_D
    return _fused_round_p(block_d, interpret, eta_z, eta_s, eta_dual,
                          rho2, a, v, g_other, mask, c, active, s, gamma)


# ---------------------------------------------------------------------------
# attention / mLSTM
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """Pads S/T to block multiples, calls the kernel, unpads."""
    interpret = _default_interpret() if interpret is None else interpret
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, t))
    s_pad = ((s + bq - 1) // bq) * bq
    t_pad = ((t + bk - 1) // bk) * bk
    # padded K positions must never win the softmax: causal masking
    # handles q_pad; for k_pad rely on causal (k_pos > q_pos). For
    # non-causal inputs no mask covers the padding — require exact
    # block multiples there.
    if not causal and (t_pad != t or s_pad != s):
        raise ValueError(
            "non-causal flash_attention requires block-aligned shapes: "
            f"got q seq len {s} (block_q={bq}, padded {s_pad}) and "
            f"k/v seq len {t} (block_k={bk}, padded {t_pad}); pad the "
            "inputs to block multiples or use causal=True")
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    out = _flash_mod.flash_attention(qp, kp, vp, causal=causal,
                                     window=window, block_q=bq, block_k=bk,
                                     interpret=interpret)
    return out[:, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlstm_chunk(q, k, v, li, lf, c, n, m, interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mlstm_mod.mlstm_chunk(q, k, v, li, lf, c, n, m,
                                  interpret=interpret)


def mlstm_sequence(q, k, v, li, lf, state, chunk: int = 256,
                   interpret: bool = None):
    """Full-sequence chunkwise mLSTM via the kernel: q/k/v (B,S,H,hd),
    li/lf (B,S,H); state dict(c,n,m) as in models.xlstm.

    The full chunks run as ONE `lax.scan` over stacked chunk slices
    (the kernel body is traced once regardless of sequence length —
    pinned by `TRACE_COUNTS["mlstm_seq_body"]`); a ragged tail shorter
    than `chunk` is a single extra kernel call at its own length (a
    second trace, but only when S % chunk != 0)."""
    b, s, h, hd = q.shape
    n_full = s // chunk
    tail = s - n_full * chunk

    def to_bh(a):                     # (B,S,H,...) -> (B,H,S,...)
        return a.transpose(0, 2, 1, 3) if a.ndim == 4 \
            else a.transpose(0, 2, 1)[..., None]

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    lib, lfb = to_bh(li), to_bh(lf)
    c = state["c"]
    n = state["n"][:, :, None]
    m = state["m"][:, :, None, None]

    ys = []
    if n_full:
        def chunked(a):               # (B,H,S,x) -> (n_full, B,H,chunk,x)
            lead = a[:, :, :n_full * chunk]
            return lead.reshape(b, h, n_full, chunk,
                                lead.shape[-1]).transpose(2, 0, 1, 3, 4)

        def body(carry, xs):
            TRACE_COUNTS["mlstm_seq_body"] += 1
            c, n, m = carry
            qc, kc, vc, lic, lfc = xs
            y, c, n, m = mlstm_chunk(qc, kc, vc, lic, lfc, c, n, m,
                                     interpret=interpret)
            return (c, n, m), y

        (c, n, m), ys_scan = jax.lax.scan(
            body, (c, n, m),
            tuple(chunked(x) for x in (qb, kb, vb, lib, lfb)))
        ys.append(ys_scan.transpose(1, 2, 0, 3, 4)
                  .reshape(b, h, n_full * chunk, hd))
    if tail:
        sl = slice(n_full * chunk, s)
        y_t, c, n, m = mlstm_chunk(qb[:, :, sl], kb[:, :, sl],
                                   vb[:, :, sl], lib[:, :, sl],
                                   lfb[:, :, sl], c, n, m,
                                   interpret=interpret)
        ys.append(y_t)
    y = jnp.concatenate(ys, axis=2).transpose(0, 2, 1, 3)
    return y, {"c": c, "n": n[:, :, 0], "m": m[:, :, 0, 0]}
