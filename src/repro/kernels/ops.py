"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the kernel body executes in Python
via the Pallas interpreter — bit-accurate semantics, no Mosaic); on a
real TPU backend pass interpret=False (or rely on the default) to get
the compiled kernels.  Models select kernels via `use_pallas` flags; the
dry-run keeps the jnp oracles (Mosaic cannot AOT-lower on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cut_eval as _cut_eval_mod
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import mlstm_chunk as _mlstm_mod


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cut_eval(a, v, c, active, block_d: int = 2048,
             interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _cut_eval_mod.cut_eval(a, v, c, active, block_d=block_d,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """Pads S/T to block multiples, calls the kernel, unpads."""
    interpret = _default_interpret() if interpret is None else interpret
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, t))
    s_pad = ((s + bq - 1) // bq) * bq
    t_pad = ((t + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    # padded K positions must never win the softmax: causal masking
    # handles q_pad; for k_pad rely on causal (k_pos > q_pos). For
    # non-causal inputs, mask via window trick is not available — require
    # causal or exact multiples there.
    if not causal:
        assert t_pad == t and s_pad == s, \
            "non-causal flash requires block-aligned shapes"
    out = _flash_mod.flash_attention(qp, kp, vp, causal=causal,
                                     window=window, block_q=bq, block_k=bk,
                                     interpret=interpret)
    return out[:, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlstm_chunk(q, k, v, li, lf, c, n, m, interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mlstm_mod.mlstm_chunk(q, k, v, li, lf, c, n, m,
                                  interpret=interpret)


def mlstm_sequence(q, k, v, li, lf, state, chunk: int = 256,
                   interpret: bool = None):
    """Full-sequence chunkwise mLSTM via the kernel: q/k/v (B,S,H,hd),
    li/lf (B,S,H); state dict(c,n,m) as in models.xlstm."""
    b, s, h, hd = q.shape
    n_chunks = max(1, s // chunk)
    cl = s // n_chunks

    def to_bh(a):                     # (B,S,H,...) -> (B,H,S,...)
        return a.transpose(0, 2, 1, 3) if a.ndim == 4 \
            else a.transpose(0, 2, 1)[..., None]

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    lib, lfb = to_bh(li), to_bh(lf)
    c = state["c"]
    n = state["n"][:, :, None]
    m = state["m"][:, :, None, None]

    ys = []
    for i in range(n_chunks):
        sl = slice(i * cl, (i + 1) * cl)
        y, c, n, m = mlstm_chunk(qb[:, :, sl], kb[:, :, sl], vb[:, :, sl],
                                 lib[:, :, sl], lfb[:, :, sl], c, n, m,
                                 interpret=interpret)
        ys.append(y)
    y = jnp.concatenate(ys, axis=2).transpose(0, 2, 1, 3)
    return y, {"c": c, "n": n[:, :, 0], "m": m[:, :, 0, 0]}
