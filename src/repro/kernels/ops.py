"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the kernel body executes in Python
via the Pallas interpreter — bit-accurate semantics, no Mosaic); on a
real TPU backend pass interpret=False (or rely on the default) to get
the compiled kernels.  Models select kernels via `use_pallas` flags; the
dry-run keeps the jnp oracles (Mosaic cannot AOT-lower on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cut_eval as _cut_eval_mod
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import mlstm_chunk as _mlstm_mod


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# cut_eval sits on differentiated paths (the inner Lagrangians are
# grad-of-grad'd through the cut terms at refresh time), and pallas_call
# has no autodiff rule — so the kernel forward gets an explicit VJP whose
# backward is the plain mat-vec algebra.  vmap (the sweep batching) maps
# the kernel natively.

def _cut_eval_impl(block_d, interpret, a, v, c, active):
    return _cut_eval_mod.cut_eval(a, v, c, active, block_d=block_d,
                                  interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _cut_eval_p(block_d, interpret, a, v, c, active):
    return _cut_eval_impl(block_d, interpret, a, v, c, active)


def _cut_eval_fwd(block_d, interpret, a, v, c, active):
    out = _cut_eval_impl(block_d, interpret, a, v, c, active)
    return out, (a, v, c, active)


def _cut_eval_bwd(block_d, interpret, res, g):
    a, v, c, active = res
    af = a.astype(jnp.float32)
    ga = (g * active).astype(jnp.float32)          # (P,)
    da = ga[:, None] * v.astype(jnp.float32)[None, :]
    dv = ga @ af
    # the raw (unmasked) values are only needed for d/dactive, which is
    # dead code on every current path (active is never differentiated) —
    # XLA removes the recomputed mat-vec when the cotangent is unused.
    dact = g * (af @ v.astype(jnp.float32) - c)
    return (da.astype(a.dtype), dv.astype(v.dtype),
            (-ga).astype(c.dtype), dact.astype(active.dtype))


_cut_eval_p.defvjp(_cut_eval_fwd, _cut_eval_bwd)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret", "impl"))
def cut_eval(a, v, c, active, block_d: int = None,
             interpret: bool = None, impl: str = None):
    """(A @ v - c) * active — the single routing point for cut mat-vecs.

    impl="pallas": the Pallas kernel (interpret off-TPU, Mosaic on TPU)
    with a custom VJP, so first-order reverse-mode works and the sweep
    vmap batches it natively.  impl="ref": the plain jnp mat-vec —
    required on paths that are differentiated to arbitrary order (the
    inner-ADMM Lagrangians are grad-of-grad'd through a scan at cut
    refresh, where a linearized kernel forward would need a Pallas JVP
    rule that does not exist).  impl=None auto-routes: the Mosaic kernel
    on TPU, the identical-math jnp mat-vec elsewhere — off-TPU the
    kernel only exists in interpret mode, an emulation-order correctness
    tool (measured 3-8x slower per call at quickstart D and ~1000x at
    paper-scale D), while XLA compiles the jnp form to the same wide
    contraction the kernel implements.

    block_d defaults to the kernel's full tile; the kernel itself clamps
    the tile to the (128-aligned) variable space, so small cut spaces
    aren't padded to a full paper-scale tile."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return (a.astype(jnp.float32) @ v.astype(jnp.float32) - c) * active
    interpret = _default_interpret() if interpret is None else interpret
    if block_d is None:
        block_d = _cut_eval_mod.BLOCK_D
    return _cut_eval_p(block_d, interpret, a, v, c, active)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """Pads S/T to block multiples, calls the kernel, unpads."""
    interpret = _default_interpret() if interpret is None else interpret
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, t))
    s_pad = ((s + bq - 1) // bq) * bq
    t_pad = ((t + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    # padded K positions must never win the softmax: causal masking
    # handles q_pad; for k_pad rely on causal (k_pos > q_pos). For
    # non-causal inputs, mask via window trick is not available — require
    # causal or exact multiples there.
    if not causal:
        assert t_pad == t and s_pad == s, \
            "non-causal flash requires block-aligned shapes"
    out = _flash_mod.flash_attention(qp, kp, vp, causal=causal,
                                     window=window, block_q=bq, block_k=bk,
                                     interpret=interpret)
    return out[:, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlstm_chunk(q, k, v, li, lf, c, n, m, interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mlstm_mod.mlstm_chunk(q, k, v, li, lf, c, n, m,
                                  interpret=interpret)


def mlstm_sequence(q, k, v, li, lf, state, chunk: int = 256,
                   interpret: bool = None):
    """Full-sequence chunkwise mLSTM via the kernel: q/k/v (B,S,H,hd),
    li/lf (B,S,H); state dict(c,n,m) as in models.xlstm."""
    b, s, h, hd = q.shape
    n_chunks = max(1, s // chunk)
    cl = s // n_chunks

    def to_bh(a):                     # (B,S,H,...) -> (B,H,S,...)
        return a.transpose(0, 2, 1, 3) if a.ndim == 4 \
            else a.transpose(0, 2, 1)[..., None]

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    lib, lfb = to_bh(li), to_bh(lf)
    c = state["c"]
    n = state["n"][:, :, None]
    m = state["m"][:, :, None, None]

    ys = []
    for i in range(n_chunks):
        sl = slice(i * cl, (i + 1) * cl)
        y, c, n, m = mlstm_chunk(qb[:, :, sl], kb[:, :, sl], vb[:, :, sl],
                                 lib[:, :, sl], lfb[:, :, sl], c, n, m,
                                 interpret=interpret)
        ys.append(y)
    y = jnp.concatenate(ys, axis=2).transpose(0, 2, 1, 3)
    return y, {"c": c, "n": n[:, :, 0], "m": m[:, :, 0, 0]}
