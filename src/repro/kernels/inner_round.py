"""Pallas TPU kernel: one fused level-2 inner-ADMM cut round.

One round of the Eq. 11 Jacobi sweep touches the canonical (P, D) cut
matrix three times when expressed as separate ops — the cut values at
the old consensus point (inside the Eq. 6 master gradient), the weighted
row-combination that IS that gradient's cut term, and the cut values at
the new point (the Eq. 11 slack/gamma steps).  XLA runs those as three
HBM passes over A.  This kernel fuses the whole cut side of the round
into ONE `pallas_call` that streams A exactly twice (the minimum: the
second mat-vec depends on the first's result through the z2 update):

  phase 0 (mv pass)   : acc    = A @ v                 tile-accumulated
      at the last tile: cutval0 = (acc - c) * active
                        viol    = (cutval0 + s) * active
                        w       = (gamma + rho2 * viol) * active
  phase 1 (fused pass): per D tile j —
                        g_cut_j = w^T A_j                      (Eq. 6 cut term)
                        v_new_j = v_j - eta_z*(g_other_j + g_cut_j * mask_j)
                        acc2   += A_j @ v_new_j
      at the last tile: cutval1 = (acc2 - c) * active
                        s'      = max(0, s - eta_s*(gamma
                                      + rho2*(cutval1 + s)) * active) * active
                        gamma'  = max(0, gamma
                                      + eta_dual*(cutval1 + s')) * active

`g_other` is the flattened non-cut part of the Eq. 6 master gradient
(zeros outside the z2 columns) and `mask` selects the z2 (a2-block)
columns, so v_new differs from v only where the round actually updates
the consensus variable.  The grid is (2, n_tiles): the TPU iterates the
grid lexicographically on one core, so the phase-0 accumulator and the
weight vector sit in scratch VMEM and are complete before phase 1 reads
them, the same way `kernels/mlstm_chunk.py` keeps its matrix memory
resident across a chunk.  The step scalars (eta_z, eta_s, eta_dual,
rho2) are jit-static hyper-parameters and close over the kernel body.

The identical-math jnp oracle and the AD story (a `custom_jvp` whose
tangents run through the `kernels.cut_ad` primitive decomposition, so
the fused op stays differentiable to arbitrary order) live in
`kernels.ops.fused_cut_round`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cut_eval import BLOCK_D, P_PAD, _clamp_block


def _round_kernel(a_ref, v_ref, g_ref, mask_ref, c_ref, act_ref, s_ref,
                  gam_ref, vnew_ref, cv_ref, snew_ref, gamnew_ref,
                  acc_ref, w_ref, *, eta_z, eta_s, eta_dual, rho2):
    ph = pl.program_id(0)
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)              # (P_pad, block_d)

    @pl.when(ph == 0)
    def _mv_pass():
        v = v_ref[...].astype(jnp.float32)          # (1, block_d)
        acc_ref[...] += jnp.sum(a * v, axis=1, keepdims=True)
        # defined content for the not-yet-updated v_new block; phase 1
        # revisits and overwrites it with the real update
        vnew_ref[...] = v

    @pl.when((ph == 0) & (j == nd - 1))
    def _weights():
        act = act_ref[...]
        cv0 = (acc_ref[...] - c_ref[...]) * act
        viol = (cv0 + s_ref[...]) * act
        w_ref[...] = (gam_ref[...] + rho2 * viol) * act
        acc_ref[...] = jnp.zeros_like(acc_ref)      # reuse for phase 1

    @pl.when(ph == 1)
    def _update_pass():
        v = v_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        msk = mask_ref[...].astype(jnp.float32)
        g_cut = jnp.sum(w_ref[...] * a, axis=0, keepdims=True)  # (1, bd)
        v_new = v - eta_z * (g + g_cut * msk)
        vnew_ref[...] = v_new
        acc_ref[...] += jnp.sum(a * v_new, axis=1, keepdims=True)

    @pl.when((ph == 1) & (j == nd - 1))
    def _epilogue():
        act = act_ref[...]
        s = s_ref[...]
        gam = gam_ref[...]
        cv1 = (acc_ref[...] - c_ref[...]) * act
        g_s = (gam + rho2 * (cv1 + s)) * act
        s_new = jnp.maximum(0.0, s - eta_s * g_s) * act
        gam_new = jnp.maximum(0.0, gam + eta_dual * (cv1 + s_new)) * act
        cv_ref[...] = cv1
        snew_ref[...] = s_new
        gamnew_ref[...] = gam_new


def fused_cut_round(a, v, g_other, mask, c, active, s, gamma, *,
                    eta_z: float, eta_s: float, eta_dual: float,
                    rho2: float, block_d: int = BLOCK_D,
                    interpret: bool = True):
    """One fused level-2 cut round.

    a: (P, D) cut matrix, v: (D,) flattened point at the OLD z2,
    g_other: (D,) non-cut master gradient (zeros off the z2 columns),
    mask: (D,) {0,1} z2-column selector, c/active/s/gamma: (P,) rows.
    Returns (v_new (D,), cutval_new (P,), s_new (P,), gamma_new (P,)),
    all f32."""
    p, d = a.shape
    p_pad = ((p + P_PAD - 1) // P_PAD) * P_PAD
    block_d = _clamp_block(d, block_d)
    d_pad = ((d + block_d - 1) // block_d) * block_d

    a_p = jnp.zeros((p_pad, d_pad), a.dtype).at[:p, :d].set(a)

    def row(x):
        return jnp.zeros((1, d_pad), jnp.float32).at[0, :d].set(
            x.astype(jnp.float32))

    def col(x):
        return jnp.zeros((p_pad, 1), jnp.float32).at[:p, 0].set(
            x.astype(jnp.float32))

    kernel = functools.partial(_round_kernel, eta_z=eta_z, eta_s=eta_s,
                               eta_dual=eta_dual, rho2=rho2)
    wide = pl.BlockSpec((1, block_d), lambda ph, j: (0, j))
    small = pl.BlockSpec((p_pad, 1), lambda ph, j: (0, 0))
    v_new, cv, s_new, gam_new = pl.pallas_call(
        kernel,
        grid=(2, d_pad // block_d),
        in_specs=[
            pl.BlockSpec((p_pad, block_d), lambda ph, j: (0, j)),
            wide, wide, wide, small, small, small, small,
        ],
        out_specs=[wide, small, small, small],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((p_pad, 1), jnp.float32),    # mv accumulator
            pltpu.VMEM((p_pad, 1), jnp.float32),    # phase-0 weights
        ],
        interpret=interpret,
    )(a_p, row(v), row(g_other), row(mask), col(c), col(active), col(s),
      col(gamma))
    return v_new[0, :d], cv[:p, 0], s_new[:p, 0], gam_new[:p, 0]
