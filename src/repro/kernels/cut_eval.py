"""Pallas TPU kernels: hyper-polyhedral cut contractions (fwd + bwd).

The paper's per-iteration hot spot (Eqs. 14, 20) is the wide contraction
of the canonical (P, D) cut matrix against a flattened variable point.
On TPU the variable dimension D is huge (the sketched cut space, or a
flattened paper-scale variable block), so every kernel here streams D in
VMEM-resident tiles along a sequential grid axis; P is padded to the
8-sublane boundary and partials accumulate in f32.

Three kernels cover the whole AD closure of the cut path (see
`kernels.cut_ad` for the primitive registrations that wire them into
jvp/transpose rules):

  matvec(a, v)  = A @ v      (P,)    the forward cut contraction
  vecmat(g, a)  = g^T A      (D,)    the row-reduction backward (dv)
  rank1(x, y)   = x y^T      (P, D)  the rank-1 backward (da)

`cut_eval` composes matvec with the tiny (P,)-sized epilogue
`(A v - c) * active` (jnp — O(P) work, fused by XLA around the kernel).

TPU adaptation (vs a GPU cutting-plane loop): one grid step's tile
(P_pad x block_d) is shaped for the MXU's (8x128) lanes — the row count
of cuts is tiny, so each kernel is deliberately a wide streaming op that
lives in VMEM, not an HBM-bound gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

P_PAD = 8          # sublane alignment for the cut axis
BLOCK_D = 2048     # lane-dim tile (multiple of 128)


def _clamp_block(d: int, block_d: int) -> int:
    # never tile wider than the (128-aligned) variable space itself —
    # quickstart-scale D would otherwise zero-pad to a full 2048 lane
    # tile and waste the whole MXU row on padding.
    return min(block_d, max(128, ((d + 127) // 128) * 128))


def _pad_mat(a, p_pad: int, d_pad: int):
    p, d = a.shape
    return jnp.zeros((p_pad, d_pad), a.dtype).at[:p, :d].set(a)


def _pad_row(v, d_pad: int):
    return jnp.zeros((1, d_pad), v.dtype).at[0, :v.shape[0]].set(v)


def _pad_col(x, p_pad: int):
    return jnp.zeros((p_pad, 1), x.dtype).at[:x.shape[0], 0].set(x)


# ---------------------------------------------------------------------------
# forward: matvec  (P,) = A @ v
# ---------------------------------------------------------------------------

def _matvec_kernel(a_ref, v_ref, out_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)          # (P_pad, block_d)
    v = v_ref[...].astype(jnp.float32)          # (1, block_d)
    out_ref[...] += jnp.sum(a * v, axis=1, keepdims=True)  # (P_pad, 1)


def matvec(a, v, *, block_d: int = BLOCK_D, interpret: bool = True):
    """a: (P, D), v: (D,) -> (P,) f32 raw contraction A @ v."""
    p, d = a.shape
    p_pad = ((p + P_PAD - 1) // P_PAD) * P_PAD
    block_d = _clamp_block(d, block_d)
    d_pad = ((d + block_d - 1) // block_d) * block_d
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec((p_pad, block_d), lambda j: (0, j)),
            pl.BlockSpec((1, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, 1), jnp.float32),
        interpret=interpret,
    )(_pad_mat(a, p_pad, d_pad), _pad_row(v, d_pad))
    return out[:p, 0]


# ---------------------------------------------------------------------------
# backward (dv): vecmat  (D,) = g^T A — row-reduction over the cut axis
# ---------------------------------------------------------------------------

def _vecmat_kernel(g_ref, a_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)          # (P_pad, 1)
    a = a_ref[...].astype(jnp.float32)          # (P_pad, block_d)
    out_ref[...] = jnp.sum(g * a, axis=0, keepdims=True)   # (1, block_d)


def vecmat(g, a, *, block_d: int = BLOCK_D, interpret: bool = True):
    """g: (P,), a: (P, D) -> (D,) f32 row-reduction g^T A.

    Each D tile is independent (the reduction runs over the resident P
    rows), so the grid has no sequential accumulator."""
    p, d = a.shape
    p_pad = ((p + P_PAD - 1) // P_PAD) * P_PAD
    block_d = _clamp_block(d, block_d)
    d_pad = ((d + block_d - 1) // block_d) * block_d
    out = pl.pallas_call(
        _vecmat_kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((p_pad, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
        interpret=interpret,
    )(_pad_col(g, p_pad), _pad_mat(a, p_pad, d_pad))
    return out[0, :d]


# ---------------------------------------------------------------------------
# backward (da): rank1  (P, D) = x y^T — the outer-product update
# ---------------------------------------------------------------------------

def _rank1_kernel(x_ref, y_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)          # (P_pad, 1)
    y = y_ref[...].astype(jnp.float32)          # (1, block_d)
    out_ref[...] = x * y                        # (P_pad, block_d)


def rank1(x, y, *, block_d: int = BLOCK_D, interpret: bool = True):
    """x: (P,), y: (D,) -> (P, D) f32 rank-1 outer product x y^T."""
    p, d = x.shape[0], y.shape[0]
    p_pad = ((p + P_PAD - 1) // P_PAD) * P_PAD
    block_d = _clamp_block(d, block_d)
    d_pad = ((d + block_d - 1) // block_d) * block_d
    out = pl.pallas_call(
        _rank1_kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p_pad, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(_pad_col(x, p_pad), _pad_row(y, d_pad))
    return out[:p, :d]


def cut_eval(a, v, c, active, *, block_d: int = BLOCK_D,
             interpret: bool = True):
    """a: (P, D), v: (D,), c: (P,), active: (P,) -> (P,) cut values.

    One streaming `matvec` kernel launch plus the O(P) jnp epilogue
    (identical math to the previously fused single-kernel form)."""
    return (matvec(a, v, block_d=block_d, interpret=interpret) - c) * active
