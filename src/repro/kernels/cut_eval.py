"""Pallas TPU kernel: hyper-polyhedral cut evaluation.

The paper's per-iteration hot spot (Eqs. 14, 20): evaluate every cutting
plane against the current variable point,

    val_l = active_l * ( sum_d A[l, d] * v[d]  -  c_l ),

where A stacks the |P| cut coefficient rows over the (flattened) variable
space.  On TPU the variable dimension D is huge (the sketched cut space,
or a flattened paper-scale variable block), so the kernel streams D in
VMEM-resident tiles along a sequential grid axis and accumulates the
(P,) partials in f32; P is padded to the 8-sublane boundary.

TPU adaptation (vs a GPU cutting-plane loop): one grid step's tile
(P_pad x block_d) is shaped for the MXU's (8x128) lanes — the row count
of cuts is tiny, so the kernel is deliberately a wide mat-vec that lives
in VMEM, not an HBM-bound gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

P_PAD = 8          # sublane alignment for the cut axis
BLOCK_D = 2048     # lane-dim tile (multiple of 128)


def _cut_eval_kernel(a_ref, v_ref, c_ref, active_ref, out_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)          # (P_pad, BLOCK_D)
    v = v_ref[...].astype(jnp.float32)          # (1, BLOCK_D)
    out_ref[...] += jnp.sum(a * v, axis=1, keepdims=True)  # (P_pad, 1)

    @pl.when(j == pl.num_programs(0) - 1)
    def _finish():
        c = c_ref[...].astype(jnp.float32)
        act = active_ref[...].astype(jnp.float32)
        out_ref[...] = (out_ref[...] - c) * act


def cut_eval(a, v, c, active, *, block_d: int = BLOCK_D,
             interpret: bool = True):
    """a: (P, D), v: (D,), c: (P,), active: (P,) -> (P,) cut values."""
    p, d = a.shape
    p_pad = ((p + P_PAD - 1) // P_PAD) * P_PAD
    # never tile wider than the (128-aligned) variable space itself —
    # quickstart-scale D would otherwise zero-pad to a full 2048 lane
    # tile and waste the whole MXU row on padding.
    block_d = min(block_d, max(128, ((d + 127) // 128) * 128))
    d_pad = ((d + block_d - 1) // block_d) * block_d
    a_p = jnp.zeros((p_pad, d_pad), a.dtype).at[:p, :d].set(a)
    v_p = jnp.zeros((1, d_pad), v.dtype).at[0, :d].set(v)
    c_p = jnp.zeros((p_pad, 1), jnp.float32).at[:p, 0].set(c)
    act_p = jnp.zeros((p_pad, 1), jnp.float32).at[:p, 0].set(active)

    grid = (d_pad // block_d,)
    out = pl.pallas_call(
        _cut_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_pad, block_d), lambda j: (0, j)),
            pl.BlockSpec((1, block_d), lambda j: (0, j)),
            pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((p_pad, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, 1), jnp.float32),
        interpret=interpret,
    )(a_p, v_p, c_p, act_p)
    return out[:p, 0]
