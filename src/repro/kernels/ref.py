"""Pure-jnp oracles for every Pallas kernel (the source of truth the
kernels are validated against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attend, causal_window_mask
from repro.models.xlstm import mlstm_chunk_body


def cut_eval_ref(a, v, c, active):
    """a: (P,D), v: (D,), c/active: (P,)."""
    val = a.astype(jnp.float32) @ v.astype(jnp.float32)
    return (val - c) * active


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd), k/v: (B,T,Hkv,hd)."""
    s, t = q.shape[1], k.shape[1]
    q_pos = jnp.arange(s)[None]
    k_pos = jnp.arange(t)[None]
    mask = None
    if causal or window:
        mask = causal_window_mask(q_pos, k_pos, window)
        if not causal:
            mask = mask | (k_pos[:, None, :] >= 0)
        mask = jnp.broadcast_to(mask, (q.shape[0],) + mask.shape[1:])
        mask = mask[:, None]
    return attend(q, k, v, mask)


def mlstm_chunk_ref(q, k, v, li, lf, c, n, m):
    """Same layout as kernels.mlstm_chunk: q/k/v (B,H,L,hd), li/lf
    (B,H,L,1), state (B,H,hd,hd)/(B,H,1,hd)/(B,H,1,1)."""
    # adapt to mlstm_chunk_body's (B,L,H,...) layout
    qb = q.transpose(0, 2, 1, 3)
    kb = k.transpose(0, 2, 1, 3)
    vb = v.transpose(0, 2, 1, 3)
    lib = li[..., 0].transpose(0, 2, 1)
    lfb = lf[..., 0].transpose(0, 2, 1)
    state = {"c": c, "n": n[:, :, 0], "m": m[:, :, 0, 0]}
    y, st = mlstm_chunk_body(qb, kb, vb, lib, lfb, state)
    return (y.transpose(0, 2, 1, 3), st["c"], st["n"][:, :, None],
            st["m"][:, :, None, None])
