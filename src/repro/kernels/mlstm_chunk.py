"""Pallas TPU kernel: chunkwise mLSTM (xLSTM's matrix-memory mixer).

One kernel invocation processes one (batch, head) pair's chunk of L
tokens against the carried (hd x hd) matrix memory C, normalizer n and
stabilizer m, producing the chunk's outputs and the updated state.  The
math mirrors `repro.models.xlstm.mlstm_chunk_body` (the oracle).

TPU adaptation: the recurrence is evaluated in its chunkwise-parallel
form so the inner ops are (L x hd)x(hd x hd) and (L x L) matmuls on the
MXU; the matrix memory tile stays resident in VMEM across the chunk.
Grid: (batch, heads) — independent programs, no sequential axis; the
sequential scan over chunks lives in the caller (ops.mlstm_sequence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mlstm_chunk_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
                        c_ref, n_ref, m_ref,
                        y_ref, c_out_ref, n_out_ref, m_out_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (L, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)              # (L, 1)
    lf = lf_ref[0, 0].astype(jnp.float32)              # (L, 1)
    c_prev = c_ref[0, 0].astype(jnp.float32)           # (hd, hd)
    n_prev = n_ref[0, 0].astype(jnp.float32)           # (1, hd)
    m_prev = m_ref[0, 0].astype(jnp.float32)           # (1, 1)

    l = q.shape[0]
    bcum = jnp.cumsum(lf, axis=0)                      # (L,1) inclusive
    btot = bcum[l - 1:l]                               # (1,1)

    # intra-chunk decay matrix D[t,s] = bcum_t - bcum_s + li_s (s <= t)
    dmat = bcum - bcum.T + li.T                        # (L,L)
    row = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    dmat = jnp.where(col <= row, dmat, NEG_INF)

    m_inter = bcum + m_prev                            # (L,1)
    m_intra = jnp.max(dmat, axis=1, keepdims=True)     # (L,1)
    m_t = jnp.maximum(m_inter, m_intra)

    w_inter = jnp.exp(m_inter - m_t)                   # (L,1)
    w_intra = jnp.exp(dmat - m_t)                      # (L,L)

    scores = (q @ k.T) * w_intra                       # (L,L)
    y_intra = scores @ v                               # (L,hd)
    den_intra = jnp.sum(scores, axis=1, keepdims=True)

    y_inter = (q @ c_prev) * w_inter                   # (L,hd)
    den_inter = (q @ n_prev.T) * w_inter               # (L,1)

    den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
    y_ref[0, 0] = ((y_intra + y_inter) / den).astype(y_ref.dtype)

    # end-of-chunk state
    m_new = jnp.maximum(btot + m_prev,
                        jnp.max(btot - bcum + li, axis=0, keepdims=True))
    w_c = jnp.exp(btot + m_prev - m_new)               # (1,1)
    w_k = jnp.exp(btot - bcum + li - m_new)            # (L,1)
    c_out_ref[0, 0] = (c_prev * w_c + (k * w_k).T @ v).astype(
        c_out_ref.dtype)
    n_out_ref[0, 0] = (n_prev * w_c + jnp.sum(k * w_k, axis=0,
                                              keepdims=True)).astype(
        n_out_ref.dtype)
    m_out_ref[0, 0] = m_new.astype(m_out_ref.dtype)


def mlstm_chunk(q, k, v, li, lf, c, n, m, *, interpret: bool = True):
    """One chunk for all (batch, head) pairs.

    q/k/v: (B,H,L,hd); li/lf: (B,H,L,1); c: (B,H,hd,hd); n: (B,H,1,hd);
    m: (B,H,1,1).  Returns (y (B,H,L,hd), c', n', m')."""
    b, h, l, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    grid = (b, h)
    spec = lambda *dims: pl.BlockSpec((1, 1) + dims,
                                      lambda bb, hh: (bb, hh, 0, 0))
    kernel = functools.partial(_mlstm_chunk_kernel, scale=scale)
    y, c2, n2, m2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec(l, hd), spec(l, hd), spec(l, hd),
                  spec(l, 1), spec(l, 1),
                  spec(hd, hd), spec(1, hd), spec(1, 1)],
        out_specs=[spec(l, hd), spec(hd, hd), spec(1, hd), spec(1, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf, c, n, m)
    return y, c2, n2, m2
