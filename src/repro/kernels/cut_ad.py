"""Differentiation-closed bilinear primitives over the cut matrix.

The three contractions the cut path ever needs —

    mv(a, v)    = A @ v            (P,)   forward cut values
    vm(g, a)    = g^T A            (D,)   row-reduction (the dv backward)
    outer(x, y) = x y^T            (P, D) rank-1 update (the da backward)

— are registered as first-class JAX primitives whose JVP, transpose and
batching rules are expressed in terms of EACH OTHER:

    jvp  mv    : (da, dv) -> mv(da, v) + mv(a, dv)
    T{mv}      : ct -> da = outer(ct, v),  dv = vm(ct, a)
    T{vm}      : ct -> dg = mv(a, ct),     da = outer(g, ct)
    T{outer}   : ct -> dx = mv(ct, y),     dy = vm(x, ct)

The set is closed under linearization AND transposition, so reverse
mode — and reverse-over-reverse, the Eq. 23/24 cut-refresh grad-of-grad
through the inner-ADMM rollouts — stays on the hand-written Pallas
kernels to arbitrary order; no differentiated path needs the
``impl="ref"`` fallback anymore.  (The obvious alternative, a
``custom_jvp``-over-``custom_vjp`` composition, fails in reverse mode on
this jax: the custom_vjp calls appearing in the tangent computation have
no transpose rule, so ``jax.grad`` of anything containing the JVP dies
with ``Transpose rule ... for 'custom_vjp_call_jaxpr' not
implemented``.)

Each primitive lowers through `mlir.lower_fun` to its kernel wrapper in
`kernels.cut_eval` (interpret mode off-TPU for bit-accurate testing,
Mosaic on a real TPU backend); ``block_d`` / ``interpret`` ride along as
static bind params so every rule's recursive binds inherit the caller's
tiling.  Batching (the sweep engine's run axis) vmaps the kernel
natively via `jax.vmap` of the impl.  All three primitives emit f32 (the
kernels accumulate in f32 regardless of input dtype); transpose rules
cast cotangents back to the primal dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.extend as jex
import jax.numpy as jnp
from jax.interpreters import ad, batching, mlir

from repro.kernels import cut_eval as _kern


# --- kernel-backed impls (also the lowering + batching bodies) -------------

def _mv_impl(a, v, *, block_d, interpret):
    return _kern.matvec(a, v, block_d=block_d, interpret=interpret)


def _vm_impl(g, a, *, block_d, interpret):
    return _kern.vecmat(g, a, block_d=block_d, interpret=interpret)


def _outer_impl(x, y, *, block_d, interpret):
    return _kern.rank1(x, y, block_d=block_d, interpret=interpret)


def _register(name, impl, abstract_eval):
    p = jex.core.Primitive(name)
    p.def_impl(functools.partial(_eager, impl))
    p.def_abstract_eval(abstract_eval)
    mlir.register_lowering(p, mlir.lower_fun(impl, multiple_results=False))

    def batch_rule(args, dims, **kw):
        x, y = args
        out = jax.vmap(functools.partial(impl, **kw), in_axes=dims)(x, y)
        return out, 0

    batching.primitive_batchers[p] = batch_rule
    return p


def _eager(impl, *args, **kw):
    return impl(*args, **kw)


def _f32(shape):
    return jax.core.ShapedArray(shape, jnp.float32)


mv_p = _register("cut_mv", _mv_impl,
                 lambda a, v, **kw: _f32((a.shape[0],)))
vm_p = _register("cut_vm", _vm_impl,
                 lambda g, a, **kw: _f32((a.shape[1],)))
outer_p = _register("cut_outer", _outer_impl,
                    lambda x, y, **kw: _f32((x.shape[0], y.shape[0])))


# --- JVPs: bilinear, each rule recurses into the same primitive ------------

ad.defjvp(mv_p,
          lambda da, a, v, **kw: mv_p.bind(da, v, **kw),
          lambda dv, a, v, **kw: mv_p.bind(a, dv, **kw))
ad.defjvp(vm_p,
          lambda dg, g, a, **kw: vm_p.bind(dg, a, **kw),
          lambda da, g, a, **kw: vm_p.bind(g, da, **kw))
ad.defjvp(outer_p,
          lambda dx, x, y, **kw: outer_p.bind(dx, y, **kw),
          lambda dy, x, y, **kw: outer_p.bind(x, dy, **kw))


# --- transposes: the closure property ---------------------------------------

def _cast_like(ct, primal):
    dtype = primal.aval.dtype if ad.is_undefined_primal(primal) else None
    return ct if dtype is None or ct.dtype == dtype else ct.astype(dtype)


def _mv_transpose(ct, a, v, **kw):
    ct = ad.instantiate_zeros(ct)
    if ad.is_undefined_primal(a):
        return _cast_like(outer_p.bind(ct, v, **kw), a), None
    return None, _cast_like(vm_p.bind(ct, a, **kw), v)


def _vm_transpose(ct, g, a, **kw):
    ct = ad.instantiate_zeros(ct)
    if ad.is_undefined_primal(g):
        return _cast_like(mv_p.bind(a, ct, **kw), g), None
    return None, _cast_like(outer_p.bind(g, ct, **kw), a)


def _outer_transpose(ct, x, y, **kw):
    ct = ad.instantiate_zeros(ct)
    if ad.is_undefined_primal(x):
        return _cast_like(mv_p.bind(ct, y, **kw), x), None
    return None, _cast_like(vm_p.bind(x, ct, **kw), y)


ad.primitive_transposes[mv_p] = _mv_transpose
ad.primitive_transposes[vm_p] = _vm_transpose
ad.primitive_transposes[outer_p] = _outer_transpose


# --- public entry points ----------------------------------------------------

def matvec(a, v, *, block_d: int = None, interpret: bool = True):
    """(P,) = A @ v through the kernel, differentiable to any order."""
    block_d = _kern.BLOCK_D if block_d is None else block_d
    return mv_p.bind(a, v, block_d=block_d, interpret=interpret)


def vecmat(g, a, *, block_d: int = None, interpret: bool = True):
    """(D,) = g^T A through the kernel, differentiable to any order."""
    block_d = _kern.BLOCK_D if block_d is None else block_d
    return vm_p.bind(g, a, block_d=block_d, interpret=interpret)


def outer(x, y, *, block_d: int = None, interpret: bool = True):
    """(P, D) = x y^T through the kernel, differentiable to any order."""
    block_d = _kern.BLOCK_D if block_d is None else block_d
    return outer_p.bind(x, y, block_d=block_d, interpret=interpret)
