"""Sharded host->device batch pipeline."""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_batch(batch, mesh: Mesh, spec: P):
    """Place a host batch onto the mesh with the given PartitionSpec."""
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


class ShardedLoader:
    """Deterministic epoch-shuffled loader over a host-resident array dict.

    Yields dicts of (global_batch, ...) arrays; with a mesh/spec it places
    them so the leading batch axis is sharded over the data axis.
    """

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0,
                 mesh: Optional[Mesh] = None, spec: Optional[P] = None,
                 drop_last: bool = True):
        sizes = {k: len(v) for k, v in arrays.items()}
        assert len(set(sizes.values())) == 1, f"ragged arrays: {sizes}"
        self.arrays = arrays
        self.n = next(iter(sizes.values()))
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.mesh, self.spec = mesh, spec
        self.drop_last = drop_last

    def __iter__(self) -> Iterator[dict]:
        idx = self.rng.permutation(self.n)
        stop = (self.n - self.batch_size + 1) if self.drop_last else self.n
        for s in range(0, max(stop, 0), self.batch_size):
            take = idx[s: s + self.batch_size]
            batch = {k: v[take] for k, v in self.arrays.items()}
            if self.mesh is not None:
                batch = shard_batch(batch, self.mesh, self.spec)
            yield batch
