"""Sharded host->device batch pipeline."""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_batch(batch, mesh: Mesh, spec: P):
    """Place a host batch onto the mesh with the given PartitionSpec."""
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


class ShardedLoader:
    """Deterministic epoch-shuffled loader over a host-resident array dict.

    Yields dicts of (global_batch, ...) arrays; with a mesh/spec it places
    them so the leading batch axis is sharded over the data axis.

    Epoch k's shuffle comes from its OWN `np.random.default_rng((seed,
    k))`, so it is a pure function of (seed, epoch index): restarting at
    epoch k reproduces epoch k's order, and concurrent iterators cannot
    scramble each other (the previous shared stateful generator advanced
    on every `__iter__`, so any interleaved or repeated iteration
    silently changed which permutation each epoch saw).
    """

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0,
                 mesh: Optional[Mesh] = None, spec: Optional[P] = None,
                 drop_last: bool = True):
        sizes = {k: len(v) for k, v in arrays.items()}
        assert len(set(sizes.values())) == 1, f"ragged arrays: {sizes}"
        self.arrays = arrays
        self.n = next(iter(sizes.values()))
        self.batch_size = batch_size
        self.seed = seed
        self._epoch = 0
        self.mesh, self.spec = mesh, spec
        self.drop_last = drop_last

    def __iter__(self) -> Iterator[dict]:
        # claim the epoch index at iter() time (not first next()), so
        # the epoch an iterator shuffles with depends only on creation
        # order, never on consumption interleaving
        epoch, self._epoch = self._epoch, self._epoch + 1
        idx = np.random.default_rng((self.seed, epoch)).permutation(self.n)
        return self._iter_epoch(idx)

    def _iter_epoch(self, idx) -> Iterator[dict]:
        stop = (self.n - self.batch_size + 1) if self.drop_last else self.n
        for s in range(0, max(stop, 0), self.batch_size):
            take = idx[s: s + self.batch_size]
            batch = {k: v[take] for k, v in self.arrays.items()}
            if self.mesh is not None:
                batch = shard_batch(batch, self.mesh, self.spec)
            yield batch
