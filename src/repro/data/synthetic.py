"""Synthetic datasets with the exact shapes of the paper's benchmarks.

This container is offline, so the UCI regression sets (Diabetes, Boston,
Red-/White-wine) and MNIST/SVHN cannot be downloaded.  We generate
synthetic stand-ins that match the originals' (n_samples, n_features) /
image geometry, label structure, and noise character, so every pipeline
stage (worker sharding, trilevel objectives, evaluation protocol) runs
unchanged.  EXPERIMENTS.md therefore validates *relative* claims (AFTO vs
SFTO speedup, AFTO vs ADBO/FedNest ordering), not absolute MSE values.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import numpy as np

# (n_samples, n_features) of the real datasets used in the paper (Table 1)
REGRESSION_SPECS: Dict[str, Tuple[int, int]] = {
    "diabetes": (442, 10),
    "boston": (506, 13),
    "red_wine": (1599, 11),
    "white_wine": (4898, 11),
}


@dataclasses.dataclass
class RegressionData:
    name: str
    x_train: np.ndarray      # (N, n_tr, d) worker-sharded
    y_train: np.ndarray      # (N, n_tr)
    x_val: np.ndarray        # (N, n_val, d)
    y_val: np.ndarray
    x_test: np.ndarray       # (n_test, d) global
    y_test: np.ndarray


def _ground_truth(x: np.ndarray, w: np.ndarray, rng) -> np.ndarray:
    """Mildly non-linear teacher: linear + tanh interaction + noise."""
    lin = x @ w[: x.shape[1]]
    inter = np.tanh(x @ np.roll(w[: x.shape[1]], 1)) * 0.5
    return lin + inter


def make_regression(name: str, n_workers: int, seed: int = 0,
                    val_frac: float = 0.2,
                    test_frac: float = 0.2) -> RegressionData:
    n, d = REGRESSION_SPECS[name]
    # crc32, not hash(): Python string hashing is salted per process
    # (PYTHONHASHSEED), which silently made every benchmark dataset —
    # and with it Table-2 MSEs — non-reproducible across runs.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d,)).astype(np.float32) / np.sqrt(d)
    y = _ground_truth(x, w, rng) + 0.1 * rng.normal(size=(n,))
    y = ((y - y.mean()) / (y.std() + 1e-8)).astype(np.float32)

    n_test = int(n * test_frac)
    x_test, y_test = x[:n_test], y[:n_test]
    x_rem, y_rem = x[n_test:], y[n_test:]
    n_val = int(len(x_rem) * val_frac)

    # equal worker shards (truncate the remainder for a rectangular array)
    def shard(a, n_per):
        per = (len(a) // n_workers)
        a = a[: per * n_workers].reshape(n_workers, per, *a.shape[1:])
        return a[:, :n_per]

    n_tr_per = (len(x_rem) - n_val) // n_workers
    n_val_per = max(1, n_val // n_workers)
    xv, yv = x_rem[:n_val], y_rem[:n_val]
    xt, yt = x_rem[n_val:], y_rem[n_val:]
    return RegressionData(
        name=name,
        x_train=shard(xt, n_tr_per), y_train=shard(yt, n_tr_per),
        x_val=shard(xv, n_val_per), y_val=shard(yv, n_val_per),
        x_test=x_test, y_test=y_test)


@dataclasses.dataclass
class DigitsData:
    """Two-domain digit recognition stand-in (MNIST-like / SVHN-like)."""
    x_pretrain: np.ndarray   # (N, n_pt, 32, 32, 1)
    y_pretrain: np.ndarray   # (N, n_pt)
    x_finetune: np.ndarray   # (N, n_ft, 32, 32, 1)
    y_finetune: np.ndarray
    x_test: np.ndarray       # (n_test, 32, 32, 1) finetune-domain test
    y_test: np.ndarray


def _render_digit(rng, label: int, domain: str) -> np.ndarray:
    """Procedural 32x32 'digit': a class-specific frequency pattern.

    The two domains differ by contrast, background clutter and blur --
    enough structure that (a) a CNN can learn it, (b) pretraining on one
    domain transfers imperfectly to the other, which is exactly the
    setting the reweighting network in Eq. 32 is meant to exploit.
    """
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32) / 32.0
    f1, f2 = 1 + label % 5, 1 + label // 5
    img = (np.sin(2 * np.pi * f1 * xx + label)
           * np.cos(2 * np.pi * f2 * yy - label))
    if domain == "svhn":
        img = 0.6 * img + 0.8 * rng.normal(size=img.shape)  # clutter
        img = img + 0.3 * np.sin(2 * np.pi * 3 * (xx + yy))  # color cast
    else:
        img = img + 0.15 * rng.normal(size=img.shape)
    img = np.clip(img, -2, 2) / 2.0
    return img[..., None].astype(np.float32)


def make_digits(n_workers: int, n_pretrain_per: int = 64,
                n_finetune_per: int = 32, n_test: int = 256,
                pretrain_domain: str = "svhn",
                seed: int = 0) -> DigitsData:
    rng = np.random.default_rng(seed)
    ft_domain = "mnist" if pretrain_domain == "svhn" else "svhn"

    def batch(n, domain):
        ys = rng.integers(0, 10, size=n)
        xs = np.stack([_render_digit(rng, int(y), domain) for y in ys])
        return xs.astype(np.float32), ys.astype(np.int32)

    xpt, ypt = zip(*[batch(n_pretrain_per, pretrain_domain)
                     for _ in range(n_workers)])
    xft, yft = zip(*[batch(n_finetune_per, ft_domain)
                     for _ in range(n_workers)])
    x_test, y_test = batch(n_test, ft_domain)
    return DigitsData(
        x_pretrain=np.stack(xpt), y_pretrain=np.stack(ypt),
        x_finetune=np.stack(xft), y_finetune=np.stack(yft),
        x_test=x_test, y_test=y_test)


def make_token_stream(vocab_size: int, batch: int, seq_len: int,
                      seed: int = 0, zipf_a: float = 1.2) -> np.ndarray:
    """Zipfian token ids for LM training/serving smoke tests."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_a, size=(batch, seq_len)).astype(np.int64)
    # overflow ranks wrap (mod) rather than clip: clipping would pile the
    # heavy zipf tail onto vocab_size-1 and make it the most frequent id
    return ((ranks - 1) % vocab_size).astype(np.int32)
