"""Device-resident data streams: per-iteration batches synthesized
INSIDE the scan from a fold-in PRNG key.

The host-fed design (precompute batches with numpy, `jnp.asarray` them
per chunk) leaves the donated scanned dispatch idle behind host→device
transfers at real model scale, and cannot express the paper's setting
where every worker draws FRESH local samples each round.  A `Stream`
replaces the resident `problem.data` arrays with a generator that runs
inside the compiled trajectory: the engines
(`repro.core.engine.run_scanned/run_swept(data=...)`, the eager runner,
`repro.launch.train --stream`) synthesize each iteration's worker
batches on device, so chunk boundaries transfer nothing.

Key discipline (the streaming contract — everything the conformance
suite `tests/test_stream.py` checks follows from these three rules):

  * `Stream.key` is the BASE key and is never advanced.  It rides the
    scan carry untouched (so chunked dispatches keep their buffers
    donated end-to-end) but batches are derived by `fold_in`, not by
    iterating/splitting the carried key forward.
  * worker j's iteration key is `fold_in(key, t_hat_j)` with j's
    ABSOLUTE consumption time — `state.stale.t_hat[j]`, the master
    iteration at which j's current local point was handed out (== the
    global `state.t` whenever every worker is active every iteration,
    the synchronous SFTO case).  Folding on the consumption time rather
    than the global counter keeps ANY chunk partition of a trajectory
    bit-identical (t_hat rides the carry), keeps a fixed seed
    reproducible across processes, AND lets a self-paced async worker
    synthesize its own batch from nothing but the `t` already riding
    its REFRESH frame (`fed/runtime/worker.py`) — the worker's fold is
    bitwise the engine's.
  * worker j's key is `fold_in(iteration_key, j)` with the GLOBAL
    worker index, so a worker-mesh shard generates exactly its own
    workers' rows shard-locally (`worker_offset = axis_index * n_local`)
    with NO data collectives — bit-identical to the replicated stream.

`StreamSpec.sample(key) -> data_j` draws ONE worker's slice; batches
stack it over workers with `jax.vmap`.  The spec is static (a jit-meta
field): reuse one `Stream`/spec object across runs the way you reuse a
`problem` — the engine caches compiled trajectories per spec identity,
and only the key is traced (so re-seeding via
`dataclasses.replace(stream, key=...)` never retraces).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Static sample spec: how to draw ONE worker's per-iteration slice.

    sample    : (key) -> data_j pytree; the key already encodes
                (base seed, iteration, worker) via fold-ins.
    n_workers : global worker count N — batches lead with (N, ...) like
                `problem.data`.
    """
    sample: Callable
    n_workers: int


@dataclasses.dataclass
class Stream:
    """A device-resident data stream: fold-in base key + static spec.

    Registered as a pytree with `key` the only leaf, so it rides scan
    carries / donated dispatches; `spec` is jit-static meta.
    """
    key: Any
    spec: StreamSpec = None


jax.tree_util.register_dataclass(Stream, data_fields=["key"],
                                 meta_fields=["spec"])


def make_stream(sample: Callable, n_workers: int, seed=0) -> Stream:
    """Build a Stream from a per-worker sample fn and an int seed (or an
    existing PRNG key)."""
    key = jax.random.PRNGKey(seed) if isinstance(seed, int) else seed
    return Stream(key=key, spec=StreamSpec(sample=sample,
                                           n_workers=n_workers))


def worker_key(key, it, j):
    """The per-(iteration, worker) key: fold-in, never iterated."""
    return jax.random.fold_in(jax.random.fold_in(key, it), j)


def batch_at(spec: StreamSpec, key, it, worker_offset=0,
             n_local: int = None):
    """The (n_local, ...)-stacked batch for iteration(s) `it`.

    `it` is a scalar (one master iteration for the whole block) or a
    per-worker vector of length n_local (each row folded at its own
    consumption time — the engines pass `state.stale.t_hat`).  A scalar
    broadcasts to the same per-lane fold-ins, so both forms are
    bit-identical where they overlap.

    worker_offset / n_local select a contiguous global-worker block —
    the sharded engines pass `axis_index * n_local` so each shard draws
    only its own rows; the defaults give the full (N, ...) batch.  Rows
    depend only on (key, it_row, global worker index), never on the
    layout (`tests/test_stream.py` pins block/offset independence).
    """
    n = spec.n_workers if n_local is None else n_local
    js = worker_offset + jnp.arange(n, dtype=jnp.int32)
    its = jnp.broadcast_to(jnp.asarray(it, jnp.int32), js.shape)
    keys = jax.vmap(lambda t, j: worker_key(key, t, j))(its, js)
    return jax.vmap(spec.sample)(keys)


def next_batch(stream: Stream, it, worker_offset=0, n_local: int = None):
    """`batch_at` on a Stream object (host-side convenience / eager)."""
    return batch_at(stream.spec, stream.key, it, worker_offset, n_local)


# ---------------------------------------------------------------------------
# stock sample specs
# ---------------------------------------------------------------------------

def normal_like(template_j, scale: float = 1.0) -> Callable:
    """Sample fn drawing iid-normal leaves shaped like ONE worker's data
    slice (`template_j`: arrays or ShapeDtypeStructs without the leading
    worker axis) — the streamed stand-in for the synthetic regression /
    quadratic problem batches of `repro.data.synthetic`."""
    leaves, tdef = jax.tree_util.tree_flatten(template_j)

    def sample(key):
        keys = jax.random.split(key, len(leaves))
        return jax.tree_util.tree_unflatten(tdef, [
            scale * jax.random.normal(k, l.shape, l.dtype)
            for k, l in zip(keys, leaves)])

    return sample


def problem_stream(data, n_workers: int, seed=0,
                   scale: float = 1.0) -> Stream:
    """Stream whose batches are normal draws shaped like `data` minus
    its leading (N,) worker axis (e.g. a `TrilevelProblem.data` tree)."""
    tpl = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), data)
    return make_stream(normal_like(tpl, scale), n_workers, seed)


def zipf_tokens(key, shape, vocab_size: int, zipf_a: float = 1.2):
    """Device-side Zipfian token ids (inverse-CDF sampling of the
    rank-CCDF power tail), the streamed counterpart of
    `data.synthetic.make_token_stream` — distribution-matched, not
    bit-matched (that one is numpy/host).  Ranks are clipped to 2^24 so
    the f32 arithmetic stays exact-integer, and overflow ranks WRAP
    (mod) rather than clip onto vocab_size-1, mirroring the host
    sampler's tail handling."""
    if zipf_a <= 1.0:
        raise ValueError(
            f"zipf_a must be > 1 (rank-CCDF exponent a-1 must be "
            f"positive); got {zipf_a}")
    u = jax.random.uniform(key, shape, jnp.float32,
                           minval=jnp.float32(1e-7))
    ranks = jnp.floor(jnp.clip(u ** (-1.0 / (zipf_a - 1.0)),
                               1.0, 2.0 ** 24))
    return jnp.mod(ranks - 1.0, vocab_size).astype(jnp.int32)
