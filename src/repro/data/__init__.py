from repro.data.synthetic import (REGRESSION_SPECS, RegressionData,
                                  DigitsData, make_regression,
                                  make_digits, make_token_stream)
from repro.data.loader import ShardedLoader, shard_batch
from repro.data.stream import (Stream, StreamSpec, make_stream,
                               next_batch, problem_stream, zipf_tokens)
