"""repro: Provably Convergent Federated Trilevel Learning (AFTO, AAAI'24)
as a production-grade multi-pod JAX framework.

Public API surface:
  repro.core        — the paper's algorithm (mu-cuts, async federated loop)
  repro.apps        — the paper's experiments (robust HPO, domain adapt)
  repro.models      — the architecture zoo (dense/MoE/SSM/hybrid/enc-dec)
  repro.fed         — mesh sharding rules + LLM-scale trilevel step
  repro.kernels     — Pallas TPU kernels (+ jnp oracles)
  repro.configs     — the 10 assigned architectures x 4 input shapes
  repro.launch      — mesh / dryrun / train / serve entry points
"""
__version__ = "1.0.0"
