"""End-to-end driver (deliverable b): federated trilevel TRAINING of a
~100M-class language model with AFTO — the paper's robust-HPO trilevel
(Eq. 31) with the model zoo as level 3, sketched mu-cuts, a straggler
scheduler, and checkpointing.  A few hundred steps on CPU.

    PYTHONPATH=src python examples/federated_llm_trilevel.py \
        [--steps 200] [--arch xlstm-125m]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, reduced
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.data.synthetic import make_token_stream
from repro.fed import (FedHyper, afto_llm_step, cut_refresh_llm,
                       init_fed_state)
from repro.models import transformer as tfm

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-125m")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--seq", type=int, default=65)
ap.add_argument("--ckpt-dir", default=None)
args = ap.parse_args()

cfg = reduced(get_config(args.arch))
N, B, SEQ = args.workers, args.batch, args.seq
hyper = FedHyper(n_workers=N, cut_mode="sketch", sketch_r=256, p_max=2,
                 k_inner=1, remat=False, eta_x=1e-3, eta_z=1e-3)
state = init_fed_state(cfg, hyper, jax.random.PRNGKey(0), B, SEQ - 1)

step = jax.jit(lambda st, bt, m: afto_llm_step(cfg, hyper, st, bt, m))
refresh = jax.jit(lambda st, bt: cut_refresh_llm(cfg, hyper, st, bt))
val_loss = jax.jit(lambda w, tk: tfm.train_loss(cfg, w, tk))

sched = StragglerScheduler(StragglerConfig(
    n_workers=N, s_active=N - 1, tau=10, n_stragglers=1,
    straggler_slowdown=5.0, seed=0))

print(f"AFTO-training {cfg.name} ({args.steps} steps, {N} workers, "
      f"S={N-1}, 1 straggler)")
t0 = time.time()
for it in range(args.steps):
    toks = jnp.asarray(make_token_stream(
        cfg.vocab_size, N * B, SEQ, seed=7919 * it)).reshape(N, B, SEQ)
    batch = {"tokens": toks, "val_tokens": toks}
    mask, sim_t = sched.next_active()
    state = step(state, batch, jnp.asarray(mask))
    if (it + 1) % 25 == 0:
        state = refresh(state, batch)
    if (it + 1) % 20 == 0 or it == args.steps - 1:
        w = jax.tree.map(lambda x: x[0], state.X3)
        print(json.dumps({
            "step": it + 1, "val_loss": round(float(val_loss(w, toks[0])),
                                              4),
            "phi": [round(float(p), 3) for p in state.z1],
            "cuts": int(jnp.sum(state.cuts.active)),
            "sim_time": round(sim_t, 1),
            "host_s": round(time.time() - t0, 1)}))
    if args.ckpt_dir and (it + 1) % 100 == 0:
        save_checkpoint(args.ckpt_dir, state.z3, it + 1)
print("done")
