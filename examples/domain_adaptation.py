"""Paper §5.2: distributed domain adaptation for pretrain & finetune
(Eq. 32) — reweighting net (level 1), finetune LeNet (level 2), pretrain
LeNet (level 3) on two-domain synthetic digits.

    PYTHONPATH=src python examples/domain_adaptation.py
"""
import jax
import jax.numpy as jnp

from repro.apps.domain_adaptation import (default_hyper,
                                          make_domain_adaptation_problem)
from repro.core import RunSpec, StragglerConfig, run

N, S, TAU = 4, 3, 5
task = make_domain_adaptation_problem(N, pretrain_domain="svhn",
                                      n_pretrain_per=32,
                                      n_finetune_per=16, seed=0)

hyper = default_hyper(N, S, TAU, t_pre=10, k_inner=2, p_max=4)
sched = StragglerConfig(n_workers=N, s_active=S, tau=TAU, n_stragglers=1,
                        straggler_slowdown=5.0, seed=0)


def metrics(state):
    v = jax.tree.map(lambda x: jnp.mean(x, 0), state.X2)  # finetune net
    return task.test_metrics(v)


res = run(RunSpec(problem=task.problem, hyper=hyper, scheduler=sched,
              n_iterations=30, metrics_fn=metrics, metrics_every=10,
              engine="scan"))
h = res.history
print("iter  sim_time  test_acc  test_loss")
for i in range(len(h["t"])):
    print(f"{h['t'][i]:>4.0f}  {h['sim_time'][i]:8.1f}  "
          f"{h['test_acc'][i]:.3f}     {h['test_loss'][i]:.4f}")
assert h["test_loss"][-1] < h["test_loss"][0]
print("OK: finetune-domain loss decreased")
