"""Quickstart: solve a tiny distributed trilevel problem with AFTO.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Hyper, RunSpec, StragglerConfig, TrilevelProblem, run

# A 4-worker quadratic trilevel problem (Eq. 2):
#   level 1: fit x1 to a worker-local linear map of x3
#   level 2: x2 opposes x3 (adversarial-style coupling)
#   level 3: x3 tracks x1 with an x2 penalty
N, DIM = 4, 3
key = jax.random.PRNGKey(0)
data = {"A": jax.random.normal(key, (N, DIM, DIM)) * 0.3,
        "b": jax.random.normal(jax.random.fold_in(key, 1), (N, DIM))}


def f1(d, x1, x2, x3):
    return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)


def f2(d, x1, x2, x3):
    return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)


def f3(d, x1, x2, x3):
    return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)


problem = TrilevelProblem(
    f1=f1, f2=f2, f3=f3, data=data, n_workers=N,
    x1_init=jnp.zeros(DIM), x2_init=jnp.zeros(DIM),
    x3_init=jnp.zeros(DIM))

hyper = Hyper(n_workers=N, s_active=3, tau=5, k_inner=3, p_max=6,
              t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=DIM)

# 1 straggler, 5x slower: AFTO's S-of-N arrival rule hides it
sched = StragglerConfig(n_workers=N, s_active=3, tau=5, n_stragglers=1,
                        straggler_slowdown=5.0, seed=0)

# mode="scan" (the default) precomputes the seeded arrival schedule and
# compiles the whole 100-iteration trajectory into one lax.scan dispatch;
# mode="eager" recovers the per-iteration host loop.
result = run(RunSpec(problem=problem, hyper=hyper, scheduler=sched,
                     n_iterations=100, metrics_every=20, engine="scan"))

print("iter  sim_time  ||grad G||^2  cuts(I/II)  max_staleness")
h = result.history
for i in range(len(h["t"])):
    print(f"{h['t'][i]:>4.0f}  {h['sim_time'][i]:8.1f}  "
          f"{h['gap_sq'][i]:12.5f}  {h['n_cuts_i'][i]:.0f}/"
          f"{h['n_cuts_ii'][i]:.0f}          {h['max_staleness'][i]:.0f}")
print("\nconsensus z1:", result.state.z1)
assert h["gap_sq"][-1] < h["gap_sq"][0], "AFTO failed to make progress"
print("OK: stationarity gap decreased "
      f"{h['gap_sq'][0]:.4f} -> {h['gap_sq'][-1]:.4f}")
