"""Paper §5.1: distributed robust hyperparameter optimization (Eq. 31).

Trains an MLP whose regularization strength (level 1) is tuned against
an adversarial input perturbation (level 2) wrapped around weight
training (level 3), across 4 federated workers with 1 straggler —
comparing AFTO with the synchronous SFTO.

    PYTHONPATH=src python examples/robust_hpo.py
"""
import jax
import jax.numpy as jnp

from repro.apps.robust_hpo import default_hyper, make_robust_hpo_problem
from repro.core import RunSpec, StragglerConfig, run

DATASET = "diabetes"   # synthetic stand-in with the UCI shapes
N, S, TAU = 4, 3, 10

task = make_robust_hpo_problem(DATASET, n_workers=N, seed=0)


def metrics(state):
    w = jax.tree.map(lambda x: jnp.mean(x, 0), state.X3)
    return {"mse_clean": task.test_mse(w, 0.0),
            "mse_noisy": task.test_mse(w, 0.3)}


for algo, s_active in (("AFTO", S), ("SFTO", N)):
    hyper = default_hyper(task, N, s_active, TAU)
    sched = StragglerConfig(n_workers=N, s_active=s_active, tau=TAU,
                            n_stragglers=1, straggler_slowdown=5.0,
                            seed=0)
    # the scanned engine runs the whole trajectory in one compiled
    # dispatch; metrics here are pure JAX so they trace into the scan
    res = run(RunSpec(problem=task.problem, hyper=hyper, scheduler=sched,
                      n_iterations=100, metrics_fn=metrics,
                      metrics_every=25, engine="scan"))
    h = res.history
    print(f"\n== {algo} ==")
    print("iter  sim_time  clean_mse  noisy_mse")
    for i in range(len(h["t"])):
        print(f"{h['t'][i]:>4.0f}  {h['sim_time'][i]:8.1f}  "
              f"{h['mse_clean'][i]:.4f}     {h['mse_noisy'][i]:.4f}")
    print(f"{algo}: reached iter {h['t'][-1]:.0f} at simulated "
          f"t={h['sim_time'][-1]:.1f} (lower sim-time per iter = faster "
          f"wall-clock convergence)")
