"""Beyond-paper: sketched-mu-cut fidelity — relative error of the
sketched cut value vs the exact cut value as a function of sketch width
r, at paper scale where exact cuts are computable."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.sketch import sketch, sketch_dot
from repro.utils.tree import tree_dot


def main(dims=(1000, 10_000), rs=(64, 256, 1024), n_trials: int = 8):
    t0 = time.perf_counter()
    rows = []
    key = jax.random.PRNGKey(0)
    for d in dims:
        for r in rs:
            errs = []
            for trial in range(n_trials):
                k1, k2 = jax.random.split(
                    jax.random.fold_in(key, d * 31 + r * 7 + trial))
                a = {"w": jax.random.normal(k1, (d,))}
                b = {"w": jax.random.normal(k2, (d,))}
                exact = float(tree_dot(a, b))
                est = float(sketch_dot(sketch(a, trial, r),
                                       sketch(b, trial, r)))
                scale = float(jnp.sqrt(tree_dot(a, a) * tree_dot(b, b)))
                errs.append(abs(est - exact) / scale)
            rows.append((f"sketch_fidelity_d{d}_r{r}",
                         (time.perf_counter() - t0) * 1e6 / n_trials,
                         f"rel_err_mean={np.mean(errs):.4f};"
                         f"rel_err_max={np.max(errs):.4f};"
                         f"jl_bound={1.0/np.sqrt(r):.4f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
