"""Aggregate the dry-run JSONL into the §Roofline table (markdown +
summary CSV rows)."""
from __future__ import annotations

import glob
import json
import os
import time


def _recompute_terms(r: dict) -> dict:
    """Re-derive terms from the stored raw fields so formula fixes apply
    to existing JSONL without re-compiling."""
    if r.get("status") != "ok":
        return r
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    chips = r["chips"]
    flops_total = r["hlo_flops_per_dev"] * chips
    flops_corr = max(flops_total, r["analytic_flops_total"])
    coll = sum(v for k, v in r["coll_bytes"].items() if k != "count")
    r = dict(r)
    r["compute_s"] = flops_total / (chips * PEAK_FLOPS_BF16)
    r["compute_corrected_s"] = flops_corr / (chips * PEAK_FLOPS_BF16)
    r["memory_s"] = r["hlo_bytes_per_dev"] / HBM_BW
    r["collective_s"] = coll / (chips * ICI_BW)
    r["useful_ratio"] = r["model_flops_total"] / max(flops_corr, 1.0)
    r["hbm_gb_per_dev"] = (r["arg_bytes"] + r["temp_bytes"]
                           + r["out_bytes"]) / 1e9
    kinds = {"compute": r["compute_corrected_s"],
             "memory": r["memory_s"], "collective": r["collective_s"]}
    r["dominant"] = max(kinds, key=kinds.get)
    return r


def load(paths=("results/dryrun_pod.jsonl", "results/dryrun_multipod.jsonl")):
    rows = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                for line in f:
                    rows.append(_recompute_terms(json.loads(line)))
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | step | compute(ms) | memory(ms) | "
           "collective(ms) | dominant | 6ND/HLO | HBM GB/dev | status |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r.get("mesh", ""), r["arch"],
                                         r["shape"])):
        if r.get("status") == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['step_kind']} "
                f"| {r['compute_corrected_s']*1e3:.2f} "
                f"| {r['memory_s']*1e3:.2f} "
                f"| {r['collective_s']*1e3:.2f} "
                f"| {r['dominant']} "
                f"| {r['useful_ratio']:.2f} "
                f"| {r['hbm_gb_per_dev']:.1f} | ok |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | - "
                f"| - | - | - | - | - | - | {r.get('status')} |")
    return "\n".join(lines)


def main():
    t0 = time.perf_counter()
    rows = load()
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    err = [r for r in rows if r.get("status") == "error"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return [("roofline_table", (time.perf_counter() - t0) * 1e6,
             f"ok={len(ok)};skipped={len(skipped)};errors={len(err)};"
             + ";".join(f"{k}_bound={v}" for k, v in sorted(doms.items())))]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    print()
    print(render_markdown(load()))
