"""Fig. 2: distributed domain adaptation — test accuracy / loss vs
simulated running time, AFTO vs SFTO, SVHN-pretrain and MNIST-pretrain
directions (synthetic two-domain digits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.conftest_shim import swept_method_histories
from repro.apps.domain_adaptation import (default_hyper,
                                          make_domain_adaptation_problem)
from repro.core import RunSpec, StragglerConfig, run

# Table 1: SVHN(finetune): N=4 S=3 1 straggler tau=5;
#          SVHN(pretrain): N=6 S=3 2 stragglers tau=15
SETTINGS = {
    "svhn_pretrain": (6, 3, 2, 15),
    "mnist_pretrain": (4, 3, 1, 5),
}


def run_direction(direction: str, n_iterations: int = 40, seed: int = 0,
                  engine: str = "sweep"):
    """AFTO vs SFTO in one swept dispatch (they differ only in arrival
    schedules); engine="scan"/"eager" keeps the per-method loop."""
    n, s, stragglers, tau = SETTINGS[direction]
    domain = "svhn" if direction == "svhn_pretrain" else "mnist"
    task = make_domain_adaptation_problem(
        n, pretrain_domain=domain, n_pretrain_per=24, n_finetune_per=12,
        seed=seed)

    def metrics(state):
        v = jax.tree.map(lambda x: jnp.mean(x, 0), state.X2)
        return task.test_metrics(v)

    algos = (("AFTO", s), ("SFTO", n))
    me = max(2, n_iterations // 8)
    if engine == "sweep":
        per_algo = swept_method_histories(
            task.problem,
            default_hyper(n, s, tau, t_pre=20, k_inner=1, p_max=2),
            [s_active for _, s_active in algos], n_iterations, metrics,
            me, n_workers=n, tau=tau, n_stragglers=stragglers, seed=seed)
    else:
        per_algo = []
        for algo, s_active in algos:
            hyper = default_hyper(n, s_active, tau, t_pre=20, k_inner=1,
                                  p_max=2)
            cfg = StragglerConfig(n_workers=n, s_active=s_active, tau=tau,
                                  n_stragglers=stragglers,
                                  straggler_slowdown=5.0, seed=seed)
            per_algo.append(run(RunSpec(
                problem=task.problem, hyper=hyper, scheduler=cfg,
                n_iterations=n_iterations, metrics_fn=metrics,
                metrics_every=me, engine=engine)).history)
    rows = []
    for (algo, _), h in zip(algos, per_algo):
        for i in range(len(h["t"])):
            rows.append({"direction": direction, "algo": algo,
                         "iter": h["t"][i], "sim_time": h["sim_time"][i],
                         "test_acc": h["test_acc"][i],
                         "test_loss": h["test_loss"][i]})
    return rows


def main(n_iterations: int = 40, directions=None, engine: str = "sweep"):
    import time
    out = []
    for d in (directions or list(SETTINGS)):
        t0 = time.perf_counter()
        rows = run_direction(d, n_iterations, engine=engine)
        dt = time.perf_counter() - t0
        # sim-time to reach the worst algo's final loss
        finals = {a: [r for r in rows if r["algo"] == a][-1]
                  for a in ("AFTO", "SFTO")}
        target = max(finals["AFTO"]["test_loss"],
                     finals["SFTO"]["test_loss"])
        t_hit = {}
        for a in ("AFTO", "SFTO"):
            hits = [r["sim_time"] for r in rows
                    if r["algo"] == a and r["test_loss"] <= target]
            t_hit[a] = hits[0] if hits else float("inf")
        accel = 1.0 - t_hit["AFTO"] / t_hit["SFTO"] \
            if t_hit["SFTO"] not in (0.0, float("inf")) else float("nan")
        out.append((f"fig2_{d}", dt * 1e6 / max(n_iterations, 1),
                    f"accel={accel:.2f};"
                    f"afto_acc={finals['AFTO']['test_acc']:.3f};"
                    f"sfto_acc={finals['SFTO']['test_acc']:.3f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
