"""Kernel microbenchmarks: oracle (jnp/XLA) wall time on CPU + interpret
-mode correctness deltas.  On CPU the *oracle* timing is the meaningful
number (interpret mode executes the kernel body in Python); on TPU the
same harness times the Mosaic kernels via interpret=False.

``--check`` turns the run into the CI kernel-parity gate: every
``interp_max_err`` column (forward, the hand-written backward, the
grad-of-grad pass, the fused inner round) must be finite and under
``CHECK_TOL`` or the process exits nonzero."""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

# f32 parity tolerance for the --check gate: the kernel and the oracle
# accumulate in different orders, so exact zeros only happen on the
# trivially small shapes.
CHECK_TOL = 5e-4


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    key = jax.random.PRNGKey(0)
    rows = []

    # cut_eval oracle at sketched-cut production size
    p, d = 8, 1 << 16
    a = jax.random.normal(key, (p, d), jnp.float32) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    c = jnp.zeros((p,))
    act = jnp.ones((p,))
    oracle = jax.jit(ref.cut_eval_ref)
    us = _time(oracle, a, v, c, act)
    got = ops.cut_eval(a, v, c, act, impl="pallas")   # force the kernel
    err = float(jnp.max(jnp.abs(got - oracle(a, v, c, act))))
    rows.append(("kernel_cut_eval_oracle", us,
                 f"P={p};D={d};interp_max_err={err:.2e}"))

    # backward: the hand-written rank-1 (da) / row-reduction (dv)
    # kernels behind jax.grad, vs the oracle's autodiff
    w = jax.random.normal(jax.random.fold_in(key, 8), (p,))

    def loss(impl):
        return lambda a, v: 0.5 * jnp.sum(
            ops.cut_eval(a, v, c, act, impl=impl) ** 2 * w)

    bwd_oracle = jax.jit(jax.grad(loss("ref"), argnums=(0, 1)))
    us = _time(bwd_oracle, a, v)
    da_k, dv_k = jax.grad(loss("pallas"), argnums=(0, 1))(a, v)
    da_r, dv_r = bwd_oracle(a, v)
    err = max(float(jnp.max(jnp.abs(da_k - da_r))),
              float(jnp.max(jnp.abs(dv_k - dv_r))))
    rows.append(("kernel_cut_eval_bwd_oracle", us,
                 f"P={p};D={d};interp_max_err={err:.2e}"))

    # grad-of-grad: the cut-refresh (Eq. 23/24) second-order shape that
    # used to force impl="ref" — now kernel-backed via cut_ad
    def gog(impl):
        inner = lambda v: jnp.sum(
            jax.grad(loss(impl), argnums=1)(a, v) ** 2)
        return jax.jit(jax.grad(inner))

    gog_oracle = gog("ref")
    us = _time(gog_oracle, v)
    err = float(jnp.max(jnp.abs(gog("pallas")(v) - gog_oracle(v))))
    scale = float(jnp.max(jnp.abs(gog_oracle(v)))) + 1.0
    rows.append(("kernel_cut_eval_gog_oracle", us,
                 f"P={p};D={d};interp_max_err={err / scale:.2e}"))

    # fused inner-ADMM round: two-pass kernel vs the jnp decomposition
    g = jax.random.normal(jax.random.fold_in(key, 9), (d,))
    mask = (jnp.arange(d) % 2).astype(jnp.float32)
    s = jnp.abs(jax.random.normal(jax.random.fold_in(key, 10), (p,)))
    gam = jnp.abs(jax.random.normal(jax.random.fold_in(key, 11), (p,)))
    kw = dict(eta_z=0.05, eta_s=0.05, eta_dual=0.05, rho2=1.0)
    us = _time(lambda *xs: ops.fused_cut_round(*xs, impl="ref", **kw),
               a, v, g, mask, c, act, s, gam, iters=10)
    got = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                              impl="pallas", **kw)
    want = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                               impl="ref", **kw)
    err = max(
        float(jnp.max(jnp.abs(x - y)) / (jnp.max(jnp.abs(y)) + 1.0))
        for x, y in zip(got, want))
    rows.append(("kernel_fused_round_oracle", us,
                 f"P={p};D={d};interp_max_err={err:.2e}"))

    # flash attention oracle vs kernel (small, interpret mode)
    b, s, h, hd = 1, 512, 8, 64
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, hd))
    oracle = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(oracle, q, k, vv, iters=5)
    got = ops.flash_attention(q[:, :128], k[:, :128], vv[:, :128],
                              block_q=64, block_k=64)
    err = float(jnp.max(jnp.abs(
        got - ref.flash_attention_ref(q[:, :128], k[:, :128],
                                      vv[:, :128]))))
    rows.append(("kernel_flash_attn_oracle", us,
                 f"S={s};H={h};hd={hd};interp_max_err={err:.2e}"))

    # mlstm chunk
    b2, h2, l2, hd2 = 2, 4, 64, 64
    q2 = jax.random.normal(key, (b2, h2, l2, hd2))
    k2 = jax.random.normal(jax.random.fold_in(key, 4), (b2, h2, l2, hd2))
    v2 = jax.random.normal(jax.random.fold_in(key, 5), (b2, h2, l2, hd2))
    li = jax.random.normal(jax.random.fold_in(key, 6), (b2, h2, l2, 1))
    lf = jax.nn.log_sigmoid(jax.random.normal(
        jax.random.fold_in(key, 7), (b2, h2, l2, 1)) + 2.0)
    c0 = jnp.zeros((b2, h2, hd2, hd2))
    n0 = jnp.zeros((b2, h2, 1, hd2))
    m0 = jnp.full((b2, h2, 1, 1), -1e9)
    oracle = jax.jit(ref.mlstm_chunk_ref)
    us = _time(oracle, q2, k2, v2, li, lf, c0, n0, m0, iters=10)
    got = ops.mlstm_chunk(q2, k2, v2, li, lf, c0, n0, m0)
    want = oracle(q2, k2, v2, li, lf, c0, n0, m0)
    err = float(jnp.max(jnp.abs(got[0] - want[0])))
    rows.append(("kernel_mlstm_chunk_oracle", us,
                 f"L={l2};hd={hd2};interp_max_err={err:.2e}"))
    return rows


def check(rows) -> int:
    """The CI kernel-parity gate: every interp_max_err must be a finite
    float under CHECK_TOL.  Returns a shell exit code."""
    bad = []
    n_checked = 0
    for name, _us, derived in rows:
        for field in derived.split(";"):
            if not field.startswith("interp_max_err="):
                continue
            n_checked += 1
            err = float(field.split("=", 1)[1])
            if not np.isfinite(err) or err > CHECK_TOL:
                bad.append((name, err))
    if not n_checked:
        print("kernel parity gate: no interp_max_err rows found", file=sys.stderr)
        return 1
    if bad:
        for name, err in bad:
            print(f"kernel parity gate FAILED: {name} err={err:.3e} "
                  f"(tol {CHECK_TOL:.0e})", file=sys.stderr)
        return 1
    print(f"kernel parity gate OK: {n_checked} rows under {CHECK_TOL:.0e}")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on any missing/non-finite/"
                         "out-of-tolerance interp_max_err row")
    ns = ap.parse_args()
    out_rows = main()
    for name, us, derived in out_rows:
        print(f"{name},{us:.1f},{derived}")
    if ns.check:
        sys.exit(check(out_rows))
