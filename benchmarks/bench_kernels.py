"""Kernel microbenchmarks: oracle (jnp/XLA) wall time on CPU + interpret
-mode correctness deltas.  On CPU the *oracle* timing is the meaningful
number (interpret mode executes the kernel body in Python); on TPU the
same harness times the Mosaic kernels via interpret=False."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    key = jax.random.PRNGKey(0)
    rows = []

    # cut_eval oracle at sketched-cut production size
    p, d = 8, 1 << 16
    a = jax.random.normal(key, (p, d), jnp.float32) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    c = jnp.zeros((p,))
    act = jnp.ones((p,))
    oracle = jax.jit(ref.cut_eval_ref)
    us = _time(oracle, a, v, c, act)
    got = ops.cut_eval(a, v, c, act, impl="pallas")   # force the kernel
    err = float(jnp.max(jnp.abs(got - oracle(a, v, c, act))))
    rows.append(("kernel_cut_eval_oracle", us,
                 f"P={p};D={d};interp_max_err={err:.2e}"))

    # flash attention oracle vs kernel (small, interpret mode)
    b, s, h, hd = 1, 512, 8, 64
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, hd))
    oracle = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(oracle, q, k, vv, iters=5)
    got = ops.flash_attention(q[:, :128], k[:, :128], vv[:, :128],
                              block_q=64, block_k=64)
    err = float(jnp.max(jnp.abs(
        got - ref.flash_attention_ref(q[:, :128], k[:, :128],
                                      vv[:, :128]))))
    rows.append(("kernel_flash_attn_oracle", us,
                 f"S={s};H={h};hd={hd};interp_max_err={err:.2e}"))

    # mlstm chunk
    b2, h2, l2, hd2 = 2, 4, 64, 64
    q2 = jax.random.normal(key, (b2, h2, l2, hd2))
    k2 = jax.random.normal(jax.random.fold_in(key, 4), (b2, h2, l2, hd2))
    v2 = jax.random.normal(jax.random.fold_in(key, 5), (b2, h2, l2, hd2))
    li = jax.random.normal(jax.random.fold_in(key, 6), (b2, h2, l2, 1))
    lf = jax.nn.log_sigmoid(jax.random.normal(
        jax.random.fold_in(key, 7), (b2, h2, l2, 1)) + 2.0)
    c0 = jnp.zeros((b2, h2, hd2, hd2))
    n0 = jnp.zeros((b2, h2, 1, hd2))
    m0 = jnp.full((b2, h2, 1, 1), -1e9)
    oracle = jax.jit(ref.mlstm_chunk_ref)
    us = _time(oracle, q2, k2, v2, li, lf, c0, n0, m0, iters=10)
    got = ops.mlstm_chunk(q2, k2, v2, li, lf, c0, n0, m0)
    want = oracle(q2, k2, v2, li, lf, c0, n0, m0)
    err = float(jnp.max(jnp.abs(got[0] - want[0])))
    rows.append(("kernel_mlstm_chunk_oracle", us,
                 f"L={l2};hd={hd2};interp_max_err={err:.2e}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
