"""Engine benchmark: eager host loop vs compiled-scan trajectory at
quickstart scale (the 4-worker quadratic trilevel problem, 200 master
iterations).  Emits the machine-readable perf record consumed by
``benchmarks/run.py --json`` so future PRs can diff
``{iters_per_sec, sim_time, gap_sq}`` across engines."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Hyper, StragglerConfig, StragglerScheduler, run
from repro.core.types import TrilevelProblem

N_WORKERS, DIM = 4, 3


def quickstart_problem(seed: int = 0) -> TrilevelProblem:
    """The examples/quickstart.py problem (kept in sync by value, not
    import, so the benchmark has no dependency on the examples tree)."""
    key = jax.random.PRNGKey(seed)
    data = {"A": jax.random.normal(key, (N_WORKERS, DIM, DIM)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (N_WORKERS, DIM))}

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    return TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=N_WORKERS,
        x1_init=jnp.zeros(DIM), x2_init=jnp.zeros(DIM),
        x3_init=jnp.zeros(DIM))


def quickstart_setup(n_iterations: int):
    problem = quickstart_problem()
    hyper = Hyper(n_workers=N_WORKERS, s_active=3, tau=5, k_inner=3,
                  p_max=6, t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=DIM)
    cfg = StragglerConfig(n_workers=N_WORKERS, s_active=3, tau=5,
                          n_stragglers=1, straggler_slowdown=5.0, seed=0)
    schedule = StragglerScheduler(cfg).precompute(n_iterations)
    return problem, hyper, cfg, schedule


def _timed_run(problem, hyper, cfg, schedule, mode: str):
    n_iterations = schedule.n_iterations
    t0 = time.perf_counter()
    res = run(problem, hyper, scheduler_cfg=cfg, n_iterations=n_iterations,
              metrics_every=max(1, n_iterations // 10), mode=mode,
              schedule=schedule)
    jax.block_until_ready(res.state)
    wall = time.perf_counter() - t0
    return res, wall


def record(n_iterations: int = 200) -> dict:
    """The perf record: eager vs cold/warm scan on the same schedule.

    eager and scan run bit-identical trajectories (same precomputed
    schedule), so sim_time/gap_sq must agree; iters_per_sec is the
    engine difference.  scan_warm is a second run reusing the cached
    compiled trajectory — the steady-state cost benchmarks and sweeps
    actually pay.
    """
    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    out = {"n_iterations": n_iterations}
    res_eager, wall = _timed_run(problem, hyper, cfg, schedule, "eager")
    out["eager"] = _entry(res_eager, wall, n_iterations)
    res_cold, wall = _timed_run(problem, hyper, cfg, schedule, "scan")
    out["scan_cold"] = _entry(res_cold, wall, n_iterations)
    res_warm, wall = _timed_run(problem, hyper, cfg, schedule, "scan")
    out["scan_warm"] = _entry(res_warm, wall, n_iterations)
    out["speedup_warm"] = out["eager"]["wall_s"] / out["scan_warm"]["wall_s"]
    out["speedup_cold"] = out["eager"]["wall_s"] / out["scan_cold"]["wall_s"]
    out["final_state_allclose"] = bool(all(
        jnp.allclose(a, b, rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(res_eager.state),
                        jax.tree.leaves(res_warm.state))))
    return out


def _entry(res, wall: float, n_iterations: int) -> dict:
    return {"wall_s": wall,
            "iters_per_sec": n_iterations / wall,
            "sim_time": float(res.history["sim_time"][-1]),
            "gap_sq": float(res.history["gap_sq"][-1])}


def main(n_iterations: int = 200, record_out: dict = None):
    """record_out, when given, receives the perf record so callers (e.g.
    ``benchmarks/run.py --json``) don't have to re-measure."""
    rec = record(n_iterations)
    if record_out is not None:
        record_out.update(rec)
    rows = []
    for key in ("eager", "scan_cold", "scan_warm"):
        e = rec[key]
        rows.append((f"engine_{key}", e["wall_s"] * 1e6 / n_iterations,
                     f"iters_per_sec={e['iters_per_sec']:.1f};"
                     f"gap_sq={e['gap_sq']:.5f}"))
    rows.append(("engine_speedup", 0.0,
                 f"warm={rec['speedup_warm']:.1f}x;"
                 f"cold={rec['speedup_cold']:.1f}x;"
                 f"allclose={rec['final_state_allclose']}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
