"""Engine benchmarks at quickstart scale (the 4-worker quadratic
trilevel problem): eager host loop vs compiled-scan trajectory, the
batched sweep engine vs an equivalent Python loop of scanned runs, the
Pallas `cut_eval` kernel at paper-scale D (forward, the hand-written
backward, one grad-of-grad pass) plus the fused inner-ADMM round
kernel, and incremental polytope
maintenance (`add_cut` row writes / `drop_inactive` masks / evictions on
the canonical `FlatCuts`) at paper-scale (P, D), the worker-mesh sharded
engine vs the replicated scan (with the analytic per-step bytes the mesh
exchanges), and the streamed engine (in-scan per-iteration batch
synthesis, incl. a chunk-partition bit-identity check) vs the host-fed
scan.  Emits the machine-readable perf record consumed by
``benchmarks/run.py --json`` so future PRs can diff ``{iters_per_sec,
runs_per_sec_swept, iters_per_sec_sharded, iters_per_sec_streamed,
cut_updates_per_sec, ...}`` across engines."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import (Hyper, RunSpec, StragglerConfig, StragglerScheduler, run,
                        run_scanned, run_swept)
from repro.core.types import TrilevelProblem

N_WORKERS, DIM = 4, 3
SWEEP_RUNS = 4          # R for the swept-vs-looped comparison
KERNEL_D = 1 << 18      # paper-scale flattened cut space (sketched)
KERNEL_P = 8
CUT_UPDATES = 64        # interleaved maintenance ops per timed pass


def quickstart_problem(seed: int = 0) -> TrilevelProblem:
    """The examples/quickstart.py problem (kept in sync by value, not
    import, so the benchmark has no dependency on the examples tree)."""
    key = jax.random.PRNGKey(seed)
    data = {"A": jax.random.normal(key, (N_WORKERS, DIM, DIM)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (N_WORKERS, DIM))}

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    return TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=N_WORKERS,
        x1_init=jnp.zeros(DIM), x2_init=jnp.zeros(DIM),
        x3_init=jnp.zeros(DIM))


def quickstart_setup(n_iterations: int):
    problem = quickstart_problem()
    hyper = Hyper(n_workers=N_WORKERS, s_active=3, tau=5, k_inner=3,
                  p_max=6, t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=DIM)
    cfg = StragglerConfig(n_workers=N_WORKERS, s_active=3, tau=5,
                          n_stragglers=1, straggler_slowdown=5.0, seed=0)
    schedule = StragglerScheduler(cfg).precompute(n_iterations)
    return problem, hyper, cfg, schedule


def quickstart_stream(seed: int = 0):
    """Device-resident stream shaped like the quickstart problem's data
    (per-iteration fresh worker batches, synthesized in-scan)."""
    from repro.data import stream as stream_lib

    def sample(key):
        ka, kb = jax.random.split(key)
        return {"A": jax.random.normal(ka, (DIM, DIM)) * 0.3,
                "b": jax.random.normal(kb, (DIM,))}

    return stream_lib.make_stream(sample, N_WORKERS, seed)


def _timed_run(problem, hyper, cfg, schedule, mode: str):
    n_iterations = schedule.n_iterations
    t0 = time.perf_counter()
    res = run(RunSpec(problem=problem, hyper=hyper, scheduler=cfg,
                      n_iterations=n_iterations,
                      metrics_every=max(1, n_iterations // 10),
                      engine=mode, schedule=schedule))
    jax.block_until_ready(res.state)
    wall = time.perf_counter() - t0
    return res, wall


def record(n_iterations: int = 200) -> dict:
    """The perf record: eager vs cold/warm scan on the same schedule,
    plus the swept-engine and cut_eval-kernel records.

    eager and scan run bit-identical trajectories (same precomputed
    schedule), so sim_time/gap_sq must agree; iters_per_sec is the
    engine difference.  scan_warm is a second run reusing the cached
    compiled trajectory — the steady-state cost benchmarks and sweeps
    actually pay.
    """
    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    out = {"n_iterations": n_iterations}
    res_eager, wall = _timed_run(problem, hyper, cfg, schedule, "eager")
    out["eager"] = _entry(res_eager, wall, n_iterations)
    res_cold, wall = _timed_run(problem, hyper, cfg, schedule, "scan")
    out["scan_cold"] = _entry(res_cold, wall, n_iterations)
    res_warm, wall = _timed_run(problem, hyper, cfg, schedule, "scan")
    out["scan_warm"] = _entry(res_warm, wall, n_iterations)
    out["speedup_warm"] = out["eager"]["wall_s"] / out["scan_warm"]["wall_s"]
    out["speedup_cold"] = out["eager"]["wall_s"] / out["scan_cold"]["wall_s"]
    out["final_state_allclose"] = bool(all(
        jnp.allclose(a, b, rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(res_eager.state),
                        jax.tree.leaves(res_warm.state))))
    out.update(sweep_record(n_iterations))
    out.update(sharded_record(n_iterations))
    out.update(streamed_record(n_iterations))
    out["cut_eval_kernel"] = kernel_record()
    out["fused_round_kernel"] = fused_round_record()
    out["cut_maintenance"] = cut_update_record()
    # top-level series for easy cross-PR diffing
    out["cut_updates_per_sec"] = out["cut_maintenance"]["updates_per_sec"]
    return out


def sharded_record(n_iterations: int = 200, reps: int = 3) -> dict:
    """Sharded-vs-replicated warm scan over the same schedule, plus the
    analytic per-step / per-refresh all-reduce payloads of the worker
    mesh (`repro.core.sharded.traffic_record` — the cut scalars and
    z-reductions that actually cross the mesh; everything else is
    shard-local).  Runs a 2-shard mesh when >= 2 (fake) devices are
    visible (CI forces fake devices via XLA_FLAGS) and degrades to a
    1-shard mesh otherwise — the shard_map machinery is identical, only
    the collectives become trivial, and `n_shards` records which one
    this was."""
    from repro.core import sharded as sharded_lib
    from repro.launch.mesh import make_worker_mesh

    n_shards = 2 if jax.device_count() >= 2 else 1
    mesh = make_worker_mesh(n_shards)
    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    me = max(1, n_iterations // 10)

    res_rep = run_scanned(problem, hyper, schedule, metrics_every=me)
    res_sh = run_scanned(problem, hyper, schedule, metrics_every=me,
                         mesh=mesh)
    rep_wall = sh_wall = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run_scanned(problem, hyper, schedule, metrics_every=me)
        rep_wall = min(rep_wall, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_scanned(problem, hyper, schedule, metrics_every=me, mesh=mesh)
        sh_wall = min(sh_wall, time.perf_counter() - t0)

    match = bool(all(
        jnp.allclose(a, b, rtol=1e-4, atol=1e-6)
        for a, b in zip(jax.tree.leaves(res_rep.state),
                        jax.tree.leaves(res_sh.state))))
    traffic = sharded_lib.traffic_record(res_sh.state.cuts_ii.spec, hyper)
    return {
        "sharded": {
            "n_shards": n_shards,
            "wall_s": sh_wall,
            "replicated_wall_s": rep_wall,
            "iters_per_sec": n_iterations / sh_wall,
            "states_allclose": match,
            **traffic,
        },
        # top-level series for easy cross-PR diffing
        "iters_per_sec_sharded": n_iterations / sh_wall,
    }


def streamed_record(n_iterations: int = 200, reps: int = 3) -> dict:
    """Warm streamed scan (per-iteration in-scan batch synthesis via
    fold-in keys) vs the host-fed warm scan on the same schedule, plus a
    2-chunk streamed pass (state-continued dispatches, the
    `launch/train.py --scan-chunk` shape) checked against the unchunked
    run — the fold-in keys on `state.t` make any chunk partition
    bit-identical, so `chunked_states_allclose` failing means the
    streaming contract broke.  Trajectories legitimately differ from
    host-fed (the data differs by construction): the host-fed column is
    the cost baseline of a constant resident dataset, the streamed one
    buys fresh per-iteration worker samples."""
    import numpy as np

    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    stream = quickstart_stream()
    me = max(1, n_iterations // 10)
    half = n_iterations // 2

    def run_chunked():
        res = run_scanned(problem, hyper, schedule.slice(0, half),
                          metrics_every=me, data=stream)
        return run_scanned(problem, hyper,
                           schedule.slice(half, n_iterations),
                           metrics_every=me, data=stream, state=res.state)

    # warm all three compiled trajectories
    res_host = run_scanned(problem, hyper, schedule, metrics_every=me)
    res_str = run_scanned(problem, hyper, schedule, metrics_every=me,
                          data=stream)
    res_chunk = run_chunked()

    host_wall = str_wall = chunk_wall = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run_scanned(problem, hyper, schedule, metrics_every=me)
        host_wall = min(host_wall, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_scanned(problem, hyper, schedule, metrics_every=me,
                    data=stream)
        str_wall = min(str_wall, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_chunked()
        chunk_wall = min(chunk_wall, time.perf_counter() - t0)

    match = bool(all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(res_str.state),
                        jax.tree.leaves(res_chunk.state))))
    gap = float(res_str.history["gap_sq"][-1])
    return {
        "streamed": {
            "wall_s": str_wall,
            "host_fed_wall_s": host_wall,
            "chunked_wall_s": chunk_wall,
            "n_chunks": 2,
            "iters_per_sec": n_iterations / str_wall,
            "gap_sq": gap,
            "gap_finite": bool(np.isfinite(gap)),
            "chunked_states_allclose": match,
        },
        # top-level series for easy cross-PR diffing
        "iters_per_sec_streamed": n_iterations / str_wall,
    }


def sweep_record(n_iterations: int = 200, n_runs: int = SWEEP_RUNS,
                 reps: int = 3) -> dict:
    """Swept-vs-looped: R seeded trajectories as one `run_swept` dispatch
    vs the equivalent warm Python loop of `run_scanned` calls.  Reports
    the best of `reps` timed passes per engine (the steady-state cost;
    single passes are noisy at quickstart scale) and cross-checks that
    the swept rows reproduce the looped final states."""
    problem, hyper, cfg, _ = quickstart_setup(n_iterations)
    schedules = [
        StragglerScheduler(dataclasses.replace(cfg, seed=s))
        .precompute(n_iterations) for s in range(n_runs)]
    me = max(1, n_iterations // 10)

    # warm both engines (compile once)
    looped_res = [run_scanned(problem, hyper, s, metrics_every=me)
                  for s in schedules]
    swept_res = run_swept(problem, hyper, schedules, metrics_every=me)

    looped_wall = swept_wall = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for s in schedules:
            run_scanned(problem, hyper, s, metrics_every=me)
        looped_wall = min(looped_wall, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_swept(problem, hyper, schedules, metrics_every=me)
        swept_wall = min(swept_wall, time.perf_counter() - t0)

    match = all(
        jnp.allclose(a, jax.tree.map(lambda x: x[r], b), rtol=2e-5,
                     atol=1e-6)
        for r in range(n_runs)
        for a, b in zip(jax.tree.leaves(looped_res[r].state),
                        jax.tree.leaves(swept_res.state)))
    return {
        "sweep": {
            "n_runs": n_runs,
            "looped_wall_s": looped_wall,
            "swept_wall_s": swept_wall,
            "runs_per_sec_looped": n_runs / looped_wall,
            "runs_per_sec_swept": n_runs / swept_wall,
            "swept_speedup": looped_wall / swept_wall,
            "states_allclose": bool(match),
        },
        # top-level series for easy cross-PR diffing
        "runs_per_sec_swept": n_runs / swept_wall,
    }


def _timed_best(fn, iters: int):
    jax.block_until_ready(fn())            # warm/compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_record(p: int = KERNEL_P, d: int = KERNEL_D,
                  iters: int = 3) -> dict:
    """cut_eval at paper-scale D, forward AND differentiated: kernel
    (interpret off-TPU, Mosaic on TPU) vs the jnp reference, with
    effective bandwidth.  The bwd row times the hand-written backward
    kernels (da = g v^T rank-1, dv = g^T A row-reduction) behind
    jax.grad; the gog row times one grad-of-grad pass — the cut-refresh
    (Eq. 23/24) shape that used to force impl="ref" and now stays
    kernel-backed through the cut_ad primitive closure."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (p, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    c = jnp.zeros((p,), jnp.float32)
    act = jnp.ones((p,), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 2), (p,), jnp.float32)

    def loss(impl):
        # quadratic in v, so grad_v depends on v and the grad-of-grad
        # pass below is a real second-order contraction (a linear loss
        # would constant-fold the whole gog graph away).
        return lambda a, v: 0.5 * jnp.sum(
            ops.cut_eval(a, v, c, act, impl=impl) ** 2 * w)

    def gog(impl):
        # d/dv of ||d loss/d v||^2: second-order through the mat-vec.
        inner = lambda v: jnp.sum(jax.grad(loss(impl), argnums=1)(a, v) ** 2)
        return jax.jit(jax.grad(inner))

    # impl forced so the record always captures kernel-vs-ref, even where
    # the auto route would (rightly) pick the jnp mat-vec (interpret-mode
    # streaming off-TPU); on TPU the kernel column is the Mosaic kernel.
    t_kernel = _timed_best(
        lambda: ops.cut_eval(a, v, c, act, impl="pallas"), iters)
    t_ref = _timed_best(
        lambda: ops.cut_eval(a, v, c, act, impl="ref"), iters)
    bwd_k = jax.jit(jax.grad(loss("pallas"), argnums=(0, 1)))
    bwd_r = jax.jit(jax.grad(loss("ref"), argnums=(0, 1)))
    t_bwd_kernel = _timed_best(lambda: bwd_k(a, v), iters)
    t_bwd_ref = _timed_best(lambda: bwd_r(a, v), iters)
    gog_k, gog_r = gog("pallas"), gog("ref")
    t_gog_kernel = _timed_best(lambda: gog_k(v), iters)
    t_gog_ref = _timed_best(lambda: gog_r(v), iters)
    bytes_touched = (p * d + d + 2 * p) * 4
    # backward touches A twice (dv = g^T A) and writes da (P, D)
    bytes_bwd = (2 * p * d + 2 * d + 2 * p) * 4
    return {"p": p, "d": d,
            "kernel_us": t_kernel * 1e6, "ref_us": t_ref * 1e6,
            "kernel_gbps": bytes_touched / t_kernel / 1e9,
            "ref_gbps": bytes_touched / t_ref / 1e9,
            "bwd_kernel_us": t_bwd_kernel * 1e6,
            "bwd_ref_us": t_bwd_ref * 1e6,
            "bwd_kernel_gbps": bytes_bwd / t_bwd_kernel / 1e9,
            "bwd_ref_gbps": bytes_bwd / t_bwd_ref / 1e9,
            "gog_kernel_us": t_gog_kernel * 1e6,
            "gog_ref_us": t_gog_ref * 1e6}


def fused_round_record(p: int = KERNEL_P, d: int = KERNEL_D,
                       iters: int = 3) -> dict:
    """One fused level-2 inner-ADMM cut round at paper-scale (P, D):
    the two-pass Pallas kernel (A streamed exactly twice) vs the jnp
    decomposition (three XLA mat-vec passes over A), plus their max
    output delta — the number `inner.rollout2(use_fused_inner=True)`
    pays per round per cut polytope."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(3)
    # 1/sqrt(D) scaling keeps the cut values O(1) at paper-scale D, so
    # the error column reads as a relative f32 accumulation-order delta
    a = jax.random.normal(key, (p, d), jnp.float32) * (d ** -0.5)
    v = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 2), (d,), jnp.float32)
    mask = (jnp.arange(d) % 2).astype(jnp.float32)
    c = jnp.zeros((p,), jnp.float32)
    act = jnp.ones((p,), jnp.float32)
    s = jnp.abs(jax.random.normal(jax.random.fold_in(key, 4), (p,)))
    gam = jnp.abs(jax.random.normal(jax.random.fold_in(key, 5), (p,)))
    kw = dict(eta_z=0.05, eta_s=0.05, eta_dual=0.05, rho2=1.0)

    t_kernel = _timed_best(lambda: ops.fused_cut_round(
        a, v, g, mask, c, act, s, gam, impl="pallas", **kw), iters)
    t_ref = _timed_best(lambda: ops.fused_cut_round(
        a, v, g, mask, c, act, s, gam, impl="ref", **kw), iters)
    got = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                              impl="pallas", **kw)
    want = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                               impl="ref", **kw)
    err = max(
        float(jnp.max(jnp.abs(x - y)) / (jnp.max(jnp.abs(y)) + 1.0))
        for x, y in zip(got, want))
    return {"p": p, "d": d,
            "kernel_us": t_kernel * 1e6, "ref_us": t_ref * 1e6,
            "kernel_gbps": 2 * p * d * 4 / t_kernel / 1e9,
            "ref_gbps": 3 * p * d * 4 / t_ref / 1e9,
            "a_passes_kernel": 2, "a_passes_ref": 3,
            "max_rel_err": err}


def cut_update_record(p: int = KERNEL_P, d: int = KERNEL_D,
                      n_updates: int = CUT_UPDATES, reps: int = 3) -> dict:
    """Incremental polytope maintenance at paper-scale (P, D): one jit'd
    `lax.scan` of interleaved `add_cut` (flatten-new-row +
    dynamic_update_slice, with evictions once the P slots fill) and
    `drop_inactive` (row mask) ops on the canonical `FlatCuts`.  This is
    the cost the engine pays at every cut refresh — before the flat
    layout became canonical it also included an O(P*D) re-flatten per
    consumer, which this record would catch regressing."""
    from repro.core import cuts as cuts_lib

    n = N_WORKERS
    dz = max(1, d // (3 + 2 * n))        # D = 3*dz + 2*N*dz ~= d
    tpl = jnp.zeros((dz,), jnp.float32)
    fc0 = cuts_lib.empty_cuts(p, n, tpl, tpl, tpl)

    key = jax.random.PRNGKey(0)
    xs = {
        "a1": jax.random.normal(key, (n_updates, dz), jnp.float32),
        "a2": jax.random.normal(jax.random.fold_in(key, 1),
                                (n_updates, dz), jnp.float32),
        "a3": jax.random.normal(jax.random.fold_in(key, 2),
                                (n_updates, dz), jnp.float32),
        "b2": jax.random.normal(jax.random.fold_in(key, 3),
                                (n_updates, n, dz), jnp.float32),
        "b3": jax.random.normal(jax.random.fold_in(key, 4),
                                (n_updates, n, dz), jnp.float32),
        "c": jax.random.normal(jax.random.fold_in(key, 5), (n_updates,),
                               jnp.float32),
        "mult": jax.random.bernoulli(jax.random.fold_in(key, 6), 0.7,
                                     (n_updates, p)).astype(jnp.float32),
        "t": jnp.arange(n_updates, dtype=jnp.int32),
    }

    @jax.jit
    def maintain(fc, xs):
        def one(fc, x):
            fc = cuts_lib.add_cut(
                fc, {"a1": x["a1"], "a2": x["a2"], "a3": x["a3"],
                     "b2": x["b2"], "b3": x["b3"]}, x["c"], x["t"])
            fc = cuts_lib.drop_inactive(fc, x["mult"])
            return fc, None
        fc, _ = jax.lax.scan(one, fc, xs)
        return fc

    jax.block_until_ready(maintain(fc0, xs))          # warm/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(maintain(fc0, xs))
        best = min(best, time.perf_counter() - t0)
    return {"p": p, "d": fc0.spec.d_total, "n_updates": n_updates,
            "wall_s": best,
            "updates_per_sec": n_updates / best,
            "us_per_update": best * 1e6 / n_updates}


def _entry(res, wall: float, n_iterations: int) -> dict:
    return {"wall_s": wall,
            "iters_per_sec": n_iterations / wall,
            "sim_time": float(res.history["sim_time"][-1]),
            "gap_sq": float(res.history["gap_sq"][-1])}


def main(n_iterations: int = 200, record_out: dict = None):
    """record_out, when given, receives the perf record so callers (e.g.
    ``benchmarks/run.py --json``) don't have to re-measure."""
    rec = record(n_iterations)
    if record_out is not None:
        record_out.update(rec)
    rows = []
    for key in ("eager", "scan_cold", "scan_warm"):
        e = rec[key]
        rows.append((f"engine_{key}", e["wall_s"] * 1e6 / n_iterations,
                     f"iters_per_sec={e['iters_per_sec']:.1f};"
                     f"gap_sq={e['gap_sq']:.5f}"))
    rows.append(("engine_speedup", 0.0,
                 f"warm={rec['speedup_warm']:.1f}x;"
                 f"cold={rec['speedup_cold']:.1f}x;"
                 f"allclose={rec['final_state_allclose']}"))
    sw = rec["sweep"]
    rows.append(("engine_sweep",
                 sw["swept_wall_s"] * 1e6 / (sw["n_runs"] * n_iterations),
                 f"runs_per_sec_swept={sw['runs_per_sec_swept']:.1f};"
                 f"runs_per_sec_looped={sw['runs_per_sec_looped']:.1f};"
                 f"speedup={sw['swept_speedup']:.1f}x;"
                 f"allclose={sw['states_allclose']}"))
    stm = rec["streamed"]
    rows.append(("engine_streamed", stm["wall_s"] * 1e6 / n_iterations,
                 f"iters_per_sec_streamed={stm['iters_per_sec']:.1f};"
                 f"host_fed_wall_s={stm['host_fed_wall_s']:.3f};"
                 f"chunk_allclose={stm['chunked_states_allclose']}"))
    sh = rec["sharded"]
    rows.append(("engine_sharded", sh["wall_s"] * 1e6 / n_iterations,
                 f"n_shards={sh['n_shards']};"
                 f"iters_per_sec_sharded={sh['iters_per_sec']:.1f};"
                 f"step_bytes={sh['step_bytes']};"
                 f"refresh_bytes={sh['refresh_bytes']};"
                 f"allclose={sh['states_allclose']}"))
    ker = rec["cut_eval_kernel"]
    rows.append(("cut_eval_kernel", ker["kernel_us"],
                 f"d={ker['d']};kernel_gbps={ker['kernel_gbps']:.2f};"
                 f"ref_gbps={ker['ref_gbps']:.2f}"))
    rows.append(("cut_eval_kernel_bwd", ker["bwd_kernel_us"],
                 f"d={ker['d']};"
                 f"bwd_kernel_gbps={ker['bwd_kernel_gbps']:.2f};"
                 f"bwd_ref_gbps={ker['bwd_ref_gbps']:.2f}"))
    rows.append(("cut_eval_kernel_gog", ker["gog_kernel_us"],
                 f"d={ker['d']};gog_ref_us={ker['gog_ref_us']:.1f}"))
    fr = rec["fused_round_kernel"]
    rows.append(("fused_round_kernel", fr["kernel_us"],
                 f"d={fr['d']};a_passes={fr['a_passes_kernel']}"
                 f"v{fr['a_passes_ref']};"
                 f"kernel_gbps={fr['kernel_gbps']:.2f};"
                 f"ref_gbps={fr['ref_gbps']:.2f};"
                 f"max_rel_err={fr['max_rel_err']:.2e}"))
    cm = rec["cut_maintenance"]
    rows.append(("cut_maintenance", cm["us_per_update"],
                 f"p={cm['p']};d={cm['d']};"
                 f"cut_updates_per_sec={cm['updates_per_sec']:.1f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
