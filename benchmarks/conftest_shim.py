"""Shared benchmark helpers: the tiny problem factory (mirrors
tests/conftest.py without importing pytest machinery) and the
method-sweep dispatch scaffold used by the figure benchmarks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import RunSpec, StragglerConfig, StragglerScheduler, run
from repro.core.types import TrilevelProblem


def swept_method_histories(problem, hyper, s_actives, n_iterations: int,
                           metrics_fn, metrics_every: int, *,
                           n_workers: int, tau: int, n_stragglers: int,
                           seed: int, straggler_slowdown: float = 5.0):
    """One swept dispatch over methods that differ only in their arrival
    schedules (e.g. AFTO's S-of-N vs SFTO's all-N): precomputes one
    schedule per `s_actives` entry and returns the per-method history
    list.  Each method's S also rides the sweep as a per-run
    `hyper.s_active`, so the rows stay correct even if the step math
    ever starts reading S directly (today only the masks differ)."""
    schedules = [
        StragglerScheduler(StragglerConfig(
            n_workers=n_workers, s_active=s_active, tau=tau,
            n_stragglers=n_stragglers,
            straggler_slowdown=straggler_slowdown,
            seed=seed)).precompute(n_iterations)
        for s_active in s_actives]
    res = run(RunSpec(problem=problem, hyper=hyper,
                      n_iterations=n_iterations, metrics_fn=metrics_fn,
                      metrics_every=metrics_every, engine="sweep",
                      schedules=schedules,
                      sweep_hypers={"s_active": list(s_actives)}))
    return [res.run(r).history for r in range(len(s_actives))]


def make_quadratic_problem(n_workers: int = 4, dim: int = 3,
                           seed: int = 0) -> TrilevelProblem:
    key = jax.random.PRNGKey(seed)
    data = {"A": jax.random.normal(key, (n_workers, dim, dim)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_workers, dim))}

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    return TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=n_workers,
        x1_init=jnp.zeros(dim), x2_init=jnp.zeros(dim),
        x3_init=jnp.zeros(dim))
