"""CI retrace gate: warm scan + sweep runs must compile exactly once.

`repro.core.engine.BUILD_COUNTS` counts how many times the scan/sweep
builders actually traced a new compiled trajectory.  In a fresh process,
two scanned runs over the same schedule plus two identical sweeps must
leave both counters at 1 — an accidental per-step `flat_spec`/re-flatten
of the canonical cut matrix (or any cache-key regression) shows up as a
retrace or a re-materialized build and fails this gate fast.  When >= 2
devices are visible (CI forces fake CPU devices via XLA_FLAGS) the gate
also covers the shard_map'd worker-mesh paths: warm sharded scan + sweep
BUILD_COUNTS must likewise stay at 1.

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python -m benchmarks.retrace_gate
"""
from __future__ import annotations

import dataclasses
import json
import sys


def main(n_iterations: int = 40, n_runs: int = 2) -> dict:
    import jax

    from benchmarks.engine_speed import quickstart_setup
    from repro.core import engine
    from repro.core.scheduler import StragglerScheduler

    fresh = {"scan": 0, "sweep": 0, "scan_sharded": 0, "sweep_sharded": 0}
    assert engine.BUILD_COUNTS == fresh, (
        "retrace gate must run in a fresh process", engine.BUILD_COUNTS)

    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    schedules = [
        StragglerScheduler(dataclasses.replace(cfg, seed=s))
        .precompute(n_iterations) for s in range(n_runs)]

    for _ in range(2):
        engine.run_scanned(problem, hyper, schedule, metrics_every=10)
    for _ in range(2):
        engine.run_swept(problem, hyper, schedules, metrics_every=10)

    want = {"scan": 1, "sweep": 1, "scan_sharded": 0, "sweep_sharded": 0}
    sharded_gated = jax.device_count() >= 2
    if sharded_gated:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(2)
        for _ in range(2):
            engine.run_scanned(problem, hyper, schedule, metrics_every=10,
                               mesh=mesh)
        for _ in range(2):
            engine.run_swept(problem, hyper, schedules, metrics_every=10,
                             mesh=mesh)
        want = {"scan": 1, "sweep": 1, "scan_sharded": 1,
                "sweep_sharded": 1}

    ok = engine.BUILD_COUNTS == want
    out = {"build_counts": dict(engine.BUILD_COUNTS),
           "sharded_gated": sharded_gated,
           "status": "ok" if ok else "RETRACE"}
    if not ok:
        raise AssertionError(
            f"scan/sweep retraced across warm runs: {engine.BUILD_COUNTS} "
            f"(expected {want})")
    return out


if __name__ == "__main__":
    try:
        print(json.dumps(main()))
    except AssertionError as e:
        print(json.dumps({"status": "FAIL", "error": str(e)}))
        sys.exit(1)
