"""CI retrace gate: warm scan + sweep runs must compile exactly once.

`repro.core.engine.BUILD_COUNTS` counts how many times the scan/sweep
builders actually traced a new compiled trajectory.  In a fresh process,
two scanned runs over the same schedule plus two identical sweeps must
leave both counters at 1 — an accidental per-step `flat_spec`/re-flatten
of the canonical cut matrix (or any cache-key regression) shows up as a
retrace or a re-materialized build and fails this gate fast.

  PYTHONPATH=src python -m benchmarks.retrace_gate
"""
from __future__ import annotations

import dataclasses
import json
import sys


def main(n_iterations: int = 40, n_runs: int = 2) -> dict:
    from benchmarks.engine_speed import quickstart_setup
    from repro.core import engine
    from repro.core.scheduler import StragglerScheduler

    assert engine.BUILD_COUNTS == {"scan": 0, "sweep": 0}, (
        "retrace gate must run in a fresh process", engine.BUILD_COUNTS)

    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    schedules = [
        StragglerScheduler(dataclasses.replace(cfg, seed=s))
        .precompute(n_iterations) for s in range(n_runs)]

    for _ in range(2):
        engine.run_scanned(problem, hyper, schedule, metrics_every=10)
    for _ in range(2):
        engine.run_swept(problem, hyper, schedules, metrics_every=10)

    ok = engine.BUILD_COUNTS == {"scan": 1, "sweep": 1}
    out = {"build_counts": dict(engine.BUILD_COUNTS),
           "status": "ok" if ok else "RETRACE"}
    if not ok:
        raise AssertionError(
            f"scan/sweep retraced across warm runs: {engine.BUILD_COUNTS} "
            "(expected {'scan': 1, 'sweep': 1})")
    return out


if __name__ == "__main__":
    try:
        print(json.dumps(main()))
    except AssertionError as e:
        print(json.dumps({"status": "FAIL", "error": str(e)}))
        sys.exit(1)
