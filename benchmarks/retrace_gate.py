"""CI retrace gate: warm scan + sweep runs must compile exactly once.

`repro.core.engine.BUILD_COUNTS` counts how many times the scan/sweep
builders actually traced a new compiled trajectory.  In a fresh process,
two scanned runs over the same schedule plus two identical sweeps must
leave both counters at 1 — an accidental per-step `flat_spec`/re-flatten
of the canonical cut matrix (or any cache-key regression) shows up as a
retrace or a re-materialized build and fails this gate fast.  When >= 2
devices are visible (CI forces fake CPU devices via XLA_FLAGS) the gate
also covers the shard_map'd worker-mesh paths: warm sharded scan + sweep
BUILD_COUNTS must likewise stay at 1.

The *_streamed counters gate the data-stream engines: two streamed runs
with DIFFERENT base keys share one compiled trajectory — the stream key
rides the donated carry as a traced value, so re-seeding must never
retrigger tracing (a key leaking into the cache key or the jaxpr as a
constant would double the counter here).

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python -m benchmarks.retrace_gate
"""
from __future__ import annotations

import dataclasses
import json
import sys


def main(n_iterations: int = 40, n_runs: int = 2) -> dict:
    import jax

    from benchmarks.engine_speed import quickstart_setup, quickstart_stream
    from repro.core import engine
    from repro.core.scheduler import StragglerScheduler

    fresh = {k: 0 for k in engine.BUILD_COUNTS}
    assert engine.BUILD_COUNTS == fresh, (
        "retrace gate must run in a fresh process", engine.BUILD_COUNTS)

    problem, hyper, cfg, schedule = quickstart_setup(n_iterations)
    schedules = [
        StragglerScheduler(dataclasses.replace(cfg, seed=s))
        .precompute(n_iterations) for s in range(n_runs)]
    stream = quickstart_stream()

    def reseed(seed):
        return dataclasses.replace(stream, key=jax.random.PRNGKey(seed))

    for _ in range(2):
        engine.run_scanned(problem, hyper, schedule, metrics_every=10)
    for _ in range(2):
        engine.run_swept(problem, hyper, schedules, metrics_every=10)
    for seed in (0, 1):          # re-seeding must hit the same build
        engine.run_scanned(problem, hyper, schedule, metrics_every=10,
                           data=reseed(seed))
        engine.run_swept(problem, hyper, schedules, metrics_every=10,
                         data=reseed(seed))

    want = dict(fresh, scan=1, sweep=1, scan_streamed=1, sweep_streamed=1)
    sharded_gated = jax.device_count() >= 2
    if sharded_gated:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh(2)
        for _ in range(2):
            engine.run_scanned(problem, hyper, schedule, metrics_every=10,
                               mesh=mesh)
        for _ in range(2):
            engine.run_swept(problem, hyper, schedules, metrics_every=10,
                             mesh=mesh)
        for seed in (0, 1):
            engine.run_scanned(problem, hyper, schedule, metrics_every=10,
                               mesh=mesh, data=reseed(seed))
            engine.run_swept(problem, hyper, schedules, metrics_every=10,
                             mesh=mesh, data=reseed(seed))
        want.update(scan_sharded=1, sweep_sharded=1,
                    scan_sharded_streamed=1, sweep_sharded_streamed=1)

    ok = engine.BUILD_COUNTS == want
    out = {"build_counts": dict(engine.BUILD_COUNTS),
           "sharded_gated": sharded_gated,
           "status": "ok" if ok else "RETRACE"}
    if not ok:
        raise AssertionError(
            f"scan/sweep retraced across warm runs: {engine.BUILD_COUNTS} "
            f"(expected {want})")
    return out


if __name__ == "__main__":
    try:
        print(json.dumps(main()))
    except AssertionError as e:
        print(json.dumps({"status": "FAIL", "error": str(e)}))
        sys.exit(1)
