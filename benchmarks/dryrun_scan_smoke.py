"""CI smoke: lower + compile a compiled-trajectory slice of the fed LLM
engine (`launch/dryrun.py --step afto_scan`) with sketch-mode cuts on a
small fake-device mesh, AND lower + run the worker-mesh SHARDED core
afto_scan (`repro.core.engine.run_scanned(mesh=...)`, 2-worker mesh) —
asserting the sharded trajectory's gap matches the replicated scan and
emitting the sharded perf-record fields for the CI artifact.

Uses the classic `jax.sharding.Mesh` API so the check runs on every jax
the repo supports (the `jax.make_mesh(axis_types=...)` path used by the
production dry-run needs a newer jax; `tests/test_dryrun_small.py`
guards on the same attribute).  Run as a subprocess-free entry point:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.dryrun_scan_smoke
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def main(arch: str = "llama3-8b", scan_chunk: int = 2) -> dict:
    from repro.configs import get_config, reduced
    from repro.configs.shapes import InputShape
    from repro.fed.trilevel_llm import FedHyper
    from repro.launch import dryrun as dr

    devices = np.asarray(jax.devices()[:8]).reshape(2, 4)
    mesh = Mesh(devices, ("data", "model"))
    cfg = reduced(get_config(arch))
    shape = InputShape("train_small", seq_len=64, global_batch=4,
                      kind="train")
    hyper = FedHyper(n_workers=2, cut_mode="sketch", sketch_r=64,
                     p_max=2, k_inner=1, remat=False, unroll=False)
    fn, args, shardings = dr.build_train_scan(cfg, shape, mesh, hyper,
                                              chunk=scan_chunk)
    named = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        shardings, is_leaf=lambda x: isinstance(x, P))
    with mesh:
        lowered = jax.jit(fn, in_shardings=named).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per program
        ca = ca[0] if ca else {}
    out = {"arch": cfg.name, "scan_chunk": scan_chunk,
           "cut_mode": hyper.cut_mode,
           "flops": float(ca.get("flops", 0.0)),
           "status": "ok"}
    out.update(sharded_core_smoke())
    return out


def sharded_core_smoke(n_iterations: int = 20, n_shards: int = 2) -> dict:
    """Lower + run the sharded core afto_scan on an `n_shards`-worker
    fake-device mesh and cross-check it against the replicated scan.
    Returns the sharded perf-record fields uploaded with the CI
    artifact (`iters_per_sec_sharded` at smoke scale plus the analytic
    per-step exchange bytes)."""
    import time

    from benchmarks.engine_speed import quickstart_setup
    from repro.core import sharded as sharded_lib
    from repro.core.engine import run_scanned
    from repro.launch.mesh import make_worker_mesh

    problem, hyper, _, schedule = quickstart_setup(n_iterations)
    mesh = make_worker_mesh(n_shards)
    ref = run_scanned(problem, hyper, schedule, metrics_every=5)
    sh = run_scanned(problem, hyper, schedule, metrics_every=5, mesh=mesh)
    gap_ok = bool(np.allclose(ref.history["gap_sq"],
                              sh.history["gap_sq"], rtol=5e-4, atol=1e-6))
    t0 = time.perf_counter()
    run_scanned(problem, hyper, schedule, metrics_every=5, mesh=mesh)
    warm = time.perf_counter() - t0
    traffic = sharded_lib.traffic_record(sh.state.cuts_ii.spec, hyper)
    return {"sharded_scan": {
        "n_shards": n_shards,
        "n_iterations": n_iterations,
        "iters_per_sec_sharded": n_iterations / warm,
        "gap_matches_replicated": gap_ok,
        **traffic,
    }}


if __name__ == "__main__":
    res = main()
    print(json.dumps(res))
    ok = (res["status"] == "ok" and res["flops"] > 0
          and res["sharded_scan"]["gap_matches_replicated"]
          and res["sharded_scan"]["iters_per_sec_sharded"] > 0)
    sys.exit(0 if ok else 1)
