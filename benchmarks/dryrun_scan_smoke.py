"""CI smoke: lower + compile a compiled-trajectory slice of the fed LLM
engine (`launch/dryrun.py --step afto_scan`) with sketch-mode cuts on a
small fake-device mesh.

Uses the classic `jax.sharding.Mesh` API so the check runs on every jax
the repo supports (the `jax.make_mesh(axis_types=...)` path used by the
production dry-run needs a newer jax; `tests/test_dryrun_small.py`
guards on the same attribute).  Run as a subprocess-free entry point:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.dryrun_scan_smoke
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def main(arch: str = "llama3-8b", scan_chunk: int = 2) -> dict:
    from repro.configs import get_config, reduced
    from repro.configs.shapes import InputShape
    from repro.fed.trilevel_llm import FedHyper
    from repro.launch import dryrun as dr

    devices = np.asarray(jax.devices()[:8]).reshape(2, 4)
    mesh = Mesh(devices, ("data", "model"))
    cfg = reduced(get_config(arch))
    shape = InputShape("train_small", seq_len=64, global_batch=4,
                      kind="train")
    hyper = FedHyper(n_workers=2, cut_mode="sketch", sketch_r=64,
                     p_max=2, k_inner=1, remat=False, unroll=False)
    fn, args, shardings = dr.build_train_scan(cfg, shape, mesh, hyper,
                                              chunk=scan_chunk)
    named = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        shardings, is_leaf=lambda x: isinstance(x, P))
    with mesh:
        lowered = jax.jit(fn, in_shardings=named).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per program
        ca = ca[0] if ca else {}
    out = {"arch": cfg.name, "scan_chunk": scan_chunk,
           "cut_mode": hyper.cut_mode,
           "flops": float(ca.get("flops", 0.0)),
           "status": "ok"}
    return out


if __name__ == "__main__":
    res = main()
    print(json.dumps(res))
    sys.exit(0 if res["status"] == "ok" and res["flops"] > 0 else 1)
