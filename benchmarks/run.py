"""Benchmark harness (deliverable d): one entry per paper table/figure
plus the framework-level benchmarks.  Prints ``name,us_per_call,derived``
CSV.  ``--fast`` trims iteration counts for CI-speed runs.  ``--json
out.json`` additionally writes the machine-readable engine perf record
(eager vs scan ``{iters_per_sec, sim_time, gap_sq}``, the swept-engine
series ``runs_per_sec_swept`` vs ``runs_per_sec_looped``, the streamed
series ``iters_per_sec_streamed`` — in-scan per-iteration batch
synthesis with a chunk-partition bit-identity check — the ``cut_eval``
kernel microbenchmark, and the incremental cut-maintenance series
``cut_updates_per_sec`` — interleaved add/drop/evict on the canonical
``FlatCuts`` at paper-scale (P, D)) for trajectory tracking across PRs.

``--json`` also drops a timestamped copy of the record as
``BENCH_<tag>.json`` at the repo root (tag from ``$BENCH_TAG`` or the
git short rev) — the committed perf-trajectory format future PRs and
re-anchors diff against; CI uploads it as an artifact and fails if any
gated series is missing or non-finite.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig1,...]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import traceback

# the series a BENCH_*.json must carry with finite values (the CI gate
# checks these; extend when a new engine/kernel series lands)
BENCH_REQUIRED = (
    "scan_warm.iters_per_sec",
    "runs_per_sec_swept",
    "iters_per_sec_sharded",
    "iters_per_sec_streamed",
    "cut_updates_per_sec",
    "cut_eval_kernel.kernel_us",
    "cut_eval_kernel.bwd_kernel_us",
    "cut_eval_kernel.gog_kernel_us",
    "fused_round_kernel.kernel_us",
    "fused_round_kernel.max_rel_err",
)


def _bench_tag() -> str:
    tag = os.environ.get("BENCH_TAG")
    if tag:
        return tag
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, check=True).stdout.strip()
    except Exception:
        return "local"


def write_bench_file(rec: dict) -> str:
    """BENCH_<tag>.json at the repo root: the perf record plus
    provenance (tag, UTC timestamp, backend/device) in a stable
    committed format."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    import jax
    tag = _bench_tag()
    doc = {
        "tag": tag,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "record": rec,
    }
    path = os.path.join(root, f"BENCH_{tag}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def _lookup(rec: dict, dotted: str):
    cur = rec
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_bench(doc: dict) -> list:
    """Missing/non-finite required series in a BENCH doc (CI gate)."""
    import math
    rec = doc.get("record", doc)
    bad = []
    for key in BENCH_REQUIRED:
        val = _lookup(rec, key)
        if not isinstance(val, (int, float)) or not math.isfinite(val):
            bad.append((key, val))
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,table2,"
                         "kernels,comm,sketch,roofline,engine")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the engine perf record (eager vs scan vs "
                         "swept, plus the cut_eval kernel and "
                         "cut-maintenance records) to this path")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_kernels, comm_complexity, engine_speed,
                            fig1_robust_hpo, fig2_domain_adapt,
                            rate_thm45, roofline_table, sketch_fidelity,
                            table2_baselines)

    engine_iters = 100 if args.fast else 200
    engine_record: dict = {}
    suites = {
        "engine": lambda: engine_speed.main(
            n_iterations=engine_iters, record_out=engine_record),
        "fig1": lambda: fig1_robust_hpo.main(
            n_iterations=60 if args.fast else 120,
            datasets=("diabetes", "boston") if args.fast else None),
        "fig2": lambda: fig2_domain_adapt.main(
            n_iterations=16 if args.fast else 40,
            directions=("mnist_pretrain",) if args.fast else None),
        "table2": lambda: table2_baselines.main(
            n_iterations=60 if args.fast else 150,
            seeds=(0,) if args.fast else (0, 1),
            datasets=("diabetes",) if args.fast
            else ("diabetes", "boston", "red_wine", "white_wine")),
        "rate": lambda: rate_thm45.main(
            n_iterations=150 if args.fast else 400),
        "kernels": bench_kernels.main,
        "comm": comm_complexity.main,
        "sketch": sketch_fidelity.main,
        "roofline": roofline_table.main,
    }

    print("name,us_per_call,derived")
    failed = 0
    for key, fn in suites.items():
        if only and key not in only:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{key},nan,ERROR:{e!r}", flush=True)
            failed += 1

    if args.json:
        try:
            # reuse the record from the engine suite if it just ran
            rec = engine_record or engine_speed.record(
                n_iterations=engine_iters)
            with open(args.json, "w") as f:
                json.dump(rec, f, indent=2)
            print(f"wrote engine perf record to {args.json}", flush=True)
            bench_path = write_bench_file(rec)
            print(f"wrote perf trajectory point to {bench_path}",
                  flush=True)
            with open(bench_path) as f:
                bad = check_bench(json.load(f))
            for key, val in bad:
                print(f"bench_gate,{key},MISSING_OR_NONFINITE:{val!r}",
                      flush=True)
            failed += len(bad)
        except Exception as e:
            traceback.print_exc()
            print(f"json,nan,ERROR:{e!r}", flush=True)
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
