"""Benchmark harness (deliverable d): one entry per paper table/figure
plus the framework-level benchmarks.  Prints ``name,us_per_call,derived``
CSV.  ``--fast`` trims iteration counts for CI-speed runs.  ``--json
out.json`` additionally writes the machine-readable engine perf record
(eager vs scan ``{iters_per_sec, sim_time, gap_sq}``, the swept-engine
series ``runs_per_sec_swept`` vs ``runs_per_sec_looped``, the streamed
series ``iters_per_sec_streamed`` — in-scan per-iteration batch
synthesis with a chunk-partition bit-identity check — the ``cut_eval``
kernel microbenchmark, and the incremental cut-maintenance series
``cut_updates_per_sec`` — interleaved add/drop/evict on the canonical
``FlatCuts`` at paper-scale (P, D)) for trajectory tracking across PRs.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig1,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,table2,"
                         "kernels,comm,sketch,roofline,engine")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the engine perf record (eager vs scan vs "
                         "swept, plus the cut_eval kernel and "
                         "cut-maintenance records) to this path")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_kernels, comm_complexity, engine_speed,
                            fig1_robust_hpo, fig2_domain_adapt,
                            rate_thm45, roofline_table, sketch_fidelity,
                            table2_baselines)

    engine_iters = 100 if args.fast else 200
    engine_record: dict = {}
    suites = {
        "engine": lambda: engine_speed.main(
            n_iterations=engine_iters, record_out=engine_record),
        "fig1": lambda: fig1_robust_hpo.main(
            n_iterations=60 if args.fast else 120,
            datasets=("diabetes", "boston") if args.fast else None),
        "fig2": lambda: fig2_domain_adapt.main(
            n_iterations=16 if args.fast else 40,
            directions=("mnist_pretrain",) if args.fast else None),
        "table2": lambda: table2_baselines.main(
            n_iterations=60 if args.fast else 150,
            seeds=(0,) if args.fast else (0, 1),
            datasets=("diabetes",) if args.fast
            else ("diabetes", "boston", "red_wine", "white_wine")),
        "rate": lambda: rate_thm45.main(
            n_iterations=150 if args.fast else 400),
        "kernels": bench_kernels.main,
        "comm": comm_complexity.main,
        "sketch": sketch_fidelity.main,
        "roofline": roofline_table.main,
    }

    print("name,us_per_call,derived")
    failed = 0
    for key, fn in suites.items():
        if only and key not in only:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{key},nan,ERROR:{e!r}", flush=True)
            failed += 1

    if args.json:
        try:
            # reuse the record from the engine suite if it just ran
            rec = engine_record or engine_speed.record(
                n_iterations=engine_iters)
            with open(args.json, "w") as f:
                json.dump(rec, f, indent=2)
            print(f"wrote engine perf record to {args.json}", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"json,nan,ERROR:{e!r}", flush=True)
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
