"""CI chaos gate: the runtime survives worker churn and stays exact.

Two phases, both fatal on failure:

  1. DETERMINISTIC CHAOS (in-process).  A seeded `ChaosScript` drops,
     duplicates, delays and mid-frame-cuts protocol frames AND crashes
     one worker mid-run (supervised back to life with a bumped resume
     epoch).  The master must complete every iteration, converge, keep
     the recorded staleness inside tau among live workers, record the
     degradation window, and the degraded arrival `Schedule` must
     replay through `run_scanned` back to the chaos run's trajectory.

  2. REAL PROCESS KILL (TCP).  A master over sockets with two worker
     subprocesses; mid-run, worker 0 is SIGKILLed.  The master must
     surface the death (reader DISCONNECT, not a hang), degrade onto
     the survivor, re-admit a respawned worker 0 (`--epoch 1`), finish
     with a decreasing gap, and its recorded Schedule must again
     replay through the scanned engine.

  PYTHONPATH=src python -m benchmarks.chaos_runtime_smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time


def _rel_err(a, b):
    import numpy as np
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-8)))


def phase_deterministic_chaos() -> dict:
    from repro.core import run_scanned
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime.chaos import ChaosScript, run_chaos_async
    from repro.fed.runtime.membership import FaultConfig

    problem, hyper = problems_lib.build("quadratic", n_workers=4)
    script = ChaosScript(seed=5, drop_p=0.08, dup_p=0.08, delay_p=0.10,
                         delay_s=0.002, cut_p=0.04,
                         crash_at_push=((2, 3),))
    fault = FaultConfig(heartbeat_every=0.02, resend_every=0.08,
                        refresh_resend_every=0.08, death_timeout=0.6,
                        poll_interval=0.005, min_iter_time=0.04)
    captured = {}
    res = run_chaos_async(problem, hyper, script, n_iterations=30,
                          fault=fault, restart_delay=0.15,
                          metrics_every=10,
                          master_hook=lambda m: captured.update(m=m))
    status = captured["m"].status
    rec = res.arrivals
    assert rec.n_iterations == 30, "chaos master did not finish"
    assert status["deaths"] >= 1, status
    assert status["rejoins"] >= 1, status
    assert rec.dead is not None and float(rec.dead[:, 2].max()) == 1.0, \
        "degradation window not recorded"
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0], f"chaos run not decreasing: {gaps}"
    max_stale = int(rec.max_staleness.max())
    assert max_stale <= hyper.tau, (max_stale, hyper.tau)

    echo = run_scanned(problem, hyper, rec, metrics_every=10)
    err = _rel_err(res.history["gap_sq"], echo.history["gap_sq"])
    assert err < 2e-5, f"degraded-schedule replay broken: {err}"
    return {"deaths": status["deaths"], "rejoins": status["rejoins"],
            "dead_iterations": int(rec.dead[:, 2].sum()),
            "corrupt_frames": status["corrupt_frames"],
            "max_staleness": max_stale, "replay_rel_err": err,
            "gap_first": float(gaps[0]), "gap_last": float(gaps[-1])}


def phase_tcp_kill_and_rejoin(n_iterations: int = 90) -> dict:
    import os
    import subprocess

    from repro.core import run_scanned
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    from repro.fed.runtime.membership import FaultConfig
    from repro.fed.runtime.transport import TcpTransport
    from repro.launch.serve import spawn_tcp_workers

    args = argparse.Namespace(problem="quadratic", workers=2, dim=3,
                              seed=0)
    problem, hyper = problems_lib.build(
        args.problem, n_workers=args.workers, dim=args.dim,
        seed=args.seed)
    transport = TcpTransport(args.workers, port=0)
    transport.master_endpoint()
    procs = spawn_tcp_workers(args, transport.port)

    def respawn_worker0():
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        src_root = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = (src_root + os.pathsep
                             + env.get("PYTHONPATH", ""))
        return subprocess.Popen(
            [sys.executable, "-m", "repro.fed.runtime.worker",
             "--problem", args.problem, "--worker", "0",
             "--port", str(transport.port),
             "--n-workers", str(args.workers), "--dim", str(args.dim),
             "--seed", str(args.seed), "--epoch", "1"], env=env)

    # pace the master so the kill -> respawn cycle (subprocess startup
    # is seconds) lands inside the run instead of after it
    fault = FaultConfig(heartbeat_every=0.05, resend_every=0.2,
                        refresh_resend_every=0.2, death_timeout=5.0,
                        poll_interval=0.01, min_iter_time=0.12)
    marks = {}

    def watcher(master):
        def wait(cond, key):
            while not cond() and not master.status["done"]:
                time.sleep(0.05)
            marks[key] = master.status["t"]

        wait(lambda: master.status["t"] >= 5, "armed_at")
        procs[0].kill()
        wait(lambda: master.status["deaths"] >= 1, "death_at")
        procs.append(respawn_worker0())
        wait(lambda: master.status["rejoins"] >= 1, "rejoin_at")
        marks["status"] = dict(master.status)

    def hook(master):
        threading.Thread(target=watcher, args=(master,),
                         daemon=True).start()

    try:
        res = run_async(problem, hyper, n_iterations=n_iterations,
                        metrics_every=10, transport=transport,
                        master_hook=hook, fault=fault,
                        accept_timeout=120.0)
    finally:
        for p in procs:
            try:
                p.wait(timeout=30)
            except Exception:
                p.kill()

    st = marks.get("status", {})
    assert st.get("deaths", 0) >= 1, f"kill never surfaced: {marks}"
    assert st.get("rejoins", 0) >= 1, f"respawn never rejoined: {marks}"
    rec = res.arrivals
    assert rec.dead is not None and float(rec.dead[:, 0].max()) == 1.0, \
        "degradation window not recorded"
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0], f"degraded run not decreasing: {gaps}"
    max_stale = int(rec.max_staleness.max())
    assert max_stale <= hyper.tau, (max_stale, hyper.tau)

    echo = run_scanned(problem, hyper, rec, metrics_every=10)
    err = _rel_err(res.history["gap_sq"], echo.history["gap_sq"])
    assert err < 2e-5, f"degraded-schedule replay broken: {err}"
    return {"killed_at": marks.get("armed_at"),
            "death_at": marks.get("death_at"),
            "rejoin_at": marks.get("rejoin_at"),
            "dead_iterations": int(rec.dead[:, 0].sum()),
            "max_staleness": max_stale, "replay_rel_err": err,
            "gap_first": float(gaps[0]), "gap_last": float(gaps[-1])}


def main() -> dict:
    return {"deterministic_chaos": phase_deterministic_chaos(),
            "tcp_kill_rejoin": phase_tcp_kill_and_rejoin()}


if __name__ == "__main__":
    rec = main()
    json.dump(rec, sys.stdout, indent=1)
    print()
    print("chaos runtime smoke: OK")
