"""Table 2: noisy-test MSE — AFTO vs ADBO vs FedNest on the regression
datasets (repeated over seeds; lower is better)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.baselines import run_adbo, run_fednest
from repro.apps.robust_hpo import default_hyper, make_robust_hpo_problem
from repro.core import (RunSpec, StragglerConfig, StragglerScheduler,
                        init_state, run)
from repro.utils.tree import tree_stack

DATASETS = ("diabetes", "boston", "red_wine", "white_wine")


def run_afto_swept(tasks, n, n_iterations, seeds):
    """All AFTO seed repetitions of one dataset as ONE swept dispatch:
    per-seed datasets ride the sweep's stacked `data` axis, per-seed
    model inits its stacked initial states, and per-seed arrival
    processes its schedule stack.  Returns the per-seed mean-worker x3.

    The per-seed tasks share their objective closures (same dataset
    family and worker count), so run 0's TrilevelProblem supplies the
    traced program and only the data/state leaves vary per run."""
    hyper = default_hyper(tasks[0], n, max(1, n - 1), 10)
    schedules = [
        StragglerScheduler(StragglerConfig(
            n_workers=n, s_active=max(1, n - 1), tau=10, n_stragglers=1,
            seed=seed)).precompute(n_iterations)
        for seed in seeds]
    data = tree_stack([t.problem.data for t in tasks])
    states = tree_stack([init_state(t.problem, hyper) for t in tasks])
    res = run(RunSpec(problem=tasks[0].problem, hyper=hyper,
                      n_iterations=n_iterations,
                      metrics_every=n_iterations, engine="sweep",
                      schedules=schedules, sweep_states=states, data=data))
    return [jax.tree.map(lambda x: jnp.mean(x[r], 0), res.state.X3)
            for r in range(len(seeds))]


def main(n_iterations: int = 150, seeds=(0, 1), noise: float = 0.3,
         datasets=DATASETS):
    """Gradient-budget-equalized comparison: FedNest's inner loop takes
    `inner_steps`(=4)+1 gradient evaluations per outer iteration, while
    AFTO/ADBO take one per master iteration — so AFTO/ADBO run 5x the
    iterations for the same total gradient work (the paper compares at
    convergence / equal running time)."""
    rows = []
    grad_equal = 5
    for ds in datasets:
        t0 = time.perf_counter()
        scores = {"AFTO": [], "ADBO": [], "FEDNEST": []}
        tasks = [make_robust_hpo_problem(ds, n_workers=4, seed=seed)
                 for seed in seeds]
        ws = run_afto_swept(tasks, 4, n_iterations * grad_equal, seeds)
        for task, seed, w in zip(tasks, seeds, ws):
            scores["AFTO"].append(float(task.test_mse(w, noise, seed)))
            out = run_adbo(task, n_iterations=n_iterations * grad_equal,
                           seed=seed)
            scores["ADBO"].append(
                float(task.test_mse(out["w"], noise, seed)))
            out = run_fednest(task, n_iterations=n_iterations, seed=seed)
            scores["FEDNEST"].append(
                float(task.test_mse(out["w"], noise, seed)))
        dt = time.perf_counter() - t0
        stat = {k: (float(np.mean(v)), float(np.std(v)))
                for k, v in scores.items()}
        best = min(stat, key=lambda k: stat[k][0])
        rows.append((f"table2_{ds}", dt * 1e6 / n_iterations,
                     ";".join(f"{k.lower()}={m:.4f}+-{s:.4f}"
                              for k, (m, s) in stat.items())
                     + f";best={best}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
