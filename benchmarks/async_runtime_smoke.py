"""CI async-runtime gate: replay parity + live convergence + streaming.

Three checks, all fatal on failure:

  1. PARITY.  The async master/worker runtime over the deterministic
     in-process transport, replaying a seeded arrival Schedule, must
     reproduce `run_scanned` under the same Schedule (gap history
     within float32 tolerance, replayed arrival order exact).
  2. LIVE.  A free-running master + workers (real thread timing, no
     replay) must converge — stationarity gap decreasing — with every
     recorded staleness within the paper's tau bound, and its RECORDED
     arrival Schedule must itself replay through run_scanned back to
     the async trajectory (the closed loop that pins the runtime to the
     proven engine).
  3. STREAMED.  A free run on a `Stream` (each worker synthesizes its
     own batch at its REFRESH's master iteration) must replay through
     the runtime itself at EXACTLY 0.0 rel err (`Master(replay=...)`
     reruns the identical compiled programs), and echo through
     `run_scanned` within 1e-5 — the scanned engine fuses batch
     synthesis + grads + step into one XLA program while the runtime
     decomposes them, so cross-engine agreement is ulp-limited (~1e-7),
     never bitwise.

  PYTHONPATH=src python -m benchmarks.async_runtime_smoke
"""
from __future__ import annotations

import json
import sys


def main(n_iterations: int = 40) -> dict:
    import numpy as np

    from repro.core import run_scanned
    from repro.core.scheduler import StragglerConfig, StragglerScheduler
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async

    problem, hyper = problems_lib.build("quadratic", n_workers=4)
    cfg = StragglerConfig(n_workers=hyper.n_workers,
                          s_active=hyper.s_active, tau=hyper.tau,
                          n_stragglers=1, straggler_slowdown=5.0, seed=0)
    schedule = StragglerScheduler(cfg).precompute(n_iterations)

    # 1. replay parity against the scanned engine
    ref = run_scanned(problem, hyper, schedule, metrics_every=10)
    rep = run_async(problem, hyper, replay=schedule, metrics_every=10)
    gap_err = float(np.max(np.abs(
        np.asarray(rep.history["gap_sq"])
        - np.asarray(ref.history["gap_sq"]))
        / np.maximum(np.abs(np.asarray(ref.history["gap_sq"])), 1e-8)))
    assert gap_err < 2e-5, f"replay parity broken: rel err {gap_err}"
    assert np.array_equal(rep.arrivals.active, schedule.active), \
        "replay consumed a different arrival order than the schedule"

    # 2. live free-run: converge, respect tau, and round-trip the
    #    recorded arrivals through the scanned engine
    live = run_async(problem, hyper, n_iterations=n_iterations,
                     metrics_every=10)
    gaps = live.history["gap_sq"]
    assert gaps[-1] < gaps[0], f"live run not decreasing: {gaps}"
    max_stale = int(live.arrivals.max_staleness.max())
    assert max_stale <= hyper.tau, (max_stale, hyper.tau)
    echo = run_scanned(problem, hyper, live.arrivals, metrics_every=10)
    echo_err = float(np.max(np.abs(
        np.asarray(live.history["gap_sq"])
        - np.asarray(echo.history["gap_sq"]))
        / np.maximum(np.abs(np.asarray(echo.history["gap_sq"])), 1e-8)))
    assert echo_err < 2e-5, f"recorded-arrival replay broken: {echo_err}"

    # 3. streamed free-run: workers synthesize their own batches; the
    #    runtime replay is bitwise (0.0), the scanned echo ulp-limited
    stream = problems_lib.build_stream("quadratic",
                                       n_workers=hyper.n_workers)
    slive = run_async(problem, hyper, n_iterations=n_iterations,
                      metrics_every=10, data=stream)
    assert int(slive.arrivals.max_staleness.max()) <= hyper.tau
    srep = run_async(problem, hyper, replay=slive.arrivals,
                     metrics_every=10, data=stream)
    stream_replay_err = float(np.max(np.abs(
        np.asarray(srep.history["gap_sq"])
        - np.asarray(slive.history["gap_sq"]))))
    assert stream_replay_err == 0.0, \
        f"streamed runtime replay not bitwise: {stream_replay_err}"
    secho = run_scanned(problem, hyper, slive.arrivals,
                        metrics_every=10, data=stream)
    stream_echo_err = float(np.max(np.abs(
        np.asarray(slive.history["gap_sq"])
        - np.asarray(secho.history["gap_sq"]))
        / np.maximum(np.abs(np.asarray(secho.history["gap_sq"])), 1e-8)))
    assert stream_echo_err < 1e-5, \
        f"streamed scanned echo broken: {stream_echo_err}"

    return {"replay_rel_err": gap_err,
            "live_gap_first": float(gaps[0]),
            "live_gap_last": float(gaps[-1]),
            "live_max_staleness": max_stale,
            "recorded_replay_rel_err": echo_err,
            "stream_runtime_replay_rel_err": stream_replay_err,
            "stream_scanned_echo_rel_err": stream_echo_err}


if __name__ == "__main__":
    rec = main()
    json.dump(rec, sys.stdout, indent=1)
    print()
    print("async runtime smoke: OK")
