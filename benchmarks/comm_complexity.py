"""Thm. 4.6: communication complexity — measured bytes per master
iteration vs the paper's analytic count C1^t = 32 S (2 sum d_i + d1 +
|P_II|), plus the cut-update cost C2."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.conftest_shim import make_quadratic_problem
from repro.core import Hyper, RunSpec, StragglerConfig, run
from repro.utils.tree import tree_size


def main(n_iterations: int = 60):
    t0 = time.perf_counter()
    prob = make_quadratic_problem(n_workers=4, dim=3)
    hyper = Hyper(n_workers=4, s_active=3, tau=5, k_inner=3, p_max=6,
                  t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=3)
    # single-seed sweep: the cut-count trajectory rides the same swept
    # dispatch path the figure benchmarks use
    res = run(RunSpec(problem=prob, hyper=hyper,
                      n_iterations=n_iterations, metrics_every=10,
                      engine="sweep", seeds=(0,))).run(0)

    d = (3, 3, 3)
    s = hyper.s_active
    p_ii = res.history["n_cuts_ii"][-1]
    # paper's per-iteration bits: C1 = 32 S (2 sum d_i + d1 + |P_II|)
    c1_bits = 32 * s * (2 * sum(d) + d[0] + p_ii)
    # measured per-iteration payload in the runtime: active workers send
    # x_{i,j}, master broadcasts z_i + lambda + theta_j
    up = s * sum(d) * 32
    down = s * (sum(d) + hyper.p_max + d[0]) * 32
    measured_bits = up + down
    dt = time.perf_counter() - t0
    ratio = measured_bits / c1_bits
    return [("comm_complexity_thm46", dt * 1e6 / n_iterations,
             f"C1_bits={c1_bits:.0f};measured_bits={measured_bits};"
             f"ratio={ratio:.2f};cuts={p_ii:.0f}")]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
