"""Thm. 4.5 non-asymptotic rate check: T(eps) = O(1/eps^2).

Run AFTO on the quadratic trilevel problem, record the running minimum of
the stationarity gap ||grad G^t||^2, and fit log T(eps) vs log(1/eps).
Theorem 4.5 predicts slope <= 2 asymptotically (iteration complexity
upper-bounded by (1/eps^2) * const for small eps); a measured slope well
below ~2.3 is consistent with (does not falsify) the bound.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.conftest_shim import make_quadratic_problem
from repro.core import Hyper, RunSpec, StragglerConfig, run


def _fit_slope(t, g, t1):
    """log T(eps) vs log(1/eps) slope from one gap trajectory."""
    # running min: first iteration achieving each eps level.  Fit ONLY
    # the post-cut-building tail (t > t1): the transient while the
    # polytope is still growing is not the regime Thm 4.5 bounds.
    gmin = np.minimum.accumulate(g)
    tail = t > t1
    if tail.sum() < 4:
        tail = t > t[len(t) // 2]
    g_ref = gmin[tail][0]
    eps_levels = np.geomspace(g_ref * 0.9, gmin[-1] * 1.1, 12)
    t_eps, inv_eps = [], []
    for eps in eps_levels:
        hit = np.nonzero(gmin <= eps)[0]
        if len(hit):
            t_eps.append(t[hit[0]])
            inv_eps.append(1.0 / eps)
    t_eps, inv_eps = np.asarray(t_eps), np.asarray(inv_eps)
    mask = t_eps > t_eps.min()          # drop the trivial prefix
    slope = float("nan")
    if mask.sum() >= 3:
        slope = float(np.polyfit(np.log(inv_eps[mask]),
                                 np.log(t_eps[mask]), 1)[0])
    return slope, gmin


def main(n_iterations: int = 400, seed: int = 0, n_seeds: int = 2):
    """Seed repetitions of the rate check run as one swept dispatch;
    the bound must hold per seed, so each row is fitted separately."""
    t0 = time.perf_counter()
    prob = make_quadratic_problem(n_workers=4, dim=3, seed=seed)
    hyper = Hyper(n_workers=4, s_active=3, tau=5, k_inner=3, p_max=6,
                  t_pre=10, t1=200, eta_x=0.05, eta_z=0.05, d1=3)
    cfg = StragglerConfig(n_workers=4, s_active=3, tau=5, n_stragglers=1,
                          seed=seed)
    res = run(RunSpec(problem=prob, hyper=hyper, scheduler=cfg,
                      n_iterations=n_iterations, metrics_every=5,
                      engine="sweep",
                      seeds=tuple(seed + i for i in range(n_seeds))))
    t = np.asarray(res.history["t"], dtype=np.float64)
    slopes, gap0, gapT = [], None, []
    for r in range(n_seeds):
        g = np.asarray(res.run(r).history["gap_sq"], dtype=np.float64)
        slope, gmin = _fit_slope(t, g, hyper.t1)
        slopes.append(slope)
        gapT.append(gmin[-1])
        if r == 0:
            gap0 = g[0]
    consistent = all(np.isnan(s) or s < 2.3 for s in slopes)
    slope_mean = float(np.nanmean(slopes)) if slopes else float("nan")
    dt = time.perf_counter() - t0
    return [("rate_thm45", dt * 1e6 / (n_iterations * n_seeds),
             f"gap0={gap0:.3f};gapT={min(gapT):.5f};"
             f"fit_slope={slopes[0]:.2f};slope_mean={slope_mean:.2f};"
             f"seeds={n_seeds};bound_slope=2.0;"
             f"consistent={'yes' if consistent else 'no'}")]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
