"""CI elastic-membership gate: late workers join mid-run, stay exact.

Two phases, both fatal on failure:

  1. DETERMINISTIC ELASTIC CHAOS (in-process).  A 3-worker run admits
     two late workers (ids 3 and 4) mid-run through the real
     ADMIT/WELCOME boundary protocol.  The recorded Schedule must be
     WIDENED (a `width` column), both newcomers must contribute
     consumed pushes, the widened trajectory must replay BIT-EXACTLY
     through the segmented engine (`run_scanned_elastic`) AND through a
     fresh `Master(replay=...)` population, and a fixed-membership
     control run with the elastic machinery enabled-but-unused must be
     bitwise identical to one without it.

  2. REAL TCP ADMISSION (subprocesses).  A master over sockets launches
     with two worker subprocesses and `--max-workers`-style headroom; a
     third worker subprocess (`--worker 2`, beyond the launch
     population) connects mid-run and must be admitted, grow the run to
     width 3, and contribute to the quorum.  Worker 0 is then SIGKILLed
     and respawned (the reconnect path sharing the elastic accept
     loop).  Gates: the widened Schedule replays through the segmented
     engine, the gap decreases, and the master endpoint's reader-thread
     list stays pruned (no one-dead-Thread-per-rejoin leak).

  PYTHONPATH=src python -m benchmarks.elastic_runtime_smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time


def _rel_err(a, b):
    import numpy as np
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-8)))


def phase_inproc_elastic() -> dict:
    import numpy as np

    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    from repro.fed.runtime.chaos import ChaosScript, run_chaos_async
    from repro.fed.runtime.membership import (FaultConfig,
                                              run_scanned_elastic)

    elastic = problems_lib.elastic_config("quadratic", 5)
    build = lambda n: problems_lib.build("quadratic", n_workers=n)  # noqa: E731
    problem, hyper = build(3)
    fault = FaultConfig(heartbeat_every=0.02, resend_every=0.1,
                        refresh_resend_every=0.1, death_timeout=2.0,
                        poll_interval=0.005, min_iter_time=0.02)

    res = run_chaos_async(problem, hyper, ChaosScript(),
                          n_iterations=24, fault=fault, elastic=elastic,
                          admit_at=((3, 0.15), (4, 0.3)))
    rec = res.arrivals
    assert rec.width is not None, "admission never widened the schedule"
    assert int(rec.width[0]) == 3 and int(rec.width[-1]) == 5, \
        rec.width.tolist()
    for j in (3, 4):
        assert float(rec.active[:, j].sum()) > 0, \
            f"late worker {j} never contributed to the quorum"
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0], f"elastic run not decreasing: {gaps}"

    # the widened Schedule must replay bit-exactly: segmented engine...
    echo = run_scanned_elastic(build, rec, metrics_every=10)
    assert np.array_equal(np.asarray(res.history["gap_sq"]),
                          np.asarray(echo.history["gap_sq"])), \
        "segmented engine replay is not bitwise"
    assert np.array_equal(np.asarray(res.state.X1),
                          np.asarray(echo.state.X1))
    # ...and a fresh master population replaying the same Schedule
    res2 = run_async(problem, hyper, n_iterations=24, replay=rec,
                     fault=fault, elastic=elastic)
    assert np.array_equal(np.asarray(res2.state.X1),
                          np.asarray(res.state.X1)), \
        "Master(replay=...) of the widened schedule is not bitwise"

    # fixed-membership conformance: elastic enabled-but-unused must not
    # perturb a run (bitwise — the elastic code paths are boundary-only)
    from repro.core.scheduler import StragglerConfig, StragglerScheduler
    sched = StragglerScheduler(StragglerConfig(
        n_workers=3, s_active=hyper.s_active, tau=hyper.tau,
        seed=7)).precompute(20)
    base = run_async(problem, hyper, n_iterations=20, replay=sched,
                     fault=fault)
    gated = run_async(problem, hyper, n_iterations=20, replay=sched,
                      fault=fault, elastic=elastic)
    assert np.array_equal(np.asarray(base.state.X1),
                          np.asarray(gated.state.X1)), \
        "elastic-enabled fixed-membership run diverged from control"
    assert gated.arrivals.width is None

    return {"width": [int(w) for w in (rec.width[0], rec.width[-1])],
            "newcomer_pushes": [float(rec.active[:, j].sum())
                                for j in (3, 4)],
            "gap_first": float(gaps[0]), "gap_last": float(gaps[-1])}


def phase_tcp_admission(n_iterations: int = 90) -> dict:
    import os
    import subprocess

    import numpy as np

    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    from repro.fed.runtime.membership import (FaultConfig,
                                              run_scanned_elastic)
    from repro.fed.runtime.transport import TcpTransport
    from repro.launch.serve import spawn_tcp_workers

    args = argparse.Namespace(problem="quadratic", workers=2, dim=3,
                              seed=0)
    build = lambda n: problems_lib.build(  # noqa: E731
        args.problem, n_workers=n, dim=args.dim, seed=args.seed)
    problem, hyper = build(args.workers)
    elastic = problems_lib.elastic_config(args.problem, 4, dim=args.dim,
                                          seed=args.seed)
    transport = TcpTransport(args.workers, port=0, max_workers=4)
    ep = transport.master_endpoint()
    procs = spawn_tcp_workers(args, transport.port)

    def spawn(worker: int, epoch: int = 0):
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        src_root = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = (src_root + os.pathsep
                             + env.get("PYTHONPATH", ""))
        return subprocess.Popen(
            [sys.executable, "-m", "repro.fed.runtime.worker",
             "--problem", args.problem, "--worker", str(worker),
             "--port", str(transport.port),
             "--n-workers", str(args.workers), "--dim", str(args.dim),
             "--seed", str(args.seed), "--epoch", str(epoch)], env=env)

    fault = FaultConfig(heartbeat_every=0.05, resend_every=0.2,
                        refresh_resend_every=0.2, death_timeout=5.0,
                        poll_interval=0.01, min_iter_time=0.12)
    marks = {}

    def watcher(master):
        def wait(cond, key):
            while not cond() and not master.status["done"]:
                time.sleep(0.05)
            marks[key] = master.status["t"]

        wait(lambda: master.status["t"] >= 5, "late_spawn_at")
        procs.append(spawn(2))             # --worker 2 > --workers 2
        wait(lambda: master.hyper.n_workers >= 3, "admitted_at")
        procs[0].kill()
        wait(lambda: master.status["deaths"] >= 1, "death_at")
        procs.append(spawn(0, epoch=1))
        wait(lambda: master.status["rejoins"] >= 1, "rejoin_at")
        # the thread-leak gate: reader threads of replaced sessions are
        # pruned on install — 3 live readers + the accept loop + at
        # most a couple not-yet-reaped corpses, never one per rejoin
        marks["n_threads"] = len(ep._threads)
        marks["status"] = dict(master.status)

    def hook(master):
        threading.Thread(target=watcher, args=(master,),
                         daemon=True).start()

    try:
        res = run_async(problem, hyper, n_iterations=n_iterations,
                        metrics_every=10, transport=transport,
                        master_hook=hook, fault=fault, elastic=elastic,
                        accept_timeout=120.0)
    finally:
        for p in procs:
            try:
                p.wait(timeout=30)
            except Exception:
                p.kill()

    st = marks.get("status", {})
    assert st.get("n_workers", 0) == 3, \
        f"late worker never admitted: {marks}"
    assert st.get("deaths", 0) >= 1, f"kill never surfaced: {marks}"
    assert st.get("rejoins", 0) >= 1, f"respawn never rejoined: {marks}"
    assert marks.get("n_threads", 99) <= 6, \
        f"reader-thread leak: {marks.get('n_threads')} threads retained"
    rec = res.arrivals
    assert rec.width is not None and int(rec.width[-1]) == 3, \
        "TCP admission did not widen the recorded schedule"
    assert float(rec.active[:, 2].sum()) > 0, \
        "admitted worker never contributed to the quorum"
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0], f"widened run not decreasing: {gaps}"
    max_stale = int(rec.max_staleness.max())

    echo = run_scanned_elastic(build, rec, metrics_every=10)
    err = _rel_err(res.history["gap_sq"], echo.history["gap_sq"])
    assert err < 2e-5, f"widened-schedule replay broken: {err}"
    assert np.array_equal(np.asarray(res.state.X1),
                          np.asarray(echo.state.X1)), \
        "widened-schedule replay is not bitwise on the carry"
    return {"late_spawn_at": marks.get("late_spawn_at"),
            "admitted_at": marks.get("admitted_at"),
            "death_at": marks.get("death_at"),
            "rejoin_at": marks.get("rejoin_at"),
            "n_threads": marks.get("n_threads"),
            "newcomer_pushes": float(rec.active[:, 2].sum()),
            "max_staleness": max_stale, "replay_rel_err": err,
            "gap_first": float(gaps[0]), "gap_last": float(gaps[-1])}


def main() -> dict:
    return {"inproc_elastic": phase_inproc_elastic(),
            "tcp_admission": phase_tcp_admission()}


if __name__ == "__main__":
    rec = main()
    json.dump(rec, sys.stdout, indent=1)
    print()
    print("elastic runtime smoke: OK")
