"""Fig. 1: distributed robust HPO — MSE (clean + noisy test) vs simulated
running time, AFTO vs SFTO, on the four regression datasets (synthetic
stand-ins with the papers' exact shapes; Table 1 worker settings)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.conftest_shim import swept_method_histories
from repro.apps.robust_hpo import default_hyper, make_robust_hpo_problem
from repro.core import RunSpec, StragglerConfig, run

# Table 1 settings: (N, S, stragglers, tau)
SETTINGS = {
    "diabetes": (4, 3, 1, 10),
    "boston": (4, 3, 1, 10),
    "red_wine": (4, 3, 1, 10),
    "white_wine": (6, 4, 1, 10),
}


def run_dataset(dataset: str, n_iterations: int = 120, seed: int = 0,
                engine: str = "sweep"):
    """AFTO vs SFTO as ONE swept dispatch: the two methods differ only in
    their arrival schedules (S-of-N vs all-N), so both trajectories ride
    the same compiled scan body under the sweep vmap."""
    n, s, stragglers, tau = SETTINGS[dataset]
    task = make_robust_hpo_problem(dataset, n_workers=n, seed=seed)

    def metrics(state):
        w = jax.tree.map(lambda x: jnp.mean(x, 0), state.X3)
        return {"mse_clean": task.test_mse(w, 0.0),
                "mse_noisy": task.test_mse(w, 0.3, seed=seed)}

    algos = (("AFTO", s), ("SFTO", n))
    rows = []
    if engine == "sweep":
        per_algo = swept_method_histories(
            task.problem, default_hyper(task, n, s, tau),
            [s_active for _, s_active in algos], n_iterations, metrics,
            10, n_workers=n, tau=tau, n_stragglers=stragglers, seed=seed)
    else:
        per_algo = []
        for algo, s_active in algos:
            hyper = default_hyper(task, n, s_active, tau)
            cfg = StragglerConfig(n_workers=n, s_active=s_active, tau=tau,
                                  n_stragglers=stragglers,
                                  straggler_slowdown=5.0, seed=seed)
            per_algo.append(run(RunSpec(
                problem=task.problem, hyper=hyper, scheduler=cfg,
                n_iterations=n_iterations, metrics_fn=metrics,
                metrics_every=10, engine=engine)).history)
    for (algo, _), h in zip(algos, per_algo):
        for i in range(len(h["t"])):
            rows.append({"dataset": dataset, "algo": algo,
                         "iter": h["t"][i], "sim_time": h["sim_time"][i],
                         "mse_clean": h["mse_clean"][i],
                         "mse_noisy": h["mse_noisy"][i],
                         "gap_sq": h["gap_sq"][i]})
    return rows


def speedup(rows, dataset: str, target_frac: float = 0.7):
    """Sim-time for each algo to first reach target_frac of its own
    initial noisy MSE; returns AFTO time saving vs SFTO (the paper's
    'maximum acceleration ~80%' metric)."""
    out = {}
    for algo in ("AFTO", "SFTO"):
        rs = [r for r in rows if r["dataset"] == dataset
              and r["algo"] == algo]
        target = rs[0]["mse_noisy"] * target_frac
        hit = [r["sim_time"] for r in rs if r["mse_noisy"] <= target]
        out[algo] = hit[0] if hit else float("inf")
    if out["SFTO"] in (0.0, float("inf")) or out["AFTO"] == float("inf"):
        return float("nan")
    return 1.0 - out["AFTO"] / out["SFTO"]


def main(n_iterations: int = 120, datasets=None, engine: str = "sweep"):
    import time
    results = []
    datasets = datasets or list(SETTINGS)
    for ds in datasets:
        t0 = time.perf_counter()
        rows = run_dataset(ds, n_iterations=n_iterations, engine=engine)
        dt = time.perf_counter() - t0
        acc = speedup(rows, ds)
        final = {a: [r for r in rows if r["algo"] == a][-1]["mse_noisy"]
                 for a in ("AFTO", "SFTO")}
        results.append((f"fig1_{ds}", dt * 1e6 / max(n_iterations, 1),
                        f"accel={acc:.2f};afto_noisy={final['AFTO']:.4f};"
                        f"sfto_noisy={final['SFTO']:.4f}"))
    return results


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
