"""Async federation runtime: wire format, transports, parity, RunSpec.

The headline contract (ISSUE 6): the async master/worker runtime over a
deterministic transport, replaying a recorded arrival order, must
reproduce `run_scanned` under the equivalent Schedule — and a live
free-run's *recorded* arrivals must replay through the scanned engine
to the async run's exact trajectory.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RunSpec, Schedule, StragglerConfig, init_state, run,
                        run_chunked, run_scanned)
from repro.core.scheduler import ArrivalRecorder
from repro.fed.runtime import (InProcTransport, Master, TcpTransport, decode,
                               encode, run_async)
from repro.fed.runtime import messages as msg_lib
from repro.fed.runtime import problems as problems_lib
from repro.fed.runtime import worker as worker_lib

from conftest import (make_hyper, make_quadratic_problem, make_schedules,
                      make_straggler_cfg)


# ---------------------------------------------------------------------------
# message layer
# ---------------------------------------------------------------------------

def test_message_roundtrip_push():
    g = (jnp.arange(3.0), jnp.ones((2, 2)), jnp.zeros(4))
    m = msg_lib.push(2, 7, g)
    out = decode(encode(m))
    assert out.kind == msg_lib.PUSH
    assert out.meta == {"worker": 2, "n_pushes": 7, "epoch": 0}
    got = msg_lib.push_grads(out, g)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(g)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_message_peek_kind():
    frame = encode(msg_lib.push(0, 1, (jnp.zeros(2),) * 3))
    assert msg_lib.peek_kind(frame) == msg_lib.PUSH
    assert msg_lib.peek_kind(encode(msg_lib.stop())) == msg_lib.STOP
    # truncated / corrupt frames peek as None, never raise
    assert msg_lib.peek_kind(frame[: len(frame) // 2]) in (msg_lib.PUSH,
                                                           None)
    assert msg_lib.peek_kind(b"\x00\x00\x00\xffjunk") is None


def test_message_roundtrip_empty_payload():
    for m in (msg_lib.hello(3), msg_lib.stop()):
        out = decode(encode(m))
        assert out.kind == m.kind and out.meta == m.meta
        assert out.arrays == {}


def test_message_leaf_count_mismatch_fails_loudly():
    m = decode(encode(msg_lib.push(0, 0, (jnp.zeros(2),) * 3)))
    bad_template = {"a": jnp.zeros(2), "b": jnp.zeros(2)}
    with pytest.raises(ValueError, match="leaves"):
        msg_lib.unpack_tree(m, "g1", bad_template)


def test_message_rejects_pickled_payload():
    # the decoder must refuse object arrays outright
    import io
    import json
    import struct
    buf = io.BytesIO()
    np.savez(buf, x=np.array([{"evil": 1}], dtype=object))
    header = json.dumps({"kind": "push", "meta": {}}).encode()
    frame = struct.pack(">I", len(header)) + header + buf.getvalue()
    with pytest.raises(ValueError):
        decode(frame)


# ---------------------------------------------------------------------------
# transports carry the same encoded frames
# ---------------------------------------------------------------------------

def test_inproc_transport_routes_frames():
    hub = InProcTransport(2)
    me = hub.master_endpoint()
    w0, w1 = hub.worker_endpoint(0), hub.worker_endpoint(1)
    w1.send(encode(msg_lib.hello(1)))
    got = decode(me.recv())
    assert got.kind == msg_lib.HELLO and got.meta["worker"] == 1
    me.send(0, encode(msg_lib.stop()))
    assert decode(w0.recv()).kind == msg_lib.STOP
    assert me.recv(timeout=0.0) is None


def test_tcp_transport_handshake_and_frames():
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    conns = []
    try:
        conns = [TcpTransport.connect("127.0.0.1", hub.port, j)
                 for j in range(2)]
        me.wait_for_workers()
        conns[1].send(encode(msg_lib.push(1, 0, (jnp.ones(2),) * 3)))
        got = decode(me.recv())
        assert got.kind == msg_lib.PUSH and got.meta["worker"] == 1
        me.send(1, encode(msg_lib.stop()))
        assert decode(conns[1].recv()).kind == msg_lib.STOP
    finally:
        for c in conns:
            c.close()
        me.close()


def test_tcp_accept_timeout_names_missing_workers():
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    try:
        c0 = TcpTransport.connect("127.0.0.1", hub.port, 0)
        with pytest.raises(TimeoutError, match=r"missing \[1\]"):
            me.wait_for_workers(timeout=0.3)
        c0.close()
    finally:
        me.close()


def test_tcp_duplicate_hello_rejected():
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    conns = []
    try:
        conns = [TcpTransport.connect("127.0.0.1", hub.port, 0)
                 for _ in range(2)]    # same worker id twice
        with pytest.raises(ConnectionError, match="duplicate"):
            me.wait_for_workers(timeout=5.0)
    finally:
        for c in conns:
            c.close()
        me.close()


def test_tcp_out_of_range_hello_tolerated():
    """An out-of-range HELLO (a worker launched against a stale config,
    a port scanner replaying frames) is closed and skipped — the launch
    completes once the real population arrives.  This used to abort
    `wait_for_workers` and leak the accepted socket."""
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    conns = []
    try:
        bad = TcpTransport.connect("127.0.0.1", hub.port, 7)
        conns = [TcpTransport.connect("127.0.0.1", hub.port, j)
                 for j in range(2)]
        me.wait_for_workers(timeout=10.0)
        assert sorted(me._socks) == [0, 1]   # probe not installed
        bad.close()
    finally:
        for c in conns:
            c.close()
        me.close()


def test_tcp_launch_survives_garbage_preconnections():
    """Malformed probe connections arriving before the real workers —
    a complete-but-undecodable frame and a syntactically valid frame of
    the wrong kind — must each be closed and skipped, not abort the
    launch or block the handshake quorum."""
    import socket as socket_lib
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    probes, conns = [], []
    try:
        s = socket_lib.create_connection(("127.0.0.1", hub.port))
        s.sendall(b"\x00\x00\x00\x04junk")        # garbage 4-byte body
        probes.append(s)
        s = socket_lib.create_connection(("127.0.0.1", hub.port))
        s.sendall(encode(msg_lib.stop()))         # wrong opening kind
        probes.append(s)
        conns = [TcpTransport.connect("127.0.0.1", hub.port, j)
                 for j in range(2)]
        me.wait_for_workers(timeout=10.0)
        assert sorted(me._socks) == [0, 1]
        me.send(0, encode(msg_lib.stop()))        # population is live
        assert decode(conns[0].recv(timeout=5.0)).kind == msg_lib.STOP
    finally:
        for c in conns + probes:
            try:
                c.close()
            except OSError:
                pass
        me.close()


def test_tcp_reader_threads_pruned_across_rejoins():
    """Each reconnect install prunes finished reader threads; the
    endpoint must not retain one dead Thread object per rejoin for the
    life of a long-serving master."""
    hub = TcpTransport(1, port=0)
    me = hub.master_endpoint()
    try:
        c = TcpTransport.connect("127.0.0.1", hub.port, 0)
        me.wait_for_workers()
        for k in range(1, 9):                  # 8 die/rejoin cycles
            c.close()
            got = decode(me.recv(timeout=5.0))
            assert got.kind == msg_lib.DISCONNECT
            c = TcpTransport.connect("127.0.0.1", hub.port, 0, epoch=k)
            got = decode(me.recv(timeout=5.0))
            assert got.kind == msg_lib.HELLO and got.meta["epoch"] == k
        # one live reader + the accept loop + bounded not-yet-reaped
        # slop — NOT one retained corpse per rejoin
        assert len(me._threads) <= 4, len(me._threads)
        c.close()
    finally:
        me.close()


def test_tcp_worker_death_surfaces_disconnect_frame():
    """A broken worker connection must never be swallowed: the reader
    thread surfaces a synthetic DISCONNECT frame to the master loop."""
    hub = TcpTransport(2, port=0)
    me = hub.master_endpoint()
    conns = []
    try:
        conns = [TcpTransport.connect("127.0.0.1", hub.port, j)
                 for j in range(2)]
        me.wait_for_workers()
        conns[0].close()               # worker 0 dies
        got = decode(me.recv(timeout=5.0))
        assert got.kind == msg_lib.DISCONNECT
        assert got.meta["worker"] == 0
    finally:
        for c in conns[1:]:
            c.close()
        me.close()


def test_tcp_rejoin_replaces_socket_and_surfaces_hello():
    """A post-launch re-HELLO (bumped epoch) must install the new socket
    and surface the original HELLO so the master can replay rows."""
    hub = TcpTransport(1, port=0)
    me = hub.master_endpoint()
    try:
        c0 = TcpTransport.connect("127.0.0.1", hub.port, 0)
        me.wait_for_workers()
        c0.close()
        got = decode(me.recv(timeout=5.0))
        assert got.kind == msg_lib.DISCONNECT
        c1 = TcpTransport.connect("127.0.0.1", hub.port, 0, epoch=1)
        got = decode(me.recv(timeout=5.0))
        assert got.kind == msg_lib.HELLO and got.meta["epoch"] == 1
        me.send(0, encode(msg_lib.stop()))   # lands on the NEW socket
        assert decode(c1.recv(timeout=5.0)).kind == msg_lib.STOP
        c1.close()
    finally:
        me.close()


def test_tcp_worker_recv_timeout_returns_none():
    hub = TcpTransport(1, port=0)
    me = hub.master_endpoint()
    try:
        c0 = TcpTransport.connect("127.0.0.1", hub.port, 0)
        me.wait_for_workers()
        assert c0.recv(timeout=0.1) is None    # idle, no desync
        me.send(0, encode(msg_lib.stop()))
        assert decode(c0.recv(timeout=5.0)).kind == msg_lib.STOP
        c0.close()
    finally:
        me.close()


# ---------------------------------------------------------------------------
# arrival recorder
# ---------------------------------------------------------------------------

def test_arrival_recorder_matches_scheduler_semantics():
    rec = ArrivalRecorder(3)
    rec.record(np.array([1, 0, 1], np.float32), 1.0)
    rec.record(np.array([1, 0, 1], np.float32), 2.0)
    # worker 1 never active: staleness (t+1) - last_active = 3
    np.testing.assert_array_equal(rec.staleness(), [1, 3, 1])
    # consuming worker 1 resets it; workers 0/2 now lag by one
    stale = rec.record(np.array([0, 1, 0], np.float32), 3.0)
    assert stale == 1
    sched = rec.to_schedule()
    assert isinstance(sched, Schedule)
    assert sched.n_iterations == 3 and sched.n_workers == 3
    np.testing.assert_array_equal(sched.active[:, 1], [0, 0, 1])
    np.testing.assert_array_equal(sched.sim_time, [1.0, 2.0, 3.0])


# ---------------------------------------------------------------------------
# the parity contracts
# ---------------------------------------------------------------------------

def _tiny():
    prob = make_quadratic_problem()
    hyper = make_hyper()
    return prob, hyper


def test_async_replay_matches_run_scanned():
    """Deterministic transport + recorded arrival order == run_scanned
    under the equivalent Schedule (the ISSUE acceptance contract)."""
    prob, hyper = _tiny()
    (schedule,) = make_schedules(30, seeds=(0,))
    ref = run_scanned(prob, hyper, schedule, metrics_every=5)
    res = run_async(prob, hyper, replay=schedule, metrics_every=5)
    np.testing.assert_allclose(res.history["gap_sq"],
                               ref.history["gap_sq"], rtol=2e-5)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    # replay reproduces the schedule it was given
    np.testing.assert_array_equal(res.arrivals.active, schedule.active)


def test_async_free_run_arrivals_replay_through_scanned_engine():
    """A live free-run's recorded Schedule, replayed through
    run_scanned, reproduces the async trajectory."""
    prob, hyper = _tiny()
    res = run_async(prob, hyper, n_iterations=25, metrics_every=5)
    rec = res.arrivals
    assert rec.n_iterations == 25
    # the master's arrival rule respects the paper's staleness bound
    assert int(rec.max_staleness.max()) <= hyper.tau
    ref = run_scanned(prob, hyper, rec, metrics_every=5)
    np.testing.assert_allclose(res.history["gap_sq"],
                               ref.history["gap_sq"], rtol=2e-5)
    # and the run itself converges
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0]


def _tiny_stream(hyper):
    return problems_lib.build_stream("quadratic",
                                     n_workers=hyper.n_workers,
                                     dim=3, seed=0)


def test_async_streamed_free_run_replays_through_scanned_engine():
    """TENTPOLE acceptance: data may be a Stream — each worker
    synthesizes its own batch at its REFRESH's master iteration — and
    the live run's recorded Schedule replays through `run_scanned` with
    the same Stream.  Cross-engine agreement is ulp-limited (the scan
    fuses batch synthesis + grads + step into one XLA program, the
    runtime decomposes them into separate jits; same math, ~1e-7
    context-dependent rounding — the same floor as the static-data
    async contract), so the gate here is 1e-5; the EXACT 0.0 replay is
    through the runtime itself, pinned below."""
    prob, hyper = _tiny()
    strm = _tiny_stream(hyper)
    res = run_async(prob, hyper, n_iterations=25, metrics_every=5,
                    data=strm)
    assert res.arrivals.n_iterations == 25
    assert int(res.arrivals.max_staleness.max()) <= hyper.tau
    ref = run_scanned(prob, hyper, res.arrivals, metrics_every=5,
                      data=strm)
    np.testing.assert_allclose(res.history["gap_sq"],
                               ref.history["gap_sq"], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(res.state),
                    jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_async_streamed_live_run_replays_bitwise_through_runtime():
    """The exact-replay contract under streaming: a live streamed run's
    recorded Schedule, replayed through a fresh `Master(replay=...)`
    with the same Stream, reproduces the trajectory BITWISE (identical
    compiled programs on a deterministic transport — 0.0 rel err)."""
    prob, hyper = _tiny()
    strm = _tiny_stream(hyper)
    live = run_async(prob, hyper, n_iterations=25, metrics_every=5,
                     data=strm)
    echo = run_async(prob, hyper, replay=live.arrivals, metrics_every=5,
                     data=strm)
    np.testing.assert_array_equal(echo.history["gap_sq"],
                                  live.history["gap_sq"])
    for a, b in zip(jax.tree.leaves(echo.state),
                    jax.tree.leaves(live.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(echo.arrivals.active,
                                  live.arrivals.active)


def test_async_streamed_replay_matches_run_scanned():
    """Replay mode under streamed data: a precomputed Schedule driven
    through the runtime equals the scanned engine with the Stream (to
    the cross-engine ulp floor, see above)."""
    prob, hyper = _tiny()
    strm = _tiny_stream(hyper)
    (schedule,) = make_schedules(20, seeds=(0,))
    ref = run_scanned(prob, hyper, schedule, metrics_every=5, data=strm)
    res = run_async(prob, hyper, replay=schedule, metrics_every=5,
                    data=strm)
    np.testing.assert_allclose(res.history["gap_sq"],
                               ref.history["gap_sq"], rtol=1e-5)
    np.testing.assert_array_equal(res.arrivals.active, schedule.active)


def test_async_policy_adapted_run_replays_bitwise():
    """A live run under an `ArrivalPolicy` records its per-iteration
    effective (s, tau) as Schedule audit columns, and the adapted
    trajectory replays BITWISE through a fresh `Master(replay=...)` —
    the policy only shapes who arrives when; the masks determine the
    math.  The replayed recorder echoes the audit columns."""
    from repro.core.scheduler import ArrivalPolicy
    prob, hyper = _tiny()
    live = run_async(prob, hyper, n_iterations=20, metrics_every=5,
                     policy=ArrivalPolicy(s_active=hyper.s_active,
                                          tau=hyper.tau))
    sched = live.arrivals
    assert sched.s_eff is not None and sched.tau_eff is not None
    assert (sched.s_eff >= 1).all()
    assert (1 <= sched.tau_eff).all() and (sched.tau_eff
                                           <= hyper.tau).all()
    assert int(sched.max_staleness.max()) <= hyper.tau

    echo = run_async(prob, hyper, replay=sched, metrics_every=5)
    np.testing.assert_array_equal(echo.history["gap_sq"],
                                  live.history["gap_sq"])
    for a, b in zip(jax.tree.leaves(echo.state),
                    jax.tree.leaves(live.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(echo.arrivals.s_eff, sched.s_eff)
    np.testing.assert_array_equal(echo.arrivals.tau_eff, sched.tau_eff)


def test_async_stream_worker_count_mismatch_fails_loudly():
    from repro.data import stream as stream_lib
    prob, hyper = _tiny()
    bad = stream_lib.problem_stream(prob.data, hyper.n_workers + 1)
    with pytest.raises(ValueError, match="workers"):
        run_async(prob, hyper, n_iterations=2, data=bad)


def test_worker_rejects_refresh_without_iteration_stamp():
    """Regression: a REFRESH whose meta lacks `t` used to default to
    t=0 <= last_t and read as a duplicate — wedging the worker into an
    infinite push-retransmit loop.  It must surface as a protocol
    error instead."""
    prob, hyper = _tiny()
    hub = InProcTransport(hyper.n_workers)
    me = hub.master_endpoint()
    we = hub.worker_endpoint(0)
    state = init_state(prob, hyper)
    rows = (jax.tree.map(lambda x: x[0], state.X1),
            jax.tree.map(lambda x: x[0], state.X2),
            jax.tree.map(lambda x: x[0], state.X3))
    good = msg_lib.refresh(0, 0, rows)
    me.send(0, encode(good))                      # consumed: last_t = 0
    bad = msg_lib.Message(msg_lib.REFRESH,
                          {"worker": 0},          # no "t" stamp
                          dict(good.arrays))
    me.send(0, encode(bad))
    with pytest.raises(ValueError, match="REFRESH without"):
        worker_lib.worker_loop(prob, 0, we)


def test_run_spec_async_engine_routes_to_runtime():
    prob, hyper = _tiny()
    (schedule,) = make_schedules(12, seeds=(0,))
    ref = run_scanned(prob, hyper, schedule, metrics_every=4)
    res = run(RunSpec(problem=prob, hyper=hyper, engine="async",
                      schedule=schedule, metrics_every=4))
    np.testing.assert_allclose(res.history["gap_sq"],
                               ref.history["gap_sq"], rtol=2e-5)


# ---------------------------------------------------------------------------
# RunSpec front end + deprecation shims
# ---------------------------------------------------------------------------

def test_run_spec_equivalent_to_legacy_kwargs():
    prob, hyper = _tiny()
    cfg = make_straggler_cfg()
    spec_res = run(RunSpec(problem=prob, hyper=hyper, scheduler=cfg,
                           n_iterations=20, metrics_every=5))
    with pytest.warns(DeprecationWarning, match="RunSpec"):
        legacy = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
                     metrics_every=5, mode="scan")
    np.testing.assert_array_equal(spec_res.history["gap_sq"],
                                  legacy.history["gap_sq"])


def test_run_spec_defaults_scheduler_from_hyper():
    prob, hyper = _tiny()
    spec = RunSpec(problem=prob, hyper=hyper)
    cfg = spec.resolved_scheduler()
    assert cfg.n_workers == hyper.n_workers
    assert cfg.s_active == hyper.s_active and cfg.tau == hyper.tau


def test_run_spec_schedule_wins_iteration_count():
    prob, hyper = _tiny()
    (schedule,) = make_schedules(13, seeds=(0,))
    spec = RunSpec(problem=prob, hyper=hyper, schedule=schedule,
                   n_iterations=999)
    assert spec.resolved_iterations() == 13
    res = run(spec)
    assert int(res.history["t"][-1]) == 13


def test_legacy_unknown_kwarg_still_typeerror():
    prob, hyper = _tiny()
    with pytest.raises(TypeError, match="nonsense"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            run(prob, hyper, nonsense=1)


def test_run_spec_validation_errors_preserved():
    prob, hyper = _tiny()
    with pytest.raises(ValueError, match="unknown mode"):
        run(RunSpec(problem=prob, hyper=hyper, engine="warp"))
    with pytest.raises(ValueError, match="chunk"):
        run(RunSpec(problem=prob, hyper=hyper, chunk_hook=lambda s, t: None))
    with pytest.raises(ValueError, match="jit"):
        run(RunSpec(problem=prob, hyper=hyper, engine="sweep", jit=False,
                    seeds=(0,)))


def test_run_spec_chunked_scan_matches_monolithic():
    prob, hyper = _tiny()
    (schedule,) = make_schedules(12, seeds=(0,))
    ref = run(RunSpec(problem=prob, hyper=hyper, schedule=schedule,
                      metrics_every=3))
    boundaries = []
    res = run(RunSpec(problem=prob, hyper=hyper, schedule=schedule,
                      metrics_every=3, chunk_size=5,
                      chunk_hook=lambda st, t: boundaries.append(t)))
    # chunking is exact on the state; the history gains each chunk's
    # final record, so compare at the shared absolute iterations
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)
    shared = {int(t): g for t, g in zip(res.history["t"],
                                        res.history["gap_sq"])}
    for t, g in zip(ref.history["t"], ref.history["gap_sq"]):
        if int(t) in shared:
            np.testing.assert_allclose(shared[int(t)], g, rtol=1e-6)
    assert boundaries == [5, 10, 12]


def test_run_chunked_exported_from_core():
    prob, hyper = _tiny()
    (schedule,) = make_schedules(8, seeds=(0,))
    ref = run_scanned(prob, hyper, schedule, metrics_every=4)
    res = run_chunked(prob, hyper, schedule, chunk_size=3, metrics_every=4)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)
    np.testing.assert_allclose(res.history["gap_sq"][-1],
                               ref.history["gap_sq"][-1], rtol=1e-6)


# ---------------------------------------------------------------------------
# CutSet deprecation
# ---------------------------------------------------------------------------

def test_cutset_surface_warns_flatcuts_does_not():
    from repro.core import cuts as cuts_lib
    tpl = jnp.zeros(3)
    with pytest.warns(DeprecationWarning, match="FlatCuts"):
        cs = cuts_lib.empty_cutset(2, 1, tpl, tpl, tpl)
    flat = cuts_lib.empty_cuts(2, 1, tpl, tpl, tpl)
    with warnings.catch_warnings():   # the canonical path must NOT warn
        warnings.simplefilter("error", DeprecationWarning)
        flat = cuts_lib.add_cut(flat, {"a1": jnp.ones(3)}, 0.5, t=0)
        cuts_lib.eval_cuts(flat, jnp.ones(3), jnp.zeros(3), jnp.zeros(3))
    with pytest.warns(DeprecationWarning, match="FlatCuts"):
        cuts_lib.eval_cuts(cs, jnp.ones(3), jnp.zeros(3), jnp.zeros(3))


# ---------------------------------------------------------------------------
# worker loop unit behavior
# ---------------------------------------------------------------------------

def test_worker_loop_stops_on_stop_message():
    prob, hyper = _tiny()
    hub = InProcTransport(hyper.n_workers)
    me = hub.master_endpoint()
    we = hub.worker_endpoint(0)
    me.send(0, encode(msg_lib.stop()))
    n = worker_lib.worker_loop(prob, 0, we)
    assert n == 0


def test_worker_loop_pushes_f1_gradient_rows():
    prob, hyper = _tiny()
    hub = InProcTransport(hyper.n_workers)
    me = hub.master_endpoint()
    we = hub.worker_endpoint(0)
    state = init_state(prob, hyper)
    rows = (jax.tree.map(lambda x: x[0], state.X1),
            jax.tree.map(lambda x: x[0], state.X2),
            jax.tree.map(lambda x: x[0], state.X3))
    me.send(0, encode(msg_lib.refresh(0, 0, rows)))
    me.send(0, encode(msg_lib.stop()))
    n = worker_lib.worker_loop(prob, 0, we)
    assert n == 1
    # the session opens with the worker's HELLO, then the push
    got = decode(me.recv())
    assert got.kind == msg_lib.HELLO and got.meta["epoch"] == 0
    got = decode(me.recv())
    assert got.kind == msg_lib.PUSH
    g1, g2, g3 = msg_lib.push_grads(got, rows)
    data0 = jax.tree.map(lambda x: x[0], prob.data)
    want = jax.grad(lambda a, b, c: prob.f1(data0, a, b, c),
                    argnums=(0, 1, 2))(*rows)
    for a, b in zip(jax.tree.leaves((g1, g2, g3)),
                    jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# problems registry
# ---------------------------------------------------------------------------

def test_problem_registry_rebuilds_identically():
    p1, h1 = problems_lib.build("quadratic", n_workers=3, dim=2, seed=4)
    p2, h2 = problems_lib.build("quadratic", n_workers=3, dim=2, seed=4)
    for a, b in zip(jax.tree.leaves(p1.data), jax.tree.leaves(p2.data)):
        np.testing.assert_array_equal(a, b)
    assert h1 == h2
    with pytest.raises(KeyError, match="unknown problem"):
        problems_lib.build("no-such-problem")


def test_problem_registry_rows_stable_under_width():
    """The elastic data contract: worker j's data row is a function of
    (seed, j) alone, so a build at ANY width > j yields the same row —
    a late joiner building its problem at width j+1 holds exactly the
    row the master's wider build assigns it."""
    p3, _ = problems_lib.build("quadratic", n_workers=3, dim=4, seed=9)
    p5, _ = problems_lib.build("quadratic", n_workers=5, dim=4, seed=9)
    for k in p3.data:
        np.testing.assert_array_equal(np.asarray(p3.data[k]),
                                      np.asarray(p5.data[k])[:3])


# ---------------------------------------------------------------------------
# elastic membership (ISSUE 10)
# ---------------------------------------------------------------------------

def test_same_epoch_restart_does_not_wedge_run():
    """A worker that crashes and reconnects with the SAME resume epoch
    (a supervisor that lost the bump) must be re-fed rows and have its
    dedup cursor reset.  The master used to treat the re-HELLO as a
    stale duplicate: no row replay, the restarted session's pushes
    (seq restarting at 1) deduped as replays — the worker wedged for
    the rest of the run."""
    import threading
    import time

    from repro.fed.runtime.chaos import (ChaosCrash, ChaosScript,
                                         ChaosWorkerEndpoint)
    from repro.fed.runtime.master import Master
    from repro.fed.runtime.membership import FaultConfig

    prob, hyper = problems_lib.build("quadratic", n_workers=3)
    script = ChaosScript(crash_at_push=((0, 3),))
    fault = FaultConfig(heartbeat_every=0.02, resend_every=0.1,
                        refresh_resend_every=0.1, death_timeout=2.0,
                        poll_interval=0.005, min_iter_time=0.02)
    hub = InProcTransport(3)
    stop_flag = threading.Event()

    def supervise(j):
        armed = True
        while not stop_flag.is_set():
            ep = ChaosWorkerEndpoint(hub.worker_endpoint(j), j, script,
                                     armed=armed)
            try:
                worker_lib.worker_loop(prob, j, ep, epoch=0, fault=fault)
                return
            except ChaosCrash:
                hub.to_master.put(encode(msg_lib.disconnect(j)))
                armed = False
                time.sleep(0.05)
                # deliberately NOT bumping the epoch: the regression

    threads = [threading.Thread(target=supervise, args=(j,), daemon=True)
               for j in range(3)]
    for t in threads:
        t.start()
    master = Master(prob, hyper, hub.master_endpoint(), n_iterations=20,
                    metrics_every=10, fault=fault)
    try:
        res = master.run()
    finally:
        stop_flag.set()
    for t in threads:
        t.join(timeout=30.0)
    assert master.status["deaths"] >= 1     # the crash surfaced
    assert master.status["rejoins"] >= 1    # the same-epoch re-HELLO
    # the discriminating bit: worker 0 contributes AFTER the restart
    rec = res.arrivals
    assert float(rec.active[10:, 0].sum()) > 0, \
        "restarted worker never re-entered the quorum (wedged)"
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0]


def test_elastic_admission_widens_and_replays_bitwise():
    """A live in-proc run that admits a late worker mid-run records a
    WIDENED Schedule that replays bit-exactly through the segmented
    engine and through a fresh `Master(replay=...)` population — and
    the newcomer actually contributes to the quorum."""
    from repro.fed.runtime.chaos import ChaosScript, run_chaos_async
    from repro.fed.runtime.membership import (FaultConfig,
                                              run_scanned_elastic)

    elastic = problems_lib.elastic_config("quadratic", 4)
    build = lambda n: problems_lib.build("quadratic", n_workers=n)  # noqa: E731
    prob, hyper = build(3)
    fault = FaultConfig(heartbeat_every=0.02, resend_every=0.1,
                        refresh_resend_every=0.1, death_timeout=2.0,
                        poll_interval=0.005, min_iter_time=0.02)
    res = run_chaos_async(prob, hyper, ChaosScript(), n_iterations=16,
                          metrics_every=8, fault=fault, elastic=elastic,
                          admit_at=((3, 0.1),))
    rec = res.arrivals
    assert rec.width is not None
    assert int(rec.width[0]) == 3 and int(rec.width[-1]) == 4
    assert float(rec.active[:, 3].sum()) > 0

    echo = run_scanned_elastic(build, rec, metrics_every=8)
    np.testing.assert_array_equal(np.asarray(res.history["gap_sq"]),
                                  np.asarray(echo.history["gap_sq"]))
    np.testing.assert_array_equal(np.asarray(res.state.X1),
                                  np.asarray(echo.state.X1))
    res2 = run_async(prob, hyper, n_iterations=16, replay=rec,
                     fault=fault, elastic=elastic)
    np.testing.assert_array_equal(np.asarray(res2.state.X1),
                                  np.asarray(res.state.X1))


def test_elastic_fixed_membership_is_bitwise_unchanged():
    """Elastic machinery enabled-but-unused must not perturb a
    fixed-membership replay (the boundary-only code-path contract)."""
    elastic = problems_lib.elastic_config("quadratic", 6)
    prob, hyper = _tiny()
    (schedule,) = make_schedules(20, seeds=(1,))
    base = run_async(prob, hyper, replay=schedule, metrics_every=5)
    gated = run_async(prob, hyper, replay=schedule, metrics_every=5,
                      elastic=elastic)
    for a, b in zip(jax.tree.leaves(base.state),
                    jax.tree.leaves(gated.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert gated.arrivals.width is None
