"""Membership: the failure-detector state machine + exact resharding.

The resharding conformance contract (ISSUE 7): partitioning the
canonical `AFTOState` into per-shard worker views and reassembling it is
bitwise lossless, and a mid-trajectory membership re-layout leaves the
continuation bit-identical to the fixed-membership run.
"""
import jax
import numpy as np
import pytest

from repro.core import init_state, run_scanned
from repro.fed.runtime.membership import (FaultConfig, Membership,
                                          assemble_state, make_views,
                                          reshard_state)

from conftest import make_hyper, make_quadratic_problem, make_schedules


# ---------------------------------------------------------------------------
# the failure detector (deterministic via a fake clock)
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _members(n=3, **cfg):
    clock = _Clock()
    m = Membership(n, FaultConfig(**cfg), clock=clock)
    return m, clock


def test_membership_disconnect_and_resurrect():
    m, _ = _members()
    assert m.n_live == 3
    assert m.disconnect(1) is True       # newly dead
    assert m.disconnect(1) is False      # idempotent
    assert m.n_live == 2 and m.deaths == 1
    # ANY frame from a presumed-dead worker resurrects it
    assert m.saw(1) is True
    assert m.n_live == 3 and m.rejoins == 1
    assert m.saw(1) is False             # still alive: no-op


def test_membership_deadline_detection():
    m, clock = _members(death_timeout=1.0)
    clock.t = 0.5
    m.saw(0)                             # worker 0 checked in at 0.5
    clock.t = 1.4
    assert m.overdue() == [1, 2]         # silent since t=0
    for j in m.overdue():
        m.mark_dead(j)
    assert m.n_live == 1 and m.deaths == 2
    assert m.overdue() == []             # dead workers aren't re-reported


def test_membership_epoch_and_seq_dedup():
    m, _ = _members()
    # session 0: pushes 1, 2 consumed
    assert m.fresh_push(0, epoch=0, seq=1) is True
    m.consumed(0, 1)
    assert m.fresh_push(0, epoch=0, seq=1) is False   # duplicate
    assert m.fresh_push(0, epoch=0, seq=2) is True
    m.consumed(0, 2)
    # a rejoin HELLO with a bumped epoch restarts the sequence space
    assert m.hello(0, epoch=1) is True
    assert int(m.epoch[0]) == 1 and int(m.consumed_seq[0]) == 0
    assert m.fresh_push(0, epoch=1, seq=1) is True    # NOT a duplicate
    # frames from the dead session are dropped
    assert m.fresh_push(0, epoch=0, seq=3) is False
    # a stale re-HELLO does not regress the session
    assert m.hello(0, epoch=0) is False
    assert int(m.epoch[0]) == 1


def test_membership_epoch_advance_observed_on_any_frame():
    """A lost rejoin HELLO must not wedge the session: the first push of
    the new epoch advances the bookkeeping."""
    m, _ = _members()
    m.consumed(2, 5)
    assert m.observe_epoch(2, 1) is True
    assert int(m.consumed_seq[2]) == 0
    assert m.fresh_push(2, epoch=1, seq=1) is True
    assert m.observe_epoch(2, 1) is False    # same epoch: no-op


def test_membership_state_dict_round_trip_and_session_reset():
    m, _ = _members()
    m.hello(1, epoch=2)
    m.consumed(1, 7)
    m.disconnect(0)
    d = m.state_dict()
    m2, _ = _members()
    m2.load_state_dict(d)
    np.testing.assert_array_equal(m2.epoch, [0, 2, 0])
    np.testing.assert_array_equal(m2.consumed_seq, [0, 7, 0])
    np.testing.assert_array_equal(m2.alive, [False, True, True])
    # a resumed master faces a fresh population: sessions reset
    m2.reset_sessions()
    assert m2.epoch.sum() == 0 and m2.consumed_seq.sum() == 0
    assert m2.alive.all()


def test_membership_status_shape():
    m, clock = _members()
    clock.t = 2.5
    rows = m.status()
    assert [r["worker"] for r in rows] == [0, 1, 2]
    for r in rows:
        assert set(r) == {"worker", "alive", "last_seen_age", "epoch",
                          "consumed_seq"}
        assert r["last_seen_age"] == pytest.approx(2.5)


# ---------------------------------------------------------------------------
# exact resharding
# ---------------------------------------------------------------------------

def _state():
    prob = make_quadratic_problem()      # 4 workers
    hyper = make_hyper()
    return prob, hyper, init_state(prob, hyper)


def test_make_views_assemble_is_bitwise_identity():
    prob, hyper, state = _state()
    # exercise a non-trivial state: a few optimization steps first
    (sched,) = make_schedules(8, seeds=(0,))
    state = run_scanned(prob, hyper, sched, metrics_every=4).state
    for n_shards in (1, 2, 4):
        views = make_views(state, n_shards)
        assert [v.index for v in views] == list(range(n_shards))
        back = assemble_state(state, views)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_state_is_bitwise_identity():
    prob, hyper, state = _state()
    (sched,) = make_schedules(6, seeds=(1,))
    state = run_scanned(prob, hyper, sched, metrics_every=3).state
    for n_old, n_new in ((2, 4), (4, 2), (1, 4), (4, 1)):
        out = reshard_state(state, n_old, n_new)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_views_do_not_partition_raises():
    _, _, state = _state()
    with pytest.raises(ValueError, match="partition"):
        make_views(state, 3)             # 4 workers over 3 shards


def test_assemble_rejects_incomplete_shard_set():
    _, _, state = _state()
    views = make_views(state, 4)
    with pytest.raises(ValueError, match="complete"):
        assemble_state(state, views[:3])
    with pytest.raises(ValueError, match="complete"):
        assemble_state(state, [views[0], views[0], views[2], views[3]])


def test_resharded_continuation_matches_fixed_membership_run():
    """The membership-change conformance anchor: run half the
    trajectory, re-layout the state over a different worker grouping,
    continue — bit-identical to never having resharded."""
    prob, hyper, _ = _state()
    (sched,) = make_schedules(20, seeds=(0,))
    first = run_scanned(prob, hyper, sched.slice(0, 10), metrics_every=5)

    fixed = run_scanned(prob, hyper, sched.slice(10, 20),
                        state=first.state, metrics_every=5)
    resharded = run_scanned(prob, hyper, sched.slice(10, 20),
                            state=reshard_state(first.state, 2, 4),
                            metrics_every=5)
    for a, b in zip(jax.tree.leaves(fixed.state),
                    jax.tree.leaves(resharded.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(fixed.history["gap_sq"],
                                  resharded.history["gap_sq"])
