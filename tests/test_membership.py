"""Membership: the failure-detector state machine + exact resharding.

The resharding conformance contract (ISSUE 7): partitioning the
canonical `AFTOState` into per-shard worker views and reassembling it is
bitwise lossless, and a mid-trajectory membership re-layout leaves the
continuation bit-identical to the fixed-membership run.

The elastic-growth contract (ISSUE 10): `grow_state` widens the worker
axis with zero rows exactly — at t=0 a grown state is bitwise a fresh
init at the larger width, and mid-run the widened trajectory replays
through the segmented engine (`run_scanned_elastic`); the `Membership`
state machine upholds its session invariants under ANY interleaving of
hello/saw/disconnect/observe_epoch/fresh_push (property-tested).
"""
import jax
import numpy as np
import pytest

from repro.core import init_state, run_scanned
from repro.core import cuts as cuts_lib
from repro.fed.runtime.membership import (FaultConfig, Membership,
                                          assemble_state, grow_state,
                                          make_views, reshard_state)

from conftest import make_hyper, make_quadratic_problem, make_schedules


# ---------------------------------------------------------------------------
# the failure detector (deterministic via a fake clock)
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _members(n=3, **cfg):
    clock = _Clock()
    m = Membership(n, FaultConfig(**cfg), clock=clock)
    return m, clock


def test_membership_disconnect_and_resurrect():
    m, _ = _members()
    assert m.n_live == 3
    assert m.disconnect(1) is True       # newly dead
    assert m.disconnect(1) is False      # idempotent
    assert m.n_live == 2 and m.deaths == 1
    # ANY frame from a presumed-dead worker resurrects it
    assert m.saw(1) is True
    assert m.n_live == 3 and m.rejoins == 1
    assert m.saw(1) is False             # still alive: no-op


def test_membership_deadline_detection():
    m, clock = _members(death_timeout=1.0)
    clock.t = 0.5
    m.saw(0)                             # worker 0 checked in at 0.5
    clock.t = 1.4
    assert m.overdue() == [1, 2]         # silent since t=0
    for j in m.overdue():
        m.mark_dead(j)
    assert m.n_live == 1 and m.deaths == 2
    assert m.overdue() == []             # dead workers aren't re-reported


def test_membership_epoch_and_seq_dedup():
    m, _ = _members()
    # session 0: pushes 1, 2 consumed
    assert m.fresh_push(0, epoch=0, seq=1) is True
    m.consumed(0, 1)
    assert m.fresh_push(0, epoch=0, seq=1) is False   # duplicate
    assert m.fresh_push(0, epoch=0, seq=2) is True
    m.consumed(0, 2)
    # a rejoin HELLO with a bumped epoch restarts the sequence space
    assert m.hello(0, epoch=1) is True
    assert int(m.epoch[0]) == 1 and int(m.consumed_seq[0]) == 0
    assert m.fresh_push(0, epoch=1, seq=1) is True    # NOT a duplicate
    # frames from the dead session are dropped
    assert m.fresh_push(0, epoch=0, seq=3) is False
    m.consumed(0, 1)
    # EVERY re-HELLO requests a row replay (the same-epoch-restart fix:
    # a restarted worker that reuses its epoch must still get its rows),
    # but a STALE epoch never regresses the session bookkeeping
    assert m.hello(0, epoch=0) is True
    assert int(m.epoch[0]) == 1
    assert int(m.consumed_seq[0]) == 1   # stale hello didn't reset seqs


def test_membership_same_epoch_restart_resets_consumed_seq():
    """The same-epoch-restart wedge (regression): a worker that dies and
    restarts WITHOUT bumping its epoch resets its own push counter to 1,
    but the master's consumed_seq was already past it — before the fix
    its re-HELLO returned False (no row replay) and every fresh push was
    dropped as a duplicate until the death timeout fired."""
    m, _ = _members()
    m.hello(1, epoch=0)
    m.consumed(1, 1)
    m.consumed(1, 2)
    assert m.fresh_push(1, epoch=0, seq=1) is False   # the wedge, pre-fix
    # the restarted worker re-HELLOs at the SAME epoch: rows must replay
    # and its restarted sequence space must be accepted again
    assert m.hello(1, epoch=0) is True
    assert int(m.consumed_seq[1]) == 0
    assert m.fresh_push(1, epoch=0, seq=1) is True


def test_membership_grow_and_admit():
    m, clock = _members(n=3)
    with pytest.raises(ValueError, match="grow"):
        m.grow(2)
    m.grow(3)                            # no-op at the same width
    assert m.n == 3
    m.grow(5)
    assert m.n == 5 and len(m.alive) == 5
    # grown slots are NOT alive until their ADMIT is processed (a gap id
    # that never said ADMIT stays dead, like a crashed worker)
    assert not m.alive[3] and not m.alive[4]
    assert m.n_live == 3
    clock.t = 1.0
    m.admit(3, epoch=2)
    assert m.alive[3] and int(m.epoch[3]) == 2
    assert int(m.consumed_seq[3]) == 0 and m.n_live == 4
    # state-dict round trip at the grown width restores the grown n
    d = m.state_dict()
    m2, _ = _members(n=3)
    m2.load_state_dict(d)
    assert m2.n == 5 and len(m2.last_seen) == 5
    np.testing.assert_array_equal(m2.alive, m.alive)


def test_membership_epoch_advance_observed_on_any_frame():
    """A lost rejoin HELLO must not wedge the session: the first push of
    the new epoch advances the bookkeeping."""
    m, _ = _members()
    m.consumed(2, 5)
    assert m.observe_epoch(2, 1) is True
    assert int(m.consumed_seq[2]) == 0
    assert m.fresh_push(2, epoch=1, seq=1) is True
    assert m.observe_epoch(2, 1) is False    # same epoch: no-op


def test_membership_state_dict_round_trip_and_session_reset():
    m, _ = _members()
    m.hello(1, epoch=2)
    m.consumed(1, 7)
    m.disconnect(0)
    d = m.state_dict()
    m2, _ = _members()
    m2.load_state_dict(d)
    np.testing.assert_array_equal(m2.epoch, [0, 2, 0])
    np.testing.assert_array_equal(m2.consumed_seq, [0, 7, 0])
    np.testing.assert_array_equal(m2.alive, [False, True, True])
    # a resumed master faces a fresh population: sessions reset
    m2.reset_sessions()
    assert m2.epoch.sum() == 0 and m2.consumed_seq.sum() == 0
    assert m2.alive.all()


def test_membership_status_shape():
    m, clock = _members()
    clock.t = 2.5
    rows = m.status()
    assert [r["worker"] for r in rows] == [0, 1, 2]
    for r in rows:
        assert set(r) == {"worker", "alive", "last_seen_age", "epoch",
                          "consumed_seq"}
        assert r["last_seen_age"] == pytest.approx(2.5)


# ---------------------------------------------------------------------------
# session-invariant property: any op interleaving, model-checked
# ---------------------------------------------------------------------------

def _check_op_sequence(ops):
    """Apply `ops` to a Membership next to an independent reference
    model and assert after EVERY op: epochs are monotone, the dedup
    bookkeeping matches the model, n_live is consistent, and the
    death/rejoin counters agree."""
    n = 3
    m, _ = _members(n=n)
    epoch = np.zeros(n, np.int64)
    consumed = np.zeros(n, np.int64)
    alive = np.ones(n, bool)
    deaths = rejoins = 0
    for kind, j, arg in ops:
        prev_epoch = m.epoch.copy()
        if kind == "hello":
            assert m.hello(j, arg) is True   # rows ALWAYS replay
            if not alive[j]:
                alive[j] = True
                rejoins += 1
            if arg >= epoch[j]:
                epoch[j] = arg
                consumed[j] = 0
        elif kind == "saw":
            r = m.saw(j)
            assert r == (not alive[j])
            if not alive[j]:
                alive[j] = True
                rejoins += 1
        elif kind == "disconnect":
            r = m.disconnect(j)
            assert r == bool(alive[j])
            if alive[j]:
                alive[j] = False
                deaths += 1
        elif kind == "observe":
            r = m.observe_epoch(j, arg)
            assert r == (arg > epoch[j])
            if arg > epoch[j]:
                epoch[j] = arg
                consumed[j] = 0
        elif kind == "push":
            e, s = arg
            r = m.fresh_push(j, e, s)
            assert r == (e == epoch[j] and s > consumed[j])
            if r:
                m.consumed(j, s)
                consumed[j] = s
        else:  # pragma: no cover
            raise AssertionError(kind)
        assert (m.epoch >= prev_epoch).all(), "session epoch regressed"
        np.testing.assert_array_equal(m.epoch, epoch)
        np.testing.assert_array_equal(m.consumed_seq, consumed)
        np.testing.assert_array_equal(m.alive, alive)
        assert m.n_live == int(alive.sum())
        assert m.deaths == deaths and m.rejoins == rejoins


_OP_KINDS = ("hello", "saw", "disconnect", "observe", "push")


def _random_ops(rng, length):
    ops = []
    for _ in range(length):
        kind = _OP_KINDS[int(rng.integers(len(_OP_KINDS)))]
        j = int(rng.integers(3))
        if kind == "push":
            arg = (int(rng.integers(4)), int(rng.integers(1, 6)))
        elif kind in ("hello", "observe"):
            arg = int(rng.integers(4))
        else:
            arg = None
        ops.append((kind, j, arg))
    return ops


def test_membership_op_sequence_invariants_seeded():
    """Always-on fallback for the hypothesis property below: 200 seeded
    random interleavings through the same model checker."""
    for seed in range(200):
        rng = np.random.default_rng(seed)
        _check_op_sequence(_random_ops(rng, int(rng.integers(1, 40))))


def test_membership_op_sequence_invariants_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    worker = st.integers(0, 2)
    epoch = st.integers(0, 3)
    op = st.one_of(
        st.tuples(st.just("hello"), worker, epoch),
        st.tuples(st.just("saw"), worker, st.none()),
        st.tuples(st.just("disconnect"), worker, st.none()),
        st.tuples(st.just("observe"), worker, epoch),
        st.tuples(st.just("push"), worker,
                  st.tuples(epoch, st.integers(1, 5))))

    @hyp.given(st.lists(op, min_size=1, max_size=60))
    @hyp.settings(max_examples=200, deadline=None)
    def prop(ops):
        _check_op_sequence(ops)

    prop()


# ---------------------------------------------------------------------------
# exact resharding
# ---------------------------------------------------------------------------

def _state():
    prob = make_quadratic_problem()      # 4 workers
    hyper = make_hyper()
    return prob, hyper, init_state(prob, hyper)


def test_make_views_assemble_is_bitwise_identity():
    prob, hyper, state = _state()
    # exercise a non-trivial state: a few optimization steps first
    (sched,) = make_schedules(8, seeds=(0,))
    state = run_scanned(prob, hyper, sched, metrics_every=4).state
    for n_shards in (1, 2, 4):
        views = make_views(state, n_shards)
        assert [v.index for v in views] == list(range(n_shards))
        back = assemble_state(state, views)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_state_is_bitwise_identity():
    prob, hyper, state = _state()
    (sched,) = make_schedules(6, seeds=(1,))
    state = run_scanned(prob, hyper, sched, metrics_every=3).state
    for n_old, n_new in ((2, 4), (4, 2), (1, 4), (4, 1)):
        out = reshard_state(state, n_old, n_new)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_views_do_not_partition_raises():
    _, _, state = _state()
    with pytest.raises(ValueError, match="partition"):
        make_views(state, 3)             # 4 workers over 3 shards


def test_assemble_rejects_incomplete_shard_set():
    _, _, state = _state()
    views = make_views(state, 4)
    with pytest.raises(ValueError, match="complete"):
        assemble_state(state, views[:3])
    with pytest.raises(ValueError, match="complete"):
        assemble_state(state, [views[0], views[0], views[2], views[3]])


def test_resharded_continuation_matches_fixed_membership_run():
    """The membership-change conformance anchor: run half the
    trajectory, re-layout the state over a different worker grouping,
    continue — bit-identical to never having resharded."""
    prob, hyper, _ = _state()
    (sched,) = make_schedules(20, seeds=(0,))
    first = run_scanned(prob, hyper, sched.slice(0, 10), metrics_every=5)

    fixed = run_scanned(prob, hyper, sched.slice(10, 20),
                        state=first.state, metrics_every=5)
    resharded = run_scanned(prob, hyper, sched.slice(10, 20),
                            state=reshard_state(first.state, 2, 4),
                            metrics_every=5)
    for a, b in zip(jax.tree.leaves(fixed.state),
                    jax.tree.leaves(resharded.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(fixed.history["gap_sq"],
                                  resharded.history["gap_sq"])


# ---------------------------------------------------------------------------
# elastic growth (ISSUE 10)
# ---------------------------------------------------------------------------

def _registry(n):
    from repro.fed.runtime import problems as problems_lib
    return problems_lib.build("quadratic", n_workers=n)


def test_grow_state_rejects_shrink_and_is_idempotent_at_width():
    prob, hyper = _registry(3)
    state = init_state(prob, hyper)
    with pytest.raises(ValueError, match="grows"):
        grow_state(state, 2)
    assert grow_state(state, 3) is state


def test_grow_then_continue_matches_run_started_at_larger_width():
    """The grow-then-reshard conformance anchor: growing a fresh state
    is bitwise a fresh init at the larger width (zero rows, zero cut
    columns, t_hat at the boundary), so the continuation under any
    width-5 schedule is the width-5 run itself, bit for bit.  Relies on
    the registry's per-worker-row data stability."""
    p3, h3 = _registry(3)
    p5, h5 = _registry(5)
    grown = grow_state(init_state(p3, h3), 5)
    fresh = init_state(p5, h5)
    assert grown.cuts_i.spec == fresh.cuts_i.spec
    assert grown.cuts_ii.spec == fresh.cuts_ii.spec
    for a, b in zip(jax.tree.leaves(grown), jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # registry data rows shared between the widths are identical too
    # (the contract that lets a late worker build its own problem)
    np.testing.assert_array_equal(np.asarray(p3.data["A"]),
                                  np.asarray(p5.data["A"])[:3])
    np.testing.assert_array_equal(np.asarray(p3.data["b"]),
                                  np.asarray(p5.data["b"])[:3])

    (sched,) = make_schedules(10, seeds=(3,), n_workers=5)
    cont = run_scanned(p5, h5, sched, state=grown, metrics_every=5)
    ref = run_scanned(p5, h5, sched, state=fresh, metrics_every=5)
    for a, b in zip(jax.tree.leaves(cont.state),
                    jax.tree.leaves(ref.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grow_cuts_pads_b_columns_and_preserves_a_columns():
    """Mid-run growth of a POPULATED polytope: the replicated a-columns
    and every old worker's b-columns are byte-identical, the new
    workers' b-columns are zero, and t_hat of the grown rows starts at
    the admission boundary `state.t`."""
    prob, hyper = _registry(3)
    (sched,) = make_schedules(12, seeds=(2,), n_workers=3)
    state = run_scanned(prob, hyper, sched, metrics_every=6).state
    assert float(np.sum(np.asarray(state.cuts_ii.active))) > 0
    grown = grow_state(state, 5)
    assert int(np.shape(grown.X1)[0]) == 5
    np.testing.assert_array_equal(np.asarray(grown.X1)[:3],
                                  np.asarray(state.X1))
    np.testing.assert_array_equal(np.asarray(grown.X1)[3:], 0.0)
    t_hat = np.asarray(grown.stale.t_hat)
    np.testing.assert_array_equal(t_hat[:3], np.asarray(state.stale.t_hat))
    np.testing.assert_array_equal(t_hat[3:], int(state.t))
    for fc, gc in ((state.cuts_i, grown.cuts_i),
                   (state.cuts_ii, grown.cuts_ii)):
        old_spec, new_spec = fc.spec, gc.spec
        np.testing.assert_array_equal(np.asarray(fc.c), np.asarray(gc.c))
        np.testing.assert_array_equal(np.asarray(fc.active),
                                      np.asarray(gc.active))
        na = cuts_lib.n_a_leaves(old_spec)
        p = np.asarray(fc.a).shape[0]
        for i in range(len(old_spec.sizes)):
            old_col = np.asarray(fc.a)[:, old_spec.offsets[i]:
                                       old_spec.offsets[i]
                                       + old_spec.sizes[i]]
            new_col = np.asarray(gc.a)[:, new_spec.offsets[i]:
                                       new_spec.offsets[i]
                                       + new_spec.sizes[i]]
            if i < na:
                np.testing.assert_array_equal(old_col, new_col)
            else:
                per = old_spec.sizes[i] // 3
                old3 = old_col.reshape(p, 3, per)
                new5 = new_col.reshape(p, 5, per)
                np.testing.assert_array_equal(new5[:, :3], old3)
                np.testing.assert_array_equal(new5[:, 3:], 0.0)
