"""Deterministic fault injection: the ISSUE 7 acceptance harness.

Under a seeded `ChaosScript` (drops + duplicates + delays + mid-frame
cuts + one scripted crash with rejoin), the master must complete its
iterations, the degraded trajectory's recorded `Schedule` must replay
bit-exactly through BOTH `run_scanned` and a fresh `Master(replay=...)`,
and a master killed mid-run and resumed from its durable checkpoint
must match the uninterrupted run bitwise.
"""
import os

import jax
import numpy as np
import pytest

from repro.core import run_scanned
from repro.fed.runtime import run_async
from repro.fed.runtime.chaos import ChaosScript, run_chaos_async
from repro.fed.runtime.membership import FaultConfig

from conftest import make_hyper, make_quadratic_problem, make_schedules


def _tiny():
    return make_quadratic_problem(), make_hyper()


FAST = FaultConfig(heartbeat_every=0.02, resend_every=0.08,
                   refresh_resend_every=0.08, death_timeout=0.6,
                   poll_interval=0.005, all_dead_timeout=10.0)


# ---------------------------------------------------------------------------
# the script itself is deterministic
# ---------------------------------------------------------------------------

def test_chaos_script_draws_are_deterministic():
    s = ChaosScript(seed=7, drop_p=0.3, dup_p=0.3, delay_p=0.3, cut_p=0.3)
    a = [s.draw(role, w, k) for role in (0, 1) for w in range(3)
         for k in range(20)]
    b = [s.draw(role, w, k) for role in (0, 1) for w in range(3)
         for k in range(20)]
    assert a == b
    # independent streams per (role, worker, frame): not all identical
    assert len({tuple(d.values()) for d in a}) > 1
    # a different seed reprograms the faults
    s2 = ChaosScript(seed=8, drop_p=0.3, dup_p=0.3, delay_p=0.3, cut_p=0.3)
    assert [s2.draw(0, 0, k) for k in range(20)] != \
        [s.draw(0, 0, k) for k in range(20)]


def test_chaos_script_crash_point_lookup():
    s = ChaosScript(crash_at_push=((1, 4), (3, 2)))
    assert s.crash_point(1) == 4 and s.crash_point(3) == 2
    assert s.crash_point(0) is None


# ---------------------------------------------------------------------------
# lossy network: drops + dups + delays + cuts, no deaths
# ---------------------------------------------------------------------------

def test_chaos_lossy_network_completes_and_replays():
    """Dropped, duplicated, delayed and mid-frame-cut frames: the
    retransmit protocol heals them all; the run completes and the
    recorded Schedule replays through run_scanned AND a fresh replay
    master to the exact same trajectory."""
    prob, hyper = _tiny()
    script = ChaosScript(seed=3, drop_p=0.10, dup_p=0.10, delay_p=0.15,
                         delay_s=0.002, cut_p=0.05)
    captured = {}
    res = run_chaos_async(prob, hyper, script, n_iterations=20,
                          fault=FAST, metrics_every=5,
                          master_hook=lambda m: captured.update(m=m))
    rec = res.arrivals
    assert rec.n_iterations == 20
    assert int(rec.max_staleness.max()) <= hyper.tau
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0]

    ref = run_scanned(prob, hyper, rec, metrics_every=5)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)

    # and a fresh replay master (clean transport) is bit-identical: the
    # masks fully determine the math, chaos only shaped who arrived when
    res2 = run_async(prob, hyper, replay=rec, metrics_every=5)
    for a, b in zip(jax.tree.leaves(res.state),
                    jax.tree.leaves(res2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(res2.arrivals.active, rec.active)


# ---------------------------------------------------------------------------
# cut STOP frames: the shutdown handshake's own fault
# ---------------------------------------------------------------------------

def test_cut_stop_frames_are_resent_until_workers_dismiss():
    """The unstoppable-worker regression: a STOP corrupted in flight is
    dropped by the worker's decode (and STOP has no worker-side
    retransmit to heal it), so before the resend fix the worker would
    spin in its push-retransmit loop forever.  The master must now
    resend STOP until every session closes.  Seeded so the FIRST STOP
    to every worker is cut."""
    import dataclasses
    import threading

    from repro.fed.runtime import transport as transport_lib
    from repro.fed.runtime import worker as worker_lib
    from repro.fed.runtime.chaos import ChaosMasterEndpoint
    from repro.fed.runtime.master import Master

    prob, hyper = _tiny()
    n = hyper.n_workers
    script = ChaosScript(seed=0, stop_cut_p=0.7)
    # preconditions: the fault is real (every worker's first STOP is
    # cut — exactly the frame the pre-fix shutdown sent exactly once)
    # and survivable (some retransmit gets through within 30 tries)
    assert all(script.stop_cut(j, 0) for j in range(n))
    assert all(any(not script.stop_cut(j, k) for k in range(1, 30))
               for j in range(n))

    hub = transport_lib.InProcTransport(n)
    fault = dataclasses.replace(FAST, stop_timeout=30.0)
    threads = [threading.Thread(
        target=worker_lib.worker_loop,
        args=(prob, j, hub.worker_endpoint(j)),
        kwargs={"fault": fault}, daemon=True) for j in range(n)]
    for t in threads:
        t.start()
    master = Master(prob, hyper,
                    ChaosMasterEndpoint(hub.master_endpoint(), script),
                    n_iterations=8, metrics_every=4, fault=fault)
    res = master.run()
    for t in threads:
        t.join(timeout=20.0)
    # the resend drain dismissed every worker despite the cut STOPs
    assert not any(t.is_alive() for t in threads)
    assert res.arrivals.n_iterations == 8


# ---------------------------------------------------------------------------
# scripted crash + rejoin
# ---------------------------------------------------------------------------

def test_chaos_crash_death_rejoin_and_exact_replay():
    """Worker 1 dies at its 3rd push: the master must declare it dead
    (DISCONNECT surfaced, pending dropped, tau-forcing suspended),
    degrade onto the survivors, record the degradation, re-admit the
    respawned session (bumped epoch), and the whole degraded trajectory
    must still replay exactly."""
    import dataclasses
    prob, hyper = _tiny()
    script = ChaosScript(seed=11, crash_at_push=((1, 3),))
    # pace the master (~25 it/s) so the crash->rejoin window (0.15s)
    # spans recorded iterations instead of hiding inside one
    paced = dataclasses.replace(FAST, min_iter_time=0.04)
    captured = {}
    res = run_chaos_async(prob, hyper, script, n_iterations=30,
                          fault=paced, restart_delay=0.15, metrics_every=5,
                          master_hook=lambda m: captured.update(m=m))
    master = captured["m"]
    assert master.status["deaths"] >= 1
    assert master.status["rejoins"] >= 1
    rec = res.arrivals
    assert rec.n_iterations == 30
    # the degradation is recorded: worker 1 spent iterations dead...
    assert rec.dead is not None and rec.dead[:, 1].max() == 1.0
    # ...and came back (the final recorded population is whole again)
    assert rec.dead[-1].sum() == 0.0
    # the staleness bound holds among live workers throughout
    assert int(rec.max_staleness.max()) <= hyper.tau
    gaps = res.history["gap_sq"]
    assert gaps[-1] < gaps[0]

    # exact replay of the degraded schedule through the scanned engine
    ref = run_scanned(prob, hyper, rec, metrics_every=5)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    # and bit-exact through a fresh replay master
    res2 = run_async(prob, hyper, replay=rec, metrics_every=5)
    for a, b in zip(jax.tree.leaves(res.state),
                    jax.tree.leaves(res2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# kill the master, resume from the durable checkpoint
# ---------------------------------------------------------------------------

def test_master_kill_and_resume_matches_uninterrupted_bitwise(tmp_path):
    """Replay mode makes the trajectory deterministic, so the resume
    contract is provable bitwise: run 20 iterations straight; then run
    10, 'lose' the master, resume from its checkpoint for the remaining
    10 — final states identical to the last bit."""
    prob, hyper = _tiny()
    (sched,) = make_schedules(20, seeds=(0,))
    ckpt = os.fspath(tmp_path / "master_ckpt")

    ref = run_async(prob, hyper, replay=sched, metrics_every=10)

    # the doomed master: checkpoints every 5 arrivals, "dies" after 10
    run_async(prob, hyper, replay=sched.slice(0, 10), metrics_every=10,
              ckpt_dir=ckpt, ckpt_every=5)
    assert sorted(os.listdir(ckpt))[-1] == "step_00000010"

    # resume: fresh master process, fresh worker population
    res = run_async(prob, hyper, replay=sched, metrics_every=10,
                    ckpt_dir=ckpt, resume=True)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(ref.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(res.arrivals.active, ref.arrivals.active)
    np.testing.assert_array_equal(res.history["gap_sq"],
                                  ref.history["gap_sq"])
    np.testing.assert_array_equal(res.history["t"], ref.history["t"])


def test_resume_without_checkpoint_fails_loudly(tmp_path):
    from repro.checkpoint.io import CheckpointError
    prob, hyper = _tiny()
    (sched,) = make_schedules(4, seeds=(0,))
    with pytest.raises(CheckpointError, match="no checkpoints"):
        run_async(prob, hyper, replay=sched,
                  ckpt_dir=os.fspath(tmp_path / "empty"), resume=True)
