import os
import sys

# tests see CPU devices; the worker-mesh suite (test_sharded_engine.py)
# needs a small fake-device mesh, and the count must be fixed before jax
# initializes a backend — so the whole suite runs on 8 fake CPU devices
# (single-device code paths are unaffected: unsharded jits execute on
# device 0).  The 512-device production override lives ONLY in
# repro.launch.dryrun.  Keep math in f32 for tight tolerances.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    # append rather than setdefault: an unrelated pre-set XLA_FLAGS must
    # not silently drop the fake devices (and with them every sharded
    # conformance test via the device_count skipif)
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)


def make_quadratic_problem(n_workers: int = 4, dim: int = 3, seed: int = 0):
    """Tiny trilevel problem used across core tests."""
    import jax.numpy as jnp
    from repro.core.types import TrilevelProblem

    key = jax.random.PRNGKey(seed)
    data = {"A": jax.random.normal(key, (n_workers, dim, dim)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_workers, dim))}

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    return TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=n_workers,
        x1_init=jnp.zeros(dim), x2_init=jnp.zeros(dim),
        x3_init=jnp.zeros(dim))


# ---------------------------------------------------------------------------
# shared small-problem builders (hoisted from the engine test files so
# test_engine / test_system / test_sharded_engine use ONE definition)
# ---------------------------------------------------------------------------

def make_hyper(**kw):
    """The quickstart-scale Hyper used across engine/system tests."""
    from repro.core.types import Hyper

    base = dict(n_workers=4, s_active=3, tau=5, k_inner=3, p_max=6,
                t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=3)
    base.update(kw)
    return Hyper(**base)


def make_straggler_cfg(**kw):
    """The matching 1-straggler arrival-process config."""
    from repro.core.scheduler import StragglerConfig

    base = dict(n_workers=4, s_active=3, tau=5, n_stragglers=1,
                straggler_slowdown=5.0, seed=0)
    base.update(kw)
    return StragglerConfig(**base)


def make_schedules(n_iterations, seeds, **cfg_kw):
    """One precomputed schedule per seed (shared cfg overrides)."""
    from repro.core.scheduler import StragglerScheduler

    return [StragglerScheduler(make_straggler_cfg(seed=s, **cfg_kw))
            .precompute(n_iterations) for s in seeds]
