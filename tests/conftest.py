import os
import sys

# tests see the real single CPU device (the 512-device override lives
# ONLY in repro.launch.dryrun); keep math in f32 for tight tolerances.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)


def make_quadratic_problem(n_workers: int = 4, dim: int = 3, seed: int = 0):
    """Tiny trilevel problem used across core tests."""
    import jax.numpy as jnp
    from repro.core.types import TrilevelProblem

    key = jax.random.PRNGKey(seed)
    data = {"A": jax.random.normal(key, (n_workers, dim, dim)) * 0.3,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_workers, dim))}

    def f1(d, x1, x2, x3):
        return jnp.sum((x1 - d["A"] @ x3 - d["b"]) ** 2)

    def f2(d, x1, x2, x3):
        return jnp.sum((x2 + x3) ** 2) + 0.1 * jnp.sum(x2 ** 2)

    def f3(d, x1, x2, x3):
        return jnp.sum((x3 - x1) ** 2) + 0.1 * jnp.sum((x3 - x2) ** 2)

    return TrilevelProblem(
        f1=f1, f2=f2, f3=f3, data=data, n_workers=n_workers,
        x1_init=jnp.zeros(dim), x2_init=jnp.zeros(dim),
        x3_init=jnp.zeros(dim))
