"""Device-resident stream conformance harness (the PR-5 contract).

Three rules define `repro.data.stream` (see its docstring): the base key
is never advanced, each worker's iteration key folds on its ABSOLUTE
consumption time (the pre-step `state.stale.t_hat` row — the master
iteration its current local point was handed out, == the global
iteration under full participation), worker keys fold on the GLOBAL
worker index.  Everything here follows from them and guards them:

  * chunking invariance — any chunk partition of a trajectory (batch
    sequence AND state-continued engine dispatches, refreshes included)
    is bit-identical to the unchunked run;
  * streamed parity — eager / scanned / sharded (1-, 2-, 4-worker fake
    meshes) / swept engines agree to f32 tolerance, and all match an
    independent host-fed reference loop that materializes each batch;
  * determinism — a fixed seed reproduces the batch stream across
    processes; re-seeding a stream never retraces the compiled
    trajectory.
"""
import dataclasses
import hashlib
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (make_hyper, make_quadratic_problem, make_schedules,
                      make_straggler_cfg)
from repro.core import StragglerScheduler, run, run_scanned, run_swept
from repro.core import afto as afto_lib
from repro.core import engine as engine_lib
from repro.data import stream as stream_lib

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # the [test] extra installs it;
    HAVE_HYPOTHESIS = False             # the deterministic variants of
                                        # every property still run

DIM = 3


def _sample(key):
    ka, kb = jax.random.split(key)
    return {"A": jax.random.normal(ka, (DIM, DIM)) * 0.3,
            "b": jax.random.normal(kb, (DIM,))}


def _stream(seed=0, n_workers=4):
    return stream_lib.make_stream(_sample, n_workers, seed)


def _schedule(n, **kw):
    return StragglerScheduler(make_straggler_cfg(**kw)).precompute(n)


def _assert_trees_close(t1, t2, rtol=1e-4, atol=1e-6):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# key discipline: fold-in determinism + worker-block locality
# ---------------------------------------------------------------------------

def test_next_batch_fold_in_determinism():
    s = _stream(seed=3)
    _assert_trees_equal(stream_lib.next_batch(s, 0),
                        stream_lib.next_batch(s, 0))
    # iterations draw distinct batches; the base key never advances
    b0 = stream_lib.next_batch(s, 0)
    b1 = stream_lib.next_batch(s, 1)
    assert not np.allclose(np.asarray(b0["A"]), np.asarray(b1["A"]))
    # worker rows are distinct draws
    assert not np.allclose(np.asarray(b0["A"][0]), np.asarray(b0["A"][1]))
    # a different seed is a different stream
    b0_other = stream_lib.next_batch(_stream(seed=4), 0)
    assert not np.allclose(np.asarray(b0["A"]), np.asarray(b0_other["A"]))


def test_worker_blocks_are_layout_independent():
    """A (worker_offset, n_local) block reproduces the same global rows
    the full batch has — the property the sharded engines rely on to
    draw shard-locally with no collectives."""
    s = _stream(seed=1)
    full = stream_lib.next_batch(s, 5)
    for off, n_loc in ((0, 1), (1, 2), (2, 2), (0, 4)):
        part = stream_lib.next_batch(s, 5, worker_offset=off,
                                     n_local=n_loc)
        _assert_trees_equal(part, jax.tree.map(
            lambda x: x[off:off + n_loc], full))


def test_batch_sequence_chunk_invariant():
    """Fold-in (not iterated) keys: regenerating any sub-range of the
    iteration axis reproduces the full sequence bitwise — there is no
    sequential key state a chunk boundary could disturb."""
    s = _stream(seed=2)
    seq = [stream_lib.next_batch(s, it) for it in range(8)]
    for a, b in ((0, 3), (3, 8), (2, 5)):
        for it in range(a, b):
            _assert_trees_equal(seq[it], stream_lib.next_batch(s, it))


def test_stream_validation():
    prob = make_quadratic_problem()
    sched = _schedule(4)
    with pytest.raises(ValueError):   # worker-count mismatch
        run_scanned(prob, make_hyper(), sched, data=_stream(n_workers=3))
    with pytest.raises(ValueError):   # spec-less stream
        run_scanned(prob, make_hyper(), sched,
                    data=stream_lib.Stream(key=jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# chunking invariance of whole trajectories (hypothesis)
# ---------------------------------------------------------------------------

def _assert_chunking_invariant(prob, hyper, sched, strm, bounds):
    """Chunked state-continued dispatches over `bounds` must reproduce
    the unchunked final state BITWISE, INCLUDING t_pre refreshes (both
    the batch fold-in and the refresh predicate run on the carried
    absolute `state.t`, not the per-dispatch iteration index)."""
    T = sched.n_iterations
    full = run_scanned(prob, hyper, sched, metrics_every=T, data=strm)
    state = None
    for a, b in zip(bounds[:-1], bounds[1:]):
        state = run_scanned(prob, hyper, sched.slice(a, b),
                            metrics_every=T, data=strm, state=state).state
    _assert_trees_equal(state, full.state)


@pytest.mark.parametrize("bounds", [
    [0, 7, 12, 20],       # boundaries misaligned with t_pre=3
    [0, 1, 20],           # single-iteration first chunk
    [0, 19, 20],          # single-iteration final chunk
    [0, 3, 6, 9, 20],     # boundaries ON the refresh stride
])
def test_chunked_trajectory_bit_identical(bounds):
    prob = make_quadratic_problem()
    _assert_chunking_invariant(prob, make_hyper(t_pre=3), _schedule(20),
                               _stream(), bounds)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=12)
    @given(data=st.data())
    def test_chunked_trajectory_bit_identical_property(data):
        """Hypothesis sweep of the same invariant over arbitrary
        partitions and trajectory lengths."""
        T = data.draw(st.integers(4, 20), label="n_iterations")
        bounds = [0] + sorted(data.draw(
            st.sets(st.integers(1, T - 1), max_size=3),
            label="cuts")) + [T]
        prob = make_quadratic_problem()
        _assert_chunking_invariant(prob, make_hyper(t_pre=3),
                                   _schedule(T), _stream(), bounds)


# ---------------------------------------------------------------------------
# streamed parity: eager vs scanned vs host-fed reference
# ---------------------------------------------------------------------------

def test_streamed_scan_matches_eager():
    prob = make_quadratic_problem()
    hyper, cfg = make_hyper(), make_straggler_cfg()
    sched = _schedule(30)
    strm = _stream()
    res_e = run(prob, hyper, scheduler_cfg=cfg, mode="eager",
                schedule=sched, metrics_every=10, data=strm)
    res_s = run(prob, hyper, scheduler_cfg=cfg, mode="scan",
                schedule=sched, metrics_every=10, data=strm)
    _assert_trees_close(res_e.state, res_s.state, rtol=1e-5)
    np.testing.assert_allclose(res_e.history["gap_sq"],
                               res_s.history["gap_sq"],
                               rtol=1e-4, atol=1e-6)
    assert list(res_e.history["n_cuts_ii"]) == \
        list(res_s.history["n_cuts_ii"])


def test_streamed_matches_host_fed_reference():
    """Independent host-fed reference: materialize every iteration's
    batch on the host (numpy round-trip) and drive jitted afto_step /
    cut_refresh with `problem.data` replaced per iteration — the
    pre-stream architecture.  Worker j's row folds at its CONSUMPTION
    time t_hat_j (tracked host-side here: the iteration j's current
    local point was handed out), matching the async runtime's
    fold-at-refresh-`t` contract.  The streamed scan must reproduce it
    to f32 tolerance."""
    prob = make_quadratic_problem()
    hyper = make_hyper(t_pre=5)
    T = 25
    n = hyper.n_workers
    sched = _schedule(T)
    strm = _stream()

    step = jax.jit(lambda s, m, d: afto_lib.afto_step(
        dataclasses.replace(prob, data=d), hyper, s, m))
    refresh = jax.jit(lambda s, d: afto_lib.cut_refresh(
        dataclasses.replace(prob, data=d), hyper, s))

    state = afto_lib.init_state(prob, hyper)
    t_hat = np.zeros(n, np.int32)           # pre-step consumption times
    for it in range(T):
        batch = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(x)),       # host round-trip
            stream_lib.next_batch(strm, t_hat))
        state = step(state, jnp.asarray(sched.active[it]), batch)
        t_hat = np.where(sched.active[it] > 0, it + 1, t_hat) \
            .astype(np.int32)
        if (it + 1) % hyper.t_pre == 0 and it < hyper.t1:
            state = refresh(state, batch)

    res = run_scanned(prob, hyper, sched, metrics_every=T, data=strm)
    _assert_trees_close(state, res.state, rtol=1e-5)


# ---------------------------------------------------------------------------
# worker-mesh parity (1-, 2-, 4-shard fake meshes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_streamed_sharded_matches_replicated(n_shards):
    if jax.device_count() < n_shards:
        pytest.skip(f"needs {n_shards} devices")
    from repro.launch.mesh import make_worker_mesh

    prob = make_quadratic_problem()
    hyper = make_hyper()
    sched = _schedule(20)
    strm = _stream()
    ref = run_scanned(prob, hyper, sched, metrics_every=5, data=strm)
    sh = run_scanned(prob, hyper, sched, metrics_every=5, data=strm,
                     mesh=make_worker_mesh(n_shards))
    _assert_trees_close(ref.state, sh.state)
    np.testing.assert_allclose(ref.history["gap_sq"],
                               sh.history["gap_sq"],
                               rtol=1e-3, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=8)
    @given(seed=st.integers(0, 2 ** 16), sched_seed=st.integers(0, 2 ** 8))
    def test_streamed_two_shard_parity_property(seed, sched_seed):
        """Hypothesis variant of the 2-worker-mesh parity: arbitrary
        stream seeds x arrival processes stay f32-close to the
        replicated engine."""
        if jax.device_count() < 2:
            pytest.skip("needs 2 devices")
        from repro.launch.mesh import make_worker_mesh

        prob = make_quadratic_problem()
        hyper = make_hyper()
        sched = _schedule(12, seed=sched_seed)
        strm = _stream(seed=seed)
        ref = run_scanned(prob, hyper, sched, metrics_every=4, data=strm)
        sh = run_scanned(prob, hyper, sched, metrics_every=4, data=strm,
                         mesh=make_worker_mesh(2))
        _assert_trees_close(ref.state, sh.state)


# ---------------------------------------------------------------------------
# swept engine
# ---------------------------------------------------------------------------

def test_streamed_sweep_rows_match_scanned():
    prob = make_quadratic_problem()
    hyper = make_hyper()
    scheds = make_schedules(15, (0, 1))
    strm = _stream()
    swept = run_swept(prob, hyper, scheds, metrics_every=5, data=strm)
    for r in range(2):
        single = run_scanned(prob, hyper, scheds[r], metrics_every=5,
                             data=strm)
        np.testing.assert_allclose(single.history["gap_sq"],
                                   swept.run(r).history["gap_sq"],
                                   rtol=2e-4, atol=1e-6)
        _assert_trees_close(single.state,
                            jax.tree.map(lambda x: x[r], swept.state),
                            rtol=2e-4)


def test_streamed_sharded_sweep_matches_replicated_sweep():
    """The streamed sharded-sweep engine (vmap inside shard_map, in-scan
    batches, shared key) reproduces the replicated streamed sweep."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices")
    from repro.launch.mesh import make_worker_mesh

    prob = make_quadratic_problem()
    hyper = make_hyper()
    scheds = make_schedules(12, (0, 1))
    strm = _stream()
    rep = run_swept(prob, hyper, scheds, metrics_every=4, data=strm)
    sh = run_swept(prob, hyper, scheds, metrics_every=4, data=strm,
                   mesh=make_worker_mesh(2))
    _assert_trees_close(rep.state, sh.state, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(rep.history["gap_sq"]),
                               np.asarray(sh.history["gap_sq"]),
                               rtol=1e-3, atol=1e-6)


# ---------------------------------------------------------------------------
# retrace + determinism
# ---------------------------------------------------------------------------

def test_streamed_reseed_does_not_retrace():
    prob = make_quadratic_problem()
    hyper = make_hyper()
    sched = _schedule(12)
    strm = _stream()
    run_scanned(prob, hyper, sched, metrics_every=6, data=strm)
    builds = engine_lib.BUILD_COUNTS["scan_streamed"]
    run_scanned(prob, hyper, sched, metrics_every=6,
                data=dataclasses.replace(strm, key=jax.random.PRNGKey(9)))
    assert engine_lib.BUILD_COUNTS["scan_streamed"] == builds


_DIGEST_SNIPPET = textwrap.dedent("""
    import hashlib

    import jax
    import numpy as np

    from repro.data import stream as stream_lib

    DIM = 3

    def _sample(key):
        ka, kb = jax.random.split(key)
        return {"A": jax.random.normal(ka, (DIM, DIM)) * 0.3,
                "b": jax.random.normal(kb, (DIM,))}

    def digest(seed=7, n_workers=4, iters=4):
        s = stream_lib.make_stream(_sample, n_workers, seed)
        h = hashlib.sha256()
        for it in range(iters):
            b = stream_lib.next_batch(s, it)
            h.update(np.asarray(b["A"], np.float32).tobytes())
            h.update(np.asarray(b["b"], np.float32).tobytes())
        return h.hexdigest()
""")


def test_cross_process_seed_determinism():
    """A fixed seed reproduces the exact batch bytes in a FRESH process
    (fold-in keys carry no process state — unlike e.g. salted string
    hashing, which silently broke dataset reproducibility once before;
    see data/synthetic.py)."""
    ns: dict = {}
    exec(compile(_DIGEST_SNIPPET, "<digest>", "exec"), ns)
    here = ns["digest"]()

    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run(
        [sys.executable, "-c", _DIGEST_SNIPPET + "\nprint(digest())"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip() == here


# ---------------------------------------------------------------------------
# LLM token streams
# ---------------------------------------------------------------------------

def test_zipf_tokens_device_side():
    toks = stream_lib.zipf_tokens(jax.random.PRNGKey(0), (64, 128), 1000)
    toks = np.asarray(toks)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 1000
    # zipf: token 0 is the most frequent id
    vals, counts = np.unique(toks, return_counts=True)
    assert vals[np.argmax(counts)] == 0
    # a <= 1 has no normalizable rank tail (a == 1 would divide by zero,
    # a < 1 degenerates to all-zero ids) — rejected at entry
    for bad_a in (1.0, 0.9):
        with pytest.raises(ValueError, match="zipf_a"):
            stream_lib.zipf_tokens(jax.random.PRNGKey(0), (2, 4), 16,
                                   zipf_a=bad_a)


def test_llm_batch_stream_layout():
    from repro.configs import get_config, reduced
    from repro.fed.trilevel_llm import batch_stream

    cfg = reduced(get_config("xlstm-125m"))
    s = batch_stream(cfg, n_workers=2, b_local=1, seq=16, seed=0)
    b = stream_lib.next_batch(s, 0)
    assert b["tokens"].shape == (2, 1, 16)
    assert b["tokens"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b["val_tokens"]))
    assert np.asarray(b["tokens"]).max() < cfg.vocab_size
    # shard-local block == the same global rows (mesh contract)
    part = stream_lib.next_batch(s, 0, worker_offset=1, n_local=1)
    np.testing.assert_array_equal(np.asarray(part["tokens"]),
                                  np.asarray(b["tokens"][1:2]))
