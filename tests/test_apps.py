"""The paper's two applications + baselines (short CPU runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.baselines import run_adbo, run_fednest
from repro.apps.robust_hpo import default_hyper, make_robust_hpo_problem
from repro.core import StragglerConfig, run


@pytest.fixture(scope="module")
def task():
    return make_robust_hpo_problem("diabetes", n_workers=4, seed=0)


def test_robust_hpo_afto_learns(task):
    hyper = default_hyper(task, 4, 3, 10)
    cfg = StragglerConfig(n_workers=4, s_active=3, tau=10,
                          n_stragglers=1, seed=0)

    def metrics(state):
        from repro.models.simple import mlp_apply
        def per(d_j, x3_j):
            pred = mlp_apply(x3_j, d_j["xval"])[:, 0]
            return jnp.mean((pred - d_j["yval"]) ** 2)
        return {"val_mse": jnp.mean(
            jax.vmap(per)(task.problem.data, state.X3))}

    res = run(task.problem, hyper, scheduler_cfg=cfg, n_iterations=60,
              metrics_fn=metrics, metrics_every=20)
    mses = res.history["val_mse"]
    assert mses[-1] < mses[0] * 0.7
    assert res.history["gap_sq"][-1] < res.history["gap_sq"][0]


def test_fednest_baseline_runs(task):
    out = run_fednest(task, n_iterations=30)
    assert np.isfinite(out["history"]["val_mse"][-1])
    assert out["history"]["val_mse"][-1] < out["history"]["val_mse"][0] * 2


def test_adbo_baseline_runs(task):
    out = run_adbo(task, n_iterations=30)
    assert np.isfinite(out["history"]["val_mse"][-1])


def test_domain_adaptation_short():
    from repro.apps.domain_adaptation import (default_hyper as dh,
                                              make_domain_adaptation_problem)
    t = make_domain_adaptation_problem(2, n_pretrain_per=8,
                                       n_finetune_per=8, seed=0)
    hyper = dh(2, 2, 5, t_pre=50, k_inner=1, p_max=2)
    res = run(t.problem, hyper, n_iterations=8, metrics_every=4,
              metrics_fn=lambda s: t.test_metrics(
                  jax.tree.map(lambda x: jnp.mean(x, 0), s.X2)))
    assert np.isfinite(res.history["test_loss"][-1])
