"""Compiled trajectory engine: schedule precompute, scan-vs-eager, and
the batched sweep (swept-vs-looped equivalence + retrace caching)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (make_hyper, make_quadratic_problem, make_schedules,
                      make_straggler_cfg)
from repro.core import StragglerScheduler, run, run_scanned, run_swept
from repro.core import engine as engine_lib
from repro.core.engine import SweepResult, record_slots

# shared small-problem builders live in conftest (one definition for
# test_engine / test_system / test_sharded_engine)
_hyper = make_hyper
_cfg = make_straggler_cfg


# ---------------------------------------------------------------------------
# schedule precompute (regression: bit-identical to stepping)
# ---------------------------------------------------------------------------

def test_precompute_bit_identical_to_stepping():
    sched = StragglerScheduler(_cfg())
    stepped = StragglerScheduler(_cfg())
    schedule = sched.precompute(64)
    assert schedule.n_iterations == 64
    assert schedule.n_workers == 4
    for i in range(64):
        mask, t_done = stepped.next_active()
        assert np.array_equal(schedule.active[i], mask), i
        assert schedule.sim_time[i] == t_done, i
        assert schedule.max_staleness[i] == stepped.max_staleness(), i


def test_precompute_leaves_scheduler_untouched():
    sched = StragglerScheduler(_cfg(seed=7))
    sched.precompute(32)
    fresh = StragglerScheduler(_cfg(seed=7))
    for _ in range(5):
        m1, t1 = sched.next_active()
        m2, t2 = fresh.next_active()
        assert np.array_equal(m1, m2) and t1 == t2


def test_precompute_mid_stream():
    """Precompute after stepping continues the same process."""
    sched = StragglerScheduler(_cfg(seed=3))
    ref = StragglerScheduler(_cfg(seed=3))
    for _ in range(10):
        sched.next_active()
        ref.next_active()
    schedule = sched.precompute(16)
    for i in range(16):
        mask, t_done = ref.next_active()
        assert np.array_equal(schedule.active[i], mask)
        assert schedule.sim_time[i] == t_done


def test_precompute_respects_tau():
    schedule = StragglerScheduler(
        _cfg(s_active=2, tau=4, n_stragglers=2,
             straggler_slowdown=20.0, seed=3)).precompute(60)
    assert schedule.max_staleness.max() <= 4


# ---------------------------------------------------------------------------
# record layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_iterations,metrics_every", [
    (40, 10), (41, 10), (7, 10), (1, 1), (10, 3)])
def test_record_slots_matches_eager_layout(n_iterations, metrics_every):
    record_its, slots = record_slots(n_iterations, metrics_every)
    expect = [it for it in range(n_iterations)
              if (it + 1) % metrics_every == 0 or it == n_iterations - 1]
    assert record_its.tolist() == expect
    for it in range(n_iterations):
        if it in expect:
            assert slots[it] == expect.index(it)
        else:
            assert slots[it] == -1


# ---------------------------------------------------------------------------
# scan-vs-eager equivalence
# ---------------------------------------------------------------------------

def test_scan_matches_eager_trajectory():
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg()
    schedule = StragglerScheduler(cfg).precompute(40)

    res_e = run(prob, hyper, scheduler_cfg=cfg, n_iterations=40,
                metrics_every=10, mode="eager", schedule=schedule)
    res_s = run(prob, hyper, scheduler_cfg=cfg, n_iterations=40,
                metrics_every=10, mode="scan", schedule=schedule)

    for a, b in zip(jax.tree.leaves(res_e.state),
                    jax.tree.leaves(res_s.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    h_e, h_s = res_e.history, res_s.history
    assert list(h_e["t"]) == list(h_s["t"])
    np.testing.assert_allclose(h_e["sim_time"], h_s["sim_time"])
    np.testing.assert_allclose(h_e["max_staleness"], h_s["max_staleness"])
    np.testing.assert_allclose(h_e["gap_sq"], h_s["gap_sq"],
                               rtol=1e-4, atol=1e-6)
    assert list(h_e["n_cuts_i"]) == list(h_s["n_cuts_i"])
    assert list(h_e["n_cuts_ii"]) == list(h_s["n_cuts_ii"])


def test_scan_matches_eager_with_metrics_fn():
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg(seed=1)
    schedule = StragglerScheduler(cfg).precompute(25)

    def metrics(state):
        return {"z1_norm_sq": jnp.sum(state.z1 ** 2)}

    res_e = run(prob, hyper, scheduler_cfg=cfg, n_iterations=25,
                metrics_every=10, metrics_fn=metrics, mode="eager",
                schedule=schedule)
    res_s = run(prob, hyper, scheduler_cfg=cfg, n_iterations=25,
                metrics_every=10, metrics_fn=metrics, mode="scan",
                schedule=schedule)
    # 25 iters at stride 10 -> records at 10, 20, 25 (the final iter)
    assert len(res_s.history["z1_norm_sq"]) == 3
    np.testing.assert_allclose(res_e.history["z1_norm_sq"],
                               res_s.history["z1_norm_sq"],
                               rtol=1e-5, atol=1e-7)


def test_scan_fresh_schedule_matches_eager_fresh_scheduler():
    """No explicit schedule: both modes materialize the same seeded
    process from scheduler_cfg, so trajectories still agree."""
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg()
    res_e = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
                metrics_every=5, mode="eager")
    res_s = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
                metrics_every=5, mode="scan")
    np.testing.assert_allclose(res_e.history["gap_sq"],
                               res_s.history["gap_sq"],
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(res_e.history["sim_time"],
                               res_s.history["sim_time"])


def test_run_scanned_caller_state_not_donated():
    from repro.core import afto as afto_lib
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg()
    schedule = StragglerScheduler(cfg).precompute(10)
    state = afto_lib.init_state(prob, hyper)
    res = run_scanned(prob, hyper, schedule, metrics_every=5, state=state)
    # the caller's buffers must remain readable after the run
    assert np.all(np.isfinite(np.asarray(state.z1)))
    assert np.all(np.isfinite(res.history["gap_sq"]))


def test_run_rejects_unknown_mode():
    prob = make_quadratic_problem()
    with pytest.raises(ValueError):
        run(prob, _hyper(), n_iterations=2, mode="wat")


def test_no_reflatten_on_scanned_path(monkeypatch):
    """Acceptance guard: `flat_spec`/`flatten_cuts` never execute while
    tracing afto_step_aux / cut_refresh / stationarity_gap_sq — the
    canonical `FlatCuts` matrix is consumed as stored, and flattening
    happens only at cut construction (`flatten_coeffs`) and at the
    `to_tree`/`from_tree` compatibility boundary."""
    from repro.core import afto as afto_lib
    from repro.core import cuts as cuts_lib
    from repro.core import stationarity as stat_lib

    calls = []
    orig_spec, orig_flat = cuts_lib.flat_spec, cuts_lib.flatten_cuts
    monkeypatch.setattr(
        cuts_lib, "flat_spec",
        lambda *a, **k: (calls.append("flat_spec"), orig_spec(*a, **k))[1])
    monkeypatch.setattr(
        cuts_lib, "flatten_cuts",
        lambda *a, **k: (calls.append("flatten_cuts"),
                         orig_flat(*a, **k))[1])

    prob = make_quadratic_problem()
    hyper = _hyper()
    state = afto_lib.init_state(prob, hyper)
    jax.eval_shape(
        lambda s: afto_lib.afto_step_aux(prob, hyper, s, jnp.ones(4)),
        state)
    jax.eval_shape(lambda s: afto_lib.cut_refresh(prob, hyper, s), state)
    jax.eval_shape(
        lambda s: stat_lib.stationarity_gap_sq(prob, hyper, s), state)
    assert calls == []


def test_scan_cache_hit_does_not_retrace():
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg()
    schedule = StragglerScheduler(cfg).precompute(12)
    run_scanned(prob, hyper, schedule, metrics_every=6)
    builds = engine_lib.BUILD_COUNTS["scan"]
    run_scanned(prob, hyper, schedule, metrics_every=6)
    assert engine_lib.BUILD_COUNTS["scan"] == builds


# ---------------------------------------------------------------------------
# batched sweep: swept rows must reproduce individual scanned runs
# ---------------------------------------------------------------------------

_schedules = make_schedules


def test_swept_matches_looped_scanned():
    """Row r of run_swept reproduces run_scanned on schedule r.

    Tolerance, not bit-equality: the vmapped body batches every
    contraction over the run axis, which reorders f32 accumulations
    relative to the single-run scan (e.g. batched matvec vs matvec);
    observed drift at 40 quickstart-scale iterations is < 1e-6 relative.
    """
    prob = make_quadratic_problem()
    hyper = _hyper()
    scheds = _schedules(40, (0, 1, 2))

    def metrics(state):
        return {"z1_norm_sq": jnp.sum(state.z1 ** 2)}

    swept = run_swept(prob, hyper, scheds, metrics_fn=metrics,
                      metrics_every=10)
    assert swept.n_runs == 3
    for r in range(3):
        single = run_scanned(prob, hyper, scheds[r], metrics_fn=metrics,
                             metrics_every=10)
        row = swept.run(r)
        for a, b in zip(jax.tree.leaves(single.state),
                        jax.tree.leaves(row.state)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(single.history["gap_sq"],
                                   row.history["gap_sq"],
                                   rtol=2e-4, atol=1e-6)
        np.testing.assert_allclose(single.history["z1_norm_sq"],
                                   row.history["z1_norm_sq"],
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(single.history["sim_time"],
                                   row.history["sim_time"])
        np.testing.assert_allclose(single.history["max_staleness"],
                                   row.history["max_staleness"])
        assert list(single.history["t"]) == list(row.history["t"])
        assert list(single.history["n_cuts_ii"]) == \
            list(row.history["n_cuts_ii"])


def test_swept_cache_hit_does_not_retrace():
    prob = make_quadratic_problem()
    hyper = _hyper()
    scheds = _schedules(16, (0, 1))
    run_swept(prob, hyper, scheds, metrics_every=8)
    builds = engine_lib.BUILD_COUNTS["sweep"]
    # identical sweep: cached compiled trajectory, no new trace
    run_swept(prob, hyper, scheds, metrics_every=8)
    assert engine_lib.BUILD_COUNTS["sweep"] == builds
    # fresh schedules with the same shape also reuse the trace
    run_swept(prob, hyper, _schedules(16, (5, 6)), metrics_every=8)
    assert engine_lib.BUILD_COUNTS["sweep"] == builds


def test_swept_hyper_sweep_matches_scanned():
    prob = make_quadratic_problem()
    hyper = _hyper()
    scheds = _schedules(25, (0, 0))       # same arrival process
    swept = run_swept(prob, hyper, scheds, metrics_every=10,
                      sweep_hypers={"eta_z": [0.05, 0.01]})
    for r, eta_z in enumerate((0.05, 0.01)):
        single = run_scanned(prob, dataclasses.replace(hyper, eta_z=eta_z),
                             scheds[r], metrics_every=10)
        np.testing.assert_allclose(single.history["gap_sq"],
                                   swept.run(r).history["gap_sq"],
                                   rtol=2e-4, atol=1e-6)


def test_swept_rejects_bad_inputs():
    prob = make_quadratic_problem()
    hyper = _hyper()
    with pytest.raises(ValueError):
        run_swept(prob, hyper, [])
    scheds = _schedules(10, (0, 1))
    with pytest.raises(ValueError):                 # length mismatch
        run_swept(prob, hyper, [scheds[0], _schedules(12, (1,))[0]])
    with pytest.raises(ValueError):                 # unknown hyper field
        run_swept(prob, hyper, scheds, sweep_hypers={"nope": [1, 2]})
    with pytest.raises(ValueError):                 # shape-determining
        run_swept(prob, hyper, scheds, sweep_hypers={"p_max": [4, 8]})
    with pytest.raises(ValueError):                 # wrong sweep length
        run_swept(prob, hyper, scheds, sweep_hypers={"eta_z": [0.1]})


def test_run_mode_sweep_dispatch_and_host_time():
    """runner.run(mode='sweep') seeds R schedules and the history carries
    per-run rows with the elapsed/R host_time proration."""
    prob = make_quadratic_problem()
    hyper, cfg = _hyper(), _cfg()
    res = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
              metrics_every=5, mode="sweep", seeds=(0, 1))
    assert isinstance(res, SweepResult)
    assert res.history["gap_sq"].shape == (2, 4)
    assert res.history["host_time"].shape == (2, 4)
    # equal 1/R share, prorated over iterations: rows identical and
    # increasing, final entry = elapsed / R
    np.testing.assert_allclose(res.history["host_time"][0],
                               res.history["host_time"][1])
    assert np.all(np.diff(res.history["host_time"][0]) > 0)
    # seed 0's row matches a plain scan run over the same process
    single = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
                 metrics_every=5, mode="scan")
    np.testing.assert_allclose(single.history["gap_sq"],
                               res.run(0).history["gap_sq"],
                               rtol=2e-4, atol=1e-6)
    with pytest.raises(ValueError):
        run(prob, hyper, n_iterations=4, mode="sweep", jit=False)


def test_swept_respects_caller_states_and_data():
    """Stacked per-run initial states and per-run data: each row must
    match a run_scanned with that run's state/data, and the caller's
    buffers must survive the donated dispatch."""
    from repro.core import afto as afto_lib
    from repro.utils.tree import tree_stack

    hyper = _hyper()
    probs = [make_quadratic_problem(seed=s) for s in (0, 3)]
    scheds = _schedules(15, (0, 1))
    states = tree_stack([afto_lib.init_state(p, hyper) for p in probs])
    data = tree_stack([p.data for p in probs])
    swept = run_swept(probs[0], hyper, scheds, states=states, data=data,
                      metrics_every=5)
    for r in range(2):
        single = run_scanned(probs[r], hyper, scheds[r], metrics_every=5,
                             state=afto_lib.init_state(probs[r], hyper))
        np.testing.assert_allclose(single.history["gap_sq"],
                                   swept.run(r).history["gap_sq"],
                                   rtol=2e-4, atol=1e-6)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(states))
