"""Smoke tests for the CLI drivers (train/serve) as subprocesses, plus
in-process coverage of `run_afto_scan`'s chunk-boundary logic (logging /
checkpoint crossings, final partial chunk) and the `--stream`
device-resident path."""
import argparse
import os
import subprocess
import sys

import numpy as np
import pytest

ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=900):
    return subprocess.run([sys.executable, "-m"] + args, env=ENV,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))


def _train_args(**overrides):
    """The afto driver namespace mirroring `train.main`'s defaults."""
    base = dict(arch="xlstm-125m", reduced=True, mode="afto",
                engine="scan", cut_mode="sketch", sketch_r=32, steps=9,
                workers=2, batch=1, seq=17, lr=3e-3, tau=4, t_pre=4,
                t1=10_000, log_every=2, scan_chunk=6, mesh_workers=None,
                ckpt_dir=None, ckpt_every=5, seed=0, stream=False)
    base.update(overrides)
    return argparse.Namespace(**base)


def _tiny_cfg():
    """A 2-layer d_model=32 xlstm family member: real lowering, CPU-cheap
    (the full reduced configs stay covered by the subprocess smokes)."""
    from repro.models.config import BlockSpec, ModelConfig, Stage

    m = BlockSpec(mixer="mlstm", mlp="none")
    s = BlockSpec(mixer="slstm", mlp="none")
    return ModelConfig(name="xlstm-tiny", arch_type="ssm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                       d_ff=0, vocab_size=128,
                       stages=(Stage((m, s), 1),)).validate()


def _run_afto_scan(cfg, args):
    from repro.launch import train

    hyper, state, sched, val_loss = train._afto_setup(cfg, args)
    return train.run_afto_scan(cfg, args, hyper, state, sched, val_loss)


def _ckpt_steps(ckpt_dir):
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir))


@pytest.fixture()
def stub_afto_step(monkeypatch):
    """Identity AFTO step/refresh + constant val loss: the chunk loop's
    boundary logic (what run_afto_scan owns) exercised without paying a
    model compile per parametrization."""
    import jax.numpy as jnp

    from repro.launch import train

    monkeypatch.setattr(train, "afto_llm_step",
                        lambda cfg, hyper, st, batch, mask: st)
    monkeypatch.setattr(train, "cut_refresh_llm",
                        lambda cfg, hyper, st, batch: st)

    def run(cfg, args):
        hyper, state, sched, _ = train._afto_setup(cfg, args)
        return train.run_afto_scan(cfg, args, hyper, state, sched,
                                   lambda w, tk: jnp.float32(0.125))
    return run


# ---------------------------------------------------------------------------
# chunk-boundary logic (in-process; previously untested)
# ---------------------------------------------------------------------------

def test_chunk_larger_than_log_every_logs_once_per_crossing(
        stub_afto_step, tmp_path):
    """chunk=6 > log_every=2: each chunk crosses several log boundaries
    but logs ONCE (at the chunk end); the final PARTIAL chunk [6, 9)
    logs because stop == steps; ckpt_every=5 is crossed only inside the
    first chunk, so exactly one checkpoint is written, at step 6."""
    out = stub_afto_step(_tiny_cfg(), _train_args(
        steps=9, scan_chunk=6, log_every=2,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=5))
    assert [h["step"] for h in out["history"]] == [6, 9]
    assert all(np.isfinite(h["loss"]) for h in out["history"])
    assert _ckpt_steps(tmp_path / "ck") == [6]


def test_final_partial_chunk_always_logs(stub_afto_step):
    """log_every=10 is never crossed in 9 steps, but the final partial
    chunk still logs (stop == steps) so a run is never silent."""
    out = stub_afto_step(_tiny_cfg(),
                         _train_args(steps=9, scan_chunk=6, log_every=10))
    assert [h["step"] for h in out["history"]] == [9]


def test_default_chunk_keeps_log_cadence(stub_afto_step):
    """scan_chunk=None defaults to log_every: one log per chunk plus the
    final iteration — the pre-flag behavior."""
    out = stub_afto_step(_tiny_cfg(), _train_args(steps=7, scan_chunk=None,
                                                  log_every=3))
    assert [h["step"] for h in out["history"]] == [3, 6, 7]


def test_streamed_chunk_boundaries_match_host_path(stub_afto_step,
                                                   tmp_path):
    """--stream shares the host path's boundary behavior: one log per
    crossed-or-final chunk, checkpoints at crossed ckpt boundaries."""
    out = stub_afto_step(_tiny_cfg(), _train_args(
        steps=9, scan_chunk=6, log_every=2, stream=True,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=5))
    assert [h["step"] for h in out["history"]] == [6, 9]
    assert _ckpt_steps(tmp_path / "ck") == [6]


# ---------------------------------------------------------------------------
# --stream: device-resident token scan (real tiny model)
# ---------------------------------------------------------------------------

def test_streamed_scan_no_host_tokens(monkeypatch, tmp_path):
    """--stream must never synthesize tokens on the host (_chunk_tokens /
    make_token_stream are poisoned), equal-size warm chunks must reuse
    ONE compiled trace (the donated state/key/cursor chain would break
    on a retrace), and losses must come out finite."""
    from repro.launch import train

    def _boom(*a, **k):
        raise AssertionError("host token synthesis on the streamed path")

    # patch train's OWN bindings (it calls the imported names, not the
    # synthetic module attribute)
    monkeypatch.setattr(train, "_chunk_tokens", _boom)
    monkeypatch.setattr(train, "make_token_stream", _boom)
    before = dict(train.SCAN_TRACES)
    out = _run_afto_scan(_tiny_cfg(), _train_args(
        steps=8, scan_chunk=4, log_every=4, seq=17, stream=True,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=4))
    assert [h["step"] for h in out["history"]] == [4, 8]
    assert all(np.isfinite(h["loss"]) for h in out["history"])
    # two equal-size chunks -> one trace; the host runner stayed cold
    assert train.SCAN_TRACES["stream"] == before["stream"] + 1
    assert train.SCAN_TRACES["host"] == before["host"]
    assert _ckpt_steps(tmp_path / "ck") == [4, 8]


def test_stream_requires_scan_engine():
    from repro.launch import train

    args = _train_args(engine="eager", stream=True)
    with pytest.raises(ValueError, match="--engine scan"):
        train.run_afto(_tiny_cfg(), args)


def test_train_afto_driver(tmp_path):
    out = _run(["repro.launch.train", "--arch", "xlstm-125m", "--reduced",
                "--mode", "afto", "--steps", "8", "--workers", "2",
                "--batch", "1", "--seq", "33", "--t-pre", "4",
                "--log-every", "4",
                "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"loss"' in out.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ck"))


def test_train_plain_driver():
    out = _run(["repro.launch.train", "--arch", "llama3-8b", "--reduced",
                "--mode", "plain", "--steps", "6", "--workers", "2",
                "--batch", "1", "--seq", "33", "--log-every", "3"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"loss"' in out.stdout


def test_serve_driver():
    out = _run(["repro.launch.serve", "--arch", "llama3-8b", "--reduced",
                "--batch", "2", "--prompt-len", "16", "--gen", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout


# ---------------------------------------------------------------------------
# resume-after-restart (checkpointed streamed chunk loop)
# ---------------------------------------------------------------------------

@pytest.fixture()
def mixing_afto_step(monkeypatch):
    """A cheap step that folds the BATCH into the state: resume is only
    bitwise-exact if (state, key, cursor) all restore correctly — an
    identity stub would pass even with a broken stream cursor."""
    import jax
    import jax.numpy as jnp

    from repro.launch import train

    def step(cfg, hyper, st, batch, mask):
        s = jnp.float32(0.0)
        for leaf in jax.tree.leaves(batch):
            s = s + jnp.sum(jnp.asarray(leaf).astype(jnp.float32))
        bump = (s % 977.0) * 1e-4
        return jax.tree.map(
            lambda x: x + bump.astype(x.dtype)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
            st)

    monkeypatch.setattr(train, "afto_llm_step", step)
    monkeypatch.setattr(train, "cut_refresh_llm",
                        lambda cfg, hyper, st, batch: st)

    def run(cfg, args):
        from repro.launch import train as train_lib
        hyper, state, sched, _ = train_lib._afto_setup(cfg, args)
        import jax.numpy as jnp2
        return train_lib.run_afto_scan(cfg, args, hyper, state, sched,
                                       lambda w, tk: jnp2.float32(0.125))
    return run


def test_resume_after_restart_is_bitwise_identical(mixing_afto_step,
                                                   tmp_path):
    """Kill-and-restore: a run resumed from the step-4 checkpoint must
    land on a bitwise-identical step-8 checkpoint (the streamed carry
    (state, key, cursor) is the WHOLE resume surface)."""
    import shutil

    full_dir, res_dir = tmp_path / "full", tmp_path / "resume"
    mixing_afto_step(_tiny_cfg(), _train_args(
        steps=8, scan_chunk=4, log_every=4, stream=True,
        ckpt_dir=str(full_dir), ckpt_every=4))
    assert _ckpt_steps(full_dir) == [4, 8]

    # simulate the restart: only the step-4 checkpoint survives
    res_dir.mkdir()
    shutil.copytree(full_dir / "step_00000004", res_dir / "step_00000004")
    mixing_afto_step(_tiny_cfg(), _train_args(
        steps=8, scan_chunk=4, log_every=4, stream=True,
        ckpt_dir=str(res_dir), ckpt_every=4, resume=True))
    assert _ckpt_steps(res_dir) == [4, 8]

    a = np.load(full_dir / "step_00000008" / "arrays.npz")
    b = np.load(res_dir / "step_00000008" / "arrays.npz")
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        assert np.array_equal(a[k], b[k]), k
