"""Smoke tests for the CLI drivers (train/serve) as subprocesses."""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=900):
    return subprocess.run([sys.executable, "-m"] + args, env=ENV,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))


def test_train_afto_driver(tmp_path):
    out = _run(["repro.launch.train", "--arch", "xlstm-125m", "--reduced",
                "--mode", "afto", "--steps", "8", "--workers", "2",
                "--batch", "1", "--seq", "33", "--t-pre", "4",
                "--log-every", "4",
                "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"loss"' in out.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ck"))


def test_train_plain_driver():
    out = _run(["repro.launch.train", "--arch", "llama3-8b", "--reduced",
                "--mode", "plain", "--steps", "6", "--workers", "2",
                "--batch", "1", "--seq", "33", "--log-every", "3"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"loss"' in out.stdout


def test_serve_driver():
    out = _run(["repro.launch.serve", "--arch", "llama3-8b", "--reduced",
                "--batch", "2", "--prompt-len", "16", "--gen", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
