"""Array-dict checkpoints: the async master's durable-state substrate.

Satellite contract (ISSUE 7): `checkpoint/io.py` round-trips the
master's FULL runtime carry — canonical state, recorder history, pending
push map, membership bookkeeping — and a corrupted or truncated
checkpoint raises `CheckpointError` instead of resuming from garbage.
"""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.io import (CheckpointError, latest_step,
                                 load_array_dict, save_array_dict,
                                 save_checkpoint)

from conftest import make_hyper, make_quadratic_problem


# ---------------------------------------------------------------------------
# array-dict round trip
# ---------------------------------------------------------------------------

def _sample():
    return {
        "f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "i64": np.array([1, -2, 3], np.int64),
        "bools": np.array([True, False, True]),
        "scalar": np.asarray(7, np.int64),
        "empty_hist": np.zeros((0, 4), np.float32),
    }


def test_array_dict_round_trip(tmp_path):
    d = os.fspath(tmp_path / "ck")
    path = save_array_dict(d, _sample(), step=3)
    assert path.endswith("step_00000003")
    out = load_array_dict(d)
    assert sorted(out) == sorted(_sample())
    for k, v in _sample().items():
        np.testing.assert_array_equal(out[k], v)
        assert out[k].dtype == v.dtype


def test_array_dict_steps_and_retention(tmp_path):
    d = os.fspath(tmp_path / "ck")
    for step in (1, 2, 3, 4, 5):
        save_array_dict(d, {"x": np.full(2, step)}, step=step, keep=3)
    assert latest_step(d) == 5
    assert sorted(os.listdir(d)) == [f"step_0000000{s}" for s in (3, 4, 5)]
    np.testing.assert_array_equal(load_array_dict(d, step=4)["x"],
                                  [4, 4])
    np.testing.assert_array_equal(load_array_dict(d)["x"], [5, 5])


def test_array_dict_missing_raises(tmp_path):
    with pytest.raises(CheckpointError, match="no checkpoints"):
        load_array_dict(os.fspath(tmp_path / "nope"))


def test_array_dict_corruption_detected(tmp_path):
    d = os.fspath(tmp_path / "ck")
    save_array_dict(d, _sample(), step=1)
    npz = os.path.join(d, "step_00000001", "arrays.npz")
    blob = bytearray(open(npz, "rb").read())
    blob[len(blob) // 2] ^= 0xFF            # flip one byte mid-payload
    with open(npz, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointError, match="checksum"):
        load_array_dict(d)


def test_array_dict_truncation_detected(tmp_path):
    d = os.fspath(tmp_path / "ck")
    save_array_dict(d, _sample(), step=1)
    npz = os.path.join(d, "step_00000001", "arrays.npz")
    blob = open(npz, "rb").read()
    with open(npz, "wb") as f:
        f.write(blob[: len(blob) // 2])     # torn write
    with pytest.raises(CheckpointError, match="checksum"):
        load_array_dict(d)


def test_array_dict_unreadable_manifest_raises(tmp_path):
    d = os.fspath(tmp_path / "ck")
    save_array_dict(d, _sample(), step=1)
    man = os.path.join(d, "step_00000001", "manifest.json")
    with open(man, "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError, match="manifest"):
        load_array_dict(d)


def test_array_dict_rejects_template_checkpoints(tmp_path):
    """The two checkpoint families must not be confused: loading a
    template-shaped checkpoint through the array-dict path fails with a
    pointed error, not garbage keys."""
    d = os.fspath(tmp_path / "ck")
    save_checkpoint(d, {"w": np.zeros(3)}, step=1)
    with pytest.raises(CheckpointError, match="load_checkpoint"):
        load_array_dict(d)


# ---------------------------------------------------------------------------
# the master's full runtime carry round-trips
# ---------------------------------------------------------------------------

def _master(ckpt_dir):
    from repro.fed.runtime.master import Master
    from repro.fed.runtime.transport import InProcTransport

    prob = make_quadratic_problem()
    hyper = make_hyper()
    hub = InProcTransport(hyper.n_workers)
    return Master(prob, hyper, hub.master_endpoint(), n_iterations=10,
                  ckpt_dir=ckpt_dir)


def test_master_runtime_carry_round_trip(tmp_path):
    d = os.fspath(tmp_path / "master_ck")
    m = _master(d)
    # fabricate a mid-run carry: arrival history, a death, a pending
    # push, refresh bookkeeping and metrics history
    m.recorder.record(np.array([1, 0, 1, 1], np.float32), 0.5)
    m.recorder.mark_dead(1)
    m.recorder.record(np.array([0, 0, 1, 0], np.float32), 0.9)
    row = lambda s, j: jax.tree.map(lambda x: np.asarray(x[j]) + 1.0, s)
    m.pending[2] = (4, (row(m.state.X1, 2), row(m.state.X2, 2),
                        row(m.state.X3, 2)))
    m.last_refresh_t[:] = [3, 0, 2, 2]
    m.hist["t"].append(2.0)
    m.hist["gap_sq"].append(0.125)
    m.members.hello(2, epoch=1)
    m.members.consumed(2, 3)
    m.save(step=2)

    m2 = _master(d)
    assert m2.restore() == 2
    assert m2.start_it == 2 and m2.status["resumed_from"] == 2
    # canonical state: bitwise
    for a, b in zip(jax.tree.leaves(m.state), jax.tree.leaves(m2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # recorder: full history + liveness clocks
    for k, v in m.recorder.state_dict().items():
        np.testing.assert_array_equal(m2.recorder.state_dict()[k], v)
    # pending push map: same workers, same seqs, same gradient rows
    assert sorted(m2.pending) == sorted(m.pending)
    seq, grads = m2.pending[2]
    assert seq == 4
    for a, b in zip(jax.tree.leaves(grads),
                    jax.tree.leaves(m.pending[2][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(m2.last_refresh_t, m.last_refresh_t)
    assert m2.hist["t"] == [2.0] and m2.hist["gap_sq"] == [0.125]
    # connection-scoped bookkeeping resets: fresh worker population
    assert m2.members.epoch.sum() == 0
    assert m2.members.consumed_seq.sum() == 0
    assert m2.members.alive.all()


def test_master_grown_carry_round_trip(tmp_path):
    """ISSUE 10: a checkpoint written AFTER an elastic growth records
    the grown width; restoring it into a master launched at the
    ORIGINAL width (with elastic headroom) grows first, then restores
    the leaves bitwise.  Without headroom the widened checkpoint is
    refused, and a narrow checkpoint never shrinks a wider master."""
    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime.master import Master
    from repro.fed.runtime.transport import InProcTransport

    d = os.fspath(tmp_path / "master_ck")
    elastic = problems_lib.elastic_config("quadratic", 5)

    def fresh(ckpt_dir, n_workers=3, elastic_cfg=elastic):
        prob, hyper = problems_lib.build("quadratic", n_workers=n_workers)
        hub = InProcTransport(n_workers)
        return Master(prob, hyper, hub.master_endpoint(),
                      n_iterations=10, ckpt_dir=ckpt_dir,
                      elastic=elastic_cfg)

    m = fresh(d)
    m._grow_to(5)
    m.recorder.record(np.array([1, 0, 1, 1, 1], np.float32), 0.5)
    m.save(step=4)

    m2 = fresh(d)                       # launched at width 3
    assert m2.restore() == 4
    assert m2.hyper.n_workers == 5      # grew before restoring leaves
    for a, b in zip(jax.tree.leaves(m.state), jax.tree.leaves(m2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k, v in m.recorder.state_dict().items():
        np.testing.assert_array_equal(m2.recorder.state_dict()[k], v)
    assert m2.members.n == 5

    # no elastic headroom: the widened checkpoint must be refused
    with pytest.raises(CheckpointError, match="elastic"):
        fresh(d, elastic_cfg=None).restore()

    # membership only grows: a narrow checkpoint never shrinks a master
    d2 = os.fspath(tmp_path / "narrow_ck")
    fresh(d2).save(step=1)
    with pytest.raises(CheckpointError, match="grows"):
        fresh(d2, n_workers=4).restore()


def test_master_restore_rejects_shape_mismatch(tmp_path):
    from repro.fed.runtime.master import Master
    from repro.fed.runtime.transport import InProcTransport

    d = os.fspath(tmp_path / "master_ck")
    m = _master(d)
    m.save(step=1)
    prob = make_quadratic_problem(dim=5)       # different problem shape
    hyper = make_hyper()
    hub = InProcTransport(hyper.n_workers)
    other = Master(prob, hyper, hub.master_endpoint(), n_iterations=10,
                   ckpt_dir=d)
    with pytest.raises(CheckpointError, match="shape"):
        other.restore()
