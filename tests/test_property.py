"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cuts as cuts_lib
from repro.core.scheduler import StragglerConfig, StragglerScheduler
from repro.fed.sketch import sketch, sketch_dot, unsketch

_settings = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# mu-cut validity (Props. 3.3/3.4): for a mu-weakly-convex h, the cut
# generated at any point never excludes any feasible point in the ball.
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), curv=st.floats(0.1, 2.0),
       dim=st.integers(2, 6))
@settings(**_settings)
def test_mu_cut_never_excludes_feasible(seed, curv, dim):
    def h(v):
        return jnp.sum(v ** 2) + curv * jnp.sum(jnp.cos(2.0 * v)) / 2.0

    mu = 2.0 * curv  # second derivative of curv/2*cos(2v) is >= -2curv
    key = jax.random.PRNGKey(seed)
    radius = 2.0
    alpha = radius ** 2
    eps = float(h(jnp.zeros(dim))) + 0.2

    v0 = jax.random.normal(key, (dim,)) * 0.7
    g = jax.grad(h)(v0)
    c = eps + mu * (alpha + float(jnp.sum(v0 ** 2))) - float(h(v0)) \
        + float(g @ v0)

    for i in range(50):
        v = jax.random.normal(jax.random.fold_in(key, i), (dim,))
        n = jnp.linalg.norm(v)
        v = jnp.where(n > radius, v * (radius / n), v)
        if float(h(v)) <= eps:
            assert float(g @ v) <= c + 1e-4


@given(seed=st.integers(0, 10_000))
@settings(**_settings)
def test_mu_zero_reduces_to_convex_cut(seed):
    """mu=0 on a convex h gives the classical (tight) cutting plane."""
    def h(v):
        return jnp.sum(v ** 2)

    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (4,))
    g = jax.grad(h)(v0)
    eps = 0.5
    c = eps + 0.0 - float(h(v0)) + float(g @ v0)
    # the cut must be tight at points where h == eps along the gradient ray
    # and valid for all h(v) <= eps
    for i in range(50):
        v = jax.random.normal(jax.random.fold_in(key, i), (4,)) * 0.4
        if float(h(v)) <= eps:
            assert float(g @ v) <= c + 1e-5


# ---------------------------------------------------------------------------
# polytope bookkeeping invariants (canonical FlatCuts + tree view agree)
# ---------------------------------------------------------------------------

@given(n_adds=st.integers(1, 10), p_max=st.integers(1, 5),
       seed=st.integers(0, 1000))
@settings(**_settings)
def test_cutset_capacity_invariant(n_adds, p_max, seed):
    key = jax.random.PRNGKey(seed)
    tpl = jnp.zeros((2,))
    fc = cuts_lib.empty_cuts(p_max, 2, tpl, tpl, tpl)
    for t in range(n_adds):
        a = jax.random.normal(jax.random.fold_in(key, t), (2,))
        fc = cuts_lib.add_cut(fc, {"a1": a}, 0.0, t)
    n_act = float(cuts_lib.n_active(fc))
    assert n_act == min(n_adds, p_max)
    # ages of active slots are the most recent adds
    ages = np.asarray(fc.age)[np.asarray(fc.active) > 0]
    assert set(ages.tolist()) == set(range(max(0, n_adds - p_max), n_adds))


@given(seed=st.integers(0, 1000))
@settings(**_settings)
def test_drop_inactive_only_drops_zero_multipliers(seed):
    key = jax.random.PRNGKey(seed)
    tpl = jnp.zeros((2,))
    fc = cuts_lib.empty_cuts(4, 2, tpl, tpl, tpl)
    for t in range(4):
        fc = cuts_lib.add_cut(
            fc, {"a1": jax.random.normal(jax.random.fold_in(key, t),
                                         (2,))}, 0.0, t)
    fc2 = cuts_lib.drop_inactive(fc, jnp.array([0.0, 1.0, 0.0, 2.0]))
    np.testing.assert_array_equal(np.asarray(fc2.active),
                                  np.array([0.0, 1.0, 0.0, 1.0]))
    # the derived tree view carries the same mask
    np.testing.assert_array_equal(
        np.asarray(cuts_lib.to_tree(fc2).active),
        np.array([0.0, 1.0, 0.0, 1.0]))


# ---------------------------------------------------------------------------
# scheduler: staleness bound + S-arrival rule
# ---------------------------------------------------------------------------

@given(n=st.integers(2, 12), s=st.integers(1, 12), tau=st.integers(1, 8),
       seed=st.integers(0, 100))
@settings(**_settings)
def test_scheduler_staleness_bound(n, s, tau, seed):
    s = min(s, n)
    sched = StragglerScheduler(StragglerConfig(
        n_workers=n, s_active=s, tau=tau, n_stragglers=min(2, n - 1),
        straggler_slowdown=25.0, seed=seed))
    times = []
    for _ in range(50):
        mask, t = sched.next_active()
        assert mask.sum() >= min(s, n)
        assert sched.max_staleness() <= tau
        times.append(t)
    assert all(b >= a for a, b in zip(times, times[1:]))  # clock monotone


# ---------------------------------------------------------------------------
# count-sketch: adjoint identity + unbiasedness
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), r=st.sampled_from([32, 64, 128]),
       n=st.integers(10, 200))
@settings(**_settings)
def test_sketch_adjoint_identity(seed, r, n):
    key = jax.random.PRNGKey(seed)
    v = {"x": jax.random.normal(key, (n,))}
    w = {"x": jax.random.normal(jax.random.fold_in(key, 1), (n,))}
    sv, sw = sketch(v, seed, r), sketch(w, seed, r)
    lifted = unsketch(w, sv, seed)
    lhs = float(jnp.sum(lifted["x"] * w["x"]))
    rhs = float(sketch_dot(sv, sw))
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(rhs))


def test_sketch_dot_unbiased():
    """E[<S(a),S(b)>] = <a,b> over hash seeds."""
    key = jax.random.PRNGKey(0)
    a = {"x": jax.random.normal(key, (300,))}
    b = {"x": jax.random.normal(jax.random.fold_in(key, 1), (300,))}
    exact = float(jnp.sum(a["x"] * b["x"]))
    ests = [float(sketch_dot(sketch(a, s, 128), sketch(b, s, 128)))
            for s in range(40)]
    assert abs(np.mean(ests) - exact) < 4 * np.std(ests) / np.sqrt(40) + 1.0
