"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned architecture runs one forward/train step (and a decode
step) on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.data.synthetic import make_token_stream
from repro.models import (decode_step, forward, init_params, prefill,
                          train_loss)

B, S = 2, 32


def _setup(name):
    cfg = reduced(get_config(name))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(make_token_stream(cfg.vocab_size, B, S, seed=1))
    frames = None
    if cfg.frontend == "frames":
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.float32)
    return cfg, params, toks, frames


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_shapes_finite(name):
    cfg, params, toks, frames = _setup(name)
    logits, aux, _ = forward(cfg, params, toks, frames)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_train_step_finite(name):
    cfg, params, toks, frames = _setup(name)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, toks, frames))(params)
    assert np.isfinite(float(loss)), name
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_prefill_decode_consistent(name):
    cfg, params, toks, frames = _setup(name)
    logits_full, _, _ = forward(cfg, params, toks, frames)
    logits_pf, caches = prefill(cfg, params, toks, frames)
    np.testing.assert_allclose(
        np.asarray(logits_pf, np.float32),
        np.asarray(logits_full, np.float32), rtol=3e-2, atol=3e-2)
    nxt = jnp.argmax(logits_pf[:, -1], -1).astype(jnp.int32)[:, None]
    dl, _ = decode_step(cfg, params, caches, nxt,
                        jnp.full((B,), S, jnp.int32))
    assert dl.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(dl, np.float32)).all()


@pytest.mark.parametrize("name", ["llama3-8b", "jamba-v0.1-52b",
                                  "xlstm-125m", "gemma3-12b"])
def test_scan_unroll_equivalence(name):
    cfg, params, toks, frames = _setup(name)
    a, _, _ = forward(cfg, params, toks, frames, unroll=False)
    b, _, _ = forward(cfg, params, toks, frames, unroll=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_stepwise_forward():
    """Greedy decode token-by-token equals teacher-forced forward."""
    cfg, params, toks, frames = _setup("llama3-8b")
    logits_full, _, _ = forward(cfg, params, toks, frames)
    _, caches = prefill(cfg, params, toks[:, : S // 2], frames,
                        max_seq=S)
    cur = toks[:, S // 2: S // 2 + 1]
    for i in range(S // 2, S - 1):
        dl, caches = decode_step(cfg, params, caches, cur,
                                 jnp.full((B,), i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(dl[:, 0], np.float32),
            np.asarray(logits_full[:, i], np.float32),
            rtol=5e-2, atol=5e-2)
        cur = toks[:, i + 1: i + 2]


def test_sliding_window_decode_ring_buffer():
    """SWA decode past the window only attends the last W positions."""
    from repro.models.config import BlockSpec, ModelConfig, uniform_stages
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      stages=uniform_stages(2, BlockSpec(window=8)),
                      dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(make_token_stream(128, 1, 24, seed=0))
    logits_full, _, _ = forward(cfg, params, toks)
    _, caches = prefill(cfg, params, toks[:, :16])
    dl, _ = decode_step(cfg, params, caches, toks[:, 16:17],
                        jnp.full((1,), 16, jnp.int32))
    np.testing.assert_allclose(np.asarray(dl[:, 0], np.float32),
                               np.asarray(logits_full[:, 16], np.float32),
                               rtol=5e-2, atol=5e-2)
