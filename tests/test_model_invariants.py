"""Structural invariants of the model substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib


# ---------------------------------------------------------------------------
# RoPE: attention logits depend only on relative position
# ---------------------------------------------------------------------------

def test_rope_relative_position():
    hd = 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, hd))

    def logit(qpos, kpos):
        qr = L.apply_rope(q, jnp.array([[qpos]]), 10_000.0)
        kr = L.apply_rope(k, jnp.array([[kpos]]), 10_000.0)
        return float(jnp.sum(qr[0, 0, 0] * kr[0, 0, 0]))

    assert abs(logit(7, 3) - logit(107, 103)) < 1e-3
    assert abs(logit(7, 3) - logit(9, 3)) > 1e-5   # but not absolute


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    s = jnp.zeros((16,))
    a = L.rms_norm(x, s)
    b = L.rms_norm(x * 100.0, s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE: capacity accounting
# ---------------------------------------------------------------------------

def test_moe_routing_weight_conservation():
    """Each surviving token's routing weights sum to <= 1 (== 1 when no
    assignment of that token was capacity-dropped)."""
    key = jax.random.PRNGKey(0)
    d, e, ff = 16, 4, 32
    params = moe_lib.moe_init(key, d, ff, e, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))
    out, aux = moe_lib.moe_apply(params, x, top_k=2, capacity_factor=2.0)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99   # Switch aux loss >= 1 at balance


def test_moe_capacity_drops_overflow():
    """With capacity_factor ~ 0, everything drops -> output ~ 0."""
    key = jax.random.PRNGKey(0)
    d, e, ff = 8, 2, 16
    params = moe_lib.moe_init(key, d, ff, e, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, d))
    out_full, _ = moe_lib.moe_apply(params, x, top_k=1,
                                    capacity_factor=4.0)
    # capacity 1 slot per expert: most tokens dropped
    out_tiny, _ = moe_lib.moe_apply(params, x, top_k=1,
                                    capacity_factor=1.0 / 16.0)
    assert float(jnp.sum(jnp.abs(out_tiny))) \
        < float(jnp.sum(jnp.abs(out_full)))


# ---------------------------------------------------------------------------
# Mamba: chunk-size invariance of the scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunks", [(8, 16), (16, 64)])
def test_mamba_chunk_size_invariance(chunks):
    key = jax.random.PRNGKey(0)
    d = 16
    params = mamba_lib.mamba_init(key, d, expand=2, d_state=4, d_conv=4,
                                  dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, d))
    y1, st1 = mamba_lib.mamba_apply(params, x, chunk=chunks[0])
    y2, st2 = mamba_lib.mamba_apply(params, x, chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1["ssm"]),
                               np.asarray(st2["ssm"]),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_apply():
    """Token-by-token decode == full-sequence scan."""
    key = jax.random.PRNGKey(0)
    d, s = 16, 12
    params = mamba_lib.mamba_init(key, d, expand=2, d_state=4, d_conv=4,
                                  dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, s, d))
    y_full, _ = mamba_lib.mamba_apply(params, x, chunk=s)
    st = mamba_lib.init_mamba_state(1, d, 2, 4, 4, jnp.float32)
    ys = []
    for i in range(s):
        y, st = mamba_lib.mamba_decode(params, x[:, i: i + 1], st)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# mLSTM: chunkwise == decode recurrence
# ---------------------------------------------------------------------------

def test_mlstm_chunkwise_matches_decode():
    key = jax.random.PRNGKey(0)
    d, h, hd, s = 32, 2, 16, 8
    params = xlstm_lib.mlstm_init(key, d, h, hd, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, s, d))
    y_chunk, _ = xlstm_lib.mlstm_apply(params, x, chunk=s)
    st = xlstm_lib.init_mlstm_state(1, h, hd)
    ys = []
    for i in range(s):
        y, st = xlstm_lib.mlstm_decode(params, x[:, i: i + 1], st)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_chunk),
                               rtol=2e-3, atol=2e-3)
