"""Host-side arrival machinery: validation, the quorum rule, the
closed-loop `ArrivalPolicy`, and the `ArrivalRecorder`'s durable state.

The quorum sweep is a seeded randomized property check (no hypothesis
dependency): for any forced set and finish order, the chosen set must
contain every tau-forced worker and have size max(|forced|, s_active).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.scheduler import (ArrivalPolicy, ArrivalRecorder, Schedule,
                                  StragglerConfig, quorum,
                                  validate_arrival_params)

from conftest import make_hyper


# ---------------------------------------------------------------------------
# construction-time validation (the silent-misconfiguration bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s_active,tau", [(0, 5), (-1, 5), (5, 5), (9, 5),
                                          (3, 0), (3, -2)])
def test_validate_arrival_params_rejects_unsatisfiable(s_active, tau):
    with pytest.raises(ValueError):
        validate_arrival_params(s_active, tau, n_workers=4)


def test_validate_arrival_params_accepts_boundaries():
    validate_arrival_params(1, 1, n_workers=4)
    validate_arrival_params(4, 1, n_workers=4)


@pytest.mark.parametrize("bad", [dict(s_active=0), dict(s_active=5),
                                 dict(tau=0)])
def test_straggler_config_validates_at_construction(bad):
    kw = dict(n_workers=4, s_active=3, tau=5)
    kw.update(bad)
    with pytest.raises(ValueError, match="StragglerConfig"):
        StragglerConfig(**kw)


@pytest.mark.parametrize("bad", [dict(s_active=0), dict(s_active=9),
                                 dict(tau=0)])
def test_hyper_validates_at_construction(bad):
    with pytest.raises(ValueError, match="Hyper"):
        make_hyper(**bad)


def test_hyper_skips_validation_for_traced_fields():
    """Swept hypers rebuild the dataclass with non-int (traced) field
    values — those must pass through construction unjudged."""
    import jax.numpy as jnp
    make_hyper(s_active=jnp.asarray(9))   # would raise if judged


# ---------------------------------------------------------------------------
# the quorum rule (seeded randomized property sweep)
# ---------------------------------------------------------------------------

def test_quorum_property_sweep():
    rng = np.random.default_rng(0)
    for _ in range(300):
        n = int(rng.integers(1, 9))
        s_active = int(rng.integers(1, n + 1))
        forced = rng.random(n) < rng.random()
        order = rng.permutation(n)
        chosen = quorum(forced, order, s_active)
        chosen_set = set(chosen.tolist())
        forced_set = set(np.nonzero(forced)[0].tolist())
        # every tau-forced worker is chosen, nobody is chosen twice,
        # and the size is exactly max(|forced|, s_active)
        assert forced_set <= chosen_set
        assert len(chosen) == len(chosen_set)
        assert len(chosen) == max(len(forced_set), s_active)
        assert list(chosen) == sorted(chosen_set)
        # the fill-up picks the earliest finishers: any non-forced
        # chosen worker beats every non-forced excluded one in `order`
        rank = {int(j): i for i, j in enumerate(order)}
        extra = chosen_set - forced_set
        skipped = set(range(n)) - chosen_set
        if extra and skipped:
            assert max(rank[j] for j in extra) < \
                min(rank[j] for j in skipped)


def test_quorum_forced_superset_of_s_active():
    chosen = quorum(np.array([1, 1, 1, 0]), np.array([3, 2, 1, 0]), 1)
    np.testing.assert_array_equal(chosen, [0, 1, 2])


# ---------------------------------------------------------------------------
# ArrivalPolicy: the closed arrival loop
# ---------------------------------------------------------------------------

def test_arrival_policy_rejects_bad_params():
    with pytest.raises(ValueError):
        ArrivalPolicy(s_active=0, tau=5)
    with pytest.raises(ValueError):
        ArrivalPolicy(s_active=3, tau=0)


def test_arrival_policy_boosts_under_pressure_and_relaxes():
    pol = ArrivalPolicy(s_active=2, tau=4, relax_after=2)
    alive = np.ones(4, bool)
    # a worker one step from the forcing horizon is pressure
    s_eff, tau_eff = pol.propose(np.array([0, 0, 0, 3]), alive)
    assert (s_eff, tau_eff) == (3, 3)
    # calm iterations decay the boost back after relax_after
    assert pol.propose(np.zeros(4), alive) == (3, 3)
    assert pol.propose(np.zeros(4), alive) == (2, 4)


def test_arrival_policy_stays_inside_tau_bound():
    """1 <= tau_eff <= tau and s_eff >= 1 under any staleness stream."""
    pol = ArrivalPolicy(s_active=3, tau=3)
    rng = np.random.default_rng(1)
    alive = np.ones(4, bool)
    for _ in range(200):
        s_eff, tau_eff = pol.propose(rng.integers(0, 10, size=4), alive)
        assert 1 <= tau_eff <= 3
        assert s_eff >= 1


def test_arrival_policy_ignores_dead_workers():
    pol = ArrivalPolicy(s_active=2, tau=4)
    alive = np.array([True, True, True, False])
    # the only pressure is on the dead worker: no boost
    assert pol.propose(np.array([0, 0, 0, 99]), alive) == (2, 4)


# ---------------------------------------------------------------------------
# ArrivalRecorder: durable state + status rows
# ---------------------------------------------------------------------------

def _record_with_deaths(rec):
    rec.record([1, 1, 0, 1], 0.1, s_eff=3, tau_eff=5)
    rec.mark_dead(2)
    rec.record([1, 1, 0, 0], 0.2, s_eff=4, tau_eff=4)
    rec.record([0, 1, 0, 1], 0.3, s_eff=4, tau_eff=4)
    rec.mark_alive(2)
    rec.record([1, 0, 1, 1], 0.4, s_eff=3, tau_eff=5)


def test_recorder_state_dict_round_trip_with_deaths_and_rejoins():
    rec = ArrivalRecorder(4)
    _record_with_deaths(rec)
    d = rec.state_dict()
    rec2 = ArrivalRecorder(4)
    rec2.load_state_dict(d)
    for k, v in rec2.state_dict().items():
        np.testing.assert_array_equal(v, d[k])
    np.testing.assert_array_equal(rec2.staleness(), rec.staleness())
    a, b = rec.to_schedule(), rec2.to_schedule()
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.dead, b.dead)
    np.testing.assert_array_equal(a.s_eff, b.s_eff)
    np.testing.assert_array_equal(a.tau_eff, b.tau_eff)
    # the restored recorder keeps recording seamlessly
    rec2.record([1, 1, 1, 1], 0.5)
    assert rec2.t == 5


def test_recorder_state_dict_round_trip_empty_history():
    rec = ArrivalRecorder(3)
    rec2 = ArrivalRecorder(3)
    rec2.load_state_dict(rec.state_dict())
    assert rec2.t == 0
    sched = rec2.to_schedule()
    assert sched.n_iterations == 0 and sched.s_eff is None
    rec2.record([1, 0, 1], 0.1)
    assert rec2.t == 1


def test_recorder_loads_pre_policy_era_checkpoints():
    """Checkpoints written before the effective-(s, tau) columns existed
    restore with -1 (unrecorded) rows and a column-free Schedule."""
    rec = ArrivalRecorder(2)
    rec.record([1, 1], 0.1, s_eff=2, tau_eff=3)
    d = rec.state_dict()
    del d["s_eff"], d["tau_eff"]
    rec2 = ArrivalRecorder(2)
    rec2.load_state_dict(d)
    assert rec2._s_eff == [-1] and rec2._tau_eff == [-1]
    assert rec2.to_schedule().s_eff is None


def test_recorder_recent_rows():
    rec = ArrivalRecorder(4)
    _record_with_deaths(rec)
    rows = rec.recent(k=2)
    assert [r["t"] for r in rows] == [3, 4]
    assert rows[-1] == {"t": 4, "arrived": [0, 2, 3], "s_eff": 3,
                        "tau_eff": 5, "max_staleness": rec.to_schedule()
                        .max_staleness[-1]}


# ---------------------------------------------------------------------------
# Schedule.slice carries the audit columns
# ---------------------------------------------------------------------------

def test_schedule_slice_preserves_effective_columns():
    rec = ArrivalRecorder(4)
    _record_with_deaths(rec)
    sched = rec.to_schedule()
    part = sched.slice(1, 3)
    np.testing.assert_array_equal(part.active, sched.active[1:3])
    np.testing.assert_array_equal(part.s_eff, [4, 4])
    np.testing.assert_array_equal(part.tau_eff, [4, 4])
    np.testing.assert_array_equal(part.dead, sched.dead[1:3])


def test_schedule_slice_keeps_absent_columns_none():
    sched = Schedule(active=np.ones((4, 2), np.float32),
                     sim_time=np.arange(4, dtype=np.float64),
                     max_staleness=np.zeros(4, np.int64))
    part = sched.slice(0, 2)
    assert part.dead is None and part.s_eff is None \
        and part.tau_eff is None
