"""Dry-run machinery on a small fake-device mesh (subprocess: the device
count must be set before jax initializes)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# the dry-run mesh path uses jax.make_mesh(..., axis_types=AxisType.Auto),
# which older jax releases don't expose
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires jax.sharding.AxisType (newer jax)")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.configs.shapes import InputShape
    from repro.fed.trilevel_llm import FedHyper
    from repro.launch import dryrun as dr
    from repro.launch import roofline as rl

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = reduced(get_config("{arch}"))
    shape = InputShape("{kind}_small", seq_len=64, global_batch=4,
                       kind="{kind}")
    hyper = FedHyper(n_workers=2, cut_mode="sketch", sketch_r=64,
                     p_max=2, k_inner=1, remat=False, unroll=False)
    if "{kind}" == "train":
        fn, args, shardings = dr.build_train(cfg, shape, mesh, hyper,
                                             "train")
    elif "{kind}" == "prefill":
        fn, args, shardings = dr.build_prefill(cfg, shape, mesh,
                                               unroll=False)
    else:
        fn, args, shardings = dr.build_decode(cfg, shape, mesh,
                                              unroll=False)
    named = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        shardings, is_leaf=lambda x: isinstance(x, P))
    with mesh:
        compiled = jax.jit(fn, in_shardings=named).lower(*args).compile()
    ca = compiled.cost_analysis()
    coll = rl.collective_bytes(compiled.as_text())
    print(json.dumps({{"flops": ca.get("flops", 0.0),
                       "coll_count": coll["count"]}}))
""")


@pytest.mark.parametrize("arch,kind", [
    ("llama3-8b", "train"),
    ("mixtral-8x22b", "prefill"),
    ("jamba-v0.1-52b", "decode"),
    ("whisper-large-v3", "decode"),
])
def test_small_mesh_dryrun(arch, kind):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("JAX_PLATFORMS", None)
    script = _SCRIPT.format(arch=arch, kind=kind)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0
